"""L2 model sanity: shapes, finiteness, masking semantics, and that a few
gradient steps actually reduce the loss on an overfit-able micro-batch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim
from compile.models import bert, convnet, transformer
from compile.models.convnet import ConvNetConfig
from compile.models.transformer import TransformerConfig

CFG = TransformerConfig(vocab=32, d_model=16, n_heads=2, n_layers=1,
                        d_ff=32, max_len=12)


def _tokens(rng, b, s, vocab):
    return jnp.asarray(rng.integers(4, vocab, size=(b, s)), jnp.int32)


class TestLM:
    def test_logits_shape(self):
        rng = np.random.default_rng(0)
        params = transformer.init_lm_params(CFG, seed=0)
        toks = _tokens(rng, 2, 8, CFG.vocab)
        logits = transformer.lm_logits(params, toks, CFG)
        assert logits.shape == (2, 8, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_loss_scalar_finite(self):
        rng = np.random.default_rng(0)
        params = transformer.init_lm_params(CFG, seed=0)
        toks = _tokens(rng, 2, 8, CFG.vocab)
        loss = transformer.lm_loss(params, toks, CFG)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))
        # random init → loss near log(vocab)
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0

    def test_causality(self):
        """Changing a future token must not change past logits."""
        rng = np.random.default_rng(0)
        params = transformer.init_lm_params(CFG, seed=0)
        toks = _tokens(rng, 1, 8, CFG.vocab)
        la = transformer.lm_logits(params, toks, CFG)
        toks2 = toks.at[0, 7].set((toks[0, 7] + 1) % CFG.vocab)
        lb = transformer.lm_logits(params, toks2, CFG)
        np.testing.assert_allclose(la[0, :7], lb[0, :7], rtol=1e-5, atol=1e-5)

    def test_sgd_overfits_microbatch(self):
        rng = np.random.default_rng(0)
        params = transformer.init_lm_params(CFG, seed=0)
        toks = _tokens(rng, 2, 8, CFG.vocab)
        loss_fn = lambda p: transformer.lm_loss(p, toks, CFG)
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        l0, _ = grad_fn(params)
        for _ in range(30):
            l, g = grad_fn(params)
            params = jax.tree_util.tree_map(lambda w, gg: w - 0.5 * gg,
                                            params, g)
        assert float(l) < float(l0) - 0.5


class TestMT:
    def test_loss_and_pad_masking(self):
        rng = np.random.default_rng(0)
        params = transformer.init_mt_params(CFG, seed=0)
        src = _tokens(rng, 2, 8, CFG.vocab)
        tgt = _tokens(rng, 2, 8, CFG.vocab)
        tgt = tgt.at[:, 0].set(1)  # BOS
        loss = transformer.mt_loss(params, src, tgt, CFG)
        assert bool(jnp.isfinite(loss))
        # padding the tail must change the loss denominator, not crash
        tgt_padded = tgt.at[:, 6:].set(0)
        loss_p = transformer.mt_loss(params, src, tgt_padded, CFG)
        assert bool(jnp.isfinite(loss_p))

    def test_greedy_decode_shape_and_range(self):
        rng = np.random.default_rng(0)
        params = transformer.init_mt_params(CFG, seed=0)
        src = _tokens(rng, 2, CFG.max_len, CFG.vocab)
        out = transformer.mt_greedy_decode(params, src, CFG)
        assert out.shape == (2, CFG.max_len - 1)
        assert out.dtype == jnp.int32
        assert bool((out >= 0).all()) and bool((out < CFG.vocab).all())

    def test_decode_deterministic(self):
        rng = np.random.default_rng(0)
        params = transformer.init_mt_params(CFG, seed=0)
        src = _tokens(rng, 2, CFG.max_len, CFG.vocab)
        a = transformer.mt_greedy_decode(params, src, CFG)
        b = transformer.mt_greedy_decode(params, src, CFG)
        np.testing.assert_array_equal(a, b)


class TestMLM:
    def _batch(self, rng, b=2, s=10, p=3):
        toks = _tokens(rng, b, s, CFG.vocab)
        pos = jnp.asarray(rng.integers(0, s, size=(b, p)), jnp.int32)
        tgt = _tokens(rng, b, p, CFG.vocab)
        wts = jnp.ones((b, p), jnp.float32)
        return toks, pos, tgt, wts

    def test_eval_counts(self):
        rng = np.random.default_rng(0)
        params = bert.init_mlm_params(CFG, seed=0)
        toks, pos, tgt, wts = self._batch(rng)
        loss, correct, total = bert.mlm_eval(params, toks, pos, tgt, wts, CFG)
        assert float(total) == 6.0
        assert 0.0 <= float(correct) <= 6.0
        assert bool(jnp.isfinite(loss))

    def test_weights_zero_out_predictions(self):
        rng = np.random.default_rng(0)
        params = bert.init_mlm_params(CFG, seed=0)
        toks, pos, tgt, wts = self._batch(rng)
        wts0 = wts.at[:, -1].set(0.0)
        _, _, total = bert.mlm_eval(params, toks, pos, tgt, wts0, CFG)
        assert float(total) == 4.0

    def test_bidirectional(self):
        """Unlike the causal LM, changing a late token changes early logits."""
        rng = np.random.default_rng(0)
        params = bert.init_mlm_params(CFG, seed=0)
        toks, pos, tgt, wts = self._batch(rng)
        pos = jnp.zeros_like(pos)  # probe logits at position 0
        la = bert.mlm_logits(params, toks, pos, CFG)
        toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
        lb = bert.mlm_logits(params, toks2, pos, CFG)
        assert not np.allclose(la[0], lb[0])


class TestConvNet:
    CCFG = ConvNetConfig(height=8, width=8, channels=3, widths=(4, 8),
                         n_classes=10)

    def test_logits_shape(self):
        rng = np.random.default_rng(0)
        params = convnet.init_convnet_params(self.CCFG, seed=0)
        imgs = jnp.asarray(rng.normal(size=(4, 8, 8, 3)), jnp.float32)
        logits = convnet.convnet_logits(params, imgs, self.CCFG)
        assert logits.shape == (4, 10)

    def test_eval_topk(self):
        rng = np.random.default_rng(0)
        params = convnet.init_convnet_params(self.CCFG, seed=0)
        imgs = jnp.asarray(rng.normal(size=(4, 8, 8, 3)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 10, size=4), jnp.int32)
        loss, top1, top5 = convnet.convnet_eval(params, imgs, labels, self.CCFG)
        assert 0 <= float(top1) <= float(top5) <= 4.0

    def test_conv_kernels_are_rank4(self):
        params = convnet.init_convnet_params(self.CCFG, seed=0)
        assert params["conv0_w"].ndim == 4  # exercises the tensor cover
