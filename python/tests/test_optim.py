"""L2 optimizer-glue tests: state trees, training convergence per
optimizer, memory-footprint assertions (the paper's core claim), and
cross-optimizer equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim
from compile.models import transformer
from compile.models.transformer import TransformerConfig

CFG = TransformerConfig(vocab=32, d_model=16, n_heads=2, n_layers=1,
                        d_ff=32, max_len=12)


def _count(tree):
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    params = transformer.init_lm_params(CFG, seed=0)
    toks = jnp.asarray(rng.integers(4, CFG.vocab, size=(2, 8)), jnp.int32)
    loss_fn = lambda p, t: transformer.lm_loss(p, t, CFG)
    return params, toks, loss_fn


class TestStateFootprint:
    """The paper's headline: optimizer-state size per optimizer."""

    def test_sm3_state_is_sublinear(self, setup):
        params, _, _ = setup
        d = _count(params)
        state = optim.init_opt_state("sm3", params)
        accs = sum(int(np.prod(x.shape))
                   for name, x in _named_leaves(state) if "/acc" in name)
        # cover accumulators alone are far below d (momentum is counted
        # separately — the paper's Section 6 leaves momentum compression
        # to future work)
        assert accs < 0.2 * d

    def test_adam_state_is_2d(self, setup):
        params, _, _ = setup
        d = _count(params)
        state = optim.init_opt_state("adam", params)
        # 2d slots + the scalar step counter
        assert _count(state) == 2 * d + 1

    def test_adagrad_state_is_2d(self, setup):
        params, _, _ = setup
        d = _count(params)
        state = optim.init_opt_state("adagrad", params)
        assert _count(state) == 2 * d

    def test_adafactor_second_moment_sublinear(self, setup):
        params, _, _ = setup
        state = optim.init_opt_state("adafactor", params)
        d = _count(params)
        factored = sum(int(np.prod(x.shape))
                       for name, x in _named_leaves(state)
                       if "/vr" in name or "/vc" in name or "/v" == name[-2:])
        assert factored < 0.2 * d


def _named_leaves(tree, prefix=""):
    out = []
    for k in sorted(tree.keys()):
        v = tree[k]
        name = f"{prefix}/{k}"
        if isinstance(v, dict):
            out.extend(_named_leaves(v, name))
        else:
            out.append((name, v))
    return out


class TestTraining:
    @pytest.mark.parametrize("opt", list(optim.OPTIMIZERS))
    def test_loss_decreases(self, setup, opt):
        params, toks, loss_fn = setup
        state = optim.init_opt_state(opt, params)
        step = jax.jit(optim.make_train_step(loss_fn, opt))
        lr = {"sgdm": 0.05, "adam": 0.01, "adafactor": 0.05}.get(opt, 0.5)
        losses = []
        p, s = params, state
        for _ in range(25):
            p, s, loss = step(p, s, toks, jnp.float32(lr))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.3, f"{opt}: {losses[0]} -> {losses[-1]}"
        assert all(np.isfinite(l) for l in losses)

    def test_sm3_matches_adagrad_on_vectors(self, setup):
        """Every vector leaf uses the singleton cover, so after identical
        gradients the SM3 acc equals the Adagrad acc on those leaves."""
        params, toks, loss_fn = setup
        s_sm3 = optim.init_opt_state("sm3", params)
        s_ada = optim.init_opt_state("adagrad", params)
        step_sm3 = jax.jit(optim.make_train_step(loss_fn, "sm3"))
        step_ada = jax.jit(optim.make_train_step(loss_fn, "adagrad"))
        p1, s1, _ = step_sm3(params, s_sm3, toks, jnp.float32(0.1))
        p2, s2, _ = step_ada(params, s_ada, toks, jnp.float32(0.1))
        np.testing.assert_allclose(
            s1["lnf_scale"]["acc0"], s2["lnf_scale"]["acc"],
            rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(p1["lnf_scale"], p2["lnf_scale"],
                                   rtol=1e-5, atol=1e-7)

    def test_grad_step_matches_train_step_loss(self, setup):
        params, toks, loss_fn = setup
        gstep = jax.jit(optim.make_grad_step(loss_fn))
        loss, grads = gstep(params, toks)
        state = optim.init_opt_state("sm3", params)
        tstep = jax.jit(optim.make_train_step(loss_fn, "sm3"))
        _, _, loss2 = tstep(params, state, toks, jnp.float32(0.1))
        np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)

    def test_split_path_equals_fused_path(self, setup):
        """grad artifact + host-side apply == fused train step."""
        params, toks, loss_fn = setup
        state = optim.init_opt_state("sm3", params)
        gstep = jax.jit(optim.make_grad_step(loss_fn))
        _, grads = gstep(params, toks)
        p_split, s_split = optim.apply_updates("sm3", params, grads, state,
                                               jnp.float32(0.1))
        tstep = jax.jit(optim.make_train_step(loss_fn, "sm3"))
        p_fused, s_fused, _ = tstep(params, state, toks, jnp.float32(0.1))
        for (n1, a), (n2, b) in zip(_named_leaves(p_split),
                                    _named_leaves(p_fused)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                       err_msg=n1)


class TestLeafNames:
    def test_matches_jax_flatten_order(self, setup):
        params, _, _ = setup
        names = optim.leaf_names(params)
        leaves = jax.tree_util.tree_leaves(params)
        assert len(names) == len(leaves)
        # spot-check a couple of known names exist
        assert "embed" in names
        assert any(n.startswith("block0/") for n in names)
