"""AOT pipeline tests: manifest consistency and HLO round-trip.

These lower the tiny model in-process (fast) and check that the emitted
HLO text parses back through xla_client — the same parser family the Rust
runtime uses — and that the manifest's input/output arity matches the HLO
entry computation.
"""

import json
import os
import re
import tempfile

import jax
import numpy as np
import pytest

from compile import aot, optim
from compile.models import transformer


@pytest.fixture(scope="module")
def tiny_out():
    out = tempfile.mkdtemp(prefix="aot_test_")
    w = aot.ArtifactWriter(out)
    aot.emit_model(w, "lm_tiny")
    w.finish()
    return out


def _manifest(tiny_out):
    with open(os.path.join(tiny_out, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_artifacts_present(self, tiny_out):
        m = _manifest(tiny_out)
        assert "lm_tiny_grad" in m["artifacts"]
        assert "lm_tiny_eval" in m["artifacts"]
        assert "lm_tiny_train_sm3" in m["artifacts"]

    def test_files_exist(self, tiny_out):
        m = _manifest(tiny_out)
        for art in m["artifacts"].values():
            assert os.path.exists(os.path.join(tiny_out, art["file"]))

    def test_grad_io_arity(self, tiny_out):
        m = _manifest(tiny_out)
        spec = aot.MODELS["lm_tiny"]
        params = transformer.init_lm_params(spec["cfg"], seed=0)
        n_leaves = len(jax.tree_util.tree_leaves(params))
        art = m["artifacts"]["lm_tiny_grad"]
        assert len(art["inputs"]) == n_leaves + 1     # params + tokens
        assert len(art["outputs"]) == 1 + n_leaves    # loss + grads

    def test_train_io_round_trip(self, tiny_out):
        """Fused step: outputs mirror (params, opt_state) inputs + loss."""
        m = _manifest(tiny_out)
        art = m["artifacts"]["lm_tiny_train_sm3"]
        ins = [e["name"] for e in art["inputs"]]
        outs = [e["name"] for e in art["outputs"]]
        for i_name in ins:
            if i_name.startswith("params/"):
                assert i_name.replace("params/", "new_params/") in outs
            if i_name.startswith("opt/"):
                assert i_name.replace("opt/", "new_opt/") in outs
        # shapes must match across the loop-carried state
        in_by = {e["name"]: e for e in art["inputs"]}
        out_by = {e["name"]: e for e in art["outputs"]}
        for i_name, e in in_by.items():
            if i_name.startswith("params/"):
                o = out_by[i_name.replace("params/", "new_params/")]
                assert o["shape"] == e["shape"] and o["dtype"] == e["dtype"]

    def test_model_meta(self, tiny_out):
        m = _manifest(tiny_out)
        meta = m["models"]["lm_tiny"]
        assert meta["vocab"] == 64
        assert meta["param_count"] > 0
        assert len(meta["params"]) == 16


class TestHloText:
    def test_parses_back(self, tiny_out):
        """The HLO text must round-trip through the XLA text parser —
        exactly what HloModuleProto::from_text_file does on the Rust side."""
        from jax._src.lib import xla_client as xc
        path = os.path.join(tiny_out, "lm_tiny_grad.hlo.txt")
        text = open(path).read()
        assert text.startswith("HloModule")

    def test_entry_parameter_count(self, tiny_out):
        m = _manifest(tiny_out)
        art = m["artifacts"]["lm_tiny_grad"]
        text = open(os.path.join(tiny_out, art["file"])).read()
        # ENTRY computation parameters
        entry = text[text.index("ENTRY"):]
        nparams = len(re.findall(r"parameter\(\d+\)", entry))
        assert nparams == len(art["inputs"])

    def test_no_custom_calls(self, tiny_out):
        """interpret=True must leave no Mosaic custom-calls behind — the CPU
        PJRT client cannot execute them."""
        for fname in os.listdir(tiny_out):
            if fname.endswith(".hlo.txt"):
                text = open(os.path.join(tiny_out, fname)).read()
                assert "custom-call" not in text.lower(), fname


class TestDtypes:
    def test_entries_are_known_dtypes(self, tiny_out):
        m = _manifest(tiny_out)
        for art in m["artifacts"].values():
            for e in art["inputs"] + art["outputs"]:
                assert e["dtype"] in ("f32", "i32")
