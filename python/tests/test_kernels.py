"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (including awkward partial-block edges), block
sizes, and hyperparameters; fixed-seed cases pin down exact expected
values. This is the CORE correctness signal for the whole stack: the Rust
`optim::` bank is tested (rust/tests) against vectors generated from these
same oracles.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import baselines, ref, sm3

RTOL = 1e-5
ATOL = 1e-6


def _rand(rng, shape, kind="normal"):
    if kind == "normal":
        return jnp.asarray(rng.normal(size=shape), jnp.float32)
    return jnp.asarray(rng.uniform(0.0, 2.0, size=shape), jnp.float32)


def _check(actual, expected, names):
    for a, e, n in zip(actual, expected, names):
        np.testing.assert_allclose(a, e, rtol=RTOL, atol=ATOL, err_msg=n)


shapes = st.tuples(st.integers(1, 33), st.integers(1, 33))
blocks = st.tuples(st.integers(1, 16), st.integers(1, 16))
lrs = st.floats(1e-4, 1.0)
betas = st.sampled_from([0.0, 0.5, 0.9, 0.95])


class TestSM3IIMatrix:
    @settings(max_examples=40, deadline=None)
    @given(shape=shapes, block=blocks, lr=lrs, beta1=betas, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, block, lr, beta1, seed):
        rng = np.random.default_rng(seed)
        m, n = shape
        w = _rand(rng, (m, n))
        g = _rand(rng, (m, n))
        row = _rand(rng, (m,), "uniform")
        col = _rand(rng, (n,), "uniform")
        mom = _rand(rng, (m, n))
        a = sm3.sm3ii_matrix(w, g, row, col, mom, lr, beta1,
                             block_m=block[0], block_n=block[1])
        e = ref.sm3ii_matrix(w, g, row, col, mom, lr, beta1)
        _check(a, e, ["w", "row", "col", "mom"])

    def test_zero_gradient_zero_acc_is_noop(self):
        """0/0 = 0 convention: no state, no gradient => no movement."""
        w = jnp.ones((4, 4))
        z = jnp.zeros((4, 4))
        zr = jnp.zeros(4)
        nw, nr, nc, nm = sm3.sm3ii_matrix(w, z, zr, zr, z, 0.5, 0.9)
        np.testing.assert_array_equal(nw, w)
        np.testing.assert_array_equal(nr, zr)

    def test_accumulators_upper_bound_gradients(self):
        """Claim 2 / Prop 3: nu'(i) >= sum_s g_s^2(i), accumulators monotone."""
        rng = np.random.default_rng(1)
        m, n = 6, 9
        w = _rand(rng, (m, n))
        row = jnp.zeros(m)
        col = jnp.zeros(n)
        mom = jnp.zeros((m, n))
        gsq = np.zeros((m, n), np.float64)
        prev_row = np.zeros(m)
        for _ in range(12):
            g = _rand(rng, (m, n))
            gsq += np.square(np.asarray(g, np.float64))
            w, row, col, mom = sm3.sm3ii_matrix(w, g, row, col, mom, 0.1, 0.9)
            # nu implied by next step's min(row,col) bounds gsq
            nu = np.minimum(np.asarray(row)[:, None], np.asarray(col)[None, :])
            assert (nu + 1e-4 >= gsq).all()
            assert (np.asarray(row) + 1e-6 >= prev_row).all(), "monotone"
            prev_row = np.asarray(row)

    def test_sm3ii_tighter_than_sm3i(self):
        """Prop 3: nu' (SM3-II) <= nu (SM3-I) for the same gradient sequence."""
        rng = np.random.default_rng(2)
        m, n = 8, 5
        w1 = w2 = _rand(rng, (m, n))
        r1 = r2 = jnp.zeros(m)
        c1 = c2 = jnp.zeros(n)
        mm = jnp.zeros((m, n))
        m1 = m2 = mm
        for _ in range(10):
            g = _rand(rng, (m, n))
            w1, r1, c1, m1 = sm3.sm3ii_matrix(w1, g, r1, c1, m1, 0.1, 0.9)
            w2, r2, c2, m2 = sm3.sm3i_matrix(w2, g, r2, c2, m2, 0.1, 0.9)
            nu2 = np.minimum(np.asarray(r1)[:, None], np.asarray(c1)[None, :])
            nu1 = np.minimum(np.asarray(r2)[:, None], np.asarray(c2)[None, :])
            assert (nu2 <= nu1 + 1e-5).all()


class TestSM3IMatrix:
    @settings(max_examples=25, deadline=None)
    @given(shape=shapes, block=blocks, lr=lrs, beta1=betas, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, block, lr, beta1, seed):
        rng = np.random.default_rng(seed)
        m, n = shape
        w = _rand(rng, (m, n))
        g = _rand(rng, (m, n))
        row = _rand(rng, (m,), "uniform")
        col = _rand(rng, (n,), "uniform")
        mom = _rand(rng, (m, n))
        a = sm3.sm3i_matrix(w, g, row, col, mom, lr, beta1,
                            block_m=block[0], block_n=block[1])
        e = ref.sm3i_matrix(w, g, row, col, mom, lr, beta1)
        _check(a, e, ["w", "row", "col", "mom"])


class TestSM3Vector:
    @settings(max_examples=25, deadline=None)
    @given(d=st.integers(1, 70), block=st.integers(1, 16), lr=lrs,
           beta1=betas, seed=st.integers(0, 2**16))
    def test_matches_ref(self, d, block, lr, beta1, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, (d,))
        g = _rand(rng, (d,))
        acc = _rand(rng, (d,), "uniform")
        mom = _rand(rng, (d,))
        a = sm3.sm3ii_vector(w, g, acc, mom, lr, beta1, block=block)
        e = ref.sm3ii_vector(w, g, acc, mom, lr, beta1)
        _check(a, e, ["w", "acc", "mom"])

    def test_equals_adagrad(self):
        """Singleton cover == Adagrad exactly (paper §3)."""
        rng = np.random.default_rng(3)
        d = 17
        w = _rand(rng, (d,))
        g = _rand(rng, (d,))
        acc = _rand(rng, (d,), "uniform")
        mom = _rand(rng, (d,))
        a = sm3.sm3ii_vector(w, g, acc, mom, 0.2, 0.9)
        e = ref.adagrad(w, g, acc, mom, 0.2, 0.9)
        _check(a, e, ["w", "acc", "mom"])


class TestAdagrad:
    @settings(max_examples=25, deadline=None)
    @given(shape=shapes, block=blocks, lr=lrs, beta1=betas, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, block, lr, beta1, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, shape)
        g = _rand(rng, shape)
        acc = _rand(rng, shape, "uniform")
        mom = _rand(rng, shape)
        a = baselines.adagrad(w, g, acc, mom, lr, beta1,
                              block_m=block[0], block_n=block[1])
        e = ref.adagrad(w, g, acc, mom, lr, beta1)
        _check(a, e, ["w", "acc", "mom"])

    def test_rank3(self):
        rng = np.random.default_rng(4)
        shape = (3, 4, 5)
        w = _rand(rng, shape)
        g = _rand(rng, shape)
        acc = _rand(rng, shape, "uniform")
        mom = _rand(rng, shape)
        a = baselines.adagrad(w, g, acc, mom, 0.1, 0.9)
        e = ref.adagrad(w, g, acc, mom, 0.1, 0.9)
        _check(a, e, ["w", "acc", "mom"])


class TestAdam:
    @settings(max_examples=25, deadline=None)
    @given(shape=shapes, block=blocks, lr=lrs,
           beta1=betas, beta2=st.sampled_from([0.9, 0.98, 0.999]),
           t=st.integers(1, 1000), seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, block, lr, beta1, beta2, t, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, shape)
        g = _rand(rng, shape)
        m = _rand(rng, shape)
        v = _rand(rng, shape, "uniform")
        a = baselines.adam(w, g, m, v, float(t), lr, beta1, beta2,
                           block_m=block[0], block_n=block[1])
        e = ref.adam(w, g, m, v, float(t), lr, beta1, beta2)
        _check(a, e, ["w", "m", "v"])


class TestAdafactor:
    @settings(max_examples=25, deadline=None)
    @given(shape=shapes, lr=lrs, beta1=betas,
           beta2=st.sampled_from([0.9, 0.98, 0.999]), seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, lr, beta1, beta2, seed):
        rng = np.random.default_rng(seed)
        m, n = shape
        w = _rand(rng, (m, n))
        g = _rand(rng, (m, n))
        vr = _rand(rng, (m,), "uniform")
        vc = _rand(rng, (n,), "uniform")
        mom = _rand(rng, (m, n))
        a = baselines.adafactor_matrix(w, g, vr, vc, mom, lr, beta1, beta2)
        e = ref.adafactor_matrix(w, g, vr, vc, mom, lr, beta1, beta2)
        _check(a, e, ["w", "vr", "vc", "mom"])

    def test_memory_is_sublinear(self):
        """The factored state is m+n floats, not m*n (the whole point)."""
        m, n = 32, 48
        vr = jnp.zeros(m)
        vc = jnp.zeros(n)
        assert vr.size + vc.size == m + n < m * n


class TestSGDM:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, block=blocks, lr=lrs, beta1=betas, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, block, lr, beta1, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, shape)
        g = _rand(rng, shape)
        mom = _rand(rng, shape)
        a = baselines.sgd_momentum(w, g, mom, lr, beta1,
                                   block_m=block[0], block_n=block[1])
        e = ref.sgd_momentum(w, g, mom, lr, beta1)
        _check(a, e, ["w", "mom"])


class TestTensorCover:
    """Rank-3/4 co-dim-1 cover properties (jnp path used by optim.py)."""

    @settings(max_examples=15, deadline=None)
    @given(shape=st.tuples(st.integers(1, 6), st.integers(1, 6),
                           st.integers(1, 6), st.integers(1, 6)),
           seed=st.integers(0, 2**16))
    def test_rank4_bound(self, shape, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, shape)
        mom = jnp.zeros(shape)
        accs = tuple(jnp.zeros((s,)) for s in shape)
        gsq = np.zeros(shape, np.float64)
        for _ in range(5):
            g = _rand(rng, shape)
            gsq += np.square(np.asarray(g, np.float64))
            w, accs, mom = ref.sm3ii_tensor(w, g, accs, mom, 0.1, 0.9)
        nu = np.full(shape, np.inf)
        for a, acc in enumerate(accs):
            view = [1] * len(shape)
            view[a] = shape[a]
            nu = np.minimum(nu, np.asarray(acc).reshape(view))
        assert (nu + 1e-4 >= gsq).all()

    def test_rank3_matches_matrix_when_degenerate(self):
        """(m, n, 1) tensor must agree with the (m, n) matrix kernel."""
        rng = np.random.default_rng(7)
        m, n = 5, 6
        w2 = _rand(rng, (m, n))
        g2 = _rand(rng, (m, n))
        mom2 = jnp.zeros((m, n))
        row = jnp.zeros(m)
        col = jnp.zeros(n)
        w3 = w2[..., None]
        g3 = g2[..., None]
        accs = (row, col, jnp.zeros((1,)))
        nw2, nr, nc, nm2 = ref.sm3ii_matrix(w2, g2, row, col, mom2, 0.1, 0.9)
        nw3, naccs, nm3 = ref.sm3ii_tensor(w3, g3, accs, mom2[..., None],
                                           0.1, 0.9)
        # the depth-1 axis accumulator equals the global max and the min over
        # covers reduces to min(row, col) as long as acc2 >= min(row,col):
        np.testing.assert_allclose(nw3[..., 0], nw2, rtol=1e-5, atol=1e-6)
