"""Layer-1 Pallas optimizer kernels (interpret=True on CPU PJRT).

`sm3` — the paper's contribution (SM3-I and SM3-II fused updates).
`baselines` — Adagrad, Adam, Adafactor, SGD+momentum comparators.
`ref` — pure-jnp oracles every kernel is tested against.
"""

from . import baselines, ref, sm3  # noqa: F401
