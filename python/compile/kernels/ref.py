"""Pure-jnp reference oracles for every optimizer update kernel.

These are the ground truth the Pallas kernels (and, transitively, the Rust
`optim::` bank) are tested against. Every function is a *single step*:
it takes the current parameter/state and one gradient and returns the new
parameter/state. All follow the paper's convention 0/0 = 0 (no epsilon in
the SM3/Adagrad preconditioner, matching Algorithm SM3-I/II verbatim).

Shapes
------
Vector parameters use the singleton cover (== Adagrad, see paper §3).
Matrix parameters use the co-dimension-1 cover {rows} ∪ {cols}:
  row accumulator  r ∈ R^m,  col accumulator  c ∈ R^n.
Rank-p tensors use p slice accumulators, one per dimension.
"""

from __future__ import annotations

import jax.numpy as jnp


def _safe_rsqrt(nu):
    """1/sqrt(nu) with the paper's 0/0 = 0 convention."""
    return jnp.where(nu > 0.0, 1.0 / jnp.sqrt(jnp.where(nu > 0.0, nu, 1.0)), 0.0)


# ---------------------------------------------------------------------------
# SM3-II (paper Algorithm SM3-II), matrix case with {rows, cols} cover.
# ---------------------------------------------------------------------------

def sm3ii_matrix(w, g, row, col, mom, lr, beta1):
    """One SM3-II step for an m×n matrix parameter.

    nu'_t(i,j) = min(row_{t-1}(i), col_{t-1}(j)) + g_t(i,j)^2
    w         -= lr * m_t          (m_t = beta1 m + (1-beta1) g/sqrt(nu'))
    row_t(i)   = max_j nu'_t(i,j)
    col_t(j)   = max_i nu'_t(i,j)
    """
    nu = jnp.minimum(row[:, None], col[None, :]) + g * g
    upd = g * _safe_rsqrt(nu)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    new_w = w - lr * new_mom
    new_row = jnp.max(nu, axis=1)
    new_col = jnp.max(nu, axis=0)
    return new_w, new_row, new_col, new_mom


def sm3ii_vector(w, g, acc, mom, lr, beta1):
    """SM3-II for a vector with the singleton cover — exactly Adagrad."""
    nu = acc + g * g
    upd = g * _safe_rsqrt(nu)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    return w - lr * new_mom, nu, new_mom


def sm3ii_tensor(w, g, accs, mom, lr, beta1):
    """SM3-II for a rank-p tensor with the co-dim-1 cover (p accumulators).

    `accs` is a tuple of p vectors, accs[a].shape == (w.shape[a],).
    """
    p = w.ndim
    nu = None
    for a in range(p):
        shape = [1] * p
        shape[a] = w.shape[a]
        acc_b = accs[a].reshape(shape)
        nu = acc_b if nu is None else jnp.minimum(nu, acc_b)
    nu = nu + g * g
    upd = g * _safe_rsqrt(nu)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    new_w = w - lr * new_mom
    new_accs = tuple(
        jnp.max(nu, axis=tuple(b for b in range(p) if b != a)) for a in range(p)
    )
    return new_w, new_accs, new_mom


# ---------------------------------------------------------------------------
# SM3-I (paper Algorithm SM3-I) — kept for the Fig. 5 tightness comparison.
# ---------------------------------------------------------------------------

def sm3i_matrix(w, g, row, col, mom, lr, beta1):
    """One SM3-I step for an m×n matrix parameter.

    mu_t(row i) = row_{t-1}(i) + max_j g^2(i,j)      (ditto columns)
    nu_t(i,j)   = min(mu_t(row i), mu_t(col j))
    w          -= lr * m_t     (momentum as in sm3ii_matrix)
    """
    g2 = g * g
    new_row = row + jnp.max(g2, axis=1)
    new_col = col + jnp.max(g2, axis=0)
    nu = jnp.minimum(new_row[:, None], new_col[None, :])
    upd = g * _safe_rsqrt(nu)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    new_w = w - lr * new_mom
    return new_w, new_row, new_col, new_mom


def sm3i_tensor(w, g, accs, mom, lr, beta1):
    """SM3-I for a rank-p tensor with the co-dim-1 cover (p accumulators)."""
    p = w.ndim
    g2 = g * g
    new_accs = tuple(
        accs[a] + jnp.max(g2, axis=tuple(b for b in range(p) if b != a))
        for a in range(p)
    )
    nu = None
    for a in range(p):
        shape = [1] * p
        shape[a] = w.shape[a]
        acc_b = new_accs[a].reshape(shape)
        nu = acc_b if nu is None else jnp.minimum(nu, acc_b)
    upd = g * _safe_rsqrt(nu)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    return w - lr * new_mom, new_accs, new_mom


# ---------------------------------------------------------------------------
# Baselines: Adagrad, Adam, Adafactor, SGD with momentum.
# ---------------------------------------------------------------------------

def adagrad(w, g, acc, mom, lr, beta1):
    """Adagrad (Eq. 1–2 of the paper) with heavy-ball momentum."""
    nu = acc + g * g
    upd = g * _safe_rsqrt(nu)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    return w - lr * new_mom, nu, new_mom


def adam(w, g, m, v, t, lr, beta1, beta2, eps=1e-8):
    """Adam (Kingma & Ba) with bias correction; `t` is the 1-based step.

    Bias-correction powers are computed in f32, matching the kernel (and
    the Rust implementation) exactly.
    """
    b1 = jnp.float32(beta1)
    b2 = jnp.float32(beta2)
    tf = jnp.float32(t)
    new_m = b1 * m + (1.0 - b1) * g
    new_v = b2 * v + (1.0 - b2) * g * g
    mhat = new_m / (1.0 - b1**tf)
    vhat = new_v / (1.0 - b2**tf)
    new_w = w - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_w, new_m, new_v


def adafactor_matrix(w, g, vr, vc, mom, lr, beta1, beta2, eps=1e-30):
    """Adafactor (Shazeer & Stern) factored second moment for a matrix.

    R_t = b2 R + (1-b2) rowmean(g^2+eps);  C_t likewise over columns;
    Vhat = R C^T / mean(R);  update = g / sqrt(Vhat), clipped at RMS 1.0
    (the paper's d=1.0 update clipping), then beta1 momentum.
    """
    g2 = g * g + eps
    new_vr = beta2 * vr + (1.0 - beta2) * jnp.mean(g2, axis=1)
    new_vc = beta2 * vc + (1.0 - beta2) * jnp.mean(g2, axis=0)
    vhat = new_vr[:, None] * new_vc[None, :] / jnp.mean(new_vr)
    upd = g / jnp.sqrt(vhat)
    rms = jnp.sqrt(jnp.mean(upd * upd))
    upd = upd / jnp.maximum(1.0, rms)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    return w - lr * new_mom, new_vr, new_vc, new_mom


def adafactor_vector(w, g, v, mom, lr, beta1, beta2, eps=1e-30):
    """Adafactor falls back to an unfactored second moment for vectors."""
    new_v = beta2 * v + (1.0 - beta2) * (g * g + eps)
    upd = g / jnp.sqrt(new_v)
    rms = jnp.sqrt(jnp.mean(upd * upd))
    upd = upd / jnp.maximum(1.0, rms)
    new_mom = beta1 * mom + (1.0 - beta1) * upd
    return w - lr * new_mom, new_v, new_mom


def sgd_momentum(w, g, mom, lr, beta1):
    """Heavy-ball SGD: m = beta1 m + g; w -= lr m."""
    new_mom = beta1 * mom + g
    return w - lr * new_mom, new_mom
