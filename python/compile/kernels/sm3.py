"""Pallas kernels for the SM3 optimizer (paper Algorithms SM3-I / SM3-II).

Layer-1 of the stack: these kernels are invoked from the Layer-2 JAX train
step (python/compile/optim.py) and lower — with ``interpret=True``, which is
mandatory on this CPU-PJRT image — into the same HLO module that the Rust
coordinator executes.

TPU mapping (see DESIGN.md §8): the weight matrix is tiled into
(BM, BN) VMEM blocks via BlockSpec; the Θ(m+n) row/col accumulators ride
along as (BM,) / (BN,) blocks. Each grid step does one pass over its block:

    nu   = min(row_acc ⊕ col_acc) + g²          (elementwise + broadcast)
    w   -= lr · (β₁·mom + (1-β₁)·g/√nu)          (0/0 = 0, no epsilon)
    row' = max-reduce(nu, axis=1), col' = max-reduce(nu, axis=0)

Cross-block max-reduction of the accumulators uses the revisited-output-
block pattern: the row-accumulator output block depends only on the grid's
i coordinate, so successive j-steps read-modify-write it (init at j == 0).
HBM traffic is ~3 reads + 1 write per parameter element versus Adam's
2 state reads + 2 state writes — the source of the paper's "slightly
improved per-step time".

Hyperparameters (lr, beta1) are runtime scalars, passed as (1, 1) arrays so
that a single AOT artifact serves the whole warmup/decay schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM block shape. 128×128 f32 blocks (64 KiB) leave ample room in
# a 16 MiB TPU VMEM for g/w/mom blocks plus accumulators and double
# buffering; on CPU-interpret the value only affects trace structure.
BLOCK_M = 128
BLOCK_N = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _safe_rsqrt(nu):
    """1/sqrt(nu) with the paper's 0/0 = 0 convention."""
    return jnp.where(nu > 0.0, jax.lax.rsqrt(jnp.where(nu > 0.0, nu, 1.0)), 0.0)


# ---------------------------------------------------------------------------
# SM3-II matrix kernel
# ---------------------------------------------------------------------------

def _sm3ii_matrix_kernel(
    lr_ref, beta1_ref,
    w_ref, g_ref, row_ref, col_ref, mom_ref,
    new_w_ref, new_row_ref, new_col_ref, new_mom_ref,
    *, bm, bn, m, n,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    g = g_ref[...]
    nu = jnp.minimum(row_ref[...][:, None], col_ref[...][None, :]) + g * g
    upd = g * _safe_rsqrt(nu)
    beta1 = beta1_ref[0, 0]
    new_mom = beta1 * mom_ref[...] + (1.0 - beta1) * upd
    new_mom_ref[...] = new_mom
    new_w_ref[...] = w_ref[...] - lr_ref[0, 0] * new_mom

    # Cross-block max reduction (sequential grid: j is the inner axis).
    # Partial edge blocks are padded with undefined values; mask them out of
    # the reductions (out-of-range lanes contribute -inf, clipped on
    # writeback anyway).
    row_ok = (i * bm + jax.lax.iota(jnp.int32, bm)) < m
    col_ok = (j * bn + jax.lax.iota(jnp.int32, bn)) < n
    neg = jnp.float32(-jnp.inf)
    block_row = jnp.max(jnp.where(col_ok[None, :], nu, neg), axis=1)
    block_col = jnp.max(jnp.where(row_ok[:, None], nu, neg), axis=0)

    @pl.when(j == 0)
    def _():
        new_row_ref[...] = block_row

    @pl.when(j != 0)
    def _():
        new_row_ref[...] = jnp.maximum(new_row_ref[...], block_row)

    @pl.when(i == 0)
    def _():
        new_col_ref[...] = block_col

    @pl.when(i != 0)
    def _():
        new_col_ref[...] = jnp.maximum(new_col_ref[...], block_col)


def sm3ii_matrix(w, g, row, col, mom, lr, beta1,
                 block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Fused SM3-II update for an m×n matrix parameter.

    Returns ``(new_w, new_row, new_col, new_mom)``. Matches
    :func:`ref.sm3ii_matrix` exactly (same op order, no epsilon).
    """
    m, n = w.shape
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (_ceil_div(m, bm), _ceil_div(n, bn))
    lr = jnp.asarray(lr, w.dtype).reshape(1, 1)
    beta1 = jnp.asarray(beta1, w.dtype).reshape(1, 1)

    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    mat = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    rowspec = pl.BlockSpec((bm,), lambda i, j: (i,))
    colspec = pl.BlockSpec((bn,), lambda i, j: (j,))

    import functools

    return pl.pallas_call(
        functools.partial(_sm3ii_matrix_kernel, bm=bm, bn=bn, m=m, n=n),
        grid=grid,
        in_specs=[scalar, scalar, mat, mat, rowspec, colspec, mat],
        out_specs=[mat, rowspec, colspec, mat],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), w.dtype),
            jax.ShapeDtypeStruct((m,), w.dtype),
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((m, n), w.dtype),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(lr, beta1, w, g, row, col, mom)


# ---------------------------------------------------------------------------
# SM3-II vector kernel (singleton cover == Adagrad)
# ---------------------------------------------------------------------------

def _sm3ii_vector_kernel(lr_ref, beta1_ref, w_ref, g_ref, acc_ref, mom_ref,
                         new_w_ref, new_acc_ref, new_mom_ref):
    g = g_ref[...]
    nu = acc_ref[...] + g * g
    upd = g * _safe_rsqrt(nu)
    beta1 = beta1_ref[0]
    new_mom = beta1 * mom_ref[...] + (1.0 - beta1) * upd
    new_acc_ref[...] = nu
    new_mom_ref[...] = new_mom
    new_w_ref[...] = w_ref[...] - lr_ref[0] * new_mom


def sm3ii_vector(w, g, acc, mom, lr, beta1, block: int = 4096):
    """Fused SM3-II update for a vector parameter (singleton cover).

    Returns ``(new_w, new_acc, new_mom)``.
    """
    (d,) = w.shape
    b = min(block, d)
    grid = (_ceil_div(d, b),)
    lr = jnp.asarray(lr, w.dtype).reshape(1)
    beta1 = jnp.asarray(beta1, w.dtype).reshape(1)
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    vec = pl.BlockSpec((b,), lambda i: (i,))
    return pl.pallas_call(
        _sm3ii_vector_kernel,
        grid=grid,
        in_specs=[scalar, scalar, vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((d,), w.dtype)] * 3,
        interpret=True,
    )(lr, beta1, w, g, acc, mom)


# ---------------------------------------------------------------------------
# SM3-I matrix kernel (Fig. 5 tightness comparison)
# ---------------------------------------------------------------------------

def _sm3i_matrix_kernel(
    lr_ref, beta1_ref,
    w_ref, g_ref, newrow_ref, newcol_ref, mom_ref,
    new_w_ref, new_mom_ref,
):
    # SM3-I needs mu_t (post-accumulation) *before* the elementwise update,
    # so the accumulators are updated in a cheap pre-pass (sm3i_matrix below)
    # and this kernel consumes the already-updated mu'_t row/col vectors.
    g = g_ref[...]
    nu = jnp.minimum(newrow_ref[...][:, None], newcol_ref[...][None, :])
    upd = g * _safe_rsqrt(nu)
    beta1 = beta1_ref[0, 0]
    new_mom = beta1 * mom_ref[...] + (1.0 - beta1) * upd
    new_mom_ref[...] = new_mom
    new_w_ref[...] = w_ref[...] - lr_ref[0, 0] * new_mom


def sm3i_matrix(w, g, row, col, mom, lr, beta1,
                block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Fused SM3-I update for an m×n matrix. Returns
    ``(new_w, new_row, new_col, new_mom)``; matches :func:`ref.sm3i_matrix`.
    """
    m, n = w.shape
    g2 = g * g
    new_row = row + jnp.max(g2, axis=1)
    new_col = col + jnp.max(g2, axis=0)

    bm, bn = min(block_m, m), min(block_n, n)
    grid = (_ceil_div(m, bm), _ceil_div(n, bn))
    lr = jnp.asarray(lr, w.dtype).reshape(1, 1)
    beta1 = jnp.asarray(beta1, w.dtype).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    mat = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    rowspec = pl.BlockSpec((bm,), lambda i, j: (i,))
    colspec = pl.BlockSpec((bn,), lambda i, j: (j,))
    new_w, new_mom = pl.pallas_call(
        _sm3i_matrix_kernel,
        grid=grid,
        in_specs=[scalar, scalar, mat, mat, rowspec, colspec, mat],
        out_specs=[mat, mat],
        out_shape=[jax.ShapeDtypeStruct((m, n), w.dtype)] * 2,
        interpret=True,
    )(lr, beta1, w, g, new_row, new_col, mom)
    return new_w, new_row, new_col, new_mom
