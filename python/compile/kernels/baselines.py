"""Pallas kernels for the baseline optimizers the paper compares against:
Adagrad (+momentum), Adam, Adafactor, and SGD with momentum.

Same conventions as :mod:`sm3`: interpret=True (CPU PJRT), runtime scalar
hyperparameters, block shapes sized for VMEM on real hardware. Each kernel
must match its :mod:`ref` oracle bit-for-bit in op order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sm3 import BLOCK_M, BLOCK_N, _ceil_div, _safe_rsqrt


def _flatten2(w):
    """View an arbitrary-rank tensor as a 2-D matrix for elementwise kernels."""
    if w.ndim == 2:
        return w, w.shape
    flat = w.reshape(-1)
    return flat.reshape(1, flat.shape[0]), w.shape


# ---------------------------------------------------------------------------
# Adagrad (elementwise second moment — Eq. (1) of the paper) + momentum
# ---------------------------------------------------------------------------

def _adagrad_kernel(lr_ref, beta1_ref, w_ref, g_ref, acc_ref, mom_ref,
                    new_w_ref, new_acc_ref, new_mom_ref):
    g = g_ref[...]
    nu = acc_ref[...] + g * g
    upd = g * _safe_rsqrt(nu)
    beta1 = beta1_ref[0, 0]
    new_mom = beta1 * mom_ref[...] + (1.0 - beta1) * upd
    new_acc_ref[...] = nu
    new_mom_ref[...] = new_mom
    new_w_ref[...] = w_ref[...] - lr_ref[0, 0] * new_mom


def adagrad(w, g, acc, mom, lr, beta1,
            block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Fused Adagrad+momentum step for any-rank parameter.

    Returns ``(new_w, new_acc, new_mom)``; matches :func:`ref.adagrad`.
    """
    w2, shape = _flatten2(w)
    g2, _ = _flatten2(g)
    acc2, _ = _flatten2(acc)
    mom2, _ = _flatten2(mom)
    m, n = w2.shape
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (_ceil_div(m, bm), _ceil_div(n, bn))
    lr = jnp.asarray(lr, w.dtype).reshape(1, 1)
    beta1 = jnp.asarray(beta1, w.dtype).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    mat = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    outs = pl.pallas_call(
        _adagrad_kernel,
        grid=grid,
        in_specs=[scalar, scalar, mat, mat, mat, mat],
        out_specs=[mat, mat, mat],
        out_shape=[jax.ShapeDtypeStruct((m, n), w.dtype)] * 3,
        interpret=True,
    )(lr, beta1, w2, g2, acc2, mom2)
    return tuple(o.reshape(shape) for o in outs)


# ---------------------------------------------------------------------------
# Adam (Kingma & Ba) with bias correction
# ---------------------------------------------------------------------------

def _adam_kernel(lr_ref, beta1_ref, beta2_ref, t_ref, w_ref, g_ref,
                 m_ref, v_ref, new_w_ref, new_m_ref, new_v_ref, *, eps):
    g = g_ref[...]
    b1 = beta1_ref[0, 0]
    b2 = beta2_ref[0, 0]
    t = t_ref[0, 0]
    new_m = b1 * m_ref[...] + (1.0 - b1) * g
    new_v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = new_m / (1.0 - b1**t)
    vhat = new_v / (1.0 - b2**t)
    new_m_ref[...] = new_m
    new_v_ref[...] = new_v
    new_w_ref[...] = w_ref[...] - lr_ref[0, 0] * mhat / (jnp.sqrt(vhat) + eps)


def adam(w, g, m, v, t, lr, beta1, beta2, eps=1e-8,
         block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Fused Adam step for any-rank parameter.

    ``t`` is the 1-based step count (runtime scalar). Returns
    ``(new_w, new_m, new_v)``; matches :func:`ref.adam`.
    """
    import functools
    w2, shape = _flatten2(w)
    g2, _ = _flatten2(g)
    m2, _ = _flatten2(m)
    v2, _ = _flatten2(v)
    mm, nn = w2.shape
    bm, bn = min(block_m, mm), min(block_n, nn)
    grid = (_ceil_div(mm, bm), _ceil_div(nn, bn))
    mk = lambda x: jnp.asarray(x, w.dtype).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    mat = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    outs = pl.pallas_call(
        functools.partial(_adam_kernel, eps=eps),
        grid=grid,
        in_specs=[scalar, scalar, scalar, scalar, mat, mat, mat, mat],
        out_specs=[mat, mat, mat],
        out_shape=[jax.ShapeDtypeStruct((mm, nn), w.dtype)] * 3,
        interpret=True,
    )(mk(lr), mk(beta1), mk(beta2), mk(t), w2, g2, m2, v2)
    return tuple(o.reshape(shape) for o in outs)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moment for matrices.
# ---------------------------------------------------------------------------
# The factored statistics need global row/col means and a global update-RMS
# for clipping, so the kernel runs as a single block over the matrix (the
# state is what is factored, not the compute); larger matrices fall back to
# a row-tiled grid with the reductions precomputed in plain jnp. We keep the
# whole update in one pallas_call for parity with the other kernels.

def _adafactor_matrix_kernel(lr_ref, beta1_ref, beta2_ref, w_ref, g_ref,
                             vr_ref, vc_ref, mom_ref,
                             new_w_ref, new_vr_ref, new_vc_ref, new_mom_ref,
                             *, eps):
    g = g_ref[...]
    b1 = beta1_ref[0, 0]
    b2 = beta2_ref[0, 0]
    g2 = g * g + eps
    new_vr = b2 * vr_ref[...] + (1.0 - b2) * jnp.mean(g2, axis=1)
    new_vc = b2 * vc_ref[...] + (1.0 - b2) * jnp.mean(g2, axis=0)
    vhat = new_vr[:, None] * new_vc[None, :] / jnp.mean(new_vr)
    upd = g / jnp.sqrt(vhat)
    rms = jnp.sqrt(jnp.mean(upd * upd))
    upd = upd / jnp.maximum(1.0, rms)
    new_mom = b1 * mom_ref[...] + (1.0 - b1) * upd
    new_vr_ref[...] = new_vr
    new_vc_ref[...] = new_vc
    new_mom_ref[...] = new_mom
    new_w_ref[...] = w_ref[...] - lr_ref[0, 0] * new_mom


def adafactor_matrix(w, g, vr, vc, mom, lr, beta1, beta2, eps=1e-30):
    """Fused Adafactor step for an m×n matrix.

    Returns ``(new_w, new_vr, new_vc, new_mom)``; matches
    :func:`ref.adafactor_matrix`.
    """
    import functools
    m, n = w.shape
    mk = lambda x: jnp.asarray(x, w.dtype).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda: (0, 0))
    mat = pl.BlockSpec((m, n), lambda: (0, 0))
    rowspec = pl.BlockSpec((m,), lambda: (0,))
    colspec = pl.BlockSpec((n,), lambda: (0,))
    return pl.pallas_call(
        functools.partial(_adafactor_matrix_kernel, eps=eps),
        grid=(),
        in_specs=[scalar, scalar, scalar, mat, mat, rowspec, colspec, mat],
        out_specs=[mat, rowspec, colspec, mat],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), w.dtype),
            jax.ShapeDtypeStruct((m,), w.dtype),
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((m, n), w.dtype),
        ],
        interpret=True,
    )(mk(lr), mk(beta1), mk(beta2), w, g, vr, vc, mom)


# ---------------------------------------------------------------------------
# SGD + heavy-ball momentum
# ---------------------------------------------------------------------------

def _sgdm_kernel(lr_ref, beta1_ref, w_ref, g_ref, mom_ref,
                 new_w_ref, new_mom_ref):
    new_mom = beta1_ref[0, 0] * mom_ref[...] + g_ref[...]
    new_mom_ref[...] = new_mom
    new_w_ref[...] = w_ref[...] - lr_ref[0, 0] * new_mom


def sgd_momentum(w, g, mom, lr, beta1,
                 block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Fused heavy-ball SGD step. Returns ``(new_w, new_mom)``."""
    w2, shape = _flatten2(w)
    g2, _ = _flatten2(g)
    mom2, _ = _flatten2(mom)
    m, n = w2.shape
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (_ceil_div(m, bm), _ceil_div(n, bn))
    mk = lambda x: jnp.asarray(x, w.dtype).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    mat = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    outs = pl.pallas_call(
        _sgdm_kernel,
        grid=grid,
        in_specs=[scalar, scalar, mat, mat, mat],
        out_specs=[mat, mat],
        out_shape=[jax.ShapeDtypeStruct((m, n), w.dtype)] * 2,
        interpret=True,
    )(mk(lr), mk(beta1), w2, g2, mom2)
    return tuple(o.reshape(shape) for o in outs)
