"""Layer-2 optimizer glue: state trees + fused train steps.

Binds the Layer-1 Pallas kernels to arbitrary parameter pytrees. Each
optimizer defines
  * ``init(params)``  → state pytree (dict leaf-name → slot dict), and
  * ``apply(params, grads, state, lr)`` → (new_params, new_state),
dispatching on tensor rank:

  rank 1 (biases, layernorm)   singleton cover  → sm3ii_vector kernel
  rank 2 (all big matrices)    {rows, cols}     → sm3ii_matrix kernel
  rank ≥3 (conv kernels)       co-dim-1 slices  → jnp path (ref.sm3ii_tensor)

The rank ≥3 jnp path is deliberate: >99% of transformer parameters are
matrices, which is where the Pallas kernel sits; conv tensors go through
the identical math in plain jnp (tested equal in python/tests).

Hyperparameters (beta1, beta2, eps) are baked per artifact; the learning
rate is a runtime scalar so a single artifact serves the whole
warmup/decay schedule. Adam's step count lives in the state ("t" slot).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import baselines, ref, sm3

OPTIMIZERS = ("sm3", "sm3i", "adagrad", "adam", "adafactor", "sgdm")


# ---------------------------------------------------------------------------
# Leaf naming — must match the Rust side's manifest ordering exactly.
# ---------------------------------------------------------------------------

def leaf_names(params, prefix=""):
    """Deterministic leaf names matching jax's dict flattening (sorted keys)."""
    names = []
    for k in sorted(params.keys()):
        v = params[k]
        name = f"{prefix}{k}"
        if isinstance(v, dict):
            names.extend(leaf_names(v, prefix=name + "/"))
        else:
            names.append(name)
    return names


def _map_leaves(fn, params, prefix=""):
    out = {}
    for k in sorted(params.keys()):
        v = params[k]
        name = f"{prefix}{k}"
        if isinstance(v, dict):
            out[k] = _map_leaves(fn, v, prefix=name + "/")
        else:
            out[k] = fn(name, v)
    return out


def _zip_leaves(fn, params, grads, state):
    """Apply fn(leaf_w, leaf_g, leaf_state) over aligned pytrees; returns
    (new_params, new_state) with the same structure."""
    new_p, new_s = {}, {}
    for k in sorted(params.keys()):
        if isinstance(params[k], dict):
            new_p[k], new_s[k] = _zip_leaves(fn, params[k], grads[k], state[k])
        else:
            new_p[k], new_s[k] = fn(params[k], grads[k], state[k])
    return new_p, new_s


def _vec(shape):
    return jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# SM3-II (the paper's shipped variant)
# ---------------------------------------------------------------------------

def sm3_init(params):
    def leaf(_name, w):
        if w.ndim <= 1:
            return {"acc0": _vec(w.shape), "mom": _vec(w.shape)}
        return {**{f"acc{a}": _vec((w.shape[a],)) for a in range(w.ndim)},
                "mom": _vec(w.shape)}
    return _map_leaves(leaf, params)


def sm3_apply(params, grads, state, lr, beta1=0.9):
    def leaf(w, g, s):
        if w.ndim == 1:
            nw, nacc, nmom = sm3.sm3ii_vector(w, g, s["acc0"], s["mom"], lr, beta1)
            return nw, {"acc0": nacc, "mom": nmom}
        if w.ndim == 2:
            nw, nr, nc, nmom = sm3.sm3ii_matrix(
                w, g, s["acc0"], s["acc1"], s["mom"], lr, beta1)
            return nw, {"acc0": nr, "acc1": nc, "mom": nmom}
        accs = tuple(s[f"acc{a}"] for a in range(w.ndim))
        nw, naccs, nmom = ref.sm3ii_tensor(w, g, accs, s["mom"], lr, beta1)
        ns = {f"acc{a}": naccs[a] for a in range(w.ndim)}
        ns["mom"] = nmom
        return nw, ns
    return _zip_leaves(leaf, params, grads, state)


# ---------------------------------------------------------------------------
# SM3-I (kept for the Fig. 5 tightness comparison)
# ---------------------------------------------------------------------------

def sm3i_init(params):
    return sm3_init(params)


def sm3i_apply(params, grads, state, lr, beta1=0.9):
    def leaf(w, g, s):
        if w.ndim == 1:
            # singleton cover: SM3-I degenerates to Adagrad, same as SM3-II
            nw, nacc, nmom = sm3.sm3ii_vector(w, g, s["acc0"], s["mom"], lr, beta1)
            return nw, {"acc0": nacc, "mom": nmom}
        if w.ndim == 2:
            nw, nr, nc, nmom = sm3.sm3i_matrix(
                w, g, s["acc0"], s["acc1"], s["mom"], lr, beta1)
            return nw, {"acc0": nr, "acc1": nc, "mom": nmom}
        accs = tuple(s[f"acc{a}"] for a in range(w.ndim))
        nw, naccs, nmom = ref.sm3i_tensor(w, g, accs, s["mom"], lr, beta1)
        ns = {f"acc{a}": naccs[a] for a in range(w.ndim)}
        ns["mom"] = nmom
        return nw, ns
    return _zip_leaves(leaf, params, grads, state)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def adagrad_init(params):
    return _map_leaves(
        lambda _n, w: {"acc": _vec(w.shape), "mom": _vec(w.shape)}, params)


def adagrad_apply(params, grads, state, lr, beta1=0.9):
    def leaf(w, g, s):
        nw, nacc, nmom = baselines.adagrad(w, g, s["acc"], s["mom"], lr, beta1)
        return nw, {"acc": nacc, "mom": nmom}
    return _zip_leaves(leaf, params, grads, state)


def adam_init(params):
    st = _map_leaves(
        lambda _n, w: {"m": _vec(w.shape), "v": _vec(w.shape)}, params)
    st["_t"] = jnp.zeros((), jnp.float32)
    return st


def adam_apply(params, grads, state, lr, beta1=0.9, beta2=0.98, eps=1e-8):
    t = state["_t"] + 1.0
    def leaf(w, g, s):
        nw, nm, nv = baselines.adam(w, g, s["m"], s["v"], t, lr, beta1, beta2,
                                    eps=eps)
        return nw, {"m": nm, "v": nv}
    inner = {k: v for k, v in state.items() if k != "_t"}
    new_p, new_s = _zip_leaves(leaf, params, grads, inner)
    new_s["_t"] = t
    return new_p, new_s


def adafactor_init(params):
    def leaf(_name, w):
        if w.ndim >= 2:
            m = 1
            for s in w.shape[:-1]:
                m *= int(s)
            return {"vr": _vec((m,)), "vc": _vec((w.shape[-1],)),
                    "mom": _vec(w.shape)}
        return {"v": _vec(w.shape), "mom": _vec(w.shape)}
    return _map_leaves(leaf, params)


def adafactor_apply(params, grads, state, lr, beta1=0.9, beta2=0.98):
    def leaf(w, g, s):
        if w.ndim >= 2:
            # rank>2 folds leading dims — Adafactor is matrix-only (paper §4)
            shp = w.shape
            w2 = w.reshape(-1, shp[-1])
            g2 = g.reshape(-1, shp[-1])
            mom2 = s["mom"].reshape(-1, shp[-1])
            nw, nvr, nvc, nmom = baselines.adafactor_matrix(
                w2, g2, s["vr"], s["vc"], mom2, lr, beta1, beta2)
            return nw.reshape(shp), {"vr": nvr, "vc": nvc,
                                     "mom": nmom.reshape(shp)}
        nw, nv, nmom = ref.adafactor_vector(w, g, s["v"], s["mom"], lr,
                                            beta1, beta2)
        return nw, {"v": nv, "mom": nmom}
    return _zip_leaves(leaf, params, grads, state)


def sgdm_init(params):
    return _map_leaves(lambda _n, w: {"mom": _vec(w.shape)}, params)


def sgdm_apply(params, grads, state, lr, beta1=0.9):
    def leaf(w, g, s):
        nw, nmom = baselines.sgd_momentum(w, g, s["mom"], lr, beta1)
        return nw, {"mom": nmom}
    return _zip_leaves(leaf, params, grads, state)


# ---------------------------------------------------------------------------
# Registry + fused train-step builder
# ---------------------------------------------------------------------------

_INIT = {
    "sm3": sm3_init, "sm3i": sm3i_init, "adagrad": adagrad_init,
    "adam": adam_init, "adafactor": adafactor_init, "sgdm": sgdm_init,
}
_APPLY = {
    "sm3": sm3_apply, "sm3i": sm3i_apply, "adagrad": adagrad_apply,
    "adam": adam_apply, "adafactor": adafactor_apply, "sgdm": sgdm_apply,
}


def init_opt_state(name, params):
    return _INIT[name](params)


def apply_updates(name, params, grads, state, lr, **hparams):
    return _APPLY[name](params, grads, state, lr, **hparams)


def make_train_step(loss_fn, opt_name, **hparams):
    """Build the fused train step lowered by aot.py:
    (params, opt_state, *batch, lr) → (new_params, new_state, loss)."""
    def train_step(params, opt_state, *batch_and_lr):
        *batch, lr = batch_and_lr
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        new_params, new_state = apply_updates(
            opt_name, params, grads, opt_state, lr, **hparams)
        return new_params, new_state, loss
    return train_step


def make_grad_step(loss_fn):
    """Split-path artifact: (params, *batch) → (loss, grads). The Rust
    `optim::` bank applies the update host-side."""
    def grad_step(params, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        return loss, grads
    return grad_step
