"""AOT compiler: lower every (model, optimizer) variant to HLO text.

This is the single point where Python runs. `make artifacts` invokes

    python -m compile.aot --out-dir ../artifacts

which writes, for every registered artifact,
    artifacts/<name>.hlo.txt      — HLO *text* (the interchange format:
                                    jax ≥0.5 emits 64-bit instruction ids in
                                    serialized protos which xla_extension
                                    0.5.1 rejects; the text parser reassigns
                                    ids and round-trips cleanly)
    artifacts/manifest.json       — calling convention for the Rust runtime:
                                    ordered input/output names, shapes,
                                    dtypes, plus model metadata.

Input flattening order is positional args in order, dicts by sorted key —
mirrored exactly by `leaf_names` and asserted at lowering time.

Token-id conventions shared with the Rust data pipeline:
    PAD=0, BOS=1, EOS=2, UNK=3, first real token = 4.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import optim
from .models import bert, convnet, transformer
from .models.convnet import ConvNetConfig
from .models.transformer import TransformerConfig

F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# Model registry
# ---------------------------------------------------------------------------

MODELS = {
    # smoke-test scale: fast to lower, fast to compile in rust tests
    "lm_tiny": dict(kind="lm", batch=4, seq=16,
                    cfg=TransformerConfig(vocab=64, d_model=32, n_heads=2,
                                          n_layers=1, d_ff=64, max_len=16)),
    # end-to-end driver scale (~1M params)
    "lm_small": dict(kind="lm", batch=4, seq=64,
                     cfg=TransformerConfig(vocab=1024, d_model=128, n_heads=4,
                                           n_layers=2, d_ff=512, max_len=64)),
    # translation (Fig. 2 / Fig. 6 / Table 1 analogue)
    "mt_small": dict(kind="mt", batch=16, seq=24,
                     cfg=TransformerConfig(vocab=256, d_model=64, n_heads=4,
                                           n_layers=2, d_ff=256, max_len=24)),
    # masked LM (Fig. 3 / Table 2 analogue). Kept small enough that the
    # attention-routing phase (the loss plateau before the model learns to
    # read a masked token's neighbors) breaks within a few hundred steps
    # on one CPU core for every optimizer family.
    "mlm_small": dict(kind="mlm", batch=16, seq=16, n_masked=3,
                      cfg=TransformerConfig(vocab=96, d_model=64, n_heads=4,
                                            n_layers=2, d_ff=256, max_len=16)),
    # image classification (Fig. 4 analogue)
    "img_small": dict(kind="img", batch=32,
                      cfg=ConvNetConfig(height=16, width=16, channels=3,
                                        widths=(16, 32, 48), n_classes=10)),
}

# fused train-step optimizer variants emitted per model
FUSED_OPTS = {
    "lm_tiny": ["sm3"],
    "lm_small": ["sm3", "sm3i", "adagrad", "adam", "adafactor", "sgdm"],
    "mt_small": ["sm3"],
    "mlm_small": ["sm3"],
    "img_small": ["sm3"],
}


def _init_params(name):
    spec = MODELS[name]
    if spec["kind"] == "lm":
        return transformer.init_lm_params(spec["cfg"], seed=0)
    if spec["kind"] == "mt":
        return transformer.init_mt_params(spec["cfg"], seed=0)
    if spec["kind"] == "mlm":
        return bert.init_mlm_params(spec["cfg"], seed=0)
    if spec["kind"] == "img":
        return convnet.init_convnet_params(spec["cfg"], seed=0)
    raise ValueError(spec["kind"])


def _loss_fn(name):
    spec = MODELS[name]
    cfg = spec["cfg"]
    if spec["kind"] == "lm":
        return lambda p, tokens: transformer.lm_loss(p, tokens, cfg)
    if spec["kind"] == "mt":
        return lambda p, src, tgt: transformer.mt_loss(p, src, tgt, cfg)
    if spec["kind"] == "mlm":
        return lambda p, tok, pos, tgt, wts: bert.mlm_loss(
            p, tok, pos, tgt, wts, cfg)
    if spec["kind"] == "img":
        return lambda p, images, labels: convnet.convnet_loss(
            p, images, labels, cfg)
    raise ValueError(spec["kind"])


def _batch_specs(name):
    """(ordered names, ShapeDtypeStructs) of the batch inputs."""
    spec = MODELS[name]
    b = spec["batch"]
    if spec["kind"] == "lm":
        return [("batch/tokens", jax.ShapeDtypeStruct((b, spec["seq"]), I32))]
    if spec["kind"] == "mt":
        s = spec["seq"]
        return [("batch/src", jax.ShapeDtypeStruct((b, s), I32)),
                ("batch/tgt", jax.ShapeDtypeStruct((b, s), I32))]
    if spec["kind"] == "mlm":
        s, p = spec["seq"], spec["n_masked"]
        return [("batch/tokens", jax.ShapeDtypeStruct((b, s), I32)),
                ("batch/positions", jax.ShapeDtypeStruct((b, p), I32)),
                ("batch/targets", jax.ShapeDtypeStruct((b, p), I32)),
                ("batch/weights", jax.ShapeDtypeStruct((b, p), F32))]
    if spec["kind"] == "img":
        cfg = spec["cfg"]
        return [("batch/images", jax.ShapeDtypeStruct(
                    (b, cfg.height, cfg.width, cfg.channels), F32)),
                ("batch/labels", jax.ShapeDtypeStruct((b,), I32))]
    raise ValueError(spec["kind"])


# ---------------------------------------------------------------------------
# Pytree naming (mirrors jax dict flattening: sorted keys, depth first)
# ---------------------------------------------------------------------------

def _tree_names(tree, prefix):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(_tree_names(tree[k], f"{prefix}/{k}"))
        return out
    return [prefix]


def _tree_specs(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _dtype_name(dt):
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _io_entry(name, spec):
    return {"name": name, "shape": [int(s) for s in spec.shape],
            "dtype": _dtype_name(spec.dtype)}


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def _flat_specs(tree):
    return [jax.ShapeDtypeStruct(x.shape, x.dtype)
            for x in jax.tree_util.tree_leaves(tree)]


class ArtifactWriter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "models": {}}
        os.makedirs(out_dir, exist_ok=True)
        # partial rebuilds (--models subset) merge into the existing manifest
        existing = os.path.join(out_dir, "manifest.json")
        if os.path.exists(existing):
            with open(existing) as f:
                self.manifest = json.load(f)

    def add_model_meta(self, name):
        spec = MODELS[name]
        params = _init_params(name)
        leaves = []
        flat = jax.tree_util.tree_leaves(params)
        names = _tree_names(params, "params")
        assert len(flat) == len(names), (len(flat), len(names))
        for n, x in zip(names, flat):
            leaves.append(_io_entry(n, jax.ShapeDtypeStruct(x.shape, x.dtype)))
        cfg = spec["cfg"]
        meta = {"kind": spec["kind"], "batch": spec["batch"],
                "param_count": int(sum(np.prod(x.shape) for x in flat)),
                "params": leaves}
        if spec["kind"] != "img":
            meta.update({"vocab": cfg.vocab, "seq": spec["seq"],
                         "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                         "n_heads": cfg.n_heads, "d_ff": cfg.d_ff})
        else:
            meta.update({"height": cfg.height, "width": cfg.width,
                         "channels": cfg.channels,
                         "n_classes": cfg.n_classes})
        if spec["kind"] == "mlm":
            meta["n_masked"] = spec["n_masked"]
        self.manifest["models"][name] = meta
        return params

    def write(self, art_name, lowered, input_entries, output_entries,
              model, kind):
        text = to_hlo_text(lowered)
        fname = f"{art_name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.manifest["artifacts"][art_name] = {
            "file": fname, "model": model, "kind": kind,
            "inputs": input_entries, "outputs": output_entries,
        }
        print(f"  wrote {fname} ({len(text)} chars, "
              f"{len(input_entries)} in / {len(output_entries)} out)")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"  wrote manifest.json ({len(self.manifest['artifacts'])} artifacts)")


def write_init_ckpt(out_dir, name, params):
    """Export initial parameters in the Rust checkpoint format
    (rust/src/checkpoint.rs): magic, count, then per tensor
    name_len/name/rank/dims(u64)/f32 data, all little-endian. Training in
    Rust starts from bit-identical values to a JAX-side run."""
    import struct

    names = _tree_names(params, "params")
    leaves = jax.tree_util.tree_leaves(params)
    path = os.path.join(out_dir, f"{name}_init.ckpt")
    with open(path, "wb") as f:
        f.write(b"SM3CKPT1")
        f.write(struct.pack("<I", len(leaves)))
        for n, x in zip(names, leaves):
            nb = n.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            arr = np.asarray(x, np.float32)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.astype("<f4").tobytes())
    print(f"  wrote {name}_init.ckpt ({len(leaves)} tensors)")


def emit_model(w: ArtifactWriter, name: str):
    print(f"[{name}]")
    spec = MODELS[name]
    params = w.add_model_meta(name)
    write_init_ckpt(w.out_dir, name, params)
    loss_fn = _loss_fn(name)
    pspecs = _tree_specs(params)
    pnames = _tree_names(params, "params")
    batch = _batch_specs(name)
    bnames = [n for n, _ in batch]
    bspecs = [s for _, s in batch]
    lr_spec = jax.ShapeDtypeStruct((), F32)

    def param_entries(prefix="params"):
        return [_io_entry(n, s) for n, s in
                zip(_tree_names(params, prefix),
                    _flat_specs(params))]

    # --- grad_step: (params, *batch) -> (loss, grads) --------------------
    grad_fn = optim.make_grad_step(loss_fn)
    lowered = _lower(grad_fn, pspecs, *bspecs)
    inputs = param_entries() + [_io_entry(n, s) for n, s in batch]
    outputs = ([{"name": "loss", "shape": [], "dtype": "f32"}]
               + [_io_entry(n, s) for n, s in
                  zip(_tree_names(params, "grads"), _flat_specs(params))])
    w.write(f"{name}_grad", lowered, inputs, outputs, name, "grad")

    # --- eval step --------------------------------------------------------
    cfg = spec["cfg"]
    if spec["kind"] == "lm":
        eval_fn = lambda p, tokens: (transformer.lm_loss(p, tokens, cfg),)
        eval_out = [{"name": "loss", "shape": [], "dtype": "f32"}]
    elif spec["kind"] == "mt":
        eval_fn = lambda p, src, tgt: (transformer.mt_loss(p, src, tgt, cfg),)
        eval_out = [{"name": "loss", "shape": [], "dtype": "f32"}]
    elif spec["kind"] == "mlm":
        eval_fn = lambda p, tok, pos, tgt, wts: bert.mlm_eval(
            p, tok, pos, tgt, wts, cfg)
        eval_out = [{"name": "loss", "shape": [], "dtype": "f32"},
                    {"name": "correct", "shape": [], "dtype": "f32"},
                    {"name": "total", "shape": [], "dtype": "f32"}]
    else:
        eval_fn = lambda p, images, labels: convnet.convnet_eval(
            p, images, labels, cfg)
        eval_out = [{"name": "loss", "shape": [], "dtype": "f32"},
                    {"name": "top1", "shape": [], "dtype": "f32"},
                    {"name": "top5", "shape": [], "dtype": "f32"}]
    lowered = _lower(eval_fn, pspecs, *bspecs)
    w.write(f"{name}_eval", lowered,
            param_entries() + [_io_entry(n, s) for n, s in batch],
            eval_out, name, "eval")

    # --- greedy decode (translation only) ---------------------------------
    if spec["kind"] == "mt":
        dec_fn = lambda p, src: (transformer.mt_greedy_decode(p, src, cfg),)
        lowered = _lower(dec_fn, pspecs, bspecs[0])
        w.write(f"{name}_decode", lowered,
                param_entries() + [_io_entry(bnames[0], bspecs[0])],
                [{"name": "tokens",
                  "shape": [spec["batch"], cfg.max_len - 1],
                  "dtype": "i32"}],
                name, "decode")

    # --- fused train steps -------------------------------------------------
    for opt_name in FUSED_OPTS.get(name, []):
        state = optim.init_opt_state(opt_name, params)
        sspecs = _tree_specs(state)
        snames = _tree_names(state, "opt")
        step_fn = optim.make_train_step(loss_fn, opt_name)
        lowered = _lower(step_fn, pspecs, sspecs, *bspecs, lr_spec)
        inputs = (param_entries()
                  + [_io_entry(n, s) for n, s in
                     zip(snames, _flat_specs(state))]
                  + [_io_entry(n, s) for n, s in batch]
                  + [{"name": "lr", "shape": [], "dtype": "f32"}])
        outputs = (param_entries("new_params")
                   + [_io_entry(n, s) for n, s in
                      zip(_tree_names(state, "new_opt"), _flat_specs(state))]
                   + [{"name": "loss", "shape": [], "dtype": "f32"}])
        w.write(f"{name}_train_{opt_name}", lowered, inputs, outputs,
                name, f"train:{opt_name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset (default: all)")
    args = ap.parse_args()
    names = args.models.split(",") if args.models else list(MODELS)
    w = ArtifactWriter(args.out_dir)
    for name in names:
        emit_model(w, name)
    w.finish()


if __name__ == "__main__":
    main()
