"""Build-time compile package: Layer-2 JAX models + Layer-1 Pallas kernels.

Nothing in here runs at training time — `aot.py` lowers every (model,
optimizer) variant to HLO text once, and the Rust coordinator executes the
artifacts via PJRT.
"""
