"""Layer-2 pure-JAX model zoo: transformer LM, seq2seq translation,
BERT-style masked LM, and a small convnet."""

from . import bert, convnet, transformer  # noqa: F401
