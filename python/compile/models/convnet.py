"""Small convolutional image classifier (Layer 2).

Stands in for AmoebaNet-D on ImageNet (paper §5.3, Fig. 4). The point of
this workload in the reproduction is (a) a second domain where SM3 is
compared against SGD+momentum and (b) rank-4 convolution kernels, which
exercise the co-dimension-1 tensor cover (4 slice accumulators per kernel,
see Fig. 7's conv activation patterns).

Input: images (B, H, W, C) f32, labels (B,) int32.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ConvNetConfig:
    height: int = 16
    width: int = 16
    channels: int = 3
    widths: tuple = (16, 32, 64)   # channels per stage (3x3 conv + 2x2 pool)
    n_classes: int = 10


def init_convnet_params(cfg: ConvNetConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    params = {}
    cin = cfg.channels
    for i, cout in enumerate(cfg.widths):
        fan_in = 3 * 3 * cin
        params[f"conv{i}_w"] = jnp.asarray(
            rng.normal(0.0, (2.0 / fan_in) ** 0.5, size=(3, 3, cin, cout)),
            jnp.float32)
        params[f"conv{i}_b"] = jnp.zeros(cout, jnp.float32)
        cin = cout
    params["fc_w"] = jnp.asarray(
        rng.normal(0.0, (1.0 / cin) ** 0.5, size=(cin, cfg.n_classes)),
        jnp.float32)
    params["fc_b"] = jnp.zeros(cfg.n_classes, jnp.float32)
    return params


def convnet_logits(params, images, cfg: ConvNetConfig):
    x = images
    for i in range(len(cfg.widths)):
        x = jax.lax.conv_general_dilated(
            x, params[f"conv{i}_w"],
            window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + params[f"conv{i}_b"])
        # 2x2 average pool, stride 2
        x = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    x = jnp.mean(x, axis=(1, 2))            # global average pool
    return x @ params["fc_w"] + params["fc_b"]


def convnet_loss(params, images, labels, cfg: ConvNetConfig):
    logits = convnet_logits(params, images, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def convnet_eval(params, images, labels, cfg: ConvNetConfig, k: int = 5):
    """Returns (loss, top1_correct, topk_correct) counts for Fig. 4.

    Top-k is computed by rank counting rather than `lax.top_k`: the topk
    HLO op grew a `largest=` attribute that the pinned xla_extension
    0.5.1 text parser rejects, while comparisons parse everywhere.
    """
    logits = convnet_logits(params, images, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    top1 = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)
    rank = jnp.sum((logits > label_logit).astype(jnp.int32), axis=-1)
    topk = jnp.sum((rank < k).astype(jnp.float32))
    return loss, top1, topk
