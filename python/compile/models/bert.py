"""BERT-style bidirectional masked-LM model (Layer 2).

Mirrors the paper's §5.2 workload: a bidirectional transformer encoder
jointly trained on a Masked-LM objective. (We drop the NSP head: the
paper's reported metric — Fig. 3 — is Masked-LM accuracy; NSP adds a
2-class head that contributes nothing to the memory/convergence story.)

Batch layout (all int32):
  tokens     (B, S)    input with [MASK] already substituted
  positions  (B, P)    indices of the masked positions
  targets    (B, P)    original token ids at those positions
  weights    (B, P)    1.0 for real predictions, 0.0 for padding   (f32)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .transformer import (
    TransformerConfig,
    _block_params,
    _dense_init,
    _layer_norm,
    _self_attn_block,
)


def init_mlm_params(cfg: TransformerConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    params = {
        "embed": _dense_init(rng, (cfg.vocab, cfg.d_model), scale=0.02),
        "pos": _dense_init(rng, (cfg.max_len, cfg.d_model), scale=0.02),
        "lnf_scale": jnp.ones(cfg.d_model, jnp.float32),
        "lnf_bias": jnp.zeros(cfg.d_model, jnp.float32),
        # MLM head: dense transform + layernorm, tied output embedding.
        "mlm_w": _dense_init(rng, (cfg.d_model, cfg.d_model)),
        "mlm_b": jnp.zeros(cfg.d_model, jnp.float32),
        "mlm_ln_scale": jnp.ones(cfg.d_model, jnp.float32),
        "mlm_ln_bias": jnp.zeros(cfg.d_model, jnp.float32),
        "mlm_out_bias": jnp.zeros(cfg.vocab, jnp.float32),
    }
    for l in range(cfg.n_layers):
        params[f"block{l}"] = _block_params(rng, cfg, cross_attention=False)
    return params


def _encode(params, tokens, cfg: TransformerConfig):
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos"][:S][None, :, :]
    mask = jnp.zeros((S, S), jnp.float32)  # fully bidirectional
    for l in range(cfg.n_layers):
        x = _self_attn_block(params[f"block{l}"], x, cfg, mask)
    return _layer_norm(x, params["lnf_scale"], params["lnf_bias"])


def mlm_logits(params, tokens, positions, cfg: TransformerConfig):
    """Logits at the masked positions only: (B, P, V)."""
    x = _encode(params, tokens, cfg)                       # (B, S, D)
    gathered = jnp.take_along_axis(x, positions[..., None], axis=1)
    h = gathered @ params["mlm_w"] + params["mlm_b"]
    h = jax.nn.gelu(h)
    h = _layer_norm(h, params["mlm_ln_scale"], params["mlm_ln_bias"])
    return h @ params["embed"].T + params["mlm_out_bias"]


def mlm_loss(params, tokens, positions, targets, weights,
             cfg: TransformerConfig):
    """Weighted masked-LM cross-entropy (scalar)."""
    logits = mlm_logits(params, tokens, positions, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def mlm_eval(params, tokens, positions, targets, weights,
             cfg: TransformerConfig):
    """Returns (loss, n_correct, n_total) for Masked-LM accuracy (Fig. 3)."""
    logits = mlm_logits(params, tokens, positions, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == targets).astype(jnp.float32) * weights)
    total = jnp.sum(weights)
    return loss, correct, total
