"""Pure-JAX transformer models (Layer 2).

Two variants, mirroring the paper's workloads:

* a decoder-only language model (`init_lm_params` / `lm_loss`) — the
  BERT/LM-style experiments and the end-to-end driver;
* an encoder-decoder translation model (`init_mt_params` / `mt_loss` /
  `mt_greedy_decode`) — the WMT'14 experiments (Fig. 2 / Fig. 6 / Table 1).

No flax/haiku — parameters are plain nested dicts of jnp arrays so the AOT
manifest can name every leaf deterministically and the Rust side can map
leaves to optimizer slots. All matrices are 2-D (embeddings, projections),
which is exactly the case the SM3 {rows, cols} cover targets; biases and
layernorm scales are vectors (singleton cover).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    max_len: int = 64
    dtype: object = jnp.float32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _dense_init(rng, shape, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)


def _block_params(rng, cfg: TransformerConfig, cross_attention: bool):
    d = cfg.d_model
    p = {
        "ln1_scale": jnp.ones(d, jnp.float32),
        "ln1_bias": jnp.zeros(d, jnp.float32),
        "wq": _dense_init(rng, (d, d)),
        "wk": _dense_init(rng, (d, d)),
        "wv": _dense_init(rng, (d, d)),
        "wo": _dense_init(rng, (d, d)),
        "ln2_scale": jnp.ones(d, jnp.float32),
        "ln2_bias": jnp.zeros(d, jnp.float32),
        "ffn_w1": _dense_init(rng, (d, cfg.d_ff)),
        "ffn_b1": jnp.zeros(cfg.d_ff, jnp.float32),
        "ffn_w2": _dense_init(rng, (cfg.d_ff, d)),
        "ffn_b2": jnp.zeros(d, jnp.float32),
    }
    if cross_attention:
        p.update({
            "lnx_scale": jnp.ones(d, jnp.float32),
            "lnx_bias": jnp.zeros(d, jnp.float32),
            "xwq": _dense_init(rng, (d, d)),
            "xwk": _dense_init(rng, (d, d)),
            "xwv": _dense_init(rng, (d, d)),
            "xwo": _dense_init(rng, (d, d)),
        })
    return p


def init_lm_params(cfg: TransformerConfig, seed: int = 0):
    """Decoder-only LM parameters: embedding (tied softmax), learned
    positions, `n_layers` causal blocks, final layernorm."""
    rng = np.random.default_rng(seed)
    params = {
        "embed": _dense_init(rng, (cfg.vocab, cfg.d_model), scale=0.02),
        "pos": _dense_init(rng, (cfg.max_len, cfg.d_model), scale=0.02),
        "lnf_scale": jnp.ones(cfg.d_model, jnp.float32),
        "lnf_bias": jnp.zeros(cfg.d_model, jnp.float32),
    }
    for l in range(cfg.n_layers):
        params[f"block{l}"] = _block_params(rng, cfg, cross_attention=False)
    return params


def init_mt_params(cfg: TransformerConfig, seed: int = 0):
    """Encoder-decoder parameters; source/target share the embedding table
    (word-piece vocab is shared, as in the paper's setup)."""
    rng = np.random.default_rng(seed)
    params = {
        "embed": _dense_init(rng, (cfg.vocab, cfg.d_model), scale=0.02),
        "pos_src": _dense_init(rng, (cfg.max_len, cfg.d_model), scale=0.02),
        "pos_tgt": _dense_init(rng, (cfg.max_len, cfg.d_model), scale=0.02),
        "enc_lnf_scale": jnp.ones(cfg.d_model, jnp.float32),
        "enc_lnf_bias": jnp.zeros(cfg.d_model, jnp.float32),
        "dec_lnf_scale": jnp.ones(cfg.d_model, jnp.float32),
        "dec_lnf_bias": jnp.zeros(cfg.d_model, jnp.float32),
    }
    for l in range(cfg.n_layers):
        params[f"enc{l}"] = _block_params(rng, cfg, cross_attention=False)
        params[f"dec{l}"] = _block_params(rng, cfg, cross_attention=True)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(q, k, v, cfg: TransformerConfig, mask):
    """Multi-head attention. q/k/v: (B, S, D) pre-projection inputs already
    projected; mask: (S_q, S_k) additive (0 or -inf)."""
    B, Sq, D = q.shape
    Sk = k.shape[1]
    h, dh = cfg.n_heads, cfg.d_head
    q = q.reshape(B, Sq, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, Sk, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, Sk, h, dh).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
    logits = logits + mask[None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out.transpose(0, 2, 1, 3).reshape(B, Sq, D)


def _self_attn_block(p, x, cfg, mask):
    h = _layer_norm(x, p["ln1_scale"], p["ln1_bias"])
    attn = _attention(h @ p["wq"], h @ p["wk"], h @ p["wv"], cfg, mask)
    x = x + attn @ p["wo"]
    h = _layer_norm(x, p["ln2_scale"], p["ln2_bias"])
    ff = jax.nn.relu(h @ p["ffn_w1"] + p["ffn_b1"]) @ p["ffn_w2"] + p["ffn_b2"]
    return x + ff


def _cross_attn(p, x, enc_out, cfg, mask):
    h = _layer_norm(x, p["lnx_scale"], p["lnx_bias"])
    attn = _attention(h @ p["xwq"], enc_out @ p["xwk"], enc_out @ p["xwv"],
                      cfg, mask)
    return x + attn @ p["xwo"]


def _causal_mask(s):
    return jnp.where(jnp.tril(jnp.ones((s, s), bool)), 0.0, -jnp.inf)


def lm_logits(params, tokens, cfg: TransformerConfig):
    """Decoder-only forward: tokens (B, S) int32 → logits (B, S, V)."""
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos"][:S][None, :, :]
    mask = _causal_mask(S)
    for l in range(cfg.n_layers):
        x = _self_attn_block(params[f"block{l}"], x, cfg, mask)
    x = _layer_norm(x, params["lnf_scale"], params["lnf_bias"])
    return x @ params["embed"].T


def lm_loss(params, tokens, cfg: TransformerConfig):
    """Next-token cross-entropy, averaged over all (B, S-1) positions."""
    logits = lm_logits(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def _encode(params, src, cfg):
    B, S = src.shape
    x = params["embed"][src] + params["pos_src"][:S][None, :, :]
    mask = jnp.zeros((S, S), jnp.float32)
    for l in range(cfg.n_layers):
        x = _self_attn_block(params[f"enc{l}"], x, cfg, mask)
    return _layer_norm(x, params["enc_lnf_scale"], params["enc_lnf_bias"])


def _decode(params, enc_out, tgt_in, cfg):
    B, S = tgt_in.shape
    Sk = enc_out.shape[1]
    x = params["embed"][tgt_in] + params["pos_tgt"][:S][None, :, :]
    causal = _causal_mask(S)
    xmask = jnp.zeros((S, Sk), jnp.float32)
    for l in range(cfg.n_layers):
        p = params[f"dec{l}"]
        h = _layer_norm(x, p["ln1_scale"], p["ln1_bias"])
        attn = _attention(h @ p["wq"], h @ p["wk"], h @ p["wv"], cfg, causal)
        x = x + attn @ p["wo"]
        x = _cross_attn(p, x, enc_out, cfg, xmask)
        h = _layer_norm(x, p["ln2_scale"], p["ln2_bias"])
        ff = jax.nn.relu(h @ p["ffn_w1"] + p["ffn_b1"]) @ p["ffn_w2"] + p["ffn_b2"]
        x = x + ff
    x = _layer_norm(x, params["dec_lnf_scale"], params["dec_lnf_bias"])
    return x @ params["embed"].T


def mt_loss(params, src, tgt, cfg: TransformerConfig, pad_id: int = 0):
    """Teacher-forced translation loss; `tgt` includes BOS at position 0.
    PAD positions (token == pad_id) are masked out of the mean."""
    enc = _encode(params, src, cfg)
    logits = _decode(params, enc, tgt[:, :-1], cfg)
    targets = tgt[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    wmask = (targets != pad_id).astype(jnp.float32)
    return jnp.sum(nll * wmask) / jnp.maximum(jnp.sum(wmask), 1.0)


def mt_greedy_decode(params, src, cfg: TransformerConfig, bos_id: int = 1,
                     max_len: int | None = None):
    """Greedy decode entirely inside the artifact (no Python at serve time).

    Runs the full decoder once per output position via `lax.scan` (no KV
    cache — O(L²) attention recompute, fine at these lengths) and returns
    (B, max_len) int32 tokens.
    """
    max_len = max_len or cfg.max_len
    B = src.shape[0]
    enc = _encode(params, src, cfg)

    def step(tgt, t):
        logits = _decode(params, enc, tgt, cfg)          # (B, L, V)
        nxt = jnp.argmax(logits[:, t, :], axis=-1).astype(jnp.int32)
        tgt = tgt.at[:, t + 1].set(nxt)
        return tgt, None

    tgt0 = jnp.full((B, max_len), 0, jnp.int32).at[:, 0].set(bos_id)
    tgt, _ = jax.lax.scan(step, tgt0, jnp.arange(max_len - 1))
    return tgt[:, 1:]


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
