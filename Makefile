# Top-level targets. `make tier1` mirrors the repository's tier-1 gate
# (and the build-test job in .github/workflows/ci.yml) exactly.

.PHONY: tier1 build test lint fmt clippy bench-optim bench-quick \
	bench-comms bench-comms-quick bench-comms-overlap bench-telemetry \
	benches docs artifacts report

tier1:
	cargo build --release && cargo test -q

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

lint: fmt clippy

# API docs with warnings promoted to errors (the `optim` module carries
# #![warn(missing_docs)], so the redesigned public API ships fully
# documented). Mirrors the docs job in .github/workflows/ci.yml.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

# Serial-vs-parallel optimizer-step numbers (EXPERIMENTS.md §Perf).
bench-optim:
	cargo bench --bench bench_optim

# CI-sized bench_optim run: small spec set, short budgets, but every
# bitwise equality assertion (chunked==whole-slot, serial==sharded)
# executes. Mirrors the ci.yml step exactly.
bench-quick:
	BENCH_QUICK=1 cargo bench --bench bench_optim

# Compressed-collectives numbers: ring all-reduce over ranks x wire
# dtype x comm threads (EXPERIMENTS.md §Compressed-collectives).
bench-comms:
	cargo bench --bench bench_collectives

# CI-sized bench_collectives run: small gradient set, short budgets, but
# every bitwise gate (f32 == legacy collectives, serial == threaded,
# rank agreement) executes. Mirrors the ci.yml step exactly.
bench-comms-quick:
	BENCH_QUICK=1 cargo bench --bench bench_collectives

# Full overlap sweep with telemetry-calibrated timing: ranks x dtype x
# bucket count x transport, measured-fit TimingModel, serial vs
# overlapped pipeline model, written to out/perf_collectives_overlap.csv
# (EXPERIMENTS.md §Overlapped-collectives). The `< serial` assertion for
# ranks >= 2 executes here at full bench sizes, and again under both
# transports because the sweep iterates TransportKind::ALL internally.
bench-comms-overlap:
	cargo bench --bench bench_collectives -- --telemetry

# Quick benches with telemetry export: writes out/BENCH_optim.json,
# out/BENCH_comms.json, out/BENCH_memory.json and validates them with
# the in-repo checker (EXPERIMENTS.md §Telemetry), holding
# BENCH_memory.json's peak pool bytes to the committed baseline
# (the peak-memory regression gate, DESIGN.md §16). Mirrors the
# ci.yml telemetry job.
bench-telemetry:
	BENCH_QUICK=1 cargo bench --bench bench_optim -- --telemetry
	BENCH_QUICK=1 cargo bench --bench bench_collectives -- --telemetry
	BENCH_QUICK=1 cargo bench --bench bench_memory -- --telemetry
	cargo run --release --bin sm3-train -- bench-check \
		--baseline ci/BENCH_memory_baseline.json \
		out/BENCH_optim.json out/BENCH_comms.json out/BENCH_memory.json

# Run-health + performance report (EXPERIMENTS.md §Run-health): quick
# benches leave BENCH_*.json documents plus a Chrome-trace timeline
# (out/trace_comms.json), then `sm3-train report --check` validates the
# trace, prints the measured hop-vs-stage overlap efficiency, and holds
# every budgeted metric to the committed baselines. With artifacts/
# present, add a trainer pass (`--trace-out out/trace_train.json
# --telemetry-jsonl out/train_events.jsonl`) and pass `--jsonl` to the
# reporter for the per-step phase budgets and watchdog verdicts.
report:
	BENCH_QUICK=1 cargo bench --bench bench_optim -- --telemetry
	BENCH_QUICK=1 cargo bench --bench bench_collectives -- --telemetry
	BENCH_QUICK=1 cargo bench --bench bench_memory -- --telemetry
	cargo run --release --bin sm3-train -- report --check \
		--trace out/trace_comms.json \
		--baseline ci/BENCH_memory_baseline.json \
		out/BENCH_optim.json out/BENCH_comms.json out/BENCH_memory.json

# Compile every harness=false bench target without running it (the CI
# build-test job runs this too, so the benches cannot silently rot).
benches:
	cargo bench --no-run --workspace

# AOT-lower the JAX models to HLO artifacts (needs the Python toolchain;
# the Rust integration tests skip themselves when artifacts/ is absent).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
