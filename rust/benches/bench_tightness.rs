//! E7/E8 — regenerates paper Fig. 5 (tightness of the SM3 approximation
//! to Adagrad's second-moment statistics) and Figs. 1 & 7 (activation-
//! pattern heatmaps).
//!
//! Method, as in the paper's Appendix B.1: train with Adagrad and record
//! its γ_t accumulators; feed the *same* gradient sequence to SM3-I and
//! SM3-II; compare the implied ν at the coordinates of the 100 largest γ
//! entries of the embedding matrix.
//!
//! Shape targets: γ ≤ ν_II ≤ ν_I everywhere (Claim 2/Prop. 3), with
//! SM3-II visibly tighter, and high row/col structure scores for the
//! trained statistics (the Fig. 1 patterns).
//!
//! Run: `cargo bench --bench bench_tightness`
//! (writes out/fig5_tightness.csv, out/fig1_*.csv, out/fig7_*.csv)

use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::metrics::RunLogger;
use sm3::optim::{Adagrad, Optimizer, ParamSpec, Sm3, Sm3Variant};
use sm3::runtime::Runtime;
use sm3::trace;
use std::sync::Arc;

const STEPS: usize = 120;
const TOP_K: usize = 100;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);

    // capture a real gradient sequence from mt_small training (embedding
    // gradients carry the Zipfian activation pattern)
    let mut cfg = TrainConfig::default();
    cfg.model = "mt_small".into();
    cfg.optim.name = "adagrad".into();
    cfg.optim.lr = 0.2;
    cfg.optim.warmup_steps = 10;
    cfg.steps = 1;
    cfg.exec = ExecMode::Split;
    let mut trainer = Trainer::with_runtime(cfg, rt)?;

    let specs: Vec<ParamSpec> = trainer.meta.param_specs();
    let embed_idx = specs.iter().position(|s| s.name == "embed")
        .expect("mt_small has an embedding");
    // pick a decoder FFN matrix for the second heatmap (Fig. 1 shows
    // attention/FFN layers too)
    let ffn_idx = specs.iter().position(|s| s.name.ends_with("ffn_w1"))
        .expect("ffn matrix");

    println!("=== Fig. 5 — accumulator tightness on {} steps of real \
              gradients ===", STEPS);
    let mut adagrad = Adagrad::new(&specs, 0.9);
    let mut sm3i = Sm3::new(&specs, Sm3Variant::I, 0.9);
    let mut sm3ii = Sm3::new(&specs, Sm3Variant::II, 0.9);
    // three parameter copies so each optimizer follows its own trajectory
    // on the SAME data stream? No — the paper compares statistics for one
    // gradient sequence; use Adagrad's trajectory as the generator and
    // feed its gradients to all three (identical g_1..g_T).
    let mut params = trainer.params();
    let mut p1 = params.clone();
    let mut p2 = params.clone();
    for step in 0..STEPS {
        let (_, grads) = trainer.compute_grads()?;
        adagrad.step(&mut params, &grads, 0.1);
        sm3i.step(&mut p1, &grads, 0.1);
        sm3ii.step(&mut p2, &grads, 0.1);
        if step % 40 == 0 {
            println!("  ... step {step}");
        }
    }

    let gamma = adagrad.accumulator(embed_idx);
    let nu_i = sm3i.implied_nu_matrix(embed_idx);
    let nu_ii = sm3ii.implied_nu_matrix(embed_idx);

    let order = trace::top_k_indices(&gamma, TOP_K);
    let mut log = RunLogger::new(Some("out/fig5_tightness.csv"),
                                 "rank,adagrad,sm3_ii,sm3_i", false)?;
    let (mut viol_bound, mut viol_order) = (0usize, 0usize);
    let (mut sum_ratio_i, mut sum_ratio_ii) = (0.0f64, 0.0f64);
    for (rank, &k) in order.iter().enumerate() {
        let g = gamma.data()[k];
        let vi = nu_i.data()[k];
        let vii = nu_ii.data()[k];
        log.row(&[rank.to_string(), format!("{g:.6e}"),
                  format!("{vii:.6e}"), format!("{vi:.6e}")])?;
        if !(g <= vii + 1e-4) || !(vii <= vi + 1e-4) {
            viol_bound += 1;
        }
        if vii > vi + 1e-4 {
            viol_order += 1;
        }
        sum_ratio_i += (vi / g.max(1e-12)) as f64;
        sum_ratio_ii += (vii / g.max(1e-12)) as f64;
    }
    log.flush()?;
    println!("  sandwich γ ≤ ν_II ≤ ν_I violations: {viol_bound} \
              (order: {viol_order}) / {TOP_K}");
    println!("  mean over-approximation on top-{TOP_K}: \
              SM3-II {:.2}x, SM3-I {:.2}x (paper: II visibly tighter)",
             sum_ratio_ii / TOP_K as f64, sum_ratio_i / TOP_K as f64);
    assert_eq!(viol_bound, 0, "Claim 2 / Prop 3 violated");

    // ---- Fig. 1 & Fig. 7: activation-pattern heatmaps -------------------
    println!("\n=== Fig. 1 — activation-pattern heatmaps (Adagrad γ) ===");
    // (γ in log scale is what the paper plots; we store raw values)
    trace::write_heatmap_csv("out/fig1_embed_gamma.csv",
                             &adagrad.accumulator(embed_idx))?;
    trace::write_heatmap_csv("out/fig1_ffn_gamma.csv",
                             &adagrad.accumulator(ffn_idx))?;
    let s_embed =
        trace::activation_pattern_score(&adagrad.accumulator(embed_idx));
    let s_ffn = trace::activation_pattern_score(&adagrad.accumulator(ffn_idx));
    println!("  rank-1 row/col structure score: embed {s_embed:.3}, \
              ffn {s_ffn:.3} (≈1 ⇒ strong pattern)");

    // Fig. 7: conv-kernel statistics from the image model — reshape the
    // rank-4 kernel stats to (hw·cin, cout) for the heatmap as the paper
    // does with conv tensors
    let mut icfg = TrainConfig::default();
    icfg.model = "img_small".into();
    icfg.optim.name = "adagrad".into();
    icfg.optim.lr = 0.05;
    icfg.steps = 1;
    icfg.exec = ExecMode::Split;
    let mut itrainer = Trainer::new(icfg)?;
    let ispecs = itrainer.meta.param_specs();
    let conv_idx = ispecs.iter().position(|s| s.shape.len() == 4).unwrap();
    let mut iada = Adagrad::new(&ispecs, 0.9);
    let mut ip = itrainer.params();
    for _ in 0..60 {
        let (_, grads) = itrainer.compute_grads()?;
        iada.step(&mut ip, &grads, 0.05);
    }
    let conv = iada.accumulator(conv_idx);
    let (s0, s1, s2, s3) = (conv.shape()[0], conv.shape()[1],
                            conv.shape()[2], conv.shape()[3]);
    let conv2d = conv.reshape(&[s0 * s1 * s2, s3]);
    trace::write_heatmap_csv("out/fig7_conv_gamma.csv", &conv2d)?;
    let s_conv = trace::activation_pattern_score(&conv2d);
    println!("\n=== Fig. 7 — conv activation patterns ===");
    println!("  conv kernel ({s0}x{s1}x{s2}x{s3}) structure score {s_conv:.3}");
    println!("\nCSV series: out/fig5_tightness.csv out/fig1_embed_gamma.csv \
              out/fig1_ffn_gamma.csv out/fig7_conv_gamma.csv");
    Ok(())
}
