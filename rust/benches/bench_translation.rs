//! E1/E2/E9 — regenerates paper Fig. 2 (test log-perplexity curves on
//! en→fr at batch B and 2B), Table 1 (BLEU + memory per core), and
//! Fig. 6 (the en→de-style second configuration).
//!
//! Shape targets (DESIGN.md §5): SM3 ≈ Adagrad ≥ Adam > Adafactor on
//! quality at equal batch; SM3@2B best overall; Adam/Adagrad marked OOM
//! at 2B by the memory accountant.
//!
//! Run: `cargo bench --bench bench_translation` (writes out/fig2_*.csv,
//! out/table1.csv, out/fig6_*.csv)

use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::memory::{inventory, MemoryModel, GIB};
use sm3::metrics::RunLogger;
use sm3::runtime::Runtime;
use std::sync::Arc;

const STEPS: u64 = 200;

fn cfg(opt: &str, lr: f64, accum: u64, seed: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = "mt_small".into();
    c.optim.name = opt.into();
    c.optim.lr = lr;
    c.optim.schedule = "paper".into();
    c.optim.warmup_steps = STEPS / 8;
    c.steps = STEPS;
    c.eval_every = STEPS / 8;
    c.grad_accum = accum;
    c.seed = seed;
    c.exec = ExecMode::Split;
    c
}

fn run(rt: &Arc<Runtime>, opt: &str, lr: f64, accum: u64,
       log: &mut RunLogger) -> anyhow::Result<(f64, f64)> {
    let mut t = Trainer::with_runtime(cfg(opt, lr, accum, 0), rt.clone())?;
    let hist = t.train()?;
    for e in &hist.evals {
        log.row(&[opt.into(), accum.to_string(), e.step.to_string(),
                  format!("{:.5}", e.loss),
                  format!("{:.2}", e.metric.unwrap_or(f64::NAN))])?;
    }
    let last = hist.evals.last().unwrap();
    Ok((last.loss, last.metric.unwrap_or(f64::NAN)))
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);

    // ---- Fig. 2: log-perplexity curves at batch B and 2B ----------------
    println!("=== Fig. 2 — mt_small eval log-perplexity (loss) curves ===");
    let mut log = RunLogger::new(Some("out/fig2_curves.csv"),
                                 "optimizer,accum,step,eval_loss,bleu", false)?;
    // (optimizer, base lr) — Table-3-style per-optimizer tuning
    let grid: &[(&str, f64)] = &[("adam", 0.003), ("adagrad", 0.3),
                                 ("adafactor", 0.01), ("sm3", 0.3)];
    let mut finals = Vec::new();
    for &(opt, lr) in grid {
        let (loss, bleu) = run(&rt, opt, lr, 1, &mut log)?;
        println!("  batch 1x  {opt:<10} final eval loss {loss:.4}  BLEU {bleu:.2}");
        finals.push((opt.to_string(), 1u64, loss, bleu));
    }
    // 2B: only the memory-efficient methods fit on real hardware (Table 1);
    // simulated here via gradient accumulation
    for &(opt, lr) in &[("adafactor", 0.01), ("sm3", 0.3)] {
        let (loss, bleu) = run(&rt, opt, lr, 2, &mut log)?;
        println!("  batch 2x  {opt:<10} final eval loss {loss:.4}  BLEU {bleu:.2}");
        finals.push((opt.to_string(), 2, loss, bleu));
    }
    log.flush()?;

    // shape checks (who wins)
    let get = |o: &str, a: u64| {
        finals.iter().find(|f| f.0 == o && f.1 == a).unwrap()
    };
    let sm3 = get("sm3", 1);
    let adaf = get("adafactor", 1);
    let sm3_2b = get("sm3", 2);
    println!("\n  shape: sm3@1x loss {:.3} vs adafactor@1x {:.3} \
              (paper: SM3 better) {}",
             sm3.2, adaf.2, if sm3.2 <= adaf.2 { "✓" } else { "✗" });
    println!("  shape: sm3@2x loss {:.3} vs sm3@1x {:.3} \
              (paper: 2x batch converges further per step) {}",
             sm3_2b.2, sm3.2, if sm3_2b.2 <= sm3.2 { "✓" } else { "✗" });

    // ---- Table 1: BLEU + memory per core --------------------------------
    println!("\n=== Table 1 — BLEU + memory/core (real Transformer-Big \
              inventory) ===");
    let mm = MemoryModel::calibrate(
        inventory::transformer_big(), 8.0 * GIB,
        ("adam", 12, 6.88 * GIB), ("sm3", 24, 7.02 * GIB))?;
    let mut t1 = RunLogger::new(Some("out/table1.csv"),
        "optimizer,batch_per_core,memory_gib,fits,bleu_small", false)?;
    println!("  {:<11} {:>7} {:>11} {:>6} {:>11}",
             "optimizer", "batch", "mem (GiB)", "fits", "BLEU(small)");
    for (opt, accum, b_core) in [("adam", 1, 12), ("adagrad", 1, 12),
                                 ("adafactor", 1, 12), ("sm3", 1, 12),
                                 ("adafactor", 2, 24), ("sm3", 2, 24)] {
        let gib = mm.gib_per_core(opt, b_core)?;
        let fits = mm.fits(opt, b_core)?;
        let bleu = finals.iter().find(|f| f.0 == opt && f.1 == accum)
            .map(|f| f.3).unwrap_or(f64::NAN);
        println!("  {opt:<11} {b_core:>7} {gib:>11.2} {:>6} {bleu:>11.2}",
                 if fits { "yes" } else { "OOM" });
        t1.row(&[opt.into(), b_core.to_string(), format!("{gib:.3}"),
                 fits.to_string(), format!("{bleu:.2}")])?;
    }
    t1.flush()?;

    // ---- Fig. 6: the en→de-style config (different seed/schedule mix) ---
    println!("\n=== Fig. 6 — second translation configuration ===");
    let mut f6 = RunLogger::new(Some("out/fig6_curves.csv"),
                                "optimizer,step,eval_loss,bleu", false)?;
    for &(opt, lr) in grid {
        let mut c = cfg(opt, lr, 1, 7);
        c.steps = STEPS / 2;
        c.eval_every = STEPS / 8;
        let mut t = Trainer::with_runtime(c, rt.clone())?;
        let hist = t.train()?;
        for e in &hist.evals {
            f6.row(&[opt.into(), e.step.to_string(),
                     format!("{:.5}", e.loss),
                     format!("{:.2}", e.metric.unwrap_or(f64::NAN))])?;
        }
        let last = hist.evals.last().unwrap();
        println!("  {opt:<10} final eval loss {:.4}  BLEU {:.2}",
                 last.loss, last.metric.unwrap_or(f64::NAN));
    }
    f6.flush()?;
    println!("\nCSV series: out/fig2_curves.csv out/table1.csv out/fig6_curves.csv");
    Ok(())
}
