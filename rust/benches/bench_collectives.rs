//! E-comm — the compressed-collectives bench (EXPERIMENTS.md
//! §Compressed-collectives): ring all-reduce throughput and simulated
//! pod cost over ranks × wire dtype × comm threads, with the
//! subsystem's bitwise determinism gates executed before any timing.
//!
//! Gates (always run, including under `BENCH_QUICK=1` in CI):
//!   * the f32 engine reproduces the legacy `collectives::allreduce_mean`
//!     reference bit for bit (so the new path cannot silently change
//!     pre-comms trajectories),
//!   * serial == 2 == 4 comm threads, bitwise, at every wire dtype —
//!     outputs AND carried error-feedback residuals,
//!   * all ranks leave an exchange with identical buffers (pod sync).
//!
//! Run: `cargo bench --bench bench_collectives` (writes
//! out/perf_collectives.csv); `BENCH_QUICK=1` or `make bench-comms-quick`
//! for the CI-sized variant. Pass `-- --telemetry` (or `SM3_TELEMETRY=1`)
//! to emit out/BENCH_comms.json: per-hop span stats, wire-byte counters
//! cross-checked against the static accountant, and the measured-vs-
//! modeled `TimingModel` delta per configuration (DESIGN.md §14).

use sm3::bench_util::{bench, speedup, telemetry_requested,
                      write_bench_json, CsvWriter, Stats};
use sm3::collectives;
use sm3::comms::{CommEngine, CommOpts, TimingModel, TransportKind};
use sm3::memory::comm_wire_bytes;
use sm3::optim::{ParamSpec, StateDtype};
use sm3::rng::Rng;
use sm3::telemetry::{self, Counter, Probe, Registry};
use sm3::tensor::Tensor;
use std::time::Duration;

/// A transformer-block-shaped gradient set (~2.1M elements; quick ~37k).
fn block_specs(quick: bool) -> Vec<ParamSpec> {
    let (v, d, ff) = if quick { (256, 64, 256) } else { (2048, 256, 1024) };
    vec![
        ParamSpec::new("embed", &[v, d]),
        ParamSpec::new("wq", &[d, d]),
        ParamSpec::new("wk", &[d, d]),
        ParamSpec::new("wv", &[d, d]),
        ParamSpec::new("wo", &[d, d]),
        ParamSpec::new("ffn_w1", &[d, ff]),
        ParamSpec::new("ffn_w2", &[ff, d]),
        ParamSpec::new("b1", &[ff]),
        ParamSpec::new("b2", &[d]),
    ]
}

fn rank_grads(specs: &[ParamSpec], ranks: usize, seed: u64)
              -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed);
    (0..ranks)
        .map(|_| {
            specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect()
        })
        .collect()
}

fn assert_bitwise(a: &[Vec<Tensor>], b: &[Vec<Tensor>], what: &str) {
    for (ra, rb) in a.iter().zip(b) {
        for (ta, tb) in ra.iter().zip(rb) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} != {y}");
            }
        }
    }
}

/// The bitwise determinism gates — the point of running this bench in
/// CI quick mode at all.
fn run_gates(specs: &[ParamSpec]) -> anyhow::Result<()> {
    println!("=== determinism gates (bitwise) ===");
    // 1. f32 path == legacy collectives reference
    for ranks in [2usize, 4] {
        let mut legacy = rank_grads(specs, ranks, 42);
        let mut new = legacy.clone();
        collectives::allreduce_mean(&mut legacy)?;
        CommEngine::new(specs, ranks, StateDtype::F32, 64, 1)?
            .allreduce_mean(&mut new)?;
        assert_bitwise(&legacy, &new, &format!("f32 vs legacy x{ranks}"));
    }
    println!("  f32 == legacy collectives          OK (x2, x4)");
    // 2. serial == 2 == 4 comm threads at every dtype, incl residuals
    for dtype in StateDtype::ALL {
        let ranks = 4;
        let base = rank_grads(specs, ranks, 7);
        let mut ref_eng = CommEngine::new(specs, ranks, dtype, 64, 1)?;
        let mut ref_out = base.clone();
        ref_eng.allreduce_mean(&mut ref_out)?;
        for threads in [2usize, 4] {
            let mut eng = CommEngine::new(specs, ranks, dtype, 64, threads)?;
            let mut out = base.clone();
            eng.allreduce_mean(&mut out)?;
            assert_bitwise(&ref_out, &out,
                           &format!("{} x{threads}", dtype.name()));
            for ((_, a), (_, b)) in ref_eng.state().iter().zip(&eng.state())
            {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{} x{threads} residual", dtype.name());
                }
            }
        }
        // 3. all ranks agree after the exchange
        for r in 1..ranks {
            for (a, b) in ref_out[0].iter().zip(&ref_out[r]) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{} rank {r} diverged", dtype.name());
                }
            }
        }
    }
    println!("  serial == 2 == 4 threads           OK (f32, bf16, q8)");
    println!("  rank agreement after exchange      OK");
    // 4. ISSUE 8: bucketed, overlapped, and channel-transport exchanges
    //    all equal the monolithic serial exchange bitwise — outputs AND
    //    carried residuals (the hard contract for the pipeline)
    for dtype in StateDtype::ALL {
        let ranks = 3;
        let base = rank_grads(specs, ranks, 11);
        let mut ref_eng = CommEngine::new(specs, ranks, dtype, 64, 1)?;
        let mut ref_out = base.clone();
        ref_eng.allreduce_mean(&mut ref_out)?;
        for transport in TransportKind::ALL {
            for buckets in [2usize, 4] {
                for overlap in [false, true] {
                    let mut eng = CommEngine::with_opts(
                        specs, ranks,
                        CommOpts { dtype, chunk: 64, threads: 1, buckets,
                                   overlap, transport })?;
                    let mut out = base.clone();
                    eng.allreduce_mean(&mut out)?;
                    let what = format!("{} b{buckets} overlap={overlap} {}",
                                       dtype.name(), transport.name());
                    assert_bitwise(&ref_out, &out, &what);
                    for ((_, a), (_, b)) in
                        ref_eng.state().iter().zip(&eng.state())
                    {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            assert_eq!(x.to_bits(), y.to_bits(),
                                       "{what} residual");
                        }
                    }
                }
            }
        }
    }
    println!("  buckets x overlap x transports     OK (bitwise, \
              incl residuals)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1")
        .unwrap_or(false);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // quick (CI) runs always emit the telemetry document — the perf
    // trajectory gate (`sm3-train bench-check`) wants BENCH_comms.json
    // from every CI run, not only the --telemetry job
    let tele = telemetry_requested(&argv) || quick;
    let _tele_guard = tele.then(telemetry::enable);
    // record every span/counter/gauge into the per-thread trace rings
    // too: the bench leaves a Chrome-trace timeline next to the JSON
    // document, and CI validates it with `sm3-train report --check`
    let _trace_guard = tele.then(telemetry::enable_tracing);
    if tele {
        sm3::telemetry::trace_event::set_thread_label("bench-main");
        println!("telemetry on — writing out/BENCH_comms.json at exit");
    }
    let budget = if quick {
        Duration::from_millis(25)
    } else {
        Duration::from_millis(300)
    };
    let min_iters = if quick { 2 } else { 5 };
    if quick {
        println!("BENCH_QUICK=1 — small gradient set, short budgets; \
                  bitwise gates run in full");
    }
    let specs = block_specs(quick);
    let d: usize = specs.iter().map(ParamSpec::numel).sum();

    run_gates(&specs)?;
    if tele {
        // the gates above ran outsized engine configs under the live
        // guard; re-arm the gauge high-water marks so the peaks in
        // BENCH_comms.json describe the measured sweeps, not the gates
        telemetry::reset_thread_run();
    }

    println!("\n=== ring all-reduce ({:.2}M floats) — ranks × dtype × \
              threads ===", d as f64 / 1e6);
    let timing = TimingModel::default();
    let mut csv = CsvWriter::create(
        "out/perf_collectives.csv",
        "ranks,dtype,threads,elements,median_ns,wire_bytes,sim_ms,\
         speedup_vs_serial")?;
    let rank_list: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    // measured-vs-modeled TimingModel entries, merged into the bench
    // registry (and so into BENCH_comms.json) at the end
    let mut treg = Registry::new();
    for &ranks in rank_list {
        for dtype in StateDtype::ALL {
            let mut serial_stats: Option<Stats> = None;
            for threads in [1usize, 2, 4] {
                let mut eng =
                    CommEngine::new(&specs, ranks, dtype, 16 * 1024,
                                    threads)?;
                // reuse one gradient set across iterations: the exchange
                // rewrites it with means, which keeps the work identical
                // without per-iteration clone noise
                let mut g = rank_grads(&specs, ranks, 3);
                let before = tele.then(telemetry::thread_totals);
                let stats = bench(
                    &format!("x{ranks} {} t{threads}", dtype.name()),
                    budget, min_iters,
                    || {
                        eng.allreduce_mean(&mut g).unwrap();
                    });
                let wire = eng.wire_bytes_per_exchange();
                // hard assert: benches run in release, where a
                // debug_assert would make this cross-check dead code
                assert_eq!(wire, comm_wire_bytes(&specs, ranks, dtype),
                           "live schedule vs static mirror drifted");
                let sim_ms = timing.exchange_seconds(wire, ranks) * 1e3;
                if let Some(before) = before {
                    // measured per-hop latencies (the calibration source
                    // for TimingModel) vs the model's simulated exchange:
                    // reported, not asserted — the model prices pod links,
                    // the measurement prices in-process memory traffic
                    let after = telemetry::thread_totals();
                    let exch = after.counter(Counter::CommExchanges)
                        .saturating_sub(
                            before.counter(Counter::CommExchanges));
                    let wired = after.counter(Counter::CommWireBytes)
                        .saturating_sub(
                            before.counter(Counter::CommWireBytes));
                    assert_eq!(wired, wire as u64 * exch,
                               "wire-byte counter drifted from the \
                                schedule's per-exchange bytes");
                    if exch > 0 {
                        let hop_ms = after.ms_since(
                            &before,
                            &[Probe::CommHopReduce, Probe::CommHopEncode,
                              Probe::CommHopGather]) / exch as f64;
                        let delta_pct =
                            100.0 * (hop_ms - sim_ms) / sim_ms;
                        println!("    hops measured {hop_ms:.4} ms vs \
                                  modeled {sim_ms:.4} ms \
                                  ({delta_pct:+.0}%)");
                        let key = format!("timing_model/x{ranks}_{}_t\
                                           {threads}", dtype.name());
                        treg.gauge(&format!("{key}/measured_hop_ns"),
                                   (hop_ms * 1e6) as u64);
                        treg.gauge(&format!("{key}/modeled_ns"),
                                   (sim_ms * 1e6) as u64);
                    }
                }
                let vs_serial = serial_stats
                    .as_ref()
                    .map(|s| speedup(s, &stats))
                    .unwrap_or(1.0);
                println!("  {stats}  wire {:>8.2} MB  sim {:>7.4} ms  \
                          {vs_serial:>5.2}x",
                         wire as f64 / 1e6, sim_ms);
                csv.row(&[ranks.to_string(), dtype.name().into(),
                          threads.to_string(), d.to_string(),
                          stats.per_iter_ns().to_string(),
                          wire.to_string(), format!("{sim_ms:.4}"),
                          format!("{vs_serial:.3}")])?;
                if threads == 1 {
                    serial_stats = Some(stats);
                }
            }
        }
    }

    // ── ISSUE 8: the overlapped pipeline — measured throughput plus the
    // calibrated overlap model (EXPERIMENTS.md §Overlapped-collectives).
    // Per configuration the engine runs overlapped, the TimingModel is
    // refit from this run's measured hop/stage spans
    // (`TimingModel::from_measured`; defaults when telemetry is off),
    // and the refit model prices the same bucket plan serial vs
    // overlapped. The acceptance gate: overlapped < serial for every
    // multi-bucket multi-rank configuration.
    println!("\n=== overlapped pipeline — ranks × dtype × buckets × \
              transport ===");
    let mut ocsv = CsvWriter::create(
        "out/perf_collectives_overlap.csv",
        "ranks,dtype,buckets,transport,elements,median_ns,wire_bytes,\
         modeled_serial_ms,modeled_overlap_ms,overlap_gain")?;
    let bucket_list: &[usize] = if quick { &[1, 4] } else { &[1, 4, 8] };
    for &ranks in rank_list {
        for dtype in [StateDtype::F32, StateDtype::Q8] {
            for transport in TransportKind::ALL {
                for &buckets in bucket_list {
                    let mut eng = CommEngine::with_opts(
                        &specs, ranks,
                        CommOpts { dtype, chunk: 16 * 1024, threads: 1,
                                   buckets, overlap: true, transport })?;
                    let mut g = rank_grads(&specs, ranks, 3);
                    let before = tele.then(telemetry::thread_totals);
                    let stats = bench(
                        &format!("x{ranks} {} b{buckets} {}", dtype.name(),
                                 transport.name()),
                        budget, min_iters,
                        || {
                            eng.allreduce_mean(&mut g).unwrap();
                        });
                    // refit the interconnect model from what this
                    // configuration actually measured
                    let (mut hops, mut stages) = (Vec::new(), Vec::new());
                    if let Some(before) = before {
                        let after = telemetry::thread_totals();
                        let exch = after.counter(Counter::CommExchanges)
                            .saturating_sub(
                                before.counter(Counter::CommExchanges));
                        let hop_probes = [Probe::CommHopReduce,
                                          Probe::CommHopEncode,
                                          Probe::CommHopGather];
                        let hop_ns: u64 = hop_probes.iter()
                            .map(|&p| after.ns(p)
                                 .saturating_sub(before.ns(p)))
                            .sum();
                        let hop_n: u64 = hop_probes.iter()
                            .map(|&p| after.spans(p) - before.spans(p))
                            .sum();
                        if exch > 0 && hop_n > 0 && hop_ns > 0 {
                            hops.push((
                                eng.wire_bytes_per_exchange()
                                    * exch as usize / hop_n as usize,
                                hop_ns as f64 / hop_n as f64 / 1e9,
                            ));
                        }
                        let stage_ns = after.ms_since(
                            &before,
                            &[Probe::CommPack, Probe::CommFeedback])
                            * 1e6;
                        if exch > 0 && stage_ns > 0.0 {
                            stages.push((
                                ranks * d * 4 * exch as usize,
                                stage_ns / 1e9,
                            ));
                        }
                    }
                    let fit = TimingModel::from_measured(&hops, &stages);
                    let serial_ms =
                        eng.plan().modeled_seconds(&fit, ranks, false) * 1e3;
                    let overlap_ms =
                        eng.plan().modeled_seconds(&fit, ranks, true) * 1e3;
                    // the acceptance gate: the pipeline model must price
                    // overlap below serial whenever there is anything to
                    // overlap, and never above it
                    assert!(overlap_ms <= serial_ms,
                            "overlap {overlap_ms} > serial {serial_ms}");
                    if buckets >= 2 && ranks >= 2 {
                        assert!(overlap_ms < serial_ms,
                                "x{ranks} b{buckets}: overlap model must \
                                 beat serial ({overlap_ms} vs {serial_ms})");
                    }
                    let gain = serial_ms / overlap_ms;
                    println!("  {stats}  serial {serial_ms:>7.4} ms  \
                              overlap {overlap_ms:>7.4} ms  {gain:>5.2}x  \
                              [{}]", transport.name());
                    ocsv.row(&[ranks.to_string(), dtype.name().into(),
                               buckets.to_string(), transport.name().into(),
                               d.to_string(),
                               stats.per_iter_ns().to_string(),
                               eng.wire_bytes_per_exchange().to_string(),
                               format!("{serial_ms:.4}"),
                               format!("{overlap_ms:.4}"),
                               format!("{gain:.3}")])?;
                    if tele {
                        let key = format!(
                            "overlap_model/x{ranks}_{}_b{buckets}_{}",
                            dtype.name(), transport.name());
                        treg.gauge(&format!("{key}/modeled_serial_ns"),
                                   (serial_ms * 1e6) as u64);
                        treg.gauge(&format!("{key}/modeled_overlap_ns"),
                                   (overlap_ms * 1e6) as u64);
                    }
                }
            }
        }
    }
    println!("  gate: modeled overlap < modeled serial for every \
              multi-bucket config   OK");
    println!("CSV series: out/perf_collectives_overlap.csv");

    // wire-compression headline (also asserted in bench_memory on the
    // real Transformer-Big inventory)
    let f = comm_wire_bytes(&specs, 4, StateDtype::F32);
    let q = comm_wire_bytes(&specs, 4, StateDtype::Q8);
    println!("\n  q8 wire reduction vs f32: {:.2}x (x4 ranks)",
             f as f64 / q as f64);
    assert!(f as f64 / q as f64 >= 3.5);
    println!("\nCSV series: out/perf_collectives.csv");

    if tele {
        telemetry::with_bench_registry(|r| r.merge(&treg));
        write_bench_json("bench_collectives", quick,
                         "out/BENCH_comms.json")?;
        println!("telemetry document: out/BENCH_comms.json");
        // drain the trace rings (bench-main lane + every engine's
        // comm-hop worker lane) into a Chrome-trace document; it must
        // pass the in-repo validator before it is worth committing to
        // an artifact
        let mut tl = telemetry::Timeline::default();
        tl.drain();
        let doc = tl.to_chrome_json();
        telemetry::validate_trace_doc(&doc)
            .map_err(|e| anyhow::anyhow!("exported trace invalid: {e}"))?;
        std::fs::write("out/trace_comms.json", format!("{doc}\n"))?;
        println!("trace timeline: out/trace_comms.json ({} events, {} \
                  dropped)", tl.records.len(), tl.dropped);
    }
    Ok(())
}
