//! E-comm — the compressed-collectives bench (EXPERIMENTS.md
//! §Compressed-collectives): ring all-reduce throughput and simulated
//! pod cost over ranks × wire dtype × comm threads, with the
//! subsystem's bitwise determinism gates executed before any timing.
//!
//! Gates (always run, including under `BENCH_QUICK=1` in CI):
//!   * the f32 engine reproduces the legacy `collectives::allreduce_mean`
//!     reference bit for bit (so the new path cannot silently change
//!     pre-comms trajectories),
//!   * serial == 2 == 4 comm threads, bitwise, at every wire dtype —
//!     outputs AND carried error-feedback residuals,
//!   * all ranks leave an exchange with identical buffers (pod sync).
//!
//! Run: `cargo bench --bench bench_collectives` (writes
//! out/perf_collectives.csv); `BENCH_QUICK=1` or `make bench-comms-quick`
//! for the CI-sized variant. Pass `-- --telemetry` (or `SM3_TELEMETRY=1`)
//! to emit out/BENCH_comms.json: per-hop span stats, wire-byte counters
//! cross-checked against the static accountant, and the measured-vs-
//! modeled `TimingModel` delta per configuration (DESIGN.md §14).

use sm3::bench_util::{bench, speedup, telemetry_requested,
                      write_bench_json, CsvWriter, Stats};
use sm3::collectives;
use sm3::comms::{CommEngine, TimingModel};
use sm3::memory::comm_wire_bytes;
use sm3::optim::{ParamSpec, StateDtype};
use sm3::rng::Rng;
use sm3::telemetry::{self, Counter, Probe, Registry};
use sm3::tensor::Tensor;
use std::time::Duration;

/// A transformer-block-shaped gradient set (~2.1M elements; quick ~37k).
fn block_specs(quick: bool) -> Vec<ParamSpec> {
    let (v, d, ff) = if quick { (256, 64, 256) } else { (2048, 256, 1024) };
    vec![
        ParamSpec::new("embed", &[v, d]),
        ParamSpec::new("wq", &[d, d]),
        ParamSpec::new("wk", &[d, d]),
        ParamSpec::new("wv", &[d, d]),
        ParamSpec::new("wo", &[d, d]),
        ParamSpec::new("ffn_w1", &[d, ff]),
        ParamSpec::new("ffn_w2", &[ff, d]),
        ParamSpec::new("b1", &[ff]),
        ParamSpec::new("b2", &[d]),
    ]
}

fn rank_grads(specs: &[ParamSpec], ranks: usize, seed: u64)
              -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed);
    (0..ranks)
        .map(|_| {
            specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect()
        })
        .collect()
}

fn assert_bitwise(a: &[Vec<Tensor>], b: &[Vec<Tensor>], what: &str) {
    for (ra, rb) in a.iter().zip(b) {
        for (ta, tb) in ra.iter().zip(rb) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} != {y}");
            }
        }
    }
}

/// The bitwise determinism gates — the point of running this bench in
/// CI quick mode at all.
fn run_gates(specs: &[ParamSpec]) -> anyhow::Result<()> {
    println!("=== determinism gates (bitwise) ===");
    // 1. f32 path == legacy collectives reference
    for ranks in [2usize, 4] {
        let mut legacy = rank_grads(specs, ranks, 42);
        let mut new = legacy.clone();
        collectives::allreduce_mean(&mut legacy)?;
        CommEngine::new(specs, ranks, StateDtype::F32, 64, 1)?
            .allreduce_mean(&mut new)?;
        assert_bitwise(&legacy, &new, &format!("f32 vs legacy x{ranks}"));
    }
    println!("  f32 == legacy collectives          OK (x2, x4)");
    // 2. serial == 2 == 4 comm threads at every dtype, incl residuals
    for dtype in StateDtype::ALL {
        let ranks = 4;
        let base = rank_grads(specs, ranks, 7);
        let mut ref_eng = CommEngine::new(specs, ranks, dtype, 64, 1)?;
        let mut ref_out = base.clone();
        ref_eng.allreduce_mean(&mut ref_out)?;
        for threads in [2usize, 4] {
            let mut eng = CommEngine::new(specs, ranks, dtype, 64, threads)?;
            let mut out = base.clone();
            eng.allreduce_mean(&mut out)?;
            assert_bitwise(&ref_out, &out,
                           &format!("{} x{threads}", dtype.name()));
            for ((_, a), (_, b)) in ref_eng.state().iter().zip(&eng.state())
            {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{} x{threads} residual", dtype.name());
                }
            }
        }
        // 3. all ranks agree after the exchange
        for r in 1..ranks {
            for (a, b) in ref_out[0].iter().zip(&ref_out[r]) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{} rank {r} diverged", dtype.name());
                }
            }
        }
    }
    println!("  serial == 2 == 4 threads           OK (f32, bf16, q8)");
    println!("  rank agreement after exchange      OK");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1")
        .unwrap_or(false);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let tele = telemetry_requested(&argv);
    let _tele_guard = tele.then(telemetry::enable);
    if tele {
        println!("telemetry on — writing out/BENCH_comms.json at exit");
    }
    let budget = if quick {
        Duration::from_millis(25)
    } else {
        Duration::from_millis(300)
    };
    let min_iters = if quick { 2 } else { 5 };
    if quick {
        println!("BENCH_QUICK=1 — small gradient set, short budgets; \
                  bitwise gates run in full");
    }
    let specs = block_specs(quick);
    let d: usize = specs.iter().map(ParamSpec::numel).sum();

    run_gates(&specs)?;

    println!("\n=== ring all-reduce ({:.2}M floats) — ranks × dtype × \
              threads ===", d as f64 / 1e6);
    let timing = TimingModel::default();
    let mut csv = CsvWriter::create(
        "out/perf_collectives.csv",
        "ranks,dtype,threads,elements,median_ns,wire_bytes,sim_ms,\
         speedup_vs_serial")?;
    let rank_list: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    // measured-vs-modeled TimingModel entries, merged into the bench
    // registry (and so into BENCH_comms.json) at the end
    let mut treg = Registry::new();
    for &ranks in rank_list {
        for dtype in StateDtype::ALL {
            let mut serial_stats: Option<Stats> = None;
            for threads in [1usize, 2, 4] {
                let mut eng =
                    CommEngine::new(&specs, ranks, dtype, 16 * 1024,
                                    threads)?;
                // reuse one gradient set across iterations: the exchange
                // rewrites it with means, which keeps the work identical
                // without per-iteration clone noise
                let mut g = rank_grads(&specs, ranks, 3);
                let before = tele.then(telemetry::thread_totals);
                let stats = bench(
                    &format!("x{ranks} {} t{threads}", dtype.name()),
                    budget, min_iters,
                    || {
                        eng.allreduce_mean(&mut g).unwrap();
                    });
                let wire = eng.wire_bytes_per_exchange();
                // hard assert: benches run in release, where a
                // debug_assert would make this cross-check dead code
                assert_eq!(wire, comm_wire_bytes(&specs, ranks, dtype),
                           "live schedule vs static mirror drifted");
                let sim_ms = timing.exchange_seconds(wire, ranks) * 1e3;
                if let Some(before) = before {
                    // measured per-hop latencies (the calibration source
                    // for TimingModel) vs the model's simulated exchange:
                    // reported, not asserted — the model prices pod links,
                    // the measurement prices in-process memory traffic
                    let after = telemetry::thread_totals();
                    let exch = after.counter(Counter::CommExchanges)
                        .saturating_sub(
                            before.counter(Counter::CommExchanges));
                    let wired = after.counter(Counter::CommWireBytes)
                        .saturating_sub(
                            before.counter(Counter::CommWireBytes));
                    assert_eq!(wired, wire as u64 * exch,
                               "wire-byte counter drifted from the \
                                schedule's per-exchange bytes");
                    if exch > 0 {
                        let hop_ms = after.ms_since(
                            &before,
                            &[Probe::CommHopReduce, Probe::CommHopEncode,
                              Probe::CommHopGather]) / exch as f64;
                        let delta_pct =
                            100.0 * (hop_ms - sim_ms) / sim_ms;
                        println!("    hops measured {hop_ms:.4} ms vs \
                                  modeled {sim_ms:.4} ms \
                                  ({delta_pct:+.0}%)");
                        let key = format!("timing_model/x{ranks}_{}_t\
                                           {threads}", dtype.name());
                        treg.gauge(&format!("{key}/measured_hop_ns"),
                                   (hop_ms * 1e6) as u64);
                        treg.gauge(&format!("{key}/modeled_ns"),
                                   (sim_ms * 1e6) as u64);
                    }
                }
                let vs_serial = serial_stats
                    .as_ref()
                    .map(|s| speedup(s, &stats))
                    .unwrap_or(1.0);
                println!("  {stats}  wire {:>8.2} MB  sim {:>7.4} ms  \
                          {vs_serial:>5.2}x",
                         wire as f64 / 1e6, sim_ms);
                csv.row(&[ranks.to_string(), dtype.name().into(),
                          threads.to_string(), d.to_string(),
                          stats.per_iter_ns().to_string(),
                          wire.to_string(), format!("{sim_ms:.4}"),
                          format!("{vs_serial:.3}")])?;
                if threads == 1 {
                    serial_stats = Some(stats);
                }
            }
        }
    }

    // wire-compression headline (also asserted in bench_memory on the
    // real Transformer-Big inventory)
    let f = comm_wire_bytes(&specs, 4, StateDtype::F32);
    let q = comm_wire_bytes(&specs, 4, StateDtype::Q8);
    println!("\n  q8 wire reduction vs f32: {:.2}x (x4 ranks)",
             f as f64 / q as f64);
    assert!(f as f64 / q as f64 >= 3.5);
    println!("\nCSV series: out/perf_collectives.csv");

    if tele {
        telemetry::with_bench_registry(|r| r.merge(&treg));
        write_bench_json("bench_collectives", quick,
                         "out/BENCH_comms.json")?;
        println!("telemetry document: out/BENCH_comms.json");
    }
    Ok(())
}
