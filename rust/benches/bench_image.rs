//! E6 — regenerates paper Fig. 4: top-1/top-5 test accuracy of SM3 vs
//! SGD+momentum on the image-classification workload (AmoebaNet-D /
//! ImageNet analogue).
//!
//! Shape target: SM3 converges at least as well as a tuned SGD+momentum
//! with its staircase schedule.
//!
//! Run: `cargo bench --bench bench_image` (writes out/fig4_curves.csv)

use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::metrics::RunLogger;
use sm3::runtime::Runtime;
use std::sync::Arc;

const STEPS: u64 = 120;

fn cfg(opt: &str, lr: f64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = "img_small".into();
    c.optim.name = opt.into();
    c.optim.lr = lr;
    c.optim.schedule = "paper".into(); // staircase for sgdm, constant for sm3
    c.optim.warmup_steps = STEPS / 10;
    c.steps = STEPS;
    c.eval_every = STEPS / 10;
    c.exec = ExecMode::Split;
    c
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);
    println!("=== Fig. 4 — image classification: SM3 vs SGD+momentum ===");
    let mut log = RunLogger::new(Some("out/fig4_curves.csv"),
                                 "optimizer,step,eval_loss,top1,top5", false)?;
    let mut last = Vec::new();
    for (opt, lr) in [("sm3", 0.1), ("sgdm", 0.02)] {
        let mut t = Trainer::with_runtime(cfg(opt, lr), rt.clone())?;
        let hist = t.train()?;
        for e in &hist.evals {
            log.row(&[opt.into(), e.step.to_string(),
                      format!("{:.5}", e.loss),
                      format!("{:.4}", e.metric.unwrap_or(0.0)),
                      format!("{:.4}", e.metric2.unwrap_or(0.0))])?;
        }
        let e = hist.final_eval().unwrap();
        println!("  {opt:<6} final top-1 {:.1}%  top-5 {:.1}%",
                 e.metric.unwrap_or(0.0) * 100.0,
                 e.metric2.unwrap_or(0.0) * 100.0);
        last.push((opt, e.metric.unwrap_or(0.0)));
    }
    log.flush()?;
    let sm3 = last.iter().find(|l| l.0 == "sm3").unwrap().1;
    let sgd = last.iter().find(|l| l.0 == "sgdm").unwrap().1;
    println!("\n  shape: SM3 ≥ SGD+m − ε (paper: improved convergence): \
              {:.3} vs {:.3} {}",
             sm3, sgd, if sm3 >= sgd - 0.05 { "✓" } else { "✗" });
    println!("\nCSV series: out/fig4_curves.csv");
    Ok(())
}
