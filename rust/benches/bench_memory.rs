//! E5 — regenerates paper Tables 1 & 2 memory columns from the *real*
//! model inventories (Transformer-Big 375.4M, BERT-Large 340M params)
//! plus the max-batch frontier the paper's batch-doubling relies on —
//! and, past the paper, the bf16/q8 quantized-state columns
//! (`optim::qstate`) with their recomputed frontier.
//!
//! Run: `cargo bench --bench bench_memory` (writes out/table1_memory.csv,
//! out/table2_memory.csv, out/max_batch.csv, out/qstate_memory.csv,
//! out/pool_occupancy.csv). Pass `-- --telemetry` (or `SM3_TELEMETRY=1`)
//! to emit out/BENCH_memory.json: the table's state/wire byte figures
//! plus the live pool-occupancy gauges, one standing document per run
//! (DESIGN.md §14). Quick runs (`BENCH_QUICK=1`) ALWAYS export the
//! document — CI uploads it and gates `mem/pool_bytes_peak` against the
//! committed baseline (`ci/BENCH_memory_baseline.json`).

use sm3::bench_util::{telemetry_requested, write_bench_json};
use sm3::comms::{CommEngine, CommOpts, TimingModel};
use sm3::pool::{Pool, Tag};
use sm3::rng::Rng;
use sm3::tensor::Tensor;
use sm3::memory::{comm_buffer_bytes, comm_wire_bytes, inventory,
                  opt_state_bytes, opt_state_floats, MemoryModel,
                  SlotLayout, GIB};
use sm3::metrics::RunLogger;
use sm3::optim::{ParamSpec, StateDtype};

fn report(name: &str, m: &MemoryModel, cells: &[(&str, usize, Option<f64>)],
          csv: &str) -> anyhow::Result<()> {
    println!("=== {name} ===");
    println!("  {:<11} {:>7} {:>11} {:>10} {:>6}",
             "optimizer", "batch", "pred (GiB)", "paper", "fits");
    let mut log = RunLogger::new(Some(csv),
        "optimizer,batch_per_core,predicted_gib,paper_gib,fits", false)?;
    for &(opt, b, paper) in cells {
        let gib = m.gib_per_core(opt, b)?;
        let fits = m.fits(opt, b)?;
        let paper_s = paper.map(|p| format!("{p:.2}"))
            .unwrap_or_else(|| "OOM".into());
        println!("  {opt:<11} {b:>7} {gib:>11.2} {paper_s:>10} {:>6}",
                 if fits { "yes" } else { "OOM" });
        if let Some(p) = paper {
            // the f32 columns are the paper's cells — the qstate subsystem
            // must leave them untouched (acceptance criterion)
            let err = (gib - p).abs() / p;
            assert!(err < 0.06, "{opt}@{b}: predicted {gib:.2} vs paper {p}");
        }
        log.row(&[opt.into(), b.to_string(), format!("{gib:.3}"),
                  paper_s, fits.to_string()])?;
    }
    log.flush()?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1")
        .unwrap_or(false);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // quick runs always export the telemetry document: CI uploads
    // BENCH_memory.json and gates its peak pool bytes (ISSUE 9)
    let tele = telemetry_requested(&argv) || quick;

    // ---- Table 1: Transformer-Big on TPUv2 (8 GiB/core) ----------------
    let big = MemoryModel::calibrate(
        inventory::transformer_big(), 8.0 * GIB,
        ("adam", 12, 6.88 * GIB), ("sm3", 24, 7.02 * GIB))?;
    report(
        "Table 1 — Transformer-Big (WMT'14 en→fr) memory per core",
        &big,
        &[
            ("adam", 12, Some(6.88)),      // calibration cell
            ("adagrad", 12, Some(6.85)),   // predicted
            ("adafactor", 12, Some(5.43)), // predicted
            ("sm3", 12, Some(5.36)),       // predicted
            ("adafactor", 24, Some(7.04)), // predicted
            ("sm3", 24, Some(7.02)),       // calibration cell
            ("adam", 24, None),            // paper: infeasible
            ("adagrad", 24, None),         // paper: infeasible
        ],
        "out/table1_memory.csv",
    )?;

    // ---- Table 2: BERT-Large -------------------------------------------
    let bert = MemoryModel::calibrate(
        inventory::bert_large(), 8.0 * GIB,
        ("adam", 8, 6.15 * GIB), ("sm3", 16, 6.02 * GIB))?;
    report(
        "\nTable 2 — BERT-Large memory per core",
        &bert,
        &[
            ("adam", 8, Some(6.15)), // calibration cell
            ("sm3", 8, Some(4.90)),  // predicted
            ("sm3", 16, Some(6.02)), // calibration cell
            ("adam", 16, None),      // paper: infeasible at 2x batch
        ],
        "out/table2_memory.csv",
    )?;

    // ---- max-batch frontier (the doubling headroom) ---------------------
    println!("\n=== max batch/core frontier (8 GiB TPUv2) ===");
    let mut log = RunLogger::new(Some("out/max_batch.csv"),
                                 "model,optimizer,max_batch_per_core", false)?;
    for (model, m) in [("transformer_big", &big), ("bert_large", &bert)] {
        for opt in ["adam", "adagrad", "adafactor", "sm3"] {
            let mb = m.max_batch(opt)?;
            println!("  {model:<16} {opt:<10} {mb:>4}");
            log.row(&[model.into(), opt.into(), mb.to_string()])?;
        }
    }
    log.flush()?;

    // ---- quantized-state columns (past the paper) ------------------------
    // Optimizer-state bytes per dtype and the frontier they buy. The q8
    // acceptance line: ≥ 3.5× second-moment reduction on Transformer-Big.
    println!("\n=== quantized optimizer state (optim::qstate) ===");
    println!("  {:<16} {:<11} {:>10} {:>10} {:>10} {:>7} {:>7} {:>7}",
             "model", "optimizer", "f32 GiB", "bf16 GiB", "q8 GiB",
             "mb@f32", "mb@bf16", "mb@q8");
    let mut qlog = RunLogger::new(
        Some("out/qstate_memory.csv"),
        "model,optimizer,dtype,state_gib,second_moment_gib,max_batch_per_core",
        false)?;
    for (model, m) in [("transformer_big", &big), ("bert_large", &bert)] {
        for opt in ["adam", "adagrad", "adafactor", "sm3", "sgdm"] {
            let mut state_gib = Vec::new();
            let mut frontier = Vec::new();
            for dtype in StateDtype::ALL {
                let layout = SlotLayout::for_optimizer(opt, &m.specs)?;
                let bytes = opt_state_bytes(opt, &m.specs, dtype)?;
                state_gib.push(bytes as f64 / GIB);
                let mb = m.max_batch_dtype(opt, dtype)?;
                frontier.push(mb);
                qlog.row(&[model.into(), opt.into(), dtype.name().into(),
                           format!("{:.4}", bytes as f64 / GIB),
                           format!("{:.4}",
                                   layout.second_moment_bytes(dtype) as f64
                                       / GIB),
                           mb.to_string()])?;
            }
            println!("  {model:<16} {opt:<11} {:>10.3} {:>10.3} {:>10.3} \
                      {:>7} {:>7} {:>7}",
                     state_gib[0], state_gib[1], state_gib[2],
                     frontier[0], frontier[1], frontier[2]);
        }
    }
    qlog.flush()?;
    // acceptance: q8 second-moment bytes ≥ 3.5× smaller on Transformer-Big
    for opt in ["adam", "adagrad", "adafactor", "sm3"] {
        let layout = SlotLayout::for_optimizer(opt, &big.specs)?;
        let red = layout.second_moment_bytes(StateDtype::F32) as f64
            / layout.second_moment_bytes(StateDtype::Q8) as f64;
        println!("  {opt:<11} second-moment q8 reduction: {red:.2}x");
        assert!(red >= 3.5, "{opt}: q8 second-moment reduction {red:.2}x");
    }
    // and the frontier strictly widens for the 2d-state optimizers
    for opt in ["adam", "adagrad"] {
        let f = big.max_batch_dtype(opt, StateDtype::F32)?;
        let q = big.max_batch_dtype(opt, StateDtype::Q8)?;
        assert!(q > f, "{opt}: q8 frontier {q} must exceed f32 {f}");
    }

    // ---- compressed-collectives wire accounting (ISSUE 5 tentpole) ------
    // Bytes one ring all-reduce moves over pod links per optimizer step,
    // by wire dtype, plus the persistent comm buffers (staging + error-
    // feedback residuals) and the TimingModel's simulated exchange cost.
    println!("\n=== gradient-exchange wire bytes (comms, ring all-reduce) \
              ===");
    println!("  {:<16} {:>5} {:<6} {:>12} {:>12} {:>9} {:>9}",
             "model", "ranks", "dtype", "wire MB/step", "buffers MB",
             "sim ms", "vs f32");
    let timing = TimingModel::default();
    let mut clog = RunLogger::new(
        Some("out/comm_wire.csv"),
        "model,ranks,dtype,wire_bytes_per_step,buffer_bytes,sim_ms", false)?;
    for (model, m) in [("transformer_big", &big), ("bert_large", &bert)] {
        for ranks in [4usize, 16] {
            let f32_wire =
                comm_wire_bytes(&m.specs, ranks, StateDtype::F32);
            for dtype in StateDtype::ALL {
                let wire = comm_wire_bytes(&m.specs, ranks, dtype);
                let bufs = comm_buffer_bytes(&m.specs, ranks, dtype);
                let ms = timing.exchange_seconds(wire, ranks) * 1e3;
                println!("  {model:<16} {ranks:>5} {:<6} {:>12.1} \
                          {:>12.1} {:>9.3} {:>8.2}x",
                         dtype.name(), wire as f64 / 1e6,
                         bufs as f64 / 1e6, ms,
                         f32_wire as f64 / wire as f64);
                clog.row(&[model.into(), ranks.to_string(),
                           dtype.name().into(), wire.to_string(),
                           bufs.to_string(), format!("{ms:.4}")])?;
            }
        }
    }
    clog.flush()?;
    // acceptance: q8 wire payloads cut all-reduce bytes ≥ 3.5× (≈ 3.7×)
    // below f32 on Transformer-Big, at pod-scale rank counts
    for ranks in [4usize, 16] {
        let f = comm_wire_bytes(&big.specs, ranks, StateDtype::F32);
        let q = comm_wire_bytes(&big.specs, ranks, StateDtype::Q8);
        let red = f as f64 / q as f64;
        println!("  transformer_big x{ranks} q8 wire reduction: {red:.2}x");
        assert!(red >= 3.5, "x{ranks}: q8 wire reduction {red:.2}x");
    }

    // ---- step-path transient buffers (ISSUE 3 tentpole accounting) ------
    // The PR 2 store dequantized EVERY slot of a leaf into full-length
    // f32 buffers each step: the transient working set scaled with the
    // largest leaf (Θ(leaf) — 2×33.5M floats for Adam's Transformer-Big
    // embedding). The tiled kernels bound it by the streaming tile for
    // element-wise leaves (and by the leaf only where reductions force
    // it: SM3 matrix/tensor covers, Adafactor). f32 tiles lend storage
    // outright — their scratch is zero; the figure below is the bf16/q8
    // decode-scratch bound.
    println!("\n=== step-path transient buffers (whole-slot vs tiled, \
              tile {} elems) ===", sm3::optim::kernel::DEFAULT_CHUNK);
    println!("  {:<16} {:<11} {:>16} {:>16} {:>9}",
             "model", "optimizer", "whole-slot peak", "tiled bound",
             "shrink");
    let chunk = sm3::optim::kernel::DEFAULT_CHUNK;
    let mut tlog = RunLogger::new(
        Some("out/step_buffers.csv"),
        "model,optimizer,whole_slot_peak_bytes,tiled_bound_bytes", false)?;
    for (model, m) in [("transformer_big", &big), ("bert_large", &bert)] {
        for opt in ["adam", "adagrad", "adafactor", "sm3", "sgdm"] {
            let mut whole_peak = 0usize;
            let mut tiled_peak = 0usize;
            for s in &m.specs {
                let leaf = SlotLayout::for_optimizer(
                    opt, std::slice::from_ref(s))?.total_floats() * 4;
                whole_peak = whole_peak.max(leaf);
                let tiled = if sm3::optim::kernel::elementwise(
                    opt, s.shape.len())
                {
                    2 * chunk * 4
                } else {
                    leaf
                };
                tiled_peak = tiled_peak.max(tiled);
            }
            println!("  {model:<16} {opt:<11} {:>13.2} MB {:>13.2} MB \
                      {:>8.0}x",
                     whole_peak as f64 / 1e6, tiled_peak as f64 / 1e6,
                     whole_peak as f64 / tiled_peak as f64);
            tlog.row(&[model.into(), opt.into(), whole_peak.to_string(),
                       tiled_peak.to_string()])?;
        }
    }
    tlog.flush()?;
    // the memcpy the PR 2 store comment deferred: for the element-wise
    // optimizers the transient working set must collapse from Θ(leaf) to
    // Θ(tile) — orders of magnitude on a real inventory
    for opt in ["adam", "adagrad", "sgdm"] {
        let embed_peak = big
            .specs
            .iter()
            .map(|s| SlotLayout::for_optimizer(opt, std::slice::from_ref(s))
                .map(|l| l.total_floats() * 4))
            .collect::<anyhow::Result<Vec<_>>>()?
            .into_iter()
            .max()
            .unwrap();
        let tiled = 2 * chunk * 4;
        assert!(embed_peak >= 50 * tiled,
                "{opt}: whole-slot peak {embed_peak} B not ≫ tiled \
                 {tiled} B — inventory shrank?");
    }

    // ---- state breakdown (the quantity the paper's abstract claims) -----
    println!("\n=== optimizer-state floats (exact arithmetic) ===");
    for (model, specs) in [
        ("transformer_big", inventory::transformer_big()),
        ("transformer_base", inventory::transformer_base()),
        ("bert_large", inventory::bert_large()),
        ("amoebanet_like", inventory::amoebanet_like()),
    ] {
        let d: usize = specs.iter().map(ParamSpec::numel).sum();
        print!("  {model:<16} d={:>7.1}M |", d as f64 / 1e6);
        for opt in ["adam", "adagrad", "adafactor", "sm3", "sgdm"] {
            let s = opt_state_floats(opt, &specs)?;
            print!(" {opt} {:>7.1}M", s as f64 / 1e6);
        }
        // SM3's second-moment share
        let sm3 = opt_state_floats("sm3", &specs)?;
        println!("  (sm3 2nd-moment: {:.2}M = {:.2}% of d)",
                 (sm3 - d) as f64 / 1e6,
                 100.0 * (sm3 - d) as f64 / d as f64);
    }
    // ---- live pool occupancy (ISSUE 9: the runtime the tables audit) ----
    // Everything above is static arithmetic; this section RUNS the pool:
    // a pooled optimizer + comm engine on a small fixed inventory, two
    // steps, then the per-tag ledger — the live counterpart of the
    // accountant columns (equality is enforced in `memory::tests`; here
    // the figures are exported so CI can gate the peak).
    println!("\n=== live memory-pool occupancy (per-tag ledger, small \
              inventory) ===");
    let pspecs = vec![
        ParamSpec::new("emb", &[512, 64]),
        ParamSpec::new("w", &[64, 64]),
        ParamSpec::new("b", &[65]),
    ];
    let mut plog = RunLogger::new(
        Some("out/pool_occupancy.csv"),
        "scenario,optimizer,state_dtype,comm_dtype,ranks,tag,\
         bytes_in_use,peak_bytes",
        false)?;
    let mut pools: Vec<Pool> = Vec::new();
    for (opt_name, sdtype) in [("sm3", StateDtype::F32),
                               ("sm3", StateDtype::Q8),
                               ("adam", StateDtype::Q8)] {
        for (cdtype, ranks) in [(StateDtype::F32, 1usize),
                                (StateDtype::Q8, 4)] {
            let pool = Pool::new();
            let mut opt = sm3::optim::OptimSpec::named(opt_name)?
                .state_dtype(sdtype)
                .threads(2)
                .pool(&pool)
                .build(&pspecs)?;
            let mut comms = if ranks > 1 {
                Some(CommEngine::with_opts_in(
                    &pspecs, ranks,
                    CommOpts { dtype: cdtype, chunk: 256, threads: 2,
                               ..CommOpts::default() },
                    &pool)?)
            } else {
                None
            };
            let mut rng = Rng::new(7);
            let mut params: Vec<Tensor> = pspecs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            for _ in 0..2 {
                let mut grads: Vec<Vec<Tensor>> = (0..ranks)
                    .map(|_| pspecs.iter()
                        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                        .collect())
                    .collect();
                if let Some(eng) = comms.as_mut() {
                    eng.allreduce_mean(&mut grads)?;
                }
                opt.step(&mut params, &grads[0], 0.1);
            }
            let scenario = format!("{opt_name}_{}_wire_{}_x{ranks}",
                                   sdtype.name(), cdtype.name());
            for tag in Tag::ALL {
                plog.row(&[scenario.clone(), opt_name.into(),
                           sdtype.name().into(), cdtype.name().into(),
                           ranks.to_string(), tag.name().into(),
                           pool.bytes_in_use_tag(tag).to_string(),
                           pool.peak_bytes_tag(tag).to_string()])?;
            }
            println!("  {scenario:<24} in_use {:>9} B  peak {:>9} B  \
                      slab {:>7} B",
                     pool.bytes_in_use(), pool.peak_bytes(),
                     pool.slab_bytes());
            // engines drop here; the pool (kept for gauge export below)
            // retains only its shelves and the run's high-water marks
            pools.push(pool);
        }
    }
    plog.flush()?;

    println!("\nCSV series: out/table1_memory.csv out/table2_memory.csv \
              out/max_batch.csv out/qstate_memory.csv out/comm_wire.csv \
              out/step_buffers.csv out/pool_occupancy.csv");

    // ---- telemetry export: the byte tables as standing gauges -----------
    // This bench is pure accounting arithmetic (no timed sections), so
    // its BENCH_memory.json carries gauges only: state bytes per
    // optimizer×dtype and ring wire bytes per dtype on both inventories.
    if tele {
        let mut reg = sm3::telemetry::Registry::new();
        for (model, m) in [("transformer_big", &big), ("bert_large", &bert)]
        {
            for opt in ["adam", "adagrad", "adafactor", "sm3", "sgdm"] {
                for dtype in StateDtype::ALL {
                    let bytes = opt_state_bytes(opt, &m.specs, dtype)?;
                    reg.gauge(
                        &format!("mem/{model}/{opt}/{}_state_bytes",
                                 dtype.name()),
                        bytes as u64);
                }
            }
            for ranks in [4usize, 16] {
                for dtype in StateDtype::ALL {
                    let wire = comm_wire_bytes(&m.specs, ranks, dtype);
                    reg.gauge(
                        &format!("comm/{model}/x{ranks}/{}_wire_bytes",
                                 dtype.name()),
                        wire as u64);
                }
            }
        }
        // live pool-occupancy gauges: `mem/pool_bytes{,_peak}` and the
        // per-tag set, folded across the scenarios above (a gauge's
        // recorded peak is the max over exports) — the CI regression
        // gate budgets `mem/pool_bytes_peak`
        for pool in &pools {
            pool.export_gauges(&mut reg);
        }
        sm3::telemetry::with_bench_registry(|r| r.merge(&reg));
        write_bench_json("bench_memory", quick, "out/BENCH_memory.json")?;
        println!("telemetry document: out/BENCH_memory.json");
    }
    Ok(())
}
