//! E5 — regenerates paper Tables 1 & 2 memory columns from the *real*
//! model inventories (Transformer-Big 375.4M, BERT-Large 340M params)
//! plus the max-batch frontier the paper's batch-doubling relies on.
//!
//! Run: `cargo bench --bench bench_memory` (writes out/table1_memory.csv,
//! out/table2_memory.csv, out/max_batch.csv)

use sm3::memory::{inventory, opt_state_floats, MemoryModel, GIB};
use sm3::metrics::RunLogger;
use sm3::optim::ParamSpec;

fn report(name: &str, m: &MemoryModel, cells: &[(&str, usize, Option<f64>)],
          csv: &str) -> anyhow::Result<()> {
    println!("=== {name} ===");
    println!("  {:<11} {:>7} {:>11} {:>10} {:>6}",
             "optimizer", "batch", "pred (GiB)", "paper", "fits");
    let mut log = RunLogger::new(Some(csv),
        "optimizer,batch_per_core,predicted_gib,paper_gib,fits", false)?;
    for &(opt, b, paper) in cells {
        let gib = m.gib_per_core(opt, b);
        let fits = m.fits(opt, b);
        let paper_s = paper.map(|p| format!("{p:.2}"))
            .unwrap_or_else(|| "OOM".into());
        println!("  {opt:<11} {b:>7} {gib:>11.2} {paper_s:>10} {:>6}",
                 if fits { "yes" } else { "OOM" });
        if let Some(p) = paper {
            let err = (gib - p).abs() / p;
            assert!(err < 0.06, "{opt}@{b}: predicted {gib:.2} vs paper {p}");
        }
        log.row(&[opt.into(), b.to_string(), format!("{gib:.3}"),
                  paper_s, fits.to_string()])?;
    }
    log.flush()?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // ---- Table 1: Transformer-Big on TPUv2 (8 GiB/core) ----------------
    let big = MemoryModel::calibrate(
        inventory::transformer_big(), 8.0 * GIB,
        ("adam", 12, 6.88 * GIB), ("sm3", 24, 7.02 * GIB));
    report(
        "Table 1 — Transformer-Big (WMT'14 en→fr) memory per core",
        &big,
        &[
            ("adam", 12, Some(6.88)),      // calibration cell
            ("adagrad", 12, Some(6.85)),   // predicted
            ("adafactor", 12, Some(5.43)), // predicted
            ("sm3", 12, Some(5.36)),       // predicted
            ("adafactor", 24, Some(7.04)), // predicted
            ("sm3", 24, Some(7.02)),       // calibration cell
            ("adam", 24, None),            // paper: infeasible
            ("adagrad", 24, None),         // paper: infeasible
        ],
        "out/table1_memory.csv",
    )?;

    // ---- Table 2: BERT-Large -------------------------------------------
    let bert = MemoryModel::calibrate(
        inventory::bert_large(), 8.0 * GIB,
        ("adam", 8, 6.15 * GIB), ("sm3", 16, 6.02 * GIB));
    report(
        "\nTable 2 — BERT-Large memory per core",
        &bert,
        &[
            ("adam", 8, Some(6.15)), // calibration cell
            ("sm3", 8, Some(4.90)),  // predicted
            ("sm3", 16, Some(6.02)), // calibration cell
            ("adam", 16, None),      // paper: infeasible at 2x batch
        ],
        "out/table2_memory.csv",
    )?;

    // ---- max-batch frontier (the doubling headroom) ---------------------
    println!("\n=== max batch/core frontier (8 GiB TPUv2) ===");
    let mut log = RunLogger::new(Some("out/max_batch.csv"),
                                 "model,optimizer,max_batch_per_core", false)?;
    for (model, m) in [("transformer_big", &big), ("bert_large", &bert)] {
        for opt in ["adam", "adagrad", "adafactor", "sm3"] {
            let mb = m.max_batch(opt);
            println!("  {model:<16} {opt:<10} {mb:>4}");
            log.row(&[model.into(), opt.into(), mb.to_string()])?;
        }
    }
    log.flush()?;

    // ---- state breakdown (the quantity the paper's abstract claims) -----
    println!("\n=== optimizer-state floats (exact arithmetic) ===");
    for (model, specs) in [
        ("transformer_big", inventory::transformer_big()),
        ("transformer_base", inventory::transformer_base()),
        ("bert_large", inventory::bert_large()),
        ("amoebanet_like", inventory::amoebanet_like()),
    ] {
        let d: usize = specs.iter().map(ParamSpec::numel).sum();
        print!("  {model:<16} d={:>7.1}M |", d as f64 / 1e6);
        for opt in ["adam", "adagrad", "adafactor", "sm3", "sgdm"] {
            let s = opt_state_floats(opt, &specs);
            print!(" {opt} {:>7.1}M", s as f64 / 1e6);
        }
        // SM3's second-moment share
        let sm3 = opt_state_floats("sm3", &specs);
        println!("  (sm3 2nd-moment: {:.2}M = {:.2}% of d)",
                 (sm3 - d) as f64 / 1e6,
                 100.0 * (sm3 - d) as f64 / d as f64);
    }
    println!("\nCSV series: out/table1_memory.csv out/table2_memory.csv \
              out/max_batch.csv");
    Ok(())
}
