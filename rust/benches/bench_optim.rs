//! §Perf (L3) — optimizer-update throughput: elements/second for every
//! optimizer on transformer-shaped parameters, plus the per-step time
//! comparison the paper reports ("a step of SM3 was faster than Adam's
//! by 3%" — fewer state reads/writes).
//!
//! Also benchmarks the ring all-reduce, the abstract-cover SM3 (the
//! O(Σ|S_r|) path) against the co-dim-1 fast path, the `ParallelStep`
//! sharded update engine against serial stepping (serial-vs-parallel
//! numbers for EXPERIMENTS.md §Perf; bitwise equality is asserted before
//! timing), and the quantized-state store (`optim::qstate`): measured
//! state bytes and update throughput per dtype.
//!
//! Run: `cargo bench --bench bench_optim` (writes out/perf_optim.csv,
//! out/perf_optim_parallel.csv, out/perf_optim_qstate.csv)

use sm3::bench_util::{bench, speedup, CsvWriter};
use sm3::collectives::ring_allreduce;
use sm3::memory::opt_state_bytes;
use sm3::optim::{self, cover::{Cover, CoverSm3II}, Optimizer, ParamSpec,
                 ParallelStep, StateDtype};
use sm3::rng::Rng;
use sm3::tensor::Tensor;
use std::time::Duration;

/// A transformer-block-shaped parameter set (~2.1M params).
fn block_specs() -> Vec<ParamSpec> {
    vec![
        ParamSpec::new("embed", &[2048, 256]),
        ParamSpec::new("wq", &[256, 256]),
        ParamSpec::new("wk", &[256, 256]),
        ParamSpec::new("wv", &[256, 256]),
        ParamSpec::new("wo", &[256, 256]),
        ParamSpec::new("ffn_w1", &[256, 1024]),
        ParamSpec::new("ffn_w2", &[1024, 256]),
        ParamSpec::new("b1", &[1024]),
        ParamSpec::new("b2", &[256]),
    ]
}

/// A transformer-scale parameter set (~17M params, 42 leaves) — big enough
/// that the host-side update loop dominates and sharding pays off.
fn transformer_specs(layers: usize) -> Vec<ParamSpec> {
    let (v, d, ff) = (8192usize, 512usize, 2048usize);
    let mut specs = vec![
        ParamSpec::new("embed", &[v, d]),
        ParamSpec::new("pos", &[1024, d]),
    ];
    for l in 0..layers {
        for w in ["wq", "wk", "wv", "wo"] {
            specs.push(ParamSpec::new(format!("l{l}/{w}"), &[d, d]));
        }
        specs.push(ParamSpec::new(format!("l{l}/ffn_w1"), &[d, ff]));
        specs.push(ParamSpec::new(format!("l{l}/ffn_b1"), &[ff]));
        specs.push(ParamSpec::new(format!("l{l}/ffn_w2"), &[ff, d]));
        specs.push(ParamSpec::new(format!("l{l}/ffn_b2"), &[d]));
        specs.push(ParamSpec::new(format!("l{l}/ln_scale"), &[d]));
        specs.push(ParamSpec::new(format!("l{l}/ln_bias"), &[d]));
    }
    specs
}

/// Assert the parallel engine's output is bitwise identical to serial over
/// a few steps (pre-flight gate for the timing runs below), at any state
/// storage precision.
fn assert_bitwise_equal_dtype(name: &str, specs: &[ParamSpec],
                              grads: &[Tensor], threads: usize,
                              dtype: StateDtype) -> anyhow::Result<()> {
    let mut serial = optim::build_with_dtype(name, specs, 0.9, 0.98, dtype)?;
    let mut par = ParallelStep::from_registry_dtype(name, specs, 0.9, 0.98,
                                                    threads, dtype)?;
    let mut pa: Vec<Tensor> =
        specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let mut pb = pa.clone();
    for step in 0..3 {
        serial.step(&mut pa, grads, 0.01);
        par.step(&mut pb, grads, 0.01);
        for (leaf, (a, b)) in pa.iter().zip(&pb).enumerate() {
            for (x, y) in a.data().iter().zip(b.data()) {
                anyhow::ensure!(
                    x.to_bits() == y.to_bits(),
                    "{name} x{threads} @ {dtype:?} diverged at step {step} \
                     leaf {leaf}: {x} vs {y}");
            }
        }
    }
    Ok(())
}

fn assert_bitwise_equal(name: &str, specs: &[ParamSpec], grads: &[Tensor],
                        threads: usize) -> anyhow::Result<()> {
    assert_bitwise_equal_dtype(name, specs, grads, threads, StateDtype::F32)
}

fn main() -> anyhow::Result<()> {
    let specs = block_specs();
    let d: usize = specs.iter().map(ParamSpec::numel).sum();
    println!("=== optimizer step throughput ({:.2}M params) ===",
             d as f64 / 1e6);
    let mut rng = Rng::new(0);
    let grads: Vec<Tensor> = specs
        .iter()
        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
        .collect();
    let budget = Duration::from_millis(400);

    let mut csv = CsvWriter::create("out/perf_optim.csv",
                                    "optimizer,median_ns,elements_per_sec")?;
    let mut per_opt = Vec::new();
    for name in optim::ALL {
        let mut opt = optim::build(name, &specs, 0.9, 0.98)?;
        let mut params: Vec<Tensor> =
            specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let stats = bench(&format!("{name} step"), budget, 10, || {
            opt.step(&mut params, &grads, 0.01);
        });
        let eps = stats.throughput(d);
        println!("  {stats}   {:.1}M elem/s", eps / 1e6);
        csv.row(&[name.to_string(), format!("{:.0}", stats.per_iter_ns()),
                  format!("{eps:.0}")])?;
        per_opt.push((name.to_string(), stats.median));
    }
    // the paper's per-step claim: SM3 not slower than Adam
    let sm3 = per_opt.iter().find(|p| p.0 == "sm3").unwrap().1;
    let adam = per_opt.iter().find(|p| p.0 == "adam").unwrap().1;
    println!("\n  sm3 step / adam step = {:.2} (paper: ≤ ~1.0, SM3 touches \
              less state)", sm3.as_secs_f64() / adam.as_secs_f64());

    // ---- abstract cover vs fast path ------------------------------------
    println!("\n=== abstract-cover SM3 (O(Σ|S_r|)) vs co-dim-1 fast path ===");
    let (m, n) = (512, 512);
    let mut fast = optim::Sm3::new(&[ParamSpec::new("w", &[m, n])],
                                   optim::Sm3Variant::II, 0.0);
    let mut pf = vec![Tensor::zeros(&[m, n])];
    let g = Tensor::randn(&[m, n], 1.0, &mut rng);
    let s1 = bench("fast path 512x512", budget, 10, || {
        fast.step(&mut pf, std::slice::from_ref(&g), 0.01);
    });
    println!("  {s1}");
    let mut abs = CoverSm3II::new(Cover::rows_cols(m, n));
    let mut wa = Tensor::zeros(&[m * n]);
    let ga = g.clone().reshape(&[m * n]);
    let s2 = bench("abstract cover 512x512", budget, 10, || {
        abs.step(&mut wa, &ga, 0.01);
    });
    println!("  {s2}");
    println!("  speedup of the specialized path: {:.1}x",
             s2.median.as_secs_f64() / s1.median.as_secs_f64());

    // ---- ParallelStep: serial vs sharded optimizer stepping --------------
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let big_specs = transformer_specs(4);
    let dbig: usize = big_specs.iter().map(ParamSpec::numel).sum();
    println!("\n=== ParallelStep — sharded update, transformer-scale set \
              ({:.1}M params, {} leaves, {} host cores) ===",
             dbig as f64 / 1e6, big_specs.len(), cores);
    let grads_big: Vec<Tensor> = big_specs
        .iter()
        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
        .collect();
    let mut pcsv = CsvWriter::create(
        "out/perf_optim_parallel.csv",
        "optimizer,threads,median_ns,elements_per_sec,speedup_vs_serial")?;
    let mut sm3_x4_speedup = None;
    for name in ["sm3", "adam"] {
        for threads in [2usize, 4, 8] {
            assert_bitwise_equal(name, &big_specs, &grads_big, threads)?;
        }
        let mut serial = optim::build(name, &big_specs, 0.9, 0.98)?;
        let mut params: Vec<Tensor> =
            big_specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let base = bench(&format!("{name} serial"), budget, 10, || {
            serial.step(&mut params, &grads_big, 0.01);
        });
        println!("  {base}   {:.1}M elem/s", base.throughput(dbig) / 1e6);
        pcsv.row(&[name.to_string(), "1".into(),
                   format!("{:.0}", base.per_iter_ns()),
                   format!("{:.0}", base.throughput(dbig)), "1.00".into()])?;
        for threads in [2usize, 4, 8] {
            let mut par = ParallelStep::from_registry(
                name, &big_specs, 0.9, 0.98, threads)?;
            let mut params: Vec<Tensor> =
                big_specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let stats = bench(&format!("{name} x{threads} threads"), budget,
                              10, || {
                par.step(&mut params, &grads_big, 0.01);
            });
            let sp = speedup(&base, &stats);
            println!("  {stats}   {:.1}M elem/s  ({sp:.2}x vs serial)",
                     stats.throughput(dbig) / 1e6);
            pcsv.row(&[name.to_string(), threads.to_string(),
                       format!("{:.0}", stats.per_iter_ns()),
                       format!("{:.0}", stats.throughput(dbig)),
                       format!("{sp:.3}")])?;
            if name == "sm3" && threads == 4 {
                sm3_x4_speedup = Some(sp);
            }
        }
    }
    if let Some(sp) = sm3_x4_speedup {
        println!("\n  sm3 step_threads=4 speedup: {sp:.2}x \
                  (acceptance target >= 1.5x; bitwise-identical output)");
    }

    // ---- quantized state: measured bytes + throughput per dtype ---------
    // (EXPERIMENTS.md §Quantized state) q8 trades ~1.06 bytes/scalar of
    // storage for one encode+decode pass per slot per step; this section
    // measures what that pass costs next to the raw update arithmetic.
    println!("\n=== quantized optimizer state (optim::qstate) — \
              {:.2}M params ===", d as f64 / 1e6);
    println!("  {:<11} {:<6} {:>12} {:>12} {:>10}",
             "optimizer", "dtype", "state bytes", "ns/step", "Melem/s");
    let mut qcsv = CsvWriter::create(
        "out/perf_optim_qstate.csv",
        "optimizer,dtype,state_bytes,median_ns,elements_per_sec,\
         bytes_vs_f32")?;
    for name in ["sm3", "adam"] {
        // determinism gate first: serial == sharded at q8, like the f32
        // ParallelStep section asserts before timing
        assert_bitwise_equal_dtype(name, &specs, &grads, 4, StateDtype::Q8)?;
        // arithmetic, not a live build: the accountant's static bytes are
        // asserted equal to Optimizer::state_bytes in memory/mod.rs tests
        let f32_bytes = opt_state_bytes(name, &specs, StateDtype::F32)?;
        for dtype in StateDtype::ALL {
            let mut opt =
                optim::build_with_dtype(name, &specs, 0.9, 0.98, dtype)?;
            let sb = opt.state_bytes();
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let stats = bench(&format!("{name} @ {}", dtype.name()), budget,
                              10, || {
                opt.step(&mut params, &grads, 0.01);
            });
            let eps = stats.throughput(d);
            println!("  {name:<11} {:<6} {sb:>12} {:>12.0} {:>10.1}",
                     dtype.name(), stats.per_iter_ns(), eps / 1e6);
            qcsv.row(&[name.to_string(), dtype.name().to_string(),
                       sb.to_string(),
                       format!("{:.0}", stats.per_iter_ns()),
                       format!("{eps:.0}"),
                       format!("{:.3}", sb as f64 / f32_bytes as f64)])?;
            if dtype == StateDtype::Q8 {
                assert!((sb as f64) * 3.5 <= f32_bytes as f64,
                        "{name}: q8 state {sb} B not ≥3.5x below f32 \
                         {f32_bytes} B");
            }
        }
    }

    // ---- ring all-reduce -------------------------------------------------
    println!("\n=== ring all-reduce ({:.2}M floats) ===", d as f64 / 1e6);
    for workers in [2usize, 4, 8] {
        let base: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let stats = bench(&format!("allreduce x{workers}"), budget, 5, || {
            let mut ranks = base.clone();
            ring_allreduce(&mut ranks);
            std::hint::black_box(&ranks);
        });
        println!("  {stats}");
    }
    Ok(())
}
