//! §Perf (L3) — optimizer-update throughput: elements/second for every
//! optimizer on transformer-shaped parameters, plus the per-step time
//! comparison the paper reports ("a step of SM3 was faster than Adam's
//! by 3%" — fewer state reads/writes).
//!
//! Also benchmarks the ring all-reduce, the abstract-cover SM3 (the
//! O(Σ|S_r|) path) against the co-dim-1 fast path, the **chunked
//! streaming kernels against the whole-slot path** (the memcpy the
//! qstate store's PR 2 docs said to measure before removing), the
//! `ParallelStep` sharded update engine against serial stepping —
//! including a **skewed-leaf scenario** where one 32k×1024 embedding
//! dominates and intra-leaf splitting is what keeps the workers busy —
//! and the quantized-state store (`optim::qstate`).
//!
//! Every timed comparison asserts bitwise equality first, so this bench
//! doubles as an execution gate: CI runs it with `BENCH_QUICK=1` (small
//! spec set, short budgets), which keeps the equality assertions
//! *executing* on every push instead of only compiling via `--no-run`.
//!
//! Also measures the **composable transform pipeline** (DESIGN.md §11):
//! clip-by-global-norm + decoupled weight decay over Adam/SM3 against
//! the bare optimizer and a hand-fused baseline, gated on bitwise
//! equality with the latter.
//!
//! Also times the **kernel backends** (DESIGN.md §13): the scalar
//! reference lanes against the 8-lane unrolled `simd` backend on
//! Adam/SM3 × f32/q8, gated on bitwise equality of the trajectories.
//!
//! Run: `cargo bench --bench bench_optim` (writes out/perf_optim.csv,
//! out/perf_optim_chunked.csv, out/perf_optim_parallel.csv,
//! out/perf_optim_qstate.csv, out/perf_optim_transforms.csv,
//! out/perf_optim_backends.csv);
//! `BENCH_QUICK=1` or `make bench-quick` for the CI-sized variant.
//! Pass `-- --telemetry` (or set `SM3_TELEMETRY=1`) to additionally
//! emit the standing perf-trajectory document out/BENCH_optim.json
//! from the telemetry registry (DESIGN.md §14).

use sm3::bench_util::{bench, speedup, telemetry_requested,
                      write_bench_json, CsvWriter};
use sm3::collectives::ring_allreduce;
use sm3::memory::opt_state_bytes;
use sm3::optim::{self, cover::{Cover, CoverSm3II}, kernel, transform,
                 Backend, OptimSpec, Optimizer, ParamSpec, ParallelStep,
                 SplitPolicy, StateDtype};
use sm3::rng::Rng;
use sm3::telemetry::{self, Gauge};
use sm3::tensor::Tensor;
use std::time::Duration;

/// One tile spanning any slot: the whole-slot reference configuration.
const WHOLE_SLOT: usize = 1 << 30;

/// A transformer-block-shaped parameter set (~2.1M params; quick: ~37k).
fn block_specs(quick: bool) -> Vec<ParamSpec> {
    let (v, d, ff) = if quick { (256, 64, 256) } else { (2048, 256, 1024) };
    vec![
        ParamSpec::new("embed", &[v, d]),
        ParamSpec::new("wq", &[d, d]),
        ParamSpec::new("wk", &[d, d]),
        ParamSpec::new("wv", &[d, d]),
        ParamSpec::new("wo", &[d, d]),
        ParamSpec::new("ffn_w1", &[d, ff]),
        ParamSpec::new("ffn_w2", &[ff, d]),
        ParamSpec::new("b1", &[ff]),
        ParamSpec::new("b2", &[d]),
    ]
}

/// A transformer-scale parameter set (~17M params, 42 leaves) — big
/// enough that the host-side update loop dominates and sharding pays
/// off. Quick mode shrinks every dimension (~170k params).
fn transformer_specs(layers: usize, quick: bool) -> Vec<ParamSpec> {
    let (v, d, ff) = if quick {
        (1024usize, 64usize, 256usize)
    } else {
        (8192, 512, 2048)
    };
    let mut specs = vec![
        ParamSpec::new("embed", &[v, d]),
        ParamSpec::new("pos", &[1024.min(v), d]),
    ];
    for l in 0..layers {
        for w in ["wq", "wk", "wv", "wo"] {
            specs.push(ParamSpec::new(format!("l{l}/{w}"), &[d, d]));
        }
        specs.push(ParamSpec::new(format!("l{l}/ffn_w1"), &[d, ff]));
        specs.push(ParamSpec::new(format!("l{l}/ffn_b1"), &[ff]));
        specs.push(ParamSpec::new(format!("l{l}/ffn_w2"), &[ff, d]));
        specs.push(ParamSpec::new(format!("l{l}/ffn_b2"), &[d]));
        specs.push(ParamSpec::new(format!("l{l}/ln_scale"), &[d]));
        specs.push(ParamSpec::new(format!("l{l}/ln_bias"), &[d]));
    }
    specs
}

/// The ISSUE 3 skewed scenario: one dominant embedding (32k×1024 ≈ 33.5M
/// elements — quick: 2k×64) plus many small leaves. Under the whole-leaf
/// plan the embedding serializes one worker; intra-leaf splitting is
/// what buys parallel speedup here.
fn skewed_specs(quick: bool) -> Vec<ParamSpec> {
    let (rows, d) = if quick { (2048usize, 64usize) } else { (32768, 1024) };
    let mut specs = vec![ParamSpec::new("embed", &[rows, d])];
    for l in 0..8 {
        specs.push(ParamSpec::new(format!("l{l}/w"), &[d, d]));
        specs.push(ParamSpec::new(format!("l{l}/b"), &[d]));
    }
    specs
}

/// Assert the parallel engine's output is bitwise identical to serial
/// over a few steps (pre-flight gate for the timing runs below), at any
/// state storage precision and split policy.
fn assert_parallel_bitwise(name: &str, specs: &[ParamSpec],
                           grads: &[Tensor], threads: usize,
                           dtype: StateDtype, policy: SplitPolicy)
                           -> anyhow::Result<()> {
    let mut serial =
        OptimSpec::named(name)?.state_dtype(dtype).build(specs)?;
    let mut par = ParallelStep::from_registry_opts(
        name, specs, 0.9, 0.98, threads, dtype, kernel::DEFAULT_CHUNK,
        policy)?;
    let mut pa: Vec<Tensor> =
        specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let mut pb = pa.clone();
    for step in 0..3 {
        serial.step(&mut pa, grads, 0.01);
        par.step(&mut pb, grads, 0.01);
        for (leaf, (a, b)) in pa.iter().zip(&pb).enumerate() {
            for (x, y) in a.data().iter().zip(b.data()) {
                anyhow::ensure!(
                    x.to_bits() == y.to_bits(),
                    "{name} x{threads} @ {dtype:?} {policy:?} diverged at \
                     step {step} leaf {leaf}: {x} vs {y}");
            }
        }
    }
    Ok(())
}

/// Assert the tiled streaming engine matches the whole-slot path bitwise
/// (chunked-vs-whole pre-flight gate).
fn assert_chunked_bitwise(name: &str, specs: &[ParamSpec], grads: &[Tensor],
                          dtype: StateDtype, chunk: usize)
                          -> anyhow::Result<()> {
    let mut tiled = OptimSpec::named(name)?
        .state_dtype(dtype).step_chunk(chunk).build(specs)?;
    let mut whole = OptimSpec::named(name)?
        .state_dtype(dtype).step_chunk(WHOLE_SLOT).build(specs)?;
    let mut pa: Vec<Tensor> =
        specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let mut pb = pa.clone();
    for step in 0..2 {
        tiled.step(&mut pa, grads, 0.01);
        whole.step(&mut pb, grads, 0.01);
        for (leaf, (a, b)) in pa.iter().zip(&pb).enumerate() {
            for (x, y) in a.data().iter().zip(b.data()) {
                anyhow::ensure!(
                    x.to_bits() == y.to_bits(),
                    "{name} @ {dtype:?} chunk {chunk} diverged from \
                     whole-slot at step {step} leaf {leaf}: {x} vs {y}");
            }
        }
    }
    Ok(())
}

/// Assert the simd backend's trajectory is bitwise identical to scalar
/// over a few steps (ISSUE 6 acceptance gate; executes under
/// BENCH_QUICK=1 in CI before any backend timing).
fn assert_backend_bitwise(name: &str, specs: &[ParamSpec], grads: &[Tensor],
                          dtype: StateDtype) -> anyhow::Result<()> {
    let mut sc = OptimSpec::named(name)?
        .state_dtype(dtype).kernel_backend(Backend::Scalar).build(specs)?;
    let mut si = OptimSpec::named(name)?
        .state_dtype(dtype).kernel_backend(Backend::Simd).build(specs)?;
    let mut pa: Vec<Tensor> =
        specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let mut pb = pa.clone();
    for step in 0..3 {
        sc.step(&mut pa, grads, 0.01);
        si.step(&mut pb, grads, 0.01);
        for (leaf, (a, b)) in pa.iter().zip(&pb).enumerate() {
            for (x, y) in a.data().iter().zip(b.data()) {
                anyhow::ensure!(
                    x.to_bits() == y.to_bits(),
                    "{name} @ {dtype:?}: simd diverged from scalar at \
                     step {step} leaf {leaf}: {x} vs {y}");
            }
        }
    }
    Ok(())
}

/// Hand-rolled twin of the clip(+decay) pipeline for the transform-
/// overhead section, built on the pipeline's own helpers so the
/// arithmetic is bitwise identical: rescale (or copy) the gradients into
/// `tg`, decay `params`; the caller then runs the bare step on `tg`.
/// One definition serves both the bitwise gate and the timed baseline,
/// so they cannot desynchronize.
fn apply_manual_transforms(tg: &mut [Tensor], grads: &[Tensor],
                           params: &mut [Tensor], clip_c: f32, wd: f32,
                           lr: f32) {
    let scale =
        transform::clip_scale(transform::global_sq_norm(grads), clip_c);
    for (t, g) in tg.iter_mut().zip(grads) {
        match scale {
            Some(s) => {
                for (o, &v) in t.data_mut().iter_mut().zip(g.data()) {
                    *o = v * s;
                }
            }
            None => t.data_mut().copy_from_slice(g.data()),
        }
    }
    // exactly the pipeline's decay factor expression (lr·scale)·wd with
    // the uniform scale 1.0
    let f = 1.0 - lr * 1.0 * wd;
    for t in params.iter_mut() {
        t.map_inplace(|v| v * f);
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1")
        .unwrap_or(false);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let tele = telemetry_requested(&argv);
    // Holding the guard flips every telemetry::span/count/gauge in the
    // measured code paths live; bench() itself records unconditionally.
    let _tele_guard = tele.then(telemetry::enable);
    if tele {
        println!("telemetry on — writing out/BENCH_optim.json at exit");
    }
    let budget = if quick {
        Duration::from_millis(25)
    } else {
        Duration::from_millis(400)
    };
    let min_iters = if quick { 2 } else { 10 };
    if quick {
        println!("BENCH_QUICK=1 — small spec set, short budgets; equality \
                  assertions run in full");
    }

    let specs = block_specs(quick);
    let d: usize = specs.iter().map(ParamSpec::numel).sum();
    println!("=== optimizer step throughput ({:.2}M params) ===",
             d as f64 / 1e6);
    let mut rng = Rng::new(0);
    let grads: Vec<Tensor> = specs
        .iter()
        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
        .collect();

    let mut csv = CsvWriter::create("out/perf_optim.csv",
                                    "optimizer,median_ns,elements_per_sec")?;
    let mut per_opt = Vec::new();
    for name in optim::ALL {
        let mut opt = OptimSpec::named(name)?.build(&specs)?;
        let mut params: Vec<Tensor> =
            specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let stats = bench(&format!("{name} step"), budget, min_iters, || {
            opt.step(&mut params, &grads, 0.01);
        });
        let eps = stats.throughput(d);
        println!("  {stats}   {:.1}M elem/s", eps / 1e6);
        csv.row(&[name.to_string(), format!("{:.0}", stats.per_iter_ns()),
                  format!("{eps:.0}")])?;
        per_opt.push((name.to_string(), stats.median));
    }
    // the paper's per-step claim: SM3 not slower than Adam
    let sm3 = per_opt.iter().find(|p| p.0 == "sm3").unwrap().1;
    let adam = per_opt.iter().find(|p| p.0 == "adam").unwrap().1;
    println!("\n  sm3 step / adam step = {:.2} (paper: ≤ ~1.0, SM3 touches \
              less state)", sm3.as_secs_f64() / adam.as_secs_f64());

    // ---- abstract cover vs fast path ------------------------------------
    println!("\n=== abstract-cover SM3 (O(Σ|S_r|)) vs co-dim-1 fast path ===");
    let (m, n) = if quick { (128, 128) } else { (512, 512) };
    let mut fast = optim::Sm3::new(&[ParamSpec::new("w", &[m, n])],
                                   optim::Sm3Variant::II, 0.0);
    let mut pf = vec![Tensor::zeros(&[m, n])];
    let g = Tensor::randn(&[m, n], 1.0, &mut rng);
    let s1 = bench(&format!("fast path {m}x{n}"), budget, min_iters, || {
        fast.step(&mut pf, std::slice::from_ref(&g), 0.01);
    });
    println!("  {s1}");
    let mut abs = CoverSm3II::new(Cover::rows_cols(m, n));
    let mut wa = Tensor::zeros(&[m * n]);
    let ga = g.clone().reshape(&[m * n]);
    let s2 = bench(&format!("abstract cover {m}x{n}"), budget, min_iters,
                   || {
        abs.step(&mut wa, &ga, 0.01);
    });
    println!("  {s2}");
    println!("  speedup of the specialized path: {:.1}x",
             s2.median.as_secs_f64() / s1.median.as_secs_f64());

    // ---- chunked streaming kernels vs whole-slot path --------------------
    // (EXPERIMENTS.md §Step-kernel-tiling) The PR 2 store documented the
    // whole-slot read/modify/write as a known tradeoff "to be removed
    // with bench numbers": this section is those numbers. f32 measures
    // the removed memcpys (tiles lend storage); bf16/q8 measure decoding
    // into an O(tile) scratch vs a full-slot buffer.
    println!("\n=== chunked step kernels vs whole-slot path \
              ({:.2}M params, tile {}) ===", d as f64 / 1e6,
             kernel::DEFAULT_CHUNK);
    println!("  {:<11} {:<6} {:>14} {:>14} {:>9}",
             "optimizer", "dtype", "whole ns/step", "tiled ns/step",
             "speedup");
    let mut ccsv = CsvWriter::create(
        "out/perf_optim_chunked.csv",
        "optimizer,dtype,chunk,median_ns,elements_per_sec,\
         speedup_vs_whole_slot")?;
    for name in ["adam", "adagrad", "sm3"] {
        for dtype in StateDtype::ALL {
            // bitwise equality gate before any timing (the acceptance
            // criterion executes here under BENCH_QUICK=1 in CI)
            assert_chunked_bitwise(name, &specs, &grads, dtype,
                                   kernel::DEFAULT_CHUNK)?;
            assert_chunked_bitwise(name, &specs, &grads, dtype, 64)?;
            let mut whole = OptimSpec::named(name)?
                .state_dtype(dtype).step_chunk(WHOLE_SLOT).build(&specs)?;
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let base = bench(&format!("{name} @ {} whole-slot",
                                      dtype.name()),
                             budget, min_iters, || {
                whole.step(&mut params, &grads, 0.01);
            });
            let mut tiled = OptimSpec::named(name)?
                .state_dtype(dtype).build(&specs)?;
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let stats = bench(&format!("{name} @ {} tiled", dtype.name()),
                              budget, min_iters, || {
                tiled.step(&mut params, &grads, 0.01);
            });
            let sp = speedup(&base, &stats);
            println!("  {name:<11} {:<6} {:>14.0} {:>14.0} {sp:>8.2}x",
                     dtype.name(), base.per_iter_ns(),
                     stats.per_iter_ns());
            for (cfg, st, s) in [(WHOLE_SLOT, &base, 1.0),
                                 (kernel::DEFAULT_CHUNK, &stats, sp)] {
                ccsv.row(&[name.to_string(), dtype.name().to_string(),
                           cfg.to_string(),
                           format!("{:.0}", st.per_iter_ns()),
                           format!("{:.0}", st.throughput(d)),
                           format!("{s:.3}")])?;
            }
        }
    }

    // ---- ParallelStep: serial vs sharded optimizer stepping --------------
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let thread_list: &[usize] = if quick { &[2] } else { &[2, 4, 8] };
    let big_specs = transformer_specs(if quick { 1 } else { 4 }, quick);
    let dbig: usize = big_specs.iter().map(ParamSpec::numel).sum();
    println!("\n=== ParallelStep — sharded update, transformer-scale set \
              ({:.1}M params, {} leaves, {} host cores) ===",
             dbig as f64 / 1e6, big_specs.len(), cores);
    let grads_big: Vec<Tensor> = big_specs
        .iter()
        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
        .collect();
    let mut pcsv = CsvWriter::create(
        "out/perf_optim_parallel.csv",
        "optimizer,spec_set,plan,threads,median_ns,elements_per_sec,\
         speedup_vs_serial")?;
    let mut sm3_x4_speedup = None;
    for name in ["sm3", "adam"] {
        for &threads in thread_list {
            assert_parallel_bitwise(name, &big_specs, &grads_big, threads,
                                    StateDtype::F32,
                                    SplitPolicy::IntraLeaf)?;
        }
        let mut serial = OptimSpec::named(name)?.build(&big_specs)?;
        let mut params: Vec<Tensor> =
            big_specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let base = bench(&format!("{name} serial"), budget, min_iters, || {
            serial.step(&mut params, &grads_big, 0.01);
        });
        println!("  {base}   {:.1}M elem/s", base.throughput(dbig) / 1e6);
        pcsv.row(&[name.to_string(), "transformer".into(), "serial".into(),
                   "1".into(), format!("{:.0}", base.per_iter_ns()),
                   format!("{:.0}", base.throughput(dbig)), "1.00".into()])?;
        for &threads in thread_list {
            let mut par = ParallelStep::from_registry(
                name, &big_specs, 0.9, 0.98, threads)?;
            let mut params: Vec<Tensor> =
                big_specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let stats = bench(&format!("{name} x{threads} threads"), budget,
                              min_iters, || {
                par.step(&mut params, &grads_big, 0.01);
            });
            let sp = speedup(&base, &stats);
            println!("  {stats}   {:.1}M elem/s  ({sp:.2}x vs serial)",
                     stats.throughput(dbig) / 1e6);
            pcsv.row(&[name.to_string(), "transformer".into(),
                       "intra_leaf".into(), threads.to_string(),
                       format!("{:.0}", stats.per_iter_ns()),
                       format!("{:.0}", stats.throughput(dbig)),
                       format!("{sp:.3}")])?;
            if name == "sm3" && threads == 4 {
                sm3_x4_speedup = Some(sp);
            }
        }
    }
    if let Some(sp) = sm3_x4_speedup {
        println!("\n  sm3 step_threads=4 speedup: {sp:.2}x \
                  (acceptance target >= 1.5x; bitwise-identical output)");
    }

    // ---- skewed leaves: whole-leaf vs intra-leaf sharding ----------------
    // (ISSUE 3) One embedding holds most of the elements. The whole-leaf
    // plan caps speedup near total/dominant regardless of threads; the
    // intra-leaf plan splits the embedding at q8-block boundaries.
    let sk = skewed_specs(quick);
    let dsk: usize = sk.iter().map(ParamSpec::numel).sum();
    println!("\n=== skewed leaves — whole-leaf vs intra-leaf sharding \
              ({:.1}M params, embedding = {:.0}% of elements) ===",
             dsk as f64 / 1e6, 100.0 * sk[0].numel() as f64 / dsk as f64);
    let grads_sk: Vec<Tensor> = sk
        .iter()
        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
        .collect();
    for name in ["adam"] {
        for &threads in thread_list {
            for policy in [SplitPolicy::WholeLeaf, SplitPolicy::IntraLeaf] {
                assert_parallel_bitwise(name, &sk, &grads_sk, threads,
                                        StateDtype::F32, policy)?;
            }
        }
        let mut serial = OptimSpec::named(name)?.build(&sk)?;
        let mut params: Vec<Tensor> =
            sk.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let base = bench(&format!("{name} serial (skewed)"), budget,
                         min_iters, || {
            serial.step(&mut params, &grads_sk, 0.01);
        });
        println!("  {base}");
        pcsv.row(&[name.to_string(), "skewed".into(), "serial".into(),
                   "1".into(), format!("{:.0}", base.per_iter_ns()),
                   format!("{:.0}", base.throughput(dsk)), "1.00".into()])?;
        for &threads in thread_list {
            let mut pair = Vec::new();
            for (plan, policy) in [("whole_leaf", SplitPolicy::WholeLeaf),
                                   ("intra_leaf", SplitPolicy::IntraLeaf)] {
                let mut par = ParallelStep::from_registry_opts(
                    name, &sk, 0.9, 0.98, threads, StateDtype::F32,
                    kernel::DEFAULT_CHUNK, policy)?;
                let parts = par.parts_per_leaf()[0];
                let mut params: Vec<Tensor> =
                    sk.iter().map(|s| Tensor::zeros(&s.shape)).collect();
                let stats = bench(
                    &format!("{name} x{threads} {plan} (embed parts: \
                              {parts})"),
                    budget, min_iters, || {
                    par.step(&mut params, &grads_sk, 0.01);
                });
                let sp = speedup(&base, &stats);
                println!("  {stats}   ({sp:.2}x vs serial)");
                pcsv.row(&[name.to_string(), "skewed".into(), plan.into(),
                           threads.to_string(),
                           format!("{:.0}", stats.per_iter_ns()),
                           format!("{:.0}", stats.throughput(dsk)),
                           format!("{sp:.3}")])?;
                pair.push(sp);
            }
            println!("    intra-leaf vs whole-leaf at x{threads}: {:.2}x",
                     pair[1] / pair[0]);
        }
    }

    // ---- quantized state: measured bytes + throughput per dtype ---------
    // (EXPERIMENTS.md §Quantized state) q8 trades ~1.06 bytes/scalar of
    // storage for one encode+decode pass per tile per step; this section
    // measures what that pass costs next to the raw update arithmetic.
    println!("\n=== quantized optimizer state (optim::qstate) — \
              {:.2}M params ===", d as f64 / 1e6);
    println!("  {:<11} {:<6} {:>12} {:>12} {:>10}",
             "optimizer", "dtype", "state bytes", "ns/step", "Melem/s");
    let mut qcsv = CsvWriter::create(
        "out/perf_optim_qstate.csv",
        "optimizer,dtype,state_bytes,median_ns,elements_per_sec,\
         bytes_vs_f32")?;
    for name in ["sm3", "adam"] {
        // determinism gate first: serial == sharded at q8, like the f32
        // ParallelStep section asserts before timing
        assert_parallel_bitwise(name, &specs, &grads, 4, StateDtype::Q8,
                                SplitPolicy::IntraLeaf)?;
        // arithmetic, not a live build: the accountant's static bytes are
        // asserted equal to Optimizer::state_bytes in memory/mod.rs tests
        let f32_bytes = opt_state_bytes(name, &specs, StateDtype::F32)?;
        for dtype in StateDtype::ALL {
            let mut opt =
                OptimSpec::named(name)?.state_dtype(dtype).build(&specs)?;
            let sb = opt.state_bytes();
            if tele {
                // live gauge must round-trip to the static accountant's
                // number — the BENCH_optim.json byte gauges are asserted,
                // not just reported
                telemetry::gauge(Gauge::OptStateBytes, sb as u64);
                // re-arm the high-water mark: this config's exported
                // peak is its own footprint, not a leak of the f32
                // predecessor's larger one (`Registry::reset_run`'s
                // per-thread half; the regression test lives there)
                telemetry::reset_thread_run();
                let stat = opt_state_bytes(name, &specs, dtype)?;
                anyhow::ensure!(
                    telemetry::thread_gauge(Gauge::OptStateBytes).last
                        == stat as u64,
                    "{name} @ {dtype:?}: telemetry state-bytes gauge \
                     {sb} disagrees with the static accountant {stat}");
            }
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let stats = bench(&format!("{name} @ {}", dtype.name()), budget,
                              min_iters, || {
                opt.step(&mut params, &grads, 0.01);
            });
            let eps = stats.throughput(d);
            println!("  {name:<11} {:<6} {sb:>12} {:>12.0} {:>10.1}",
                     dtype.name(), stats.per_iter_ns(), eps / 1e6);
            qcsv.row(&[name.to_string(), dtype.name().to_string(),
                       sb.to_string(),
                       format!("{:.0}", stats.per_iter_ns()),
                       format!("{eps:.0}"),
                       format!("{:.3}", sb as f64 / f32_bytes as f64)])?;
            if dtype == StateDtype::Q8 {
                assert!((sb as f64) * 3.5 <= f32_bytes as f64,
                        "{name}: q8 state {sb} B not ≥3.5x below f32 \
                         {f32_bytes} B");
            }
        }
    }

    // ---- kernel backends: scalar reference vs 8-lane unrolled lanes ------
    // (ISSUE 6 / DESIGN.md §13) Same KernelBackend trait, two
    // implementations; the bitwise gate runs before any timing, so CI
    // (BENCH_QUICK=1, both feature sets) executes the acceptance
    // criterion — `--kernel-backend simd == scalar` — on every push.
    println!("\n=== kernel backends — scalar vs simd lanes \
              ({:.2}M params) ===", d as f64 / 1e6);
    println!("  {:<11} {:<6} {:>15} {:>14} {:>9}",
             "optimizer", "dtype", "scalar ns/step", "simd ns/step",
             "speedup");
    let mut bcsv = CsvWriter::create(
        "out/perf_optim_backends.csv",
        "optimizer,dtype,backend,median_ns,elements_per_sec,\
         speedup_vs_scalar")?;
    for name in ["adam", "sm3"] {
        for dtype in [StateDtype::F32, StateDtype::Q8] {
            assert_backend_bitwise(name, &specs, &grads, dtype)?;
            let mut stats_by = Vec::new();
            for backend in Backend::ALL {
                let mut opt = OptimSpec::named(name)?
                    .state_dtype(dtype).kernel_backend(backend)
                    .build(&specs)?;
                let mut params: Vec<Tensor> =
                    specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
                let st = bench(&format!("{name} @ {} {}", dtype.name(),
                                        backend.name()),
                               budget, min_iters, || {
                    opt.step(&mut params, &grads, 0.01);
                });
                stats_by.push((backend, st));
            }
            let sp = speedup(&stats_by[0].1, &stats_by[1].1);
            println!("  {name:<11} {:<6} {:>15.0} {:>14.0} {sp:>8.2}x",
                     dtype.name(), stats_by[0].1.per_iter_ns(),
                     stats_by[1].1.per_iter_ns());
            for (backend, st) in &stats_by {
                let s = speedup(&stats_by[0].1, st);
                bcsv.row(&[name.to_string(), dtype.name().to_string(),
                           backend.name().to_string(),
                           format!("{:.0}", st.per_iter_ns()),
                           format!("{:.0}", st.throughput(d)),
                           format!("{s:.3}")])?;
            }
            // loose perf floor, full runs only: the unrolled lanes must
            // not badly regress the scalar reference (25ms quick budgets
            // on a noisy CI box cannot resolve timing)
            if !quick {
                anyhow::ensure!(
                    sp >= 0.8,
                    "{name} @ {dtype:?}: simd runs at {sp:.2}x scalar \
                     throughput (floor 0.8x)");
            }
        }
    }

    // ---- transform overhead: bare vs hand-rolled vs pipeline -------------
    // (ISSUE 4) The composable pipeline (clip_by_global_norm(1.0) +
    // decoupled_weight_decay(0.01), optim::OptimSpec) against two
    // baselines: the bare optimizer (what the transforms inherently
    // cost) and the same transforms hand-fused around the bare step
    // (what the *composition machinery* costs — the ≤10% assertion
    // target). The pipeline-vs-manual comparison is also a bitwise
    // equality gate, so CI executes the semantic contract under
    // BENCH_QUICK=1. Zero steady-state allocations are asserted by the
    // counting-allocator unit test in optim::transform.
    println!("\n=== transform overhead — bare vs hand-rolled vs pipeline \
              ({:.2}M params, clip_norm 1.0 + weight_decay 0.01) ===",
             d as f64 / 1e6);
    println!("  {:<11} {:<6} {:>12} {:>12} {:>12} {:>9}",
             "optimizer", "dtype", "bare ns", "manual ns", "pipeline ns",
             "pipe/man");
    let mut tcsv = CsvWriter::create(
        "out/perf_optim_transforms.csv",
        "optimizer,dtype,variant,median_ns,elements_per_sec,\
         ratio_vs_bare,ratio_vs_manual")?;
    let (clip_c, wd) = (1.0f32, 0.01f32);
    for name in ["adam", "sm3"] {
        for dtype in [StateDtype::F32, StateDtype::Q8] {
            // bitwise gate first: pipeline == hand-applied transforms
            {
                let mut pipe = OptimSpec::named(name)?
                    .state_dtype(dtype)
                    .clip_by_global_norm(clip_c)
                    .weight_decay(wd)
                    .build(&specs)?;
                let mut bare = OptimSpec::named(name)?
                    .state_dtype(dtype).build(&specs)?;
                let mut pa: Vec<Tensor> =
                    specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
                let mut pb = pa.clone();
                let mut tg: Vec<Tensor> = grads.clone();
                for step in 0..3 {
                    pipe.step(&mut pa, &grads, 0.01);
                    apply_manual_transforms(&mut tg, &grads, &mut pb,
                                            clip_c, wd, 0.01);
                    bare.step(&mut pb, &tg, 0.01);
                    for (leaf, (a, b)) in pa.iter().zip(&pb).enumerate() {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            anyhow::ensure!(
                                x.to_bits() == y.to_bits(),
                                "{name} @ {dtype:?}: pipeline diverged \
                                 from hand-rolled transforms at step \
                                 {step} leaf {leaf}: {x} vs {y}");
                        }
                    }
                }
            }
            // timings
            let mut bare = OptimSpec::named(name)?
                .state_dtype(dtype).build(&specs)?;
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let base = bench(&format!("{name} @ {} bare", dtype.name()),
                             budget, min_iters, || {
                bare.step(&mut params, &grads, 0.01);
            });
            let mut inner = OptimSpec::named(name)?
                .state_dtype(dtype).build(&specs)?;
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let mut tg: Vec<Tensor> = grads.clone();
            let manual = bench(&format!("{name} @ {} manual",
                                        dtype.name()),
                               budget, min_iters, || {
                apply_manual_transforms(&mut tg, &grads, &mut params,
                                        clip_c, wd, 0.01);
                inner.step(&mut params, &tg, 0.01);
            });
            let mut pipe = OptimSpec::named(name)?
                .state_dtype(dtype)
                .clip_by_global_norm(clip_c)
                .weight_decay(wd)
                .build(&specs)?;
            let mut params: Vec<Tensor> =
                specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            let pstats = bench(&format!("{name} @ {} pipeline",
                                        dtype.name()),
                               budget, min_iters, || {
                pipe.step(&mut params, &grads, 0.01);
            });
            let vs_bare =
                pstats.median.as_secs_f64() / base.median.as_secs_f64();
            let vs_manual =
                pstats.median.as_secs_f64() / manual.median.as_secs_f64();
            println!("  {name:<11} {:<6} {:>12.0} {:>12.0} {:>12.0} \
                      {vs_manual:>8.2}x",
                     dtype.name(), base.per_iter_ns(),
                     manual.per_iter_ns(), pstats.per_iter_ns());
            for (variant, st) in [("bare", &base), ("manual", &manual),
                                  ("pipeline", &pstats)] {
                let rb = st.median.as_secs_f64()
                    / base.median.as_secs_f64();
                let rm = st.median.as_secs_f64()
                    / manual.median.as_secs_f64();
                tcsv.row(&[name.to_string(), dtype.name().to_string(),
                           variant.to_string(),
                           format!("{:.0}", st.per_iter_ns()),
                           format!("{:.0}", st.throughput(d)),
                           format!("{rb:.3}"), format!("{rm:.3}")])?;
            }
            // the composition machinery must stay within 10% of the
            // hand-fused transforms (quick mode skips: 25ms budgets on a
            // noisy CI box cannot resolve 10%)
            if !quick {
                anyhow::ensure!(
                    vs_manual <= 1.10,
                    "{name} @ {dtype:?}: pipeline is {vs_manual:.2}x the \
                     hand-rolled transform baseline (target <= 1.10x)");
            }
        }
    }

    // ---- ring all-reduce -------------------------------------------------
    println!("\n=== ring all-reduce ({:.2}M floats) ===", d as f64 / 1e6);
    let worker_list: &[usize] = if quick { &[2] } else { &[2, 4, 8] };
    for &workers in worker_list {
        let base: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let stats = bench(&format!("allreduce x{workers}"), budget,
                          if quick { 2 } else { 5 }, || {
            let mut ranks = base.clone();
            ring_allreduce(&mut ranks).unwrap();
            std::hint::black_box(&ranks);
        });
        println!("  {stats}");
    }

    if tele {
        write_bench_json("bench_optim", quick, "out/BENCH_optim.json")?;
        println!("\ntelemetry document: out/BENCH_optim.json");
    }
    Ok(())
}
