//! §Perf (runtime + end-to-end) — PJRT execution latency per artifact,
//! split- vs fused-path step time, and the per-step wall-time comparison
//! across optimizers (the paper's "SM3 step 3% faster than Adam" claim,
//! §5.2) measured end-to-end through the HLO artifacts.
//!
//! Run: `cargo bench --bench bench_runtime` (writes out/perf_runtime.csv)

use sm3::bench_util::{bench, CsvWriter};
use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::runtime::Runtime;
use std::sync::Arc;
use std::time::Duration;

fn cfg(model: &str, opt: &str, exec: ExecMode) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optim.name = opt.into();
    c.optim.lr = 0.1;
    c.steps = 1;
    c.exec = exec;
    c
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);
    let budget = Duration::from_millis(600);
    let mut csv = CsvWriter::create(
        "out/perf_runtime.csv", "what,median_ns")?;

    // ---- artifact execution latency -------------------------------------
    println!("=== PJRT artifact step latency (lm_small) ===");
    let mut rows = Vec::new();
    for (label, opt, exec) in [
        ("split grad+rust-sm3", "sm3", ExecMode::Split),
        ("fused sm3", "sm3", ExecMode::Fused),
        ("fused adam", "adam", ExecMode::Fused),
        ("fused adagrad", "adagrad", ExecMode::Fused),
        ("fused adafactor", "adafactor", ExecMode::Fused),
        ("fused sgdm", "sgdm", ExecMode::Fused),
    ] {
        let mut t = Trainer::with_runtime(cfg("lm_small", opt, exec),
                                          rt.clone())?;
        let stats = bench(label, budget, 8, || {
            t.train_step().unwrap();
        });
        println!("  {stats}");
        csv.row(&[label.to_string(), format!("{:.0}", stats.per_iter_ns())])?;
        rows.push((label, stats.median));
    }
    let fused_sm3 = rows.iter().find(|r| r.0 == "fused sm3").unwrap().1;
    let fused_adam = rows.iter().find(|r| r.0 == "fused adam").unwrap().1;
    let split_sm3 = rows.iter()
        .find(|r| r.0 == "split grad+rust-sm3").unwrap().1;
    println!("\n  fused-sm3 / fused-adam step time: {:.3} \
              (paper §5.2: SM3 ~3% faster per step)",
             fused_sm3.as_secs_f64() / fused_adam.as_secs_f64());
    println!("  fused / split speedup for sm3: {:.2}x \
              (fusion removes host round-trips)",
             split_sm3.as_secs_f64() / fused_sm3.as_secs_f64());

    // ---- eval + decode latency ------------------------------------------
    println!("\n=== eval/decode latency ===");
    let t = Trainer::with_runtime(cfg("mt_small", "sm3", ExecMode::Split),
                                  rt.clone())?;
    let stats = bench("mt_small eval (8 batches)", budget, 3, || {
        t.evaluate().unwrap();
    });
    println!("  {stats}");
    csv.row(&["mt_eval".into(), format!("{:.0}", stats.per_iter_ns())])?;
    let stats = bench("mt_small greedy decode + BLEU", budget, 2, || {
        t.bleu().unwrap();
    });
    println!("  {stats}");
    csv.row(&["mt_decode_bleu".into(), format!("{:.0}", stats.per_iter_ns())])?;
    Ok(())
}
