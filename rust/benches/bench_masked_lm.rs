//! E3/E4 — regenerates paper Fig. 3: masked-LM quality curves per
//! optimizer (left) and steps-to-target-quality vs batch size for SM3
//! (right, the near-linear scaling claim).
//!
//! Batch scaling is realized with gradient accumulation over the grad
//! artifact (split path) — the same optimizer-step arithmetic a bigger
//! device batch would produce.
//!
//! Scale note (recorded in EXPERIMENTS.md): at this miniature scale the
//! constant-LR family (SM3/Adagrad) sits on the attention-routing loss
//! plateau for longer than Adam — so the scaling target is a held-out
//! LOSS level every run reaches, not the paper's 70%-accuracy analogue.
//! The claim under test is unchanged: larger effective batches reach the
//! target in fewer optimizer steps.
//!
//! Run: `cargo bench --bench bench_masked_lm`
//! (writes out/fig3_curves.csv, out/fig3_scaling.csv)

use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::metrics::RunLogger;
use sm3::runtime::Runtime;
use std::sync::Arc;

const STEPS: u64 = 300;
const LOSS_TARGET: f64 = 2.90;

fn cfg(opt: &str, lr: f64, accum: u64, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = "mlm_small".into();
    c.optim.name = opt.into();
    c.optim.lr = lr;
    c.optim.schedule = "constant".into();
    c.optim.warmup_steps = 20;
    c.steps = steps;
    c.eval_every = 10;
    c.grad_accum = accum;
    c.exec = ExecMode::Split;
    c
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new("artifacts")?);

    // ---- Fig. 3 left: quality curves, all optimizers -------------------
    println!("=== Fig. 3 (left) — masked-LM eval loss/accuracy curves ===");
    let mut log = RunLogger::new(Some("out/fig3_curves.csv"),
                                 "optimizer,step,eval_loss,accuracy", false)?;
    let grid: &[(&str, f64)] = &[("adam", 0.002), ("adagrad", 0.1),
                                 ("adafactor", 0.02), ("sm3", 0.1)];
    let mut finals = Vec::new();
    for &(opt, lr) in grid {
        let mut t = Trainer::with_runtime(cfg(opt, lr, 1, STEPS), rt.clone())?;
        let hist = t.train()?;
        for e in &hist.evals {
            log.row(&[opt.into(), e.step.to_string(),
                      format!("{:.5}", e.loss),
                      format!("{:.4}", e.metric.unwrap_or(0.0))])?;
        }
        let e = hist.final_eval().unwrap().clone();
        println!("  {opt:<10} final loss {:.4}  accuracy {:.1}%",
                 e.loss, e.metric.unwrap_or(0.0) * 100.0);
        finals.push((opt.to_string(), e.loss, hist));
    }
    log.flush()?;

    let loss_of = |o: &str| finals.iter().find(|f| f.0 == o).unwrap().1;
    println!("\n  shape: SM3 tracks Adagrad (the paper's equivalence): \
              {:.3} vs {:.3} {}",
             loss_of("sm3"), loss_of("adagrad"),
             if (loss_of("sm3") - loss_of("adagrad")).abs() < 0.1 { "✓" }
             else { "✗" });

    // ---- Fig. 3 right: steps to target quality vs batch size -----------
    println!("\n=== Fig. 3 (right) — SM3 steps to eval loss ≤ {LOSS_TARGET} \
              vs batch multiplier ===");
    let mut scal = RunLogger::new(Some("out/fig3_scaling.csv"),
                                  "batch_multiplier,steps_to_target", false)?;
    let mut prev: Option<u64> = None;
    for accum in [1u64, 2, 4] {
        let mut t = Trainer::with_runtime(
            cfg("sm3", 0.1, accum, STEPS), rt.clone())?;
        let hist = t.train()?;
        let steps_to = hist.steps_to_loss(LOSS_TARGET);
        let s = steps_to.map(|v| v.to_string()).unwrap_or_else(|| "-".into());
        println!("  batch {accum}x: {s} steps");
        scal.row(&[accum.to_string(), s])?;
        if let (Some(p), Some(c)) = (prev, steps_to) {
            println!("    scaling: {p} -> {c} steps ({:.1}x fewer)",
                     p as f64 / c as f64);
        }
        prev = steps_to;
    }
    scal.flush()?;
    println!("\nCSV series: out/fig3_curves.csv out/fig3_scaling.csv");
    Ok(())
}
