//! Cross-module integration: the experiment workloads at miniature scale.
//!
//! These exercise every model kind (mt/mlm/img) through the full stack —
//! data generator → grad artifact → optimizer → eval/BLEU — with a handful
//! of steps each, asserting learnability signals rather than final quality
//! (the benches run the full-length versions).

use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::runtime::Runtime;
use std::sync::{Arc, Mutex, OnceLock};

fn runtime() -> Option<Arc<Runtime>> {
    static RT: OnceLock<Option<Arc<Runtime>>> = OnceLock::new();
    RT.get_or_init(|| {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Arc::new(Runtime::new("artifacts").unwrap()))
        } else {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            None
        }
    })
    .clone()
}

fn lock() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    // poison-tolerant: one failing test must not cascade into the rest
    match L.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn cfg(model: &str, opt: &str, steps: u64, lr: f64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optim.name = opt.into();
    c.optim.lr = lr;
    c.optim.warmup_steps = steps / 5;
    c.steps = steps;
    c.eval_every = steps;
    c.exec = ExecMode::Split;
    c
}

#[test]
fn translation_learns_and_bleu_is_scored() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("mt_small") {
        eprintln!("SKIP: mt_small not built");
        return;
    }
    let mut t = Trainer::with_runtime(cfg("mt_small", "sm3", 30, 0.2), rt).unwrap();
    let b0 = t.bleu().unwrap();
    let hist = t.train().unwrap();
    let first = hist.steps.first().unwrap().loss;
    let last = hist.steps.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
    let b1 = t.bleu().unwrap();
    // BLEU is in range and decoding works both before and after training
    assert!((0.0..=100.0).contains(&b0.bleu));
    assert!((0.0..=100.0).contains(&b1.bleu));
    // the eval record for mt carries BLEU as the metric
    let e = hist.evals.last().unwrap();
    assert!(e.metric.is_some());
}

#[test]
fn masked_lm_accuracy_improves() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("mlm_small") {
        eprintln!("SKIP: mlm_small not built");
        return;
    }
    let mut t =
        Trainer::with_runtime(cfg("mlm_small", "sm3", 60, 0.3), rt).unwrap();
    let e0 = t.evaluate().unwrap();
    let _ = t.train().unwrap();
    let e1 = t.evaluate().unwrap();
    let (a0, a1) = (e0.metric.unwrap(), e1.metric.unwrap());
    assert!(a1 > a0, "masked-LM accuracy {a0} -> {a1}");
    assert!(e1.loss < e0.loss);
}

#[test]
fn image_classifier_beats_chance() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("img_small") {
        eprintln!("SKIP: img_small not built");
        return;
    }
    let mut t =
        Trainer::with_runtime(cfg("img_small", "sm3", 80, 0.1), rt).unwrap();
    let _ = t.train().unwrap();
    let e = t.evaluate().unwrap();
    let top1 = e.metric.unwrap();
    let top5 = e.metric2.unwrap();
    // 10 classes: chance is 0.10 top-1 / 0.50 top-5
    assert!(top1 > 0.2, "top1 {top1}");
    assert!(top5 >= top1);
}

/// ISSUE 2 acceptance: q8 optimizer state must not cost measurable quality
/// on the synthetic translation task — SM3 and Adam land within tolerance
/// of their f32-state runs (same seed, same data stream), and still learn.
#[test]
fn q8_state_quality_matches_f32_on_translation() {
    // |final_loss(q8) − final_loss(f32)| ≤ QSTATE_TOL · max(final_loss(f32), 1)
    const QSTATE_TOL: f64 = 0.15;
    let _g = lock();
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("mt_small") {
        eprintln!("SKIP: mt_small not built");
        return;
    }
    for (opt, lr) in [("sm3", 0.2), ("adam", 0.003)] {
        let run = |dtype: sm3::optim::StateDtype| -> (f64, f64) {
            let mut c = cfg("mt_small", opt, 30, lr);
            c.state_dtype = dtype;
            let mut t = Trainer::with_runtime(c, rt.clone()).unwrap();
            let hist = t.train().unwrap();
            (hist.steps.first().unwrap().loss,
             hist.evals.last().unwrap().loss)
        };
        let (f0, f_final) = run(sm3::optim::StateDtype::F32);
        let (q0, q_final) = run(sm3::optim::StateDtype::Q8);
        // identical data + init ⇒ identical first step (state starts zero
        // and the first quantization happens after the first update)
        assert!((f0 - q0).abs() < 1e-9,
                "{opt}: first-step loss must match ({f0} vs {q0})");
        assert!(q_final < q0, "{opt} @ q8 failed to learn: {q0} -> {q_final}");
        let tol = QSTATE_TOL * f_final.abs().max(1.0);
        assert!((q_final - f_final).abs() <= tol,
                "{opt}: q8 final eval loss {q_final:.4} vs f32 \
                 {f_final:.4} (tol {tol:.4})");
    }
}

#[test]
fn sm3_trace_probes_capture_accumulators() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let mut c = cfg("lm_tiny", "sm3", 10, 0.3);
    c.eval_every = 10;
    let mut t = Trainer::with_runtime(c, rt).unwrap();
    let _ = t.train().unwrap();
    // the split-path optimizer is introspectable: accumulators exist and
    // are non-trivial after training
    let opt = t.optimizer().unwrap();
    let state = opt.state();
    assert!(state.iter().any(|(_, slot, t)| *slot == "acc0"
        && t.data().iter().any(|&v| v > 0.0)));
}

#[test]
fn lm_small_one_step_all_fused_artifacts() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("lm_small") {
        eprintln!("SKIP: lm_small not built");
        return;
    }
    // every fused optimizer artifact must execute and produce finite loss
    for opt in ["sm3", "sm3i", "adagrad", "adam", "adafactor", "sgdm"] {
        let mut c = cfg("lm_small", opt, 1, 0.1);
        c.exec = ExecMode::Fused;
        c.eval_every = 1;
        let mut t = Trainer::with_runtime(c, rt.clone()).unwrap();
        let hist = t.train().unwrap();
        assert!(hist.steps[0].loss.is_finite(), "{opt}");
    }
}
