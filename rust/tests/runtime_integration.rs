//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run (they are skipped with a clear
//! message otherwise). They exercise the lm_tiny model end to end: load,
//! execute, split-vs-fused equivalence, determinism, checkpoint init.

use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::runtime::{HostValue, Runtime};
use std::sync::{Arc, Mutex, OnceLock};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

/// One shared runtime per test process (compilation is the slow part).
fn runtime() -> Option<Arc<Runtime>> {
    static RT: OnceLock<Option<Arc<Runtime>>> = OnceLock::new();
    RT.get_or_init(|| {
        artifacts_dir().map(|d| Arc::new(Runtime::new(d).unwrap()))
    })
    .clone()
}

/// PJRT CPU client creation is not reentrant across threads in this build;
/// serialize the trainer tests.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    // poison-tolerant: one failing test must not cascade into the rest
    match L.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn tiny_cfg(exec: ExecMode) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "lm_tiny".into();
    cfg.exec = exec;
    cfg.steps = 6;
    cfg.eval_every = 3;
    cfg.optim.lr = 0.3;
    cfg.optim.warmup_steps = 2;
    cfg
}

#[test]
fn manifest_loads_and_lists_models() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.models.contains_key("lm_tiny"));
    let meta = rt.manifest.model("lm_tiny").unwrap();
    assert_eq!(meta.kind, "lm");
    assert_eq!(meta.params.len(), 16);
    assert!(meta.param_count > 0);
}

#[test]
fn grad_artifact_executes_and_matches_manifest() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let art = rt.load("lm_tiny_grad").unwrap();
    let meta = rt.manifest.model("lm_tiny").unwrap();
    // zero params, arbitrary tokens
    let mut inputs: Vec<HostValue> = meta
        .params
        .iter()
        .map(|e| HostValue::F32(sm3::tensor::Tensor::zeros(&e.shape)))
        .collect();
    inputs.push(HostValue::I32 {
        shape: vec![meta.batch, meta.seq],
        data: vec![5; meta.batch * meta.seq],
    });
    let out = art.execute(&inputs).unwrap();
    assert_eq!(out.len(), 17);
    let loss = out[0].scalar().unwrap();
    assert!(loss.is_finite());
    // grads must mirror param shapes
    for (g, p) in out[1..].iter().zip(&meta.params) {
        assert_eq!(g.shape(), p.shape.as_slice());
    }
}

#[test]
fn execute_rejects_wrong_arity_and_shape() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let art = rt.load("lm_tiny_grad").unwrap();
    // wrong arity
    assert!(art.execute(&[]).is_err());
    // right arity, wrong shape on the last input
    let meta = rt.manifest.model("lm_tiny").unwrap();
    let mut inputs: Vec<HostValue> = meta
        .params
        .iter()
        .map(|e| HostValue::F32(sm3::tensor::Tensor::zeros(&e.shape)))
        .collect();
    inputs.push(HostValue::I32 { shape: vec![1, 2], data: vec![0, 0] });
    assert!(art.execute(&inputs).is_err());
}

#[test]
fn training_reduces_loss_split() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(ExecMode::Split);
    cfg.steps = 30;
    let mut t = Trainer::with_runtime(cfg, rt).unwrap();
    let hist = t.train().unwrap();
    let first = hist.steps.first().unwrap().loss;
    let last = hist.steps.last().unwrap().loss;
    assert!(last < first - 0.3, "{first} -> {last}");
    assert!(hist.evals.iter().all(|e| e.loss.is_finite()));
}

#[test]
fn split_and_fused_paths_agree() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let mut a = Trainer::with_runtime(tiny_cfg(ExecMode::Split), rt.clone()).unwrap();
    let mut b = Trainer::with_runtime(tiny_cfg(ExecMode::Fused), rt).unwrap();
    let ha = a.train().unwrap();
    let hb = b.train().unwrap();
    for (sa, sb) in ha.steps.iter().zip(&hb.steps) {
        // L1 Pallas kernel (fused) vs pure-Rust optim bank (split):
        // same math, fp tolerance only
        assert!((sa.loss - sb.loss).abs() < 1e-4,
                "step {}: split {} vs fused {}", sa.step, sa.loss, sb.loss);
    }
    // final params agree too
    let pa = a.params();
    let pb = b.params();
    for (ta, tb) in pa.iter().zip(&pb) {
        for (x, y) in ta.data().iter().zip(tb.data()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }
}

#[test]
fn training_is_deterministic() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let mut a = Trainer::with_runtime(tiny_cfg(ExecMode::Split), rt.clone()).unwrap();
    let mut b = Trainer::with_runtime(tiny_cfg(ExecMode::Split), rt).unwrap();
    let ha = a.train().unwrap();
    let hb = b.train().unwrap();
    for (sa, sb) in ha.steps.iter().zip(&hb.steps) {
        assert_eq!(sa.loss, sb.loss);
    }
}

#[test]
fn multi_worker_differs_from_single_but_converges() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(ExecMode::Split);
    cfg.workers = 2;
    cfg.steps = 20;
    let mut t = Trainer::with_runtime(cfg, rt).unwrap();
    let hist = t.train().unwrap();
    let first = hist.steps.first().unwrap().loss;
    let last = hist.steps.last().unwrap().loss;
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn grad_accumulation_matches_effective_batch() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    // grad_accum=2 must produce finite decreasing loss as well
    let mut cfg = tiny_cfg(ExecMode::Split);
    cfg.grad_accum = 2;
    cfg.steps = 10;
    let mut t = Trainer::with_runtime(cfg, rt).unwrap();
    let hist = t.train().unwrap();
    assert!(hist.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn all_optimizers_train_tiny_model() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    for opt in ["sm3", "sm3i", "adagrad", "adam", "adafactor", "sgdm"] {
        let mut cfg = tiny_cfg(ExecMode::Split);
        cfg.optim.name = opt.into();
        cfg.optim.lr = match opt {
            "adam" => 0.01,
            "sgdm" => 0.05,
            _ => 0.3,
        };
        cfg.steps = 15;
        let mut t = Trainer::with_runtime(cfg, rt.clone()).unwrap();
        let hist = t.train().unwrap();
        let first = hist.steps.first().unwrap().loss;
        let last = hist.steps.last().unwrap().loss;
        assert!(last < first, "{opt}: {first} -> {last}");
    }
}

/// Regression (ISSUE 5 satellite): `compute_grads` draws from its own
/// forked probe stream, so interleaving trace probes with `train_step`
/// must not perturb the training trajectory at all — bitwise.
#[test]
fn compute_grads_probe_does_not_perturb_training() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(ExecMode::Split);
    let mut plain = Trainer::with_runtime(cfg.clone(), rt.clone()).unwrap();
    let mut probed = Trainer::with_runtime(cfg, rt).unwrap();
    for step in 0..4 {
        // probe before (and mid-run, repeatedly): worker streams and the
        // parameter trajectory must be unaffected
        let (l, g) = probed.compute_grads().unwrap();
        assert!(l.is_finite() && !g.is_empty());
        if step == 2 {
            probed.compute_grads().unwrap();
        }
        let la = plain.train_step().unwrap();
        let lb = probed.train_step().unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(), "step {step} loss diverged");
    }
    for (a, b) in plain.params().iter().zip(&probed.params()) {
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "params diverged: {x} {y}");
        }
    }
    // probes are deterministic too: two fresh trainers see the same
    // probe stream
    let mut c1 = Trainer::with_runtime(tiny_cfg(ExecMode::Split),
                                       plain.runtime().clone()).unwrap();
    let mut c2 = Trainer::with_runtime(tiny_cfg(ExecMode::Split),
                                       plain.runtime().clone()).unwrap();
    let (l1, _) = c1.compute_grads().unwrap();
    let (l2, _) = c2.compute_grads().unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits());
}

/// ISSUE 5 tentpole, end to end: comm thread count is invisible to the
/// trajectory at every wire dtype, the f32 comm path equals the default
/// config bitwise, q8 still converges, and comm_ms is reported for
/// multi-worker runs.
#[test]
fn comm_dtype_and_threads_train_end_to_end() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    for dtype in ["f32", "bf16", "q8"] {
        let run = |threads: usize| {
            let mut cfg = tiny_cfg(ExecMode::Split);
            cfg.workers = 2;
            cfg.steps = 10;
            cfg.comm_dtype = sm3::optim::StateDtype::parse(dtype).unwrap();
            cfg.comm_threads = threads;
            let mut t = Trainer::with_runtime(cfg, rt.clone()).unwrap();
            let hist = t.train().unwrap();
            assert!(hist.steps.iter().all(|s| s.comm_ms > 0.0),
                    "{dtype}: comm_ms must be reported multi-worker");
            hist
        };
        let serial = run(1);
        let threaded = run(2);
        for (a, b) in serial.steps.iter().zip(&threaded.steps) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(),
                       "{dtype}: comm_threads changed step {}", a.step);
        }
        let first = serial.steps.first().unwrap().loss;
        let last = serial.steps.last().unwrap().loss;
        assert!(last < first, "{dtype}: {first} -> {last}");
        // compressed wire must report fewer simulated ms than f32 would
        if dtype == "q8" {
            let f32_hist = {
                let mut cfg = tiny_cfg(ExecMode::Split);
                cfg.workers = 2;
                cfg.steps = 10;
                let mut t =
                    Trainer::with_runtime(cfg, rt.clone()).unwrap();
                t.train().unwrap()
            };
            assert!(serial.steps[0].comm_ms < f32_hist.steps[0].comm_ms,
                    "q8 exchange must be cheaper than f32 on the wire");
        }
    }
}

/// PR 7 tentpole, end to end: the telemetry knob is bitwise invisible
/// to the trajectory; enabled, it fills the widened per-phase StepRecord
/// columns and streams a parseable JSONL event log with a final summary.
#[test]
fn telemetry_is_trajectory_invisible_and_fills_phase_columns() {
    let _g = lock();
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("sm3_runtime_telemetry_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let jsonl = dir.join("events.jsonl");
    let run = |telemetry: bool| {
        let mut cfg = tiny_cfg(ExecMode::Split);
        cfg.workers = 2;
        cfg.steps = 8;
        cfg.telemetry = telemetry;
        if telemetry {
            cfg.telemetry_jsonl = Some(jsonl.to_str().unwrap().into());
        }
        let mut t = Trainer::with_runtime(cfg, rt.clone()).unwrap();
        t.train().unwrap()
    };
    let off = run(false);
    let on = run(true);
    // 1. bitwise-identical losses: telemetry changed no trajectory bit
    for (a, b) in off.steps.iter().zip(&on.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(),
                   "telemetry changed the loss at step {}", a.step);
    }
    // 2. disabled runs report zeroed phase columns; enabled runs
    // measure real grad/opt work (comm phases are >= 0: pack/unpack on
    // tiny tensors can round below a nanosecond tick)
    assert!(off.steps.iter().all(|s| s.grad_ms == 0.0 && s.opt_ms == 0.0));
    assert!(on.steps.iter().all(|s| s.grad_ms > 0.0),
            "enabled telemetry must time the grad phase");
    assert!(on.steps.iter().all(|s| s.opt_ms > 0.0),
            "enabled telemetry must time the optimizer phase");
    assert!(on.steps.iter().all(|s| {
        s.comm_pack_ms >= 0.0 && s.comm_hop_ms >= 0.0
            && s.comm_unpack_ms >= 0.0 && s.ckpt_ms >= 0.0
    }));
    // 3. the JSONL stream parses: one step event per step + a summary
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let mut steps = 0;
    let mut summaries = 0;
    for line in text.lines() {
        let ev = sm3::json::Json::parse(line).unwrap();
        match ev.get("type").and_then(|t| t.as_str()) {
            Some("step") => {
                steps += 1;
                assert!(ev.get("grad_ms").and_then(|v| v.as_f64())
                        .is_some());
            }
            Some("summary") => {
                summaries += 1;
                assert!(ev.get("registry").is_some());
            }
            other => panic!("unexpected event type {other:?}"),
        }
    }
    assert_eq!(steps, on.steps.len());
    assert_eq!(summaries, 1);
}

#[test]
fn init_checkpoint_matches_manifest_shapes() {
    let _g = lock();
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.model("lm_tiny").unwrap();
    let loaded = sm3::checkpoint::load(
        std::path::Path::new(dir).join("lm_tiny_init.ckpt")).unwrap();
    assert_eq!(loaded.len(), meta.params.len());
    for (name, t) in &loaded {
        let e = meta.params.iter().find(|e| &e.name == name).unwrap();
        assert_eq!(t.shape(), e.shape.as_slice());
    }
}
