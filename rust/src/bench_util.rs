//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmed-up, repeated timing with median/p10/p90 reporting and a
//! throughput helper. Bench binaries (`rust/benches/*.rs`, harness=false)
//! use this to print the rows that regenerate the paper's tables/figures;
//! output is plain text + CSV so EXPERIMENTS.md can quote it directly.

use std::time::{Duration, Instant};

/// One measured statistic set.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// items/second at the median.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} median  [{:>10} .. {:>10}]  ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Time `f` with automatic warmup; targets ~`budget` of measurement wall
/// time, at least `min_iters` iterations.
pub fn bench(name: &str, budget: Duration, min_iters: usize,
             mut f: impl FnMut()) -> Stats {
    // warmup: run until ~10% of budget spent or 3 iters
    let warm_start = Instant::now();
    let mut warm = 0;
    while warm < 3 || (warm_start.elapsed() < budget / 10 && warm < 1000) {
        f();
        warm += 1;
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || (start.elapsed() < budget && samples.len() < 10_000)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    Stats {
        name: name.to_string(),
        iters: n,
        median: samples[n / 2],
        p10: samples[n / 10],
        p90: samples[(n * 9) / 10],
        mean,
    }
}

/// Median-over-median speedup of `fast` relative to `base` (>1 ⇒ faster).
pub fn speedup(base: &Stats, fast: &Stats) -> f64 {
    base.median.as_secs_f64() / fast.median.as_secs_f64()
}

/// Simple CSV writer used by bench binaries to persist series for
/// EXPERIMENTS.md (and external plotting).
pub struct CsvWriter {
    out: std::io::BufWriter<std::fs::File>,
}

impl CsvWriter {
    pub fn create(path: &str, header: &str) -> std::io::Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        use std::io::Write;
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(out, "{header}")?;
        Ok(Self { out })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        use std::io::Write;
        writeln!(self.out, "{}", fields.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("spin", Duration::from_millis(20), 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.median > Duration::ZERO);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(2),
            p10: Duration::from_secs(2),
            p90: Duration::from_secs(2),
            mean: Duration::from_secs(2),
        };
        assert!((s.throughput(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_math() {
        let at = |ms: u64| Stats {
            name: "x".into(),
            iters: 1,
            median: Duration::from_millis(ms),
            p10: Duration::from_millis(ms),
            p90: Duration::from_millis(ms),
            mean: Duration::from_millis(ms),
        };
        assert!((speedup(&at(400), &at(100)) - 4.0).abs() < 1e-9);
        assert!(speedup(&at(100), &at(400)) < 1.0);
    }
}
