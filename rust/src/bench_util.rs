//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmed-up, repeated timing with median/p10/p90 reporting and a
//! throughput helper. Bench binaries (`rust/benches/*.rs`, harness=false)
//! use this to print the rows that regenerate the paper's tables/figures;
//! output is plain text + CSV so EXPERIMENTS.md can quote it directly.
//!
//! Timing runs on the telemetry clock ([`telemetry::now_ns`]) and every
//! sample is recorded into the process-wide bench registry
//! ([`telemetry::with_bench_registry`]) under the section name, so one
//! code path feeds the printed tables, the CSV series, AND the
//! end-of-run `BENCH_*.json` perf-trajectory documents
//! ([`write_bench_json`], DESIGN.md §14).

use crate::telemetry::{self, now_ns, Registry};
use std::time::Duration;

/// One measured statistic set.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// items/second at the median.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} median  [{:>10} .. {:>10}]  ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Time `f` with automatic warmup; targets ~`budget` of measurement wall
/// time, at least `min_iters` iterations. Every sample also lands in the
/// process-wide bench registry under `name`, so [`write_bench_json`]
/// sees exactly the distribution the printed table came from.
pub fn bench(name: &str, budget: Duration, min_iters: usize,
             mut f: impl FnMut()) -> Stats {
    let budget_ns = budget.as_nanos() as u64;
    // warmup: run until ~10% of budget spent or 3 iters
    let warm_start = now_ns();
    let mut warm = 0;
    while warm < 3
        || (now_ns().saturating_sub(warm_start) < budget_ns / 10
            && warm < 1000)
    {
        f();
        warm += 1;
    }
    // samples accumulate in a section-local registry and merge into the
    // global one at the end — one lock per section, not per iteration
    let mut section = Registry::new();
    let mut samples: Vec<u64> = Vec::new();
    let start = now_ns();
    while samples.len() < min_iters
        || (now_ns().saturating_sub(start) < budget_ns
            && samples.len() < 10_000)
    {
        let t0 = now_ns();
        f();
        let ns = now_ns().saturating_sub(t0);
        section.record_ns(name, ns);
        samples.push(ns);
    }
    telemetry::with_bench_registry(|reg| reg.merge(&section));
    samples.sort_unstable();
    let n = samples.len();
    let mean_ns = samples.iter().sum::<u64>() / n as u64;
    let at = |i: usize| Duration::from_nanos(samples[i]);
    Stats {
        name: name.to_string(),
        iters: n,
        median: at(n / 2),
        p10: at(n / 10),
        p90: at((n * 9) / 10),
        mean: Duration::from_nanos(mean_ns),
    }
}

/// True when the bench invocation asked for telemetry export: a
/// `--telemetry` argument (`cargo bench --bench X -- --telemetry`) or
/// `SM3_TELEMETRY=1` in the environment.
pub fn telemetry_requested(args: &[String]) -> bool {
    args.iter().any(|a| a == "--telemetry")
        || std::env::var("SM3_TELEMETRY").map_or(false, |v| v == "1")
}

/// Write the accumulated bench registry — every [`bench`] section run so
/// far in this process, plus whatever the calling thread's telemetry
/// cells hold (trainer phases, comm counters, memory gauges) — as a
/// `BENCH_*.json` document at `path`. The document is self-validated
/// against the schema before writing, so CI's `sm3-train bench-check`
/// can never fail on a file this function produced.
pub fn write_bench_json(bench: &str, quick: bool, path: &str)
                        -> anyhow::Result<()> {
    let mut reg = telemetry::with_bench_registry(|r| r.clone());
    telemetry::thread_snapshot_into(&mut reg);
    let doc = telemetry::bench_doc(bench, quick, &reg);
    telemetry::validate_bench_doc(&doc)
        .map_err(|e| anyhow::anyhow!("telemetry self-check failed: {e}"))?;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, format!("{doc}\n"))?;
    Ok(())
}

/// Median-over-median speedup of `fast` relative to `base` (>1 ⇒ faster).
pub fn speedup(base: &Stats, fast: &Stats) -> f64 {
    base.median.as_secs_f64() / fast.median.as_secs_f64()
}

/// Simple CSV writer used by bench binaries to persist series for
/// EXPERIMENTS.md (and external plotting).
pub struct CsvWriter {
    out: std::io::BufWriter<std::fs::File>,
}

impl CsvWriter {
    pub fn create(path: &str, header: &str) -> std::io::Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        use std::io::Write;
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(out, "{header}")?;
        Ok(Self { out })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        use std::io::Write;
        writeln!(self.out, "{}", fields.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("spin", Duration::from_millis(20), 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.median > Duration::ZERO);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn bench_samples_land_in_the_global_registry() {
        let prev = telemetry::with_bench_registry(|r| {
            r.span("bu_registry_section").map_or(0, |s| s.count)
        });
        let s = bench("bu_registry_section", Duration::from_millis(10), 4,
                      || {
                          std::hint::black_box((0..500).sum::<u64>());
                      });
        let agg = telemetry::with_bench_registry(|r| {
            *r.span("bu_registry_section").unwrap()
        });
        assert_eq!(agg.count - prev, s.iters as u64,
                   "every sample must reach the bench registry");
        assert!(agg.min_ns <= agg.max_ns);
    }

    #[test]
    fn write_bench_json_round_trips_through_the_checker() {
        bench("bu_json_section", Duration::from_millis(5), 3, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let dir = std::env::temp_dir().join("sm3_bench_util_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        write_bench_json("bench_unit", true, path.to_str().unwrap())
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::json::Json::parse(&text).unwrap();
        telemetry::validate_bench_doc(&doc).unwrap();
        assert_eq!(doc.get("bench").and_then(|b| b.as_str()),
                   Some("bench_unit"));
        assert!(doc.get("spans").unwrap().get("bu_json_section").is_some(),
                "the measured section must appear in the document");
    }

    #[test]
    fn telemetry_request_parses_bench_args() {
        let argv = |s: &[&str]| -> Vec<String> {
            s.iter().map(|x| x.to_string()).collect()
        };
        assert!(telemetry_requested(&argv(&["--telemetry"])));
        assert!(telemetry_requested(&argv(&["--bench", "--telemetry"])));
        assert!(!telemetry_requested(&argv(&["--bench"])));
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(2),
            p10: Duration::from_secs(2),
            p90: Duration::from_secs(2),
            mean: Duration::from_secs(2),
        };
        assert!((s.throughput(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_math() {
        let at = |ms: u64| Stats {
            name: "x".into(),
            iters: 1,
            median: Duration::from_millis(ms),
            p10: Duration::from_millis(ms),
            p90: Duration::from_millis(ms),
            mean: Duration::from_millis(ms),
        };
        assert!((speedup(&at(400), &at(100)) - 4.0).abs() < 1e-9);
        assert!(speedup(&at(100), &at(400)) < 1.0);
    }
}
