//! Command-line argument parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options,
//! and positional arguments, with generated usage text. The binary's
//! command tree lives in `main.rs`; this module is the mechanism.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments: flags, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("invalid value for --{name}: {e}"),
            },
        }
    }

    /// Parse a count-like option, rejecting zero (thread/worker knobs).
    pub fn opt_count(&self, name: &str) -> Result<Option<usize>> {
        match self.opt_parse::<usize>(name)? {
            Some(0) => bail!("--{name} must be >= 1"),
            other => Ok(other),
        }
    }
}

/// Option/flag declaration for usage text + validation.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// A subcommand declaration.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<Spec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, specs: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, takes_value: false, help });
        self
    }

    pub fn option(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, takes_value: true, help });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("  {} — {}\n", self.name, self.about);
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <value>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            s.push_str(&format!("      {arg:<28} {}\n", spec.help));
        }
        s
    }

    /// Parse the argument list following the subcommand name.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown option --{name} for {:?}\n{}",
                                        self.name, self.usage())
                    })?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!(
                                    "--{name} requires a value"))?
                        }
                    };
                    out.options.insert(name, value);
                } else {
                    if inline.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "run training")
            .option("config", "config file")
            .option("steps", "override step count")
            .flag("verbose", "chatty output")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = cmd()
            .parse(&argv(&["--config", "c.toml", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.opt("config"), Some("c.toml"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let a = cmd().parse(&argv(&["--steps=500"])).unwrap();
        assert_eq!(a.opt_parse::<u64>("steps").unwrap(), Some(500));
    }

    #[test]
    fn unknown_option_is_an_error() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(cmd().parse(&argv(&["--config"])).is_err());
    }

    #[test]
    fn opt_count_rejects_zero() {
        let a = cmd().parse(&argv(&["--steps", "0"])).unwrap();
        assert!(a.opt_count("steps").is_err());
        let a = cmd().parse(&argv(&["--steps", "4"])).unwrap();
        assert_eq!(a.opt_count("steps").unwrap(), Some(4));
        assert_eq!(a.opt_count("config").unwrap(), None);
    }

    #[test]
    fn bad_parse_reports_name() {
        let a = cmd().parse(&argv(&["--steps", "abc"])).unwrap();
        let e = a.opt_parse::<u64>("steps").unwrap_err();
        assert!(e.to_string().contains("steps"));
    }

    #[test]
    fn usage_mentions_options() {
        let u = cmd().usage();
        assert!(u.contains("--config"));
        assert!(u.contains("--verbose"));
    }
}
