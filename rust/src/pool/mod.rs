//! The unified memory-pool runtime (DESIGN.md §16): a size-classed slab
//! allocator that owns every steady-state and transient buffer in the
//! training process, so the memory accountant (`crate::memory`) stops
//! being a hand-maintained static mirror and becomes an assertion
//! against live occupancy — `memory::… == pool.bytes_in_use()` at step
//! boundaries, enforced in tests across optimizer × state dtype × comm
//! dtype × sharding mode.
//!
//! Shape of the thing (exemplar: kubecl's `exclusive_pool` — size-classed
//! exclusive pages with reuse):
//!
//! * A [`Pool`] is a cheaply clonable handle (`Arc` inside) holding one
//!   free shelf per element type (`f32` / `u16` / `u8`), each shelf
//!   bucketed by power-of-two size class. [`Pool::take`] hands out a
//!   [`PoolBuf`] lease; dropping the lease returns the backing storage
//!   to its class shelf — never to the system — so steady-state
//!   construct/teardown cycles stop paying reallocation spikes.
//! * Every lease carries a [`Tag`] naming its purpose, so occupancy is
//!   attributable: `bytes_in_use_tag(Tag::OptState)` is exactly the
//!   quantized-slot bytes, `Tag::CommFlat` the per-rank flat buffers,
//!   and so on. Accounting tracks *requested* (logical) bytes — the
//!   quantity the static accountant mirrors — while the rounded-up
//!   class capacity parked on shelves is reported separately by
//!   [`Pool::slab_bytes`].
//! * Acquire zero-fills the lease, so a recycled buffer is
//!   indistinguishable from a fresh `vec![0; n]`: pooling is bitwise
//!   invisible to every consumer (property-tested here and end-to-end
//!   in `crate::proptest`).
//! * [`Pool::disabled`] is the off position of the on/off axis: leases
//!   are still tagged and accounted (the occupancy gauges keep
//!   working), but dropped storage goes back to the system instead of a
//!   shelf. [`PoolBuf::unpooled`] is the zero-cost legacy mode — plain
//!   `Vec` semantics, no accounting — used by constructors that predate
//!   the pool so existing call sites keep their exact behavior.
//!
//! What stays un-pooled, and why: `Tensor` payloads (they are handed
//! across API boundaries by value), scalar state (Adam's `t`, transform
//! step counters — bytes, not buffers), and the bounded channel nodes
//! inside the Inproc transport (owned by `std::sync` primitives). See
//! DESIGN.md §16 for the full contract.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Purpose tag carried by every lease, making pool occupancy
/// attributable per subsystem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    /// Quantized optimizer-state slots (`optim::qstate`).
    OptState,
    /// Step-kernel decode scratch and the leaf-granular SM3/Adafactor
    /// working buffers.
    KernelScratch,
    /// Per-rank flat gradient buffers of the comm engine.
    CommFlat,
    /// Per-thread wire staging/codec scratch of the ring exchange.
    CommWire,
    /// Per-rank error-feedback residuals (compressed wire dtypes).
    CommResidual,
    /// Inproc-transport edge slots (serialized hop payloads).
    TransportSlot,
    /// Checkpoint stitch buffers reassembling split leaves.
    CkptStitch,
}

impl Tag {
    /// Number of tags (sizes the per-tag accounting arrays).
    pub const COUNT: usize = 7;

    /// Every tag, in declaration order.
    pub const ALL: [Tag; Tag::COUNT] = [
        Tag::OptState,
        Tag::KernelScratch,
        Tag::CommFlat,
        Tag::CommWire,
        Tag::CommResidual,
        Tag::TransportSlot,
        Tag::CkptStitch,
    ];

    /// Stable snake_case name (gauge keys, CSV columns).
    pub fn name(self) -> &'static str {
        match self {
            Tag::OptState => "opt_state",
            Tag::KernelScratch => "kernel_scratch",
            Tag::CommFlat => "comm_flat",
            Tag::CommWire => "comm_wire",
            Tag::CommResidual => "comm_residual",
            Tag::TransportSlot => "transport_slot",
            Tag::CkptStitch => "ckpt_stitch",
        }
    }

    fn index(self) -> usize {
        match self {
            Tag::OptState => 0,
            Tag::KernelScratch => 1,
            Tag::CommFlat => 2,
            Tag::CommWire => 3,
            Tag::CommResidual => 4,
            Tag::TransportSlot => 5,
            Tag::CkptStitch => 6,
        }
    }
}

/// One element type's free storage, bucketed by power-of-two class.
/// Public only as an implementation detail of the sealed [`PoolItem`]
/// trait.
#[doc(hidden)]
#[derive(Debug, Default)]
pub struct Shelves<T> {
    classes: Vec<Vec<Vec<T>>>,
}

impl<T> Shelves<T> {
    fn pop(&mut self, class: usize) -> Option<Vec<T>> {
        self.classes.get_mut(class).and_then(|c| c.pop())
    }

    fn push(&mut self, class: usize, v: Vec<T>) {
        if self.classes.len() <= class {
            self.classes.resize_with(class + 1, Vec::new);
        }
        self.classes[class].push(v);
    }
}

mod sealed {
    use super::{Pool, Shelves};
    use std::sync::Mutex;

    pub trait Sealed: Sized {
        fn shelves(pool: &Pool) -> &Mutex<Shelves<Self>>;
    }

    impl Sealed for f32 {
        fn shelves(pool: &Pool) -> &Mutex<Shelves<f32>> {
            &pool.inner.f32s
        }
    }

    impl Sealed for u16 {
        fn shelves(pool: &Pool) -> &Mutex<Shelves<u16>> {
            &pool.inner.u16s
        }
    }

    impl Sealed for u8 {
        fn shelves(pool: &Pool) -> &Mutex<Shelves<u8>> {
            &pool.inner.u8s
        }
    }
}

/// Element types the pool shelves: `f32`, `u16` (bf16 words), `u8`
/// (q8 codes, wire bytes). Sealed — the shelf set is fixed.
pub trait PoolItem:
    Copy + Default + Send + Sync + sealed::Sealed + 'static
{
}

impl PoolItem for f32 {}
impl PoolItem for u16 {}
impl PoolItem for u8 {}

struct Inner {
    enabled: bool,
    f32s: Mutex<Shelves<f32>>,
    u16s: Mutex<Shelves<u16>>,
    u8s: Mutex<Shelves<u8>>,
    /// requested (logical) bytes per tag, and their high-water marks
    in_use: [AtomicUsize; Tag::COUNT],
    peak: [AtomicUsize; Tag::COUNT],
    total_in_use: AtomicUsize,
    total_peak: AtomicUsize,
    /// capacity bytes parked on shelves (held, not in use)
    slab: AtomicUsize,
}

/// The pool handle. Cheap to clone; all clones share one shelf set and
/// one accounting ledger.
#[derive(Clone)]
pub struct Pool {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("enabled", &self.inner.enabled)
            .field("bytes_in_use", &self.bytes_in_use())
            .field("peak_bytes", &self.peak_bytes())
            .field("slab_bytes", &self.slab_bytes())
            .finish()
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::new()
    }
}

/// Smallest class capacity handed out for non-empty requests.
const MIN_CLASS_ELEMS: usize = 16;

/// Class index for a request of `n` elements: the exponent of the
/// smallest power of two ≥ `max(n, MIN)`.
fn request_class(n: usize) -> usize {
    let c = n.max(MIN_CLASS_ELEMS).next_power_of_two();
    c.trailing_zeros() as usize
}

/// Class index a retiring buffer of `cap` elements files under: the
/// exponent of the largest power of two ≤ `cap` (so every buffer on
/// shelf `c` has capacity ≥ 2^c, which is what `request_class` assumes).
fn capacity_class(cap: usize) -> usize {
    debug_assert!(cap > 0);
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

impl Pool {
    /// A live pool: leases recycle through size-classed shelves.
    pub fn new() -> Self {
        Self::with_enabled(true)
    }

    /// The off position of the pool on/off axis: leases are tagged and
    /// accounted identically, but dropped storage is freed instead of
    /// shelved. Bitwise-identical to [`Pool::new`] by construction
    /// (acquire zero-fills either way) — property-tested.
    pub fn disabled() -> Self {
        Self::with_enabled(false)
    }

    fn with_enabled(enabled: bool) -> Self {
        Pool {
            inner: Arc::new(Inner {
                enabled,
                f32s: Mutex::new(Shelves::default()),
                u16s: Mutex::new(Shelves::default()),
                u8s: Mutex::new(Shelves::default()),
                in_use: std::array::from_fn(|_| AtomicUsize::new(0)),
                peak: std::array::from_fn(|_| AtomicUsize::new(0)),
                total_in_use: AtomicUsize::new(0),
                total_peak: AtomicUsize::new(0),
                slab: AtomicUsize::new(0),
            }),
        }
    }

    /// Is reuse on (see [`Pool::disabled`])?
    pub fn is_enabled(&self) -> bool {
        self.inner.enabled
    }

    /// Lease a zero-filled buffer of `n` elements under `tag`. The
    /// lease returns its storage to the pool when dropped.
    pub fn take<T: PoolItem>(&self, tag: Tag, n: usize) -> PoolBuf<T> {
        let mut data: Vec<T> = if self.inner.enabled && n > 0 {
            let recycled = {
                let mut shelves = T::shelves(self).lock().unwrap();
                shelves.pop(request_class(n))
            };
            match recycled {
                Some(v) => {
                    self.inner.slab.fetch_sub(
                        v.capacity() * std::mem::size_of::<T>(),
                        Ordering::Relaxed);
                    v
                }
                None => Vec::with_capacity(
                    1usize << request_class(n)),
            }
        } else {
            Vec::new()
        };
        // zero-fill: a recycled lease is indistinguishable from a fresh
        // `vec![0; n]` (the pooling-is-bitwise-invisible contract)
        data.clear();
        data.resize(n, T::default());
        self.add_bytes(tag, n * std::mem::size_of::<T>());
        PoolBuf { data, tag, pool: Some(self.clone()) }
    }

    /// [`Pool::take`] monomorphized to `f32` (reads better at call
    /// sites that would otherwise need a turbofish).
    pub fn take_f32(&self, tag: Tag, n: usize) -> PoolBuf<f32> {
        self.take(tag, n)
    }

    /// [`Pool::take`] monomorphized to `u16`.
    pub fn take_u16(&self, tag: Tag, n: usize) -> PoolBuf<u16> {
        self.take(tag, n)
    }

    /// [`Pool::take`] monomorphized to `u8`.
    pub fn take_u8(&self, tag: Tag, n: usize) -> PoolBuf<u8> {
        self.take(tag, n)
    }

    fn release<T: PoolItem>(&self, tag: Tag, mut data: Vec<T>) {
        self.sub_bytes(tag, data.len() * std::mem::size_of::<T>());
        if self.inner.enabled && data.capacity() > 0 {
            data.clear();
            let class = capacity_class(data.capacity());
            self.inner.slab.fetch_add(
                data.capacity() * std::mem::size_of::<T>(),
                Ordering::Relaxed);
            T::shelves(self).lock().unwrap().push(class, data);
        }
        // disabled (or zero-capacity): storage drops back to the system
    }

    fn add_bytes(&self, tag: Tag, bytes: usize) {
        if bytes == 0 {
            return;
        }
        let i = tag.index();
        let new =
            self.inner.in_use[i].fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inner.peak[i].fetch_max(new, Ordering::Relaxed);
        let total = self.inner.total_in_use.fetch_add(bytes, Ordering::Relaxed)
            + bytes;
        self.inner.total_peak.fetch_max(total, Ordering::Relaxed);
    }

    fn sub_bytes(&self, tag: Tag, bytes: usize) {
        if bytes == 0 {
            return;
        }
        self.inner.in_use[tag.index()].fetch_sub(bytes, Ordering::Relaxed);
        self.inner.total_in_use.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Requested (logical) bytes currently leased, across all tags —
    /// the live quantity the static accountant must equal at step
    /// boundaries.
    pub fn bytes_in_use(&self) -> usize {
        self.inner.total_in_use.load(Ordering::Relaxed)
    }

    /// Requested bytes currently leased under `tag`.
    pub fn bytes_in_use_tag(&self, tag: Tag) -> usize {
        self.inner.in_use[tag.index()].load(Ordering::Relaxed)
    }

    /// High-water mark of [`Pool::bytes_in_use`].
    pub fn peak_bytes(&self) -> usize {
        self.inner.total_peak.load(Ordering::Relaxed)
    }

    /// High-water mark of [`Pool::bytes_in_use_tag`].
    pub fn peak_bytes_tag(&self, tag: Tag) -> usize {
        self.inner.peak[tag.index()].load(Ordering::Relaxed)
    }

    /// Capacity bytes parked on free shelves (held for reuse, not in
    /// use). Zero for a [`Pool::disabled`] pool.
    pub fn slab_bytes(&self) -> usize {
        self.inner.slab.load(Ordering::Relaxed)
    }

    /// Export the occupancy ledger as telemetry gauges:
    /// `mem/pool_bytes{,_peak}`, `mem/pool_slab_bytes`, and the per-tag
    /// set `mem/pool/<tag>_bytes{,_peak}`.
    pub fn export_gauges(&self, reg: &mut crate::telemetry::Registry) {
        reg.gauge("mem/pool_bytes", self.bytes_in_use() as u64);
        reg.gauge("mem/pool_bytes_peak", self.peak_bytes() as u64);
        reg.gauge("mem/pool_slab_bytes", self.slab_bytes() as u64);
        for tag in Tag::ALL {
            reg.gauge(&format!("mem/pool/{}_bytes", tag.name()),
                      self.bytes_in_use_tag(tag) as u64);
            reg.gauge(&format!("mem/pool/{}_bytes_peak", tag.name()),
                      self.peak_bytes_tag(tag) as u64);
        }
    }
}

/// An RAII lease on pool storage. Dereferences to `[T]`; mutate through
/// the slice, grow with [`PoolBuf::resize`] / [`PoolBuf::ensure`] (both
/// keep the ledger exact). Dropping the lease returns the storage to
/// its size-class shelf (or frees it — disabled pool / unpooled mode).
#[derive(Debug)]
pub struct PoolBuf<T: PoolItem> {
    data: Vec<T>,
    tag: Tag,
    pool: Option<Pool>,
}

impl<T: PoolItem> PoolBuf<T> {
    /// An empty legacy-mode buffer: plain `Vec` semantics, no pool, no
    /// accounting. Constructors that predate the pool use this so their
    /// call sites keep their exact behavior.
    pub fn unpooled(tag: Tag) -> Self {
        PoolBuf { data: Vec::new(), tag, pool: None }
    }

    /// Wrap an existing vector as a legacy-mode (unaccounted) buffer.
    pub fn from_vec(tag: Tag, data: Vec<T>) -> Self {
        PoolBuf { data, tag, pool: None }
    }

    /// This lease's purpose tag.
    pub fn tag(&self) -> Tag {
        self.tag
    }

    /// Logical length in elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the buffer zero-length?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Backing capacity in elements (exceeds `len` after class
    /// round-up or shrinking resizes).
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    /// Resize to exactly `n` elements, zero-filling growth — mirrors
    /// `Vec::resize(n, 0)`, with the ledger adjusted by the delta.
    pub fn resize(&mut self, n: usize) {
        let before = self.data.len();
        self.data.resize(n, T::default());
        self.reconcile(before);
    }

    /// Resize to exactly `n` elements, filling growth with `v` —
    /// mirrors `Vec::resize(n, v)`, with the ledger adjusted by the
    /// delta.
    pub fn resize_fill(&mut self, n: usize, v: T) {
        let before = self.data.len();
        self.data.resize(n, v);
        self.reconcile(before);
    }

    /// Grow-only resize: after this, `len() >= n` (new elements
    /// zero-filled); never shrinks, so steady-state lengths are
    /// order-independent high-water marks.
    pub fn ensure(&mut self, n: usize) {
        if n > self.data.len() {
            self.resize(n);
        }
    }

    /// Truncate to zero length (ledger drops to zero for this lease;
    /// capacity is retained).
    pub fn clear(&mut self) {
        let before = self.data.len();
        self.data.clear();
        self.reconcile(before);
    }

    /// Append a slice — mirrors `Vec::extend_from_slice`, accounted.
    pub fn extend_from_slice(&mut self, s: &[T]) {
        let before = self.data.len();
        self.data.extend_from_slice(s);
        self.reconcile(before);
    }

    /// Copy out as a plain vector (checkpoint/Tensor hand-off).
    pub fn to_vec(&self) -> Vec<T> {
        self.data.clone()
    }

    /// View as a slice (explicit form of the deref).
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    /// View as a mutable slice (explicit form of the deref).
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Lend the backing `Vec` to a closure written against `Vec`
    /// (e.g. `QSlot::read_into`, the `ChunkCursor` scratch), then
    /// reconcile the ledger against whatever length it left behind.
    /// This keeps pre-pool helpers byte-for-byte unchanged while their
    /// scratch lives in the pool.
    pub fn with_vec<R>(&mut self, f: impl FnOnce(&mut Vec<T>) -> R) -> R {
        let before = self.data.len();
        let r = f(&mut self.data);
        self.reconcile(before);
        r
    }

    fn reconcile(&self, before: usize) {
        let after = self.data.len();
        if let Some(pool) = &self.pool {
            let eb = std::mem::size_of::<T>();
            if after > before {
                pool.add_bytes(self.tag, (after - before) * eb);
            } else if before > after {
                pool.sub_bytes(self.tag, (before - after) * eb);
            }
        }
    }
}

impl<T: PoolItem> Drop for PoolBuf<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            let data = std::mem::take(&mut self.data);
            pool.release(self.tag, data);
        }
    }
}

impl<T: PoolItem> std::ops::Deref for PoolBuf<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        &self.data
    }
}

impl<T: PoolItem> std::ops::DerefMut for PoolBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_tracks_requested_bytes_per_tag() {
        let pool = Pool::new();
        let a = pool.take_f32(Tag::OptState, 100); // 400 B
        let b = pool.take_u8(Tag::OptState, 100); // 100 B
        let c = pool.take_u16(Tag::CommWire, 50); // 100 B
        assert_eq!(pool.bytes_in_use_tag(Tag::OptState), 500);
        assert_eq!(pool.bytes_in_use_tag(Tag::CommWire), 100);
        assert_eq!(pool.bytes_in_use(), 600);
        assert_eq!(pool.peak_bytes(), 600);
        drop(a);
        assert_eq!(pool.bytes_in_use_tag(Tag::OptState), 100);
        assert_eq!(pool.bytes_in_use(), 200);
        drop(b);
        drop(c);
        assert_eq!(pool.bytes_in_use(), 0);
        // peaks persist past release
        assert_eq!(pool.peak_bytes(), 600);
        assert_eq!(pool.peak_bytes_tag(Tag::OptState), 500);
        // requested bytes, not class capacity: 100 f32 rounds to a
        // 128-element class, parked on the shelf after release
        assert_eq!(pool.slab_bytes(), 128 * 4 + 128 + 64 * 2);
    }

    #[test]
    fn leases_are_zero_filled_even_when_recycled() {
        let pool = Pool::new();
        let mut a = pool.take_f32(Tag::KernelScratch, 64);
        for v in a.iter_mut() {
            *v = 7.5;
        }
        drop(a);
        let b = pool.take_f32(Tag::KernelScratch, 40);
        assert!(b.iter().all(|&v| v.to_bits() == 0),
                "recycled lease must read as fresh zeros");
        assert!(b.capacity() >= 64, "lease should reuse the shelved slab");
    }

    #[test]
    fn steady_state_acquire_release_reuses_storage() {
        let pool = Pool::new();
        // warm one slab into the 64..128 class
        drop(pool.take_f32(Tag::CommFlat, 100));
        let held = pool.slab_bytes();
        assert!(held >= 100 * 4);
        for _ in 0..10 {
            let x = pool.take_f32(Tag::CommFlat, 70); // same class (128)
            assert_eq!(pool.slab_bytes(), 0, "the one slab is out on lease");
            drop(x);
            assert_eq!(pool.slab_bytes(), held, "slab returned, not freed");
        }
    }

    #[test]
    fn disabled_pool_accounts_but_never_shelves() {
        let pool = Pool::disabled();
        assert!(!pool.is_enabled());
        let a = pool.take_f32(Tag::CommResidual, 64);
        assert_eq!(pool.bytes_in_use(), 256);
        drop(a);
        assert_eq!(pool.bytes_in_use(), 0);
        assert_eq!(pool.slab_bytes(), 0);
        let b = pool.take_f32(Tag::CommResidual, 64);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn resize_ensure_clear_keep_the_ledger_exact() {
        let pool = Pool::new();
        let mut a = pool.take_f32(Tag::KernelScratch, 0);
        assert_eq!(pool.bytes_in_use(), 0);
        a.resize(10);
        assert_eq!(pool.bytes_in_use(), 40);
        a.ensure(4); // grow-only: no shrink
        assert_eq!(a.len(), 10);
        a.ensure(32);
        assert_eq!(pool.bytes_in_use(), 128);
        assert!(a[10..].iter().all(|&v| v == 0.0));
        a.resize(8);
        assert_eq!(pool.bytes_in_use(), 32);
        a.with_vec(|v| v.extend_from_slice(&[1.0; 8]));
        assert_eq!(pool.bytes_in_use(), 64);
        a.clear();
        assert_eq!(pool.bytes_in_use(), 0);
        assert_eq!(pool.peak_bytes(), 128);
        drop(a);
        assert_eq!(pool.bytes_in_use(), 0);
    }

    #[test]
    fn unpooled_mode_is_plain_vec_semantics() {
        let pool = Pool::new();
        let mut u: PoolBuf<f32> = PoolBuf::unpooled(Tag::KernelScratch);
        u.resize(100);
        u.extend_from_slice(&[1.0; 28]);
        assert_eq!(u.len(), 128);
        assert_eq!(pool.bytes_in_use(), 0, "unpooled leases are invisible");
        drop(u); // and drop frees — nothing to assert beyond not crashing
        let w = PoolBuf::from_vec(Tag::CkptStitch, vec![2.0f32; 3]);
        assert_eq!(w.as_slice(), &[2.0, 2.0, 2.0]);
    }

    /// Satellite gate: interleaved acquire/release across threads never
    /// changes the contents a consumer observes — every lease arrives
    /// zeroed, holds exactly its own writes, and two live leases never
    /// alias.
    #[test]
    fn interleaved_threaded_leases_are_isolated_and_deterministic() {
        let pool = Pool::new();
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                let pool = pool.clone();
                scope.spawn(move || {
                    for round in 0..200u32 {
                        let n = 16 + ((t * 37 + round * 13) % 300) as usize;
                        let mut buf = pool.take_f32(Tag::KernelScratch, n);
                        assert!(buf.iter().all(|&v| v.to_bits() == 0),
                                "thread {t} round {round}: dirty lease");
                        let mark = (t * 1000 + round) as f32;
                        for v in buf.iter_mut() {
                            *v = mark;
                        }
                        // another thread acquiring concurrently must not
                        // see or clobber this lease
                        assert!(buf.iter().all(|&v| v == mark),
                                "thread {t} round {round}: lease aliased");
                    }
                });
            }
        });
        assert_eq!(pool.bytes_in_use(), 0);
        assert!(pool.slab_bytes() > 0);
    }

    #[test]
    fn size_classes_cover_the_range() {
        assert_eq!(request_class(1), 4); // MIN_CLASS_ELEMS = 16 = 2^4
        assert_eq!(request_class(16), 4);
        assert_eq!(request_class(17), 5);
        assert_eq!(request_class(4096), 12);
        assert_eq!(request_class(4097), 13);
        for cap in [16usize, 17, 31, 32, 100, 4096] {
            // a buffer filed under its capacity class satisfies any
            // request routed to that class
            let c = capacity_class(cap);
            assert!(cap >= 1 << c);
            assert!(cap < 1 << (c + 1));
        }
    }

    /// Steady-state acquire/release cycles after warmup hit the shelves,
    /// not the system allocator.
    #[test]
    fn warm_cycles_are_allocation_free() {
        let pool = Pool::new();
        // warm every class this loop touches
        for n in [64usize, 100, 256, 1000] {
            drop(pool.take_f32(Tag::CommFlat, n));
            drop(pool.take_u8(Tag::CommWire, n));
        }
        let allocs = crate::alloc_count::thread_allocs();
        for _ in 0..50 {
            for n in [64usize, 100, 256, 1000] {
                let f = pool.take_f32(Tag::CommFlat, n);
                let b = pool.take_u8(Tag::CommWire, n);
                std::hint::black_box((&f[..], &b[..]));
            }
        }
        assert_eq!(crate::alloc_count::thread_allocs() - allocs, 0,
                   "warm lease cycles must not touch the heap");
    }
}
