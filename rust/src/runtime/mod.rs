//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` bindings (PJRT C API, CPU plugin): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — jax ≥0.5 serialized protos carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md §9).
//!
//! In this offline build the `xla` crate is replaced by the in-crate
//! `runtime/xla.rs` stub (the native xla_extension cannot be fetched);
//! artifact execution errors out with a clear message while everything
//! else — manifest validation, `HostValue` plumbing, the whole optimizer
//! and data stack — works and is tested.
//!
//! Compiled executables are cached per artifact name; values crossing the
//! boundary are [`HostValue`]s (f32 tensors or i32 index arrays) built and
//! validated against the manifest signature.

pub mod manifest;
mod xla;

use anyhow::{anyhow, bail, Context, Result};
use manifest::{ArtifactSpec, Dtype, Manifest};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::tensor::Tensor;

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32(Tensor::from_vec(&[], vec![v]))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostValue::F32(_) => Dtype::F32,
            HostValue::I32 { .. } => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 value"),
        }
    }

    /// Extract the single element of a rank-0/1-element f32 value.
    pub fn scalar(&self) -> Result<f32> {
        let t = self.as_f32()?;
        if t.len() != 1 {
            bail!("expected scalar, got shape {:?}", t.shape());
        }
        Ok(t.data()[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostValue::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            HostValue::I32 { data, .. } => {
                xla::Literal::vec1(data.as_slice()).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, entry: &manifest::IoEntry)
                    -> Result<Self> {
        match entry.dtype {
            Dtype::F32 => {
                let data = lit.to_vec::<f32>()
                    .with_context(|| format!("reading output {}", entry.name))?;
                if data.len() != entry.numel() {
                    bail!("output {}: got {} elems, manifest says {:?}",
                          entry.name, data.len(), entry.shape);
                }
                Ok(HostValue::F32(Tensor::from_vec(&entry.shape, data)))
            }
            Dtype::I32 => {
                let data = lit.to_vec::<i32>()
                    .with_context(|| format!("reading output {}", entry.name))?;
                if data.len() != entry.numel() {
                    bail!("output {}: wrong element count", entry.name);
                }
                Ok(HostValue::I32 { shape: entry.shape.clone(), data })
            }
        }
    }
}

/// A loaded, compiled artifact.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the underlying PJRT C API is thread-safe (the CPU client
// serializes compilation and execution internally); the `xla` wrapper types
// are only non-Send/Sync because they hold raw pointers. All mutable
// Rust-side state (the executable cache) is behind a Mutex.
unsafe impl Send for Artifact {}
unsafe impl Sync for Artifact {}

impl Artifact {
    /// Execute with host values; validates arity/shape/dtype against the
    /// manifest and returns outputs in manifest order.
    pub fn execute(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!("{}: expected {} inputs, got {}",
                  self.spec.name, self.spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (v, e) in inputs.iter().zip(&self.spec.inputs) {
            if v.shape() != e.shape.as_slice() || v.dtype() != e.dtype {
                bail!("{}: input {} expects {:?} {:?}, got {:?} {:?}",
                      self.spec.name, e.name, e.dtype, e.shape,
                      v.dtype(), v.shape());
            }
            literals.push(v.to_literal()?);
        }
        let bufs = self.exe.execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let result = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}: expected {} outputs, got {}",
                  self.spec.name, self.spec.outputs.len(), parts.len());
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, e)| HostValue::from_literal(lit, e))
            .collect()
    }

    /// Outputs whose names mirror `prefix/…` inputs (loop-carried state).
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }
}

/// The runtime: PJRT client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
}

// SAFETY: see the note on [`Artifact`].
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json` from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let artifact = Arc::new(Artifact { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Names of all artifacts for a given model.
    pub fn artifacts_for_model(&self, model: &str) -> Vec<String> {
        self.manifest
            .artifacts
            .values()
            .filter(|a| a.model == model)
            .map(|a| a.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_shapes() {
        let v = HostValue::scalar_f32(1.5);
        assert_eq!(v.shape(), &[] as &[usize]);
        assert_eq!(v.scalar().unwrap(), 1.5);
        let t = HostValue::I32 { shape: vec![2, 2], data: vec![1, 2, 3, 4] };
        assert_eq!(t.dtype(), Dtype::I32);
        assert!(t.scalar().is_err());
    }

    // Integration tests that actually execute artifacts live in
    // rust/tests/runtime_integration.rs (they need `make artifacts`).
}
