//! Artifact manifest: the calling convention contract with `aot.py`.
//!
//! `manifest.json` describes every AOT artifact's ordered inputs/outputs
//! (names, shapes, dtypes) plus per-model metadata (parameter inventory,
//! vocab/seq/batch geometry). The Rust side trusts nothing else about the
//! HLO files — all literal construction is driven from here.

use crate::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a tensor crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One named tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct IoEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let name = j.get("name").and_then(Json::as_str)
            .ok_or_else(|| anyhow!("io entry missing name"))?.to_string();
        let shape = j.get("shape").and_then(Json::as_array)
            .ok_or_else(|| anyhow!("io entry {name} missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype").and_then(Json::as_str)
                .ok_or_else(|| anyhow!("io entry {name} missing dtype"))?)?;
        Ok(Self { name, shape, dtype })
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    /// "grad" | "eval" | "decode" | "train:<opt>"
    pub kind: String,
    pub inputs: Vec<IoEntry>,
    pub outputs: Vec<IoEntry>,
}

impl ArtifactSpec {
    /// Indices of inputs whose name starts with `prefix + "/"`.
    pub fn input_range(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, e)| e.name.starts_with(prefix)
                    && e.name[prefix.len()..].starts_with('/'))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|e| e.name == name)
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|e| e.name == name)
    }
}

/// Per-model metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    /// "lm" | "mt" | "mlm" | "img"
    pub kind: String,
    pub batch: usize,
    pub param_count: usize,
    /// parameter leaves with `params/` prefix, in artifact input order
    pub params: Vec<IoEntry>,
    /// task geometry (absent fields are 0)
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_masked: usize,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub n_classes: usize,
}

impl ModelMeta {
    /// Parameter specs with the `params/` prefix stripped — feeds the
    /// optimizer bank and the memory accountant.
    pub fn param_specs(&self) -> Vec<crate::optim::ParamSpec> {
        self.params
            .iter()
            .map(|e| crate::optim::ParamSpec::new(
                e.name.strip_prefix("params/").unwrap_or(&e.name),
                &e.shape))
            .collect()
    }
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelMeta>,
}

fn get_usize(j: &Json, key: &str) -> usize {
    j.get(key).and_then(Json::as_usize).unwrap_or(0)
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        for (name, j) in root
            .get("artifacts")
            .and_then(Json::as_object)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let inputs = j.get("inputs").and_then(Json::as_array)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter().map(IoEntry::parse).collect::<Result<Vec<_>>>()?;
            let outputs = j.get("outputs").and_then(Json::as_array)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter().map(IoEntry::parse).collect::<Result<Vec<_>>>()?;
            artifacts.insert(name.clone(), ArtifactSpec {
                name: name.clone(),
                file: j.get("file").and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: missing file"))?.into(),
                model: j.get("model").and_then(Json::as_str)
                    .unwrap_or_default().into(),
                kind: j.get("kind").and_then(Json::as_str)
                    .unwrap_or_default().into(),
                inputs,
                outputs,
            });
        }
        let mut models = BTreeMap::new();
        for (name, j) in root
            .get("models")
            .and_then(Json::as_object)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let params = j.get("params").and_then(Json::as_array)
                .ok_or_else(|| anyhow!("{name}: missing params"))?
                .iter().map(IoEntry::parse).collect::<Result<Vec<_>>>()?;
            models.insert(name.clone(), ModelMeta {
                name: name.clone(),
                kind: j.get("kind").and_then(Json::as_str)
                    .unwrap_or_default().into(),
                batch: get_usize(j, "batch"),
                param_count: get_usize(j, "param_count"),
                params,
                vocab: get_usize(j, "vocab"),
                seq: get_usize(j, "seq"),
                d_model: get_usize(j, "d_model"),
                n_masked: get_usize(j, "n_masked"),
                height: get_usize(j, "height"),
                width: get_usize(j, "width"),
                channels: get_usize(j, "channels"),
                n_classes: get_usize(j, "n_classes"),
            });
        }
        Ok(Self { artifacts, models })
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "m_grad": {
          "file": "m_grad.hlo.txt", "model": "m", "kind": "grad",
          "inputs": [
            {"name": "params/w", "shape": [4, 2], "dtype": "f32"},
            {"name": "batch/tokens", "shape": [2, 8], "dtype": "i32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "grads/w", "shape": [4, 2], "dtype": "f32"}
          ]
        }
      },
      "models": {
        "m": {
          "kind": "lm", "batch": 2, "param_count": 8,
          "vocab": 64, "seq": 8, "d_model": 4,
          "params": [{"name": "params/w", "shape": [4, 2], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("m_grad").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.outputs[0].shape.len(), 0);
        let meta = m.model("m").unwrap();
        assert_eq!(meta.vocab, 64);
        assert_eq!(meta.param_specs()[0].name, "w");
    }

    #[test]
    fn input_range_by_prefix() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("m_grad").unwrap();
        assert_eq!(a.input_range("params"), vec![0]);
        assert_eq!(a.input_range("batch"), vec![1]);
        assert_eq!(a.input_range("param"), Vec::<usize>::new());
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
