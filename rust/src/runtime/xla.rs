//! Minimal in-crate stand-in for the `xla` (PJRT) bindings.
//!
//! The real `xla` crate links `xla_extension` (a native PJRT CPU plugin)
//! which cannot be fetched or built in this offline environment, so the
//! seed's `extern crate xla` could never resolve — this module provides the
//! exact API surface `runtime::mod` compiles against instead. Every
//! non-runtime layer (optimizer bank, data pipelines, config, metrics,
//! memory accountant, checkpointing) is fully functional and testable; only
//! artifact *execution* is gated, at [`HloModuleProto::from_text_file`],
//! with an error naming the missing dependency. The integration tests under
//! `rust/tests/` skip themselves when `artifacts/` is absent, so the gate
//! is reached only if someone ships HLO artifacts without swapping in the
//! real bindings.
//!
//! Swapping back: delete this module, add the `xla` crate (plus
//! `XLA_EXTENSION_DIR`) to `Cargo.toml`, and remove the `mod xla;` line in
//! `runtime/mod.rs` — the call sites are bit-for-bit the real crate's API.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's far enough for `{e:?}` formatting
/// and `anyhow` conversion at the call sites.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the PJRT runtime is stubbed in this offline build (the \
         `xla` crate and its native xla_extension are unavailable); swap in \
         the real bindings to execute HLO artifacts"
    ))
}

/// Element types that cross the literal boundary.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal. The stub never materializes device buffers, so this
/// is an empty token; conversions out of it return the gated error.
#[derive(Debug, Clone)]
pub struct Literal {}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal {})
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// A device buffer handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. Creation succeeds so `Runtime::new` can load and
/// validate a manifest without the native plugin; compilation is the
/// first operation that requires the real runtime.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient {})
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stubbed — artifacts cannot execute)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module. Parsing is the gate point: it fails before any
/// artifact bytes are trusted.
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creates_but_execution_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let err = HloModuleProto::from_text_file("nope.hlo.txt").unwrap_err();
        assert!(format!("{err}").contains("xla"), "{err}");
    }

    #[test]
    fn literals_round_shape_but_not_data() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let _ = Literal::vec1(&[1i32, 2]); // i32 path compiles too
    }
}
