//! Synthetic parallel corpus — the WMT'14 stand-in (Fig. 2 / Fig. 6 /
//! Table 1 experiments).
//!
//! Construction: a Zipf-distributed lexicon of generated source "words";
//! the target language applies a deterministic word-level mapping
//! (character rotation + suffix marking) and a local reordering rule
//! (adjacent pairs beginning with the same letter are swapped). Both sides
//! are encoded with a shared miniature-BPE [`Tokenizer`] — the same shared
//! word-piece setup as the paper. The task is learnable by a small
//! encoder-decoder transformer and scored with corpus BLEU, and the
//! Zipfian word frequencies produce the sparse embedding-row activation
//! patterns SM3's cover exploits.

use super::tokenizer::Tokenizer;
use super::{Batch, BatchSource};
use crate::rng::{Rng, Zipf};
use crate::runtime::HostValue;
use crate::vocab;

/// Number of lexicon words; sentence length range in words.
const LEXICON: usize = 120;
const MIN_WORDS: usize = 2;
const MAX_WORDS: usize = 5;
const N_EVAL: usize = 8;

/// Deterministic "translation" of one source word.
fn translate_word(src: &str) -> String {
    // rotate characters by one and append a marker suffix
    let mut cs: Vec<char> = src.chars().collect();
    cs.rotate_left(1);
    let mut t: String = cs.into_iter().collect();
    t.push('q');
    t
}

/// Generate the source lexicon: pronounceable CV(C) syllable words.
fn make_lexicon(rng: &mut Rng) -> Vec<String> {
    const CONS: &[u8] = b"bdfgklmnprstvz";
    const VOWS: &[u8] = b"aeiou";
    let mut words = Vec::with_capacity(LEXICON);
    while words.len() < LEXICON {
        let syllables = 1 + rng.index(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push(CONS[rng.index(CONS.len())] as char);
            w.push(VOWS[rng.index(VOWS.len())] as char);
        }
        if !words.contains(&w) {
            words.push(w);
        }
    }
    words
}

/// A sentence pair in word space.
fn make_pair(lex: &[String], zipf: &Zipf, rng: &mut Rng)
             -> (Vec<String>, Vec<String>) {
    let n = MIN_WORDS + rng.index(MAX_WORDS - MIN_WORDS + 1);
    let src: Vec<String> =
        (0..n).map(|_| lex[zipf.sample(rng)].clone()).collect();
    // target: translate words, then swap adjacent pairs that start with
    // the same letter (a local-reordering rule the decoder must learn)
    let mut tgt: Vec<String> = src.iter().map(|w| translate_word(w)).collect();
    let mut i = 0;
    while i + 1 < tgt.len() {
        if src[i].as_bytes()[0] == src[i + 1].as_bytes()[0] {
            tgt.swap(i, i + 1);
            i += 2;
        } else {
            i += 1;
        }
    }
    (src, tgt)
}

/// The translation batch source.
pub struct MtSource {
    seq: usize,
    batch: usize,
    tokenizer: Tokenizer,
    lexicon: Vec<String>,
    zipf: Zipf,
    rng: Rng,
    eval: Vec<(Vec<i32>, Vec<i32>)>,
    /// reference (tokenized) targets for BLEU, aligned with eval batches
    eval_refs: Vec<Vec<Vec<i32>>>,
}

impl MtSource {
    pub fn new(vocab_size: usize, seq: usize, batch: usize, seed: u64) -> Self {
        // the corpus itself (lexicon + tokenizer) is shared across workers:
        // derive it from a fixed stream, and use `seed` only for sampling
        let mut corpus_rng = Rng::new(0xC0_FFEE);
        let lexicon = make_lexicon(&mut corpus_rng);
        let zipf = Zipf::new(LEXICON, 1.1);
        // tokenizer training sample: lexicon + translations, Zipf weights
        let mut words: Vec<(String, usize)> = Vec::new();
        for (rank, w) in lexicon.iter().enumerate() {
            let f = (2.0 * LEXICON as f64 / (rank + 1) as f64) as usize + 1;
            words.push((w.clone(), f));
            words.push((translate_word(w), f));
        }
        let tokenizer = Tokenizer::train(&words, vocab_size);

        let mut s = Self {
            seq,
            batch,
            tokenizer,
            lexicon,
            zipf,
            rng: Rng::new(seed ^ 0x7A39),
            eval: Vec::new(),
            eval_refs: Vec::new(),
        };
        // held-out set from its own fixed stream
        let mut eval_rng = Rng::new(0xE7A1);
        for _ in 0..N_EVAL * batch {
            let (src, tgt) = make_pair(&s.lexicon, &s.zipf, &mut eval_rng);
            let (si, ti) = s.encode_pair(&src, &tgt);
            s.eval.push((si, ti));
        }
        for b in 0..N_EVAL {
            let refs = (0..batch)
                .map(|i| {
                    let t = &s.eval[b * batch + i].1;
                    // strip BOS and padding; keep up to (excl.) EOS
                    trim_ref(t)
                })
                .collect();
            s.eval_refs.push(refs);
        }
        s
    }

    fn encode_pair(&self, src: &[String], tgt: &[String])
                   -> (Vec<i32>, Vec<i32>) {
        let sw: Vec<&str> = src.iter().map(String::as_str).collect();
        let tw: Vec<&str> = tgt.iter().map(String::as_str).collect();
        let mut s = self.tokenizer.encode(&sw);
        s.truncate(self.seq);
        while s.len() < self.seq {
            s.push(vocab::PAD);
        }
        let mut t = vec![vocab::BOS];
        t.extend(self.tokenizer.encode(&tw));
        t.truncate(self.seq - 1);
        t.push(vocab::EOS);
        while t.len() < self.seq {
            t.push(vocab::PAD);
        }
        (s, t)
    }

    fn batch_from(&self, pairs: &[(Vec<i32>, Vec<i32>)]) -> Batch {
        let mut src = Vec::with_capacity(self.batch * self.seq);
        let mut tgt = Vec::with_capacity(self.batch * self.seq);
        for (s, t) in pairs {
            src.extend_from_slice(s);
            tgt.extend_from_slice(t);
        }
        Batch {
            values: vec![
                HostValue::I32 { shape: vec![self.batch, self.seq], data: src },
                HostValue::I32 { shape: vec![self.batch, self.seq], data: tgt },
            ],
        }
    }

    /// Reference token sequences for BLEU on eval batch `i`.
    pub fn references(&self, i: usize) -> &[Vec<i32>] {
        &self.eval_refs[i]
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }
}

/// Strip BOS/EOS/PAD from a target sequence (BLEU reference form).
pub fn trim_ref(t: &[i32]) -> Vec<i32> {
    t.iter()
        .copied()
        .skip_while(|&x| x == vocab::BOS)
        .take_while(|&x| x != vocab::EOS && x != vocab::PAD)
        .collect()
}

impl BatchSource for MtSource {
    fn next_train(&mut self) -> Batch {
        let mut pairs = Vec::with_capacity(self.batch);
        // split borrows: sample with a local copy of the rng
        let mut rng = self.rng.clone();
        for _ in 0..self.batch {
            let (s, t) = make_pair(&self.lexicon, &self.zipf, &mut rng);
            pairs.push(self.encode_pair(&s, &t));
        }
        self.rng = rng;
        self.batch_from(&pairs)
    }

    fn eval_batch(&self, i: usize) -> Batch {
        let b = i % N_EVAL;
        self.batch_from(&self.eval[b * self.batch..(b + 1) * self.batch])
    }

    fn eval_batches(&self) -> usize {
        N_EVAL
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_are_deterministic_translations() {
        let mut rng = Rng::new(1);
        let lex = make_lexicon(&mut rng);
        let zipf = Zipf::new(LEXICON, 1.1);
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        let a = make_pair(&lex, &zipf, &mut r1);
        let b = make_pair(&lex, &zipf, &mut r2);
        assert_eq!(a, b);
        assert_eq!(a.0.len(), a.1.len());
    }

    #[test]
    fn translate_word_is_injective_on_lexicon() {
        let mut rng = Rng::new(1);
        let lex = make_lexicon(&mut rng);
        let mut t: Vec<String> = lex.iter().map(|w| translate_word(w)).collect();
        t.sort();
        let n = t.len();
        t.dedup();
        assert_eq!(t.len(), n);
    }

    #[test]
    fn batches_have_manifest_shapes() {
        let mut s = MtSource::new(256, 24, 4, 0);
        let b = s.next_train();
        assert_eq!(b.values.len(), 2);
        assert_eq!(b.values[0].shape(), &[4, 24]);
        assert_eq!(b.values[1].shape(), &[4, 24]);
        // target starts with BOS
        let tgt = b.values[1].as_i32().unwrap();
        assert_eq!(tgt[0], vocab::BOS);
    }

    #[test]
    fn token_ids_within_vocab() {
        let mut s = MtSource::new(256, 24, 4, 0);
        for _ in 0..3 {
            let b = s.next_train();
            for v in &b.values {
                for &id in v.as_i32().unwrap() {
                    assert!((0..256).contains(&id));
                }
            }
        }
    }

    #[test]
    fn references_align_with_eval_batches() {
        let s = MtSource::new(256, 24, 4, 0);
        assert_eq!(s.eval_batches(), N_EVAL);
        let refs = s.references(0);
        assert_eq!(refs.len(), 4);
        let b = s.eval_batch(0);
        let tgt = b.values[1].as_i32().unwrap();
        let trimmed = trim_ref(&tgt[0..24]);
        assert_eq!(refs[0], trimmed);
    }

    #[test]
    fn trim_ref_strips_specials() {
        let t = vec![vocab::BOS, 7, 8, 9, vocab::EOS, vocab::PAD, vocab::PAD];
        assert_eq!(trim_ref(&t), vec![7, 8, 9]);
    }
}
