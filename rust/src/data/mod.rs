//! Synthetic data pipelines.
//!
//! The paper's corpora (WMT'14, Wikipedia+BooksCorpus, ImageNet) are not
//! available in this environment; each generator here is the closest
//! synthetic equivalent that exercises the same code path and — crucially
//! for SM3 — the same *gradient activation patterns* (Zipfian token
//! frequencies ⇒ sparse row-activations in embedding gradients; see
//! DESIGN.md §3 for the substitution table).
//!
//! All generators are deterministic from a `u64` seed and support host
//! sharding (worker w of W sees an independent substream), mirroring the
//! input pipelines of a TPU-pod training job.

pub mod images;
pub mod lm;
pub mod tokenizer;
pub mod translation;

use crate::runtime::HostValue;

/// A batch: named host values in the artifact's `batch/…` input order.
#[derive(Clone, Debug)]
pub struct Batch {
    pub values: Vec<HostValue>,
}

/// Anything that yields training/eval batches for a model.
pub trait BatchSource: Send {
    /// Next training batch (advances the stream).
    fn next_train(&mut self) -> Batch;
    /// Deterministic held-out batch `i` (same for every call).
    fn eval_batch(&self, i: usize) -> Batch;
    /// Number of distinct eval batches.
    fn eval_batches(&self) -> usize;
    /// Downcast hook (the trainer's BLEU path needs the typed MtSource
    /// for its references).
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Build the generator matching a model's manifest metadata.
pub fn source_for_model(
    meta: &crate::runtime::manifest::ModelMeta,
    seed: u64,
    worker: usize,
    n_workers: usize,
) -> anyhow::Result<Box<dyn BatchSource>> {
    let shard_seed = seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(worker as u64);
    Ok(match meta.kind.as_str() {
        "lm" => Box::new(lm::LmSource::new(
            meta.vocab, meta.seq, meta.batch, shard_seed, false, 0)),
        "mlm" => Box::new(lm::LmSource::new(
            meta.vocab, meta.seq, meta.batch, shard_seed, true, meta.n_masked)),
        "mt" => Box::new(translation::MtSource::new(
            meta.vocab, meta.seq, meta.batch, shard_seed)),
        "img" => Box::new(images::ImageSource::new(
            meta.height, meta.width, meta.channels, meta.n_classes,
            meta.batch, shard_seed)),
        other => anyhow::bail!("unknown model kind {other:?} (worker {worker}/{n_workers})"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelMeta;

    fn lm_meta() -> ModelMeta {
        ModelMeta {
            name: "m".into(), kind: "lm".into(), batch: 2, param_count: 0,
            params: vec![], vocab: 64, seq: 8, d_model: 4, n_masked: 0,
            height: 0, width: 0, channels: 0, n_classes: 0,
        }
    }

    #[test]
    fn source_dispatch() {
        let mut s = source_for_model(&lm_meta(), 0, 0, 1).unwrap();
        let b = s.next_train();
        assert_eq!(b.values.len(), 1);
        assert_eq!(b.values[0].shape(), &[2, 8]);
    }

    #[test]
    fn workers_get_different_streams() {
        let meta = lm_meta();
        let mut a = source_for_model(&meta, 0, 0, 2).unwrap();
        let mut b = source_for_model(&meta, 0, 1, 2).unwrap();
        let ba = a.next_train();
        let bb = b.next_train();
        assert_ne!(ba.values[0].as_i32().unwrap(),
                   bb.values[0].as_i32().unwrap());
    }

    #[test]
    fn eval_batches_are_deterministic() {
        let meta = lm_meta();
        let s = source_for_model(&meta, 0, 0, 1).unwrap();
        let a = s.eval_batch(0);
        let b = s.eval_batch(0);
        assert_eq!(a.values[0].as_i32().unwrap(),
                   b.values[0].as_i32().unwrap());
    }
}
