//! Subword tokenizer: a miniature BPE, built from scratch.
//!
//! The paper tokenizes WMT with 32K shared word-pieces (Schuster &
//! Nakajima). Our synthetic translation corpus is made of generated
//! "words" (character strings); this module learns a byte-pair vocabulary
//! from a sample of the corpus and encodes words by greedy merges —
//! the same mechanics at miniature scale, so the embedding rows the model
//! trains correspond to genuine subword units with Zipfian frequencies.
//!
//! Ids 0..4 are reserved (PAD/BOS/EOS/UNK per `crate::vocab`).

use crate::vocab;
use std::collections::HashMap;

/// A learned BPE vocabulary.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// merge rules in priority order: (left, right) -> merged token string
    merges: Vec<(String, String)>,
    /// token string -> id
    token_ids: HashMap<String, i32>,
    vocab_size: usize,
}

impl Tokenizer {
    /// Learn a BPE vocabulary of at most `vocab_size` ids (including the 4
    /// reserved ids) from a training word list with frequencies.
    pub fn train(words: &[(String, usize)], vocab_size: usize) -> Self {
        assert!(vocab_size > 8, "vocab too small");
        // start from characters
        let mut corpus: Vec<(Vec<String>, usize)> = words
            .iter()
            .map(|(w, f)| (w.chars().map(|c| c.to_string()).collect(), *f))
            .collect();
        let mut alphabet: Vec<String> = {
            let mut set: Vec<String> = corpus
                .iter()
                .flat_map(|(cs, _)| cs.iter().cloned())
                .collect();
            set.sort();
            set.dedup();
            set
        };
        alphabet.sort();
        let budget = vocab_size - vocab::FIRST as usize;
        let mut merges = Vec::new();
        let mut n_tokens = alphabet.len();
        while n_tokens < budget {
            // count adjacent pairs
            let mut counts: HashMap<(String, String), usize> = HashMap::new();
            for (cs, f) in &corpus {
                for win in cs.windows(2) {
                    *counts
                        .entry((win[0].clone(), win[1].clone()))
                        .or_insert(0) += f;
                }
            }
            // deterministic best pair: max count, ties by lexicographic
            let Some(best) = counts.into_iter().max_by(|a, b| {
                a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0))
            }) else {
                break;
            };
            if best.1 < 2 {
                break;
            }
            let (l, r) = best.0;
            let merged = format!("{l}{r}");
            // apply merge to corpus
            for (cs, _) in corpus.iter_mut() {
                let mut out = Vec::with_capacity(cs.len());
                let mut i = 0;
                while i < cs.len() {
                    if i + 1 < cs.len() && cs[i] == l && cs[i + 1] == r {
                        out.push(merged.clone());
                        i += 2;
                    } else {
                        out.push(cs[i].clone());
                        i += 1;
                    }
                }
                *cs = out;
            }
            merges.push((l, r));
            n_tokens += 1;
        }
        // assign ids: reserved, then alphabet, then merges
        let mut token_ids = HashMap::new();
        let mut next = vocab::FIRST;
        for a in &alphabet {
            token_ids.insert(a.clone(), next);
            next += 1;
        }
        for (l, r) in &merges {
            let t = format!("{l}{r}");
            token_ids.entry(t).or_insert_with(|| {
                let id = next;
                next += 1;
                id
            });
        }
        Self { merges, token_ids, vocab_size }
    }

    /// Encode one word into subword ids (UNK for unknown characters).
    pub fn encode_word(&self, word: &str) -> Vec<i32> {
        let mut parts: Vec<String> =
            word.chars().map(|c| c.to_string()).collect();
        for (l, r) in &self.merges {
            let mut out = Vec::with_capacity(parts.len());
            let mut i = 0;
            while i < parts.len() {
                if i + 1 < parts.len() && &parts[i] == l && &parts[i + 1] == r {
                    out.push(format!("{l}{r}"));
                    i += 2;
                } else {
                    out.push(parts[i].clone());
                    i += 1;
                }
            }
            parts = out;
        }
        parts
            .iter()
            .map(|p| *self.token_ids.get(p).unwrap_or(&vocab::UNK))
            .collect()
    }

    /// Encode a sentence (words joined by spaces).
    pub fn encode(&self, sentence: &[&str]) -> Vec<i32> {
        sentence.iter().flat_map(|w| self.encode_word(w)).collect()
    }

    pub fn n_tokens(&self) -> usize {
        self.token_ids.len() + vocab::FIRST as usize
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<(String, usize)> {
        vec![
            ("abab".into(), 10),
            ("abc".into(), 5),
            ("cab".into(), 3),
            ("bc".into(), 2),
        ]
    }

    #[test]
    fn learns_frequent_pairs() {
        let tok = Tokenizer::train(&sample(), 32);
        // "ab" occurs 10*2 + 5 + 3 = 28 times: must be merged first
        assert_eq!(tok.merges[0], ("a".to_string(), "b".to_string()));
        // encoding "abab" uses the merged token => at most 2 ids
        assert!(tok.encode_word("abab").len() <= 2);
    }

    #[test]
    fn ids_stay_in_vocab() {
        let tok = Tokenizer::train(&sample(), 16);
        for w in ["abab", "abc", "cab", "zzz"] {
            for id in tok.encode_word(w) {
                assert!((id as usize) < 16 || id == crate::vocab::UNK,
                        "id {id} out of range");
            }
        }
    }

    #[test]
    fn unknown_chars_map_to_unk() {
        let tok = Tokenizer::train(&sample(), 32);
        assert!(tok.encode_word("xyz").iter()
                .all(|&id| id == crate::vocab::UNK));
    }

    #[test]
    fn deterministic() {
        let a = Tokenizer::train(&sample(), 32);
        let b = Tokenizer::train(&sample(), 32);
        assert_eq!(a.encode_word("abcabc"), b.encode_word("abcabc"));
    }

    #[test]
    fn encode_sentence_concatenates() {
        let tok = Tokenizer::train(&sample(), 32);
        let s = tok.encode(&["ab", "c"]);
        let mut expect = tok.encode_word("ab");
        expect.extend(tok.encode_word("c"));
        assert_eq!(s, expect);
    }
}
