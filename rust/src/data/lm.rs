//! Synthetic language-model corpus — the Wikipedia+BooksCorpus stand-in
//! (BERT experiments, Fig. 3 / Table 2) and the end-to-end LM driver.
//!
//! A first-order Markov chain over a Zipf-distributed vocabulary: each
//! token has a small set of preferred successors, so the stream has
//! learnable bigram structure (masked-LM accuracy well above the unigram
//! baseline is achievable) while keeping Zipfian marginals (the embedding
//! activation patterns of Fig. 1).
//!
//! With `masked = true` the source emits BERT-style batches
//! `(tokens, positions, targets, weights)`: `n_masked` positions per
//! sequence are replaced by UNK (standing in for `[MASK]`).

use super::{Batch, BatchSource};
use crate::rng::{Rng, Zipf};
use crate::runtime::HostValue;
use crate::vocab;

const N_EVAL: usize = 8;
const SUCCESSORS: usize = 4;

/// Markov-Zipf token stream generator.
struct Chain {
    vocab: usize,
    zipf: Zipf,
    /// preferred successors per token
    succ: Vec<[i32; SUCCESSORS]>,
}

impl Chain {
    fn new(vocab: usize) -> Self {
        let content = vocab - vocab::FIRST as usize;
        // the chain structure is corpus-global (not per-worker)
        let mut rng = Rng::new(0xC4A1);
        let zipf = Zipf::new(content, 1.15);
        let succ = (0..content)
            .map(|_| {
                let mut s = [0i32; SUCCESSORS];
                for slot in s.iter_mut() {
                    *slot = vocab::FIRST + zipf.sample(&mut rng) as i32;
                }
                s
            })
            .collect();
        Self { vocab, zipf, succ }
    }

    fn next_token(&self, prev: i32, rng: &mut Rng) -> i32 {
        if prev >= vocab::FIRST && rng.bernoulli(0.9) {
            // follow the bigram structure; successor weights are skewed so
            // the Bayes-optimal masked-LM accuracy is ~50% (learnable but
            // not instant — the Fig. 3 curves need headroom)
            let s = &self.succ[(prev - vocab::FIRST) as usize];
            let u = rng.next_f64();
            let idx = if u < 0.55 {
                0
            } else if u < 0.80 {
                1
            } else if u < 0.95 {
                2
            } else {
                3
            };
            s[idx]
        } else {
            vocab::FIRST + self.zipf.sample(rng) as i32
        }
    }

    fn sequence(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = vocab::BOS;
        for _ in 0..len {
            let t = self.next_token(prev, rng);
            out.push(t);
            prev = t;
        }
        out
    }
}

/// LM / masked-LM batch source.
pub struct LmSource {
    chain: Chain,
    seq: usize,
    batch: usize,
    masked: bool,
    n_masked: usize,
    rng: Rng,
    eval_seqs: Vec<Vec<i32>>,
}

impl LmSource {
    pub fn new(vocab_size: usize, seq: usize, batch: usize, seed: u64,
               masked: bool, n_masked: usize) -> Self {
        let chain = Chain::new(vocab_size);
        let mut eval_rng = Rng::new(0xE7A2);
        let eval_seqs = (0..N_EVAL * batch)
            .map(|_| chain.sequence(seq, &mut eval_rng))
            .collect();
        Self {
            chain,
            seq,
            batch,
            masked,
            n_masked,
            rng: Rng::new(seed ^ 0x11B),
            eval_seqs,
        }
    }

    fn plain_batch(&self, seqs: &[Vec<i32>]) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        for s in seqs {
            tokens.extend_from_slice(s);
        }
        Batch {
            values: vec![HostValue::I32 {
                shape: vec![self.batch, self.seq],
                data: tokens,
            }],
        }
    }

    /// Build a masked batch; the mask pattern derives from `mask_seed` so
    /// eval masking is deterministic.
    fn masked_batch(&self, seqs: &[Vec<i32>], mask_seed: u64) -> Batch {
        let mut rng = Rng::new(mask_seed);
        let p = self.n_masked;
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut positions = Vec::with_capacity(self.batch * p);
        let mut targets = Vec::with_capacity(self.batch * p);
        let mut weights = Vec::with_capacity(self.batch * p);
        for s in seqs {
            let mut seq = s.clone();
            // choose p distinct positions
            let mut pos: Vec<usize> = (0..self.seq).collect();
            rng.shuffle(&mut pos);
            let mut chosen = pos[..p].to_vec();
            chosen.sort_unstable();
            for &c in &chosen {
                positions.push(c as i32);
                targets.push(seq[c]);
                weights.push(1.0f32);
                seq[c] = vocab::UNK; // the [MASK] stand-in
            }
            tokens.extend_from_slice(&seq);
        }
        Batch {
            values: vec![
                HostValue::I32 { shape: vec![self.batch, self.seq],
                                 data: tokens },
                HostValue::I32 { shape: vec![self.batch, p], data: positions },
                HostValue::I32 { shape: vec![self.batch, p], data: targets },
                HostValue::F32(crate::tensor::Tensor::from_vec(
                    &[self.batch, p], weights)),
            ],
        }
    }
}

impl BatchSource for LmSource {
    fn next_train(&mut self) -> Batch {
        let mut rng = self.rng.clone();
        let seqs: Vec<Vec<i32>> = (0..self.batch)
            .map(|_| self.chain.sequence(self.seq, &mut rng))
            .collect();
        let mask_seed = rng.next_u64();
        self.rng = rng;
        if self.masked {
            self.masked_batch(&seqs, mask_seed)
        } else {
            self.plain_batch(&seqs)
        }
    }

    fn eval_batch(&self, i: usize) -> Batch {
        let b = i % N_EVAL;
        let seqs = &self.eval_seqs[b * self.batch..(b + 1) * self.batch];
        if self.masked {
            // fixed mask seed per eval batch
            self.masked_batch(seqs, 0xEEE0 + b as u64)
        } else {
            self.plain_batch(seqs)
        }
    }

    fn eval_batches(&self) -> usize {
        N_EVAL
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_lm_shapes() {
        let mut s = LmSource::new(64, 16, 4, 0, false, 0);
        let b = s.next_train();
        assert_eq!(b.values.len(), 1);
        assert_eq!(b.values[0].shape(), &[4, 16]);
    }

    #[test]
    fn masked_lm_shapes_and_semantics() {
        let mut s = LmSource::new(64, 16, 4, 0, true, 3);
        let b = s.next_train();
        assert_eq!(b.values.len(), 4);
        assert_eq!(b.values[0].shape(), &[4, 16]);
        assert_eq!(b.values[1].shape(), &[4, 3]);
        let tokens = b.values[0].as_i32().unwrap();
        let positions = b.values[1].as_i32().unwrap();
        let targets = b.values[2].as_i32().unwrap();
        // each masked position holds UNK and its target is a content token
        for ex in 0..4 {
            for k in 0..3 {
                let pos = positions[ex * 3 + k] as usize;
                assert_eq!(tokens[ex * 16 + pos], vocab::UNK);
                assert!(targets[ex * 3 + k] >= vocab::FIRST);
            }
        }
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // following the chain beats unigram guessing: the most frequent
        // successor of a given token concentrates probability
        let chain = Chain::new(64);
        let mut rng = Rng::new(5);
        let mut follow = 0usize;
        let n = 20_000;
        let mut prev = vocab::FIRST;
        for _ in 0..n {
            let t = chain.next_token(prev, &mut rng);
            if chain.succ[(prev - vocab::FIRST) as usize].contains(&t) {
                follow += 1;
            }
            prev = t;
        }
        assert!(follow as f64 / n as f64 > 0.5, "ratio {}", follow as f64 / n as f64);
    }

    #[test]
    fn eval_masking_is_deterministic() {
        let s = LmSource::new(64, 16, 4, 0, true, 3);
        let a = s.eval_batch(2);
        let b = s.eval_batch(2);
        assert_eq!(a.values[1].as_i32().unwrap(), b.values[1].as_i32().unwrap());
        assert_eq!(a.values[2].as_i32().unwrap(), b.values[2].as_i32().unwrap());
    }

    #[test]
    fn token_range() {
        let mut s = LmSource::new(64, 16, 2, 1, false, 0);
        for _ in 0..5 {
            let b = s.next_train();
            for &t in b.values[0].as_i32().unwrap() {
                assert!((vocab::FIRST..64).contains(&t));
            }
        }
    }
}
