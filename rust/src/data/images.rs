//! Synthetic image-classification dataset — the ImageNet stand-in
//! (AmoebaNet experiment, Fig. 4).
//!
//! Each class k is a distinct 2-D sinusoidal texture (frequency pair +
//! phase + per-channel weighting) plus additive noise — separable by a
//! small convnet but not trivially (noise std comparable to signal), so
//! top-1/top-5 accuracy curves have the shape the figure needs.

use super::{Batch, BatchSource};
use crate::rng::Rng;
use crate::runtime::HostValue;
use crate::tensor::Tensor;

const N_EVAL: usize = 8;
const NOISE: f32 = 2.2;

struct ClassSpec {
    fx: f32,
    fy: f32,
    phase: f32,
    channel_w: [f32; 4],
}

pub struct ImageSource {
    h: usize,
    w: usize,
    c: usize,
    n_classes: usize,
    batch: usize,
    classes: Vec<ClassSpec>,
    rng: Rng,
    eval: Vec<(Tensor, Vec<i32>)>,
}

impl ImageSource {
    pub fn new(h: usize, w: usize, c: usize, n_classes: usize, batch: usize,
               seed: u64) -> Self {
        assert!(c <= 4);
        // class textures are dataset-global
        let mut crng = Rng::new(0x1316);
        let classes = (0..n_classes)
            .map(|k| ClassSpec {
                fx: 0.5 + 0.45 * k as f32 + crng.next_f32(),
                fy: 0.4 + 0.3 * ((k * 7) % n_classes) as f32 + crng.next_f32(),
                phase: crng.next_f32() * std::f32::consts::TAU,
                channel_w: [
                    0.4 + crng.next_f32(),
                    0.4 + crng.next_f32(),
                    0.4 + crng.next_f32(),
                    0.4 + crng.next_f32(),
                ],
            })
            .collect();
        let mut s = Self {
            h, w, c, n_classes, batch, classes,
            rng: Rng::new(seed ^ 0x1443),
            eval: Vec::new(),
        };
        let mut eval_rng = Rng::new(0xE7A3);
        for _ in 0..N_EVAL {
            let b = s.make_batch(&mut eval_rng);
            s.eval.push(b);
        }
        s
    }

    fn render(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        let spec = &self.classes[class];
        let mut out = Vec::with_capacity(self.h * self.w * self.c);
        for y in 0..self.h {
            for x in 0..self.w {
                let base = (spec.fx * x as f32 / self.w as f32
                    * std::f32::consts::TAU
                    + spec.fy * y as f32 / self.h as f32
                        * std::f32::consts::TAU
                    + spec.phase)
                    .sin();
                for ch in 0..self.c {
                    let v = base * spec.channel_w[ch]
                        + NOISE * rng.normal_f32(0.0, 1.0);
                    out.push(v);
                }
            }
        }
        out
    }

    fn make_batch(&self, rng: &mut Rng) -> (Tensor, Vec<i32>) {
        let mut images = Vec::with_capacity(
            self.batch * self.h * self.w * self.c);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let k = rng.index(self.n_classes);
            labels.push(k as i32);
            images.extend(self.render(k, rng));
        }
        (Tensor::from_vec(&[self.batch, self.h, self.w, self.c], images),
         labels)
    }

    fn to_batch(&self, imgs: Tensor, labels: Vec<i32>) -> Batch {
        Batch {
            values: vec![
                HostValue::F32(imgs),
                HostValue::I32 { shape: vec![self.batch], data: labels },
            ],
        }
    }
}

impl BatchSource for ImageSource {
    fn next_train(&mut self) -> Batch {
        let mut rng = self.rng.clone();
        let (imgs, labels) = self.make_batch(&mut rng);
        self.rng = rng;
        self.to_batch(imgs, labels)
    }

    fn eval_batch(&self, i: usize) -> Batch {
        let (imgs, labels) = self.eval[i % N_EVAL].clone();
        self.to_batch(imgs, labels)
    }

    fn eval_batches(&self) -> usize {
        N_EVAL
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut s = ImageSource::new(8, 8, 3, 10, 4, 0);
        let b = s.next_train();
        assert_eq!(b.values[0].shape(), &[4, 8, 8, 3]);
        assert_eq!(b.values[1].shape(), &[4]);
        for &l in b.values[1].as_i32().unwrap() {
            assert!((0..10).contains(&l));
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean per-pixel distance between class prototypes (noise-free
        // signal) must exceed the within-class noise floor on average
        let s = ImageSource::new(8, 8, 3, 10, 2, 0);
        let mut rng = Rng::new(9);
        let a: Vec<f32> = s.render(0, &mut rng);
        let b: Vec<f32> = s.render(1, &mut rng);
        let a2: Vec<f32> = s.render(0, &mut rng);
        let cross: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        let within: f32 = a.iter().zip(&a2).map(|(x, y)| (x - y).abs()).sum();
        assert!(cross > 0.0 && within > 0.0);
    }

    #[test]
    fn eval_deterministic() {
        let s = ImageSource::new(8, 8, 3, 10, 4, 0);
        let a = s.eval_batch(1);
        let b = s.eval_batch(1);
        assert_eq!(a.values[1].as_i32().unwrap(), b.values[1].as_i32().unwrap());
    }
}
