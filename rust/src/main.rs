//! `sm3-train` — the SM3 training framework launcher.
//!
//! Subcommands:
//!   train          run a training job from a TOML config (+ overrides)
//!   eval           evaluate a model's held-out metrics at init
//!   memory-report  per-core memory table for the real model inventories
//!                  (reproduces paper Tables 1–2)
//!   list           list AOT artifacts and models in the manifest
//!
//! Examples:
//!   sm3-train train --config configs/mt_sm3.toml
//!   sm3-train train --model lm_small --optimizer sm3 --steps 100 --exec fused
//!   sm3-train memory-report

use anyhow::{bail, Result};
use sm3::cli::Command;
use sm3::config::TrainConfig;
use sm3::coordinator::Trainer;
use sm3::memory::{inventory, MemoryModel, GIB};
use sm3::metrics::RunLogger;

fn commands() -> Vec<Command> {
    vec![
        Command::new("train", "run a training job")
            .option("config", "TOML config file (configs/*.toml)")
            .option("model", "model key override (lm_small, mt_small, ...)")
            .option("optimizer", "optimizer override (sm3|sm3i|adagrad|adam|adafactor|sgdm)")
            .option("steps", "step-count override")
            .option("lr", "base learning-rate override")
            .option("eps", "Adam eps override (split path; default 1e-8)")
            .option("clip-norm", "clip gradients to this global L2 norm (split path)")
            .option("clip-value", "clamp each gradient entry to [-c, c] (split path)")
            .option("weight-decay", "decoupled (AdamW-style) weight decay rate (split path; [[optim.group]] in TOML for per-group overrides)")
            .option("exec", "execution path: split | fused")
            .option("workers", "data-parallel worker count")
            .option("step-threads", "host threads for the optimizer update (1 = serial; bitwise-identical results)")
            .option("state-dtype", "optimizer-state storage precision: f32 | bf16 | q8 (split path)")
            .option("step-chunk", "streaming tile for the chunked step kernels, in elements (multiple of 64; bitwise-identical results)")
            .option("comm-dtype", "wire precision of the gradient exchange: f32 | bf16 | q8 (split path; compressed dtypes carry error-feedback residuals)")
            .option("comm-threads", "host threads for the ring collectives (1 = serial; bitwise-identical results)")
            .option("comm-chunk", "wire tile for the ring collectives, in elements (multiple of 64; bitwise-identical results)")
            .option("comm-buckets", "64-aligned gradient buckets the exchange pipelines over (1 = monolithic; bitwise-identical results)")
            .option("comm-transport", "hop-edge payload path: direct | inproc (bitwise-identical results; default from SM3_COMM_TRANSPORT)")
            .flag("comm-overlap", "stage bucket k+1 while bucket k's ring hops are in flight (split path; bitwise-identical results)")
            .option("kernel-backend", "tile-kernel implementation: scalar | simd (split path; bitwise-identical results)")
            .flag("no-pool", "bypass the memory-pool runtime (plain heap buffers; split path; bitwise-identical results)")
            .option("grad-accum", "microbatches per step")
            .option("seed", "data/init RNG seed")
            .option("artifacts", "artifacts directory (default: artifacts)")
            .option("out", "CSV output path for the loss curve")
            .option("save", "write final params + optimizer state here (SM3CKPT2; split path)")
            .option("telemetry-jsonl", "stream per-step telemetry events to this JSONL file (implies --telemetry semantics must hold: split path)")
            .flag("telemetry", "measure per-phase spans / counters / gauges (split path; bitwise-invisible to the trajectory)")
            .flag("quiet", "suppress per-step output"),
        Command::new("eval", "evaluate at initialization")
            .option("model", "model key")
            .option("artifacts", "artifacts directory"),
        Command::new("memory-report", "reproduce paper Tables 1-2")
            .option("out", "CSV output path"),
        Command::new("list", "list artifacts in the manifest")
            .option("artifacts", "artifacts directory"),
        Command::new("bench-check",
                     "validate BENCH_*.json telemetry documents (positional \
                      file paths; exits non-zero on schema violations)")
            .option("baseline",
                    "budget file (ci/BENCH_memory_baseline.json): gauge \
                     peaks in the checked documents must stay within the \
                     committed ceilings")
            .option("max-regress",
                    "extra headroom over each baseline ceiling, in percent \
                     (default 10)"),
    ]
}

fn usage() -> String {
    let mut s = String::from(
        "sm3-train — Memory-Efficient Adaptive Optimization (SM3), \
         NeurIPS 2019 reproduction\n\nUSAGE: sm3-train <command> [options]\n\n");
    for c in commands() {
        s.push_str(&c.usage());
        s.push('\n');
    }
    s
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd_name) = argv.first() else {
        eprintln!("{}", usage());
        bail!("missing command");
    };
    let cmds = commands();
    let Some(cmd) = cmds.iter().find(|c| c.name == cmd_name.as_str()) else {
        eprintln!("{}", usage());
        bail!("unknown command {cmd_name:?}");
    };
    let args = cmd.parse(&argv[1..])?;
    match cmd_name.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "memory-report" => cmd_memory_report(&args),
        "list" => cmd_list(&args),
        "bench-check" => cmd_bench_check(&args),
        _ => unreachable!(),
    }
}

fn build_config(args: &sm3::cli::Args) -> Result<TrainConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => TrainConfig::load(path)?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.opt("model") {
        cfg.model = m.to_string();
    }
    if let Some(o) = args.opt("optimizer") {
        cfg.optim.name = o.to_string();
    }
    if let Some(s) = args.opt_parse::<u64>("steps")? {
        cfg.steps = s;
    }
    if let Some(lr) = args.opt_parse::<f64>("lr")? {
        cfg.optim.lr = lr;
    }
    if let Some(e) = args.opt_parse::<f64>("eps")? {
        cfg.optim.eps = e;
    }
    if let Some(c) = args.opt_parse::<f64>("clip-norm")? {
        cfg.optim.clip_norm = Some(c);
    }
    if let Some(c) = args.opt_parse::<f64>("clip-value")? {
        cfg.optim.clip_value = Some(c);
    }
    if let Some(w) = args.opt_parse::<f64>("weight-decay")? {
        cfg.optim.weight_decay = w;
    }
    if let Some(e) = args.opt("exec") {
        cfg.exec = sm3::config::ExecMode::parse(e)?;
    }
    if let Some(w) = args.opt_parse::<usize>("workers")? {
        cfg.workers = w;
    }
    if let Some(t) = args.opt_count("step-threads")? {
        cfg.step_threads = t;
    }
    if let Some(d) = args.opt("state-dtype") {
        cfg.state_dtype = sm3::optim::StateDtype::parse(d)?;
    }
    if let Some(c) = args.opt_count("step-chunk")? {
        cfg.step_chunk = c; // cfg.validate() checks block alignment
    }
    if let Some(d) = args.opt("comm-dtype") {
        cfg.comm_dtype = sm3::optim::StateDtype::parse(d)?;
    }
    if let Some(t) = args.opt_count("comm-threads")? {
        cfg.comm_threads = t;
    }
    if let Some(c) = args.opt_count("comm-chunk")? {
        cfg.comm_chunk = c; // cfg.validate() checks block alignment
    }
    if let Some(b) = args.opt_count("comm-buckets")? {
        cfg.comm_buckets = b; // engine rejects untileable bucket counts
    }
    if args.has_flag("comm-overlap") {
        cfg.comm_overlap = true;
    }
    if let Some(t) = args.opt("comm-transport") {
        cfg.comm_transport = sm3::comms::TransportKind::parse(t)?;
    }
    if let Some(b) = args.opt("kernel-backend") {
        cfg.kernel_backend = sm3::optim::Backend::parse(b)?;
    }
    if args.has_flag("no-pool") {
        cfg.pool = false;
    }
    if let Some(g) = args.opt_parse::<u64>("grad-accum")? {
        cfg.grad_accum = g;
    }
    if let Some(s) = args.opt_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if args.has_flag("telemetry") {
        cfg.telemetry = true;
    }
    if let Some(p) = args.opt("telemetry-jsonl") {
        // the JSONL stream implies measurement (validate() enforces the
        // pairing for TOML configs; the CLI just does the obvious thing)
        cfg.telemetry = true;
        cfg.telemetry_jsonl = Some(p.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &sm3::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let quiet = args.has_flag("quiet");
    // fail fast: a fused run cannot save (the artifact owns its optimizer
    // state), and learning that after the full run would discard the work
    if args.opt("save").is_some() && cfg.exec == sm3::config::ExecMode::Fused {
        bail!("--save needs the split path (the fused artifact owns its \
               optimizer state)");
    }
    println!(
        "sm3-train: model={} optimizer={} exec={:?} steps={} workers={} \
         grad_accum={} step_threads={} state_dtype={} step_chunk={}",
        cfg.model, cfg.optim.name, cfg.exec, cfg.steps, cfg.workers,
        cfg.grad_accum, cfg.step_threads, cfg.state_dtype.name(),
        cfg.step_chunk
    );
    if cfg.workers > 1 {
        println!(
            "  comms: dtype={} threads={} chunk={} buckets={} overlap={} \
             transport={} (ring all-reduce, error feedback {})",
            cfg.comm_dtype.name(), cfg.comm_threads, cfg.comm_chunk,
            cfg.comm_buckets, cfg.comm_overlap, cfg.comm_transport.name(),
            if cfg.comm_dtype == sm3::optim::StateDtype::F32 {
                "off"
            } else {
                "on"
            }
        );
    }
    if cfg.optim.has_transforms() {
        println!(
            "  pipeline: clip_value={} clip_norm={} weight_decay={} \
             groups={}",
            cfg.optim.clip_value.map_or("-".into(), |v| v.to_string()),
            cfg.optim.clip_norm.map_or("-".into(), |v| v.to_string()),
            cfg.optim.weight_decay, cfg.optim.groups.len()
        );
    }
    let mut trainer = Trainer::new(cfg.clone())?;
    println!("  platform: {}", trainer.runtime().platform());
    println!("  params:   {:.2}M", trainer.meta.param_count as f64 / 1e6);
    if let Some(opt) = trainer.optimizer() {
        println!("  opt state: {:.2}M floats / {:.2} MiB as {} ({})",
                 opt.state_floats() as f64 / 1e6,
                 opt.state_bytes() as f64 / (1024.0 * 1024.0),
                 opt.state_dtype().name(), opt.name());
    }
    let mut logger = RunLogger::new(
        args.opt("out"),
        "step,loss,loss_ema,lr,wall_ms,comm_ms,grad_ms,opt_ms,\
         comm_pack_ms,comm_hop_ms,comm_unpack_ms,ckpt_ms",
        false)?;
    let hist = trainer.train()?;
    for s in &hist.steps {
        logger.row(&[s.step.to_string(), format!("{:.6}", s.loss),
                     format!("{:.6}", s.loss_ema), format!("{:.6e}", s.lr),
                     format!("{:.2}", s.wall_ms),
                     format!("{:.4}", s.comm_ms),
                     format!("{:.4}", s.grad_ms),
                     format!("{:.4}", s.opt_ms),
                     format!("{:.4}", s.comm_pack_ms),
                     format!("{:.4}", s.comm_hop_ms),
                     format!("{:.4}", s.comm_unpack_ms),
                     format!("{:.4}", s.ckpt_ms)])?;
        if !quiet && (s.step % 10 == 0 || s.step == 1) {
            println!("  step {:>6}  loss {:.4}  (ema {:.4})  lr {:.3e}  {:.0} ms",
                     s.step, s.loss, s.loss_ema, s.lr, s.wall_ms);
        }
    }
    logger.flush()?;
    if cfg.telemetry {
        let reg = trainer.telemetry_registry();
        println!("  telemetry (per-phase, whole run):");
        for (name, s) in reg.spans() {
            println!("    {name:<18} n={:<6} total {:>9.3} ms  \
                      mean {:>9.1} us",
                     s.count, s.total_ns as f64 / 1e6, s.mean_ns() / 1e3);
        }
        for (name, v) in reg.counters() {
            println!("    {name:<18} {v}");
        }
        for (name, g) in reg.gauges() {
            println!("    {name:<18} last={} peak={}", g.last, g.peak);
        }
    }
    for e in &hist.evals {
        let metric = e.metric.map(|m| format!("  metric {m:.4}"))
            .unwrap_or_default();
        println!("  eval @ {:>6}: loss {:.4}{}", e.step, e.loss, metric);
    }
    if let Some(path) = args.opt("save") {
        trainer.save_checkpoint(path)?;
        println!("  checkpoint: {path} (params f32 + optimizer state as {})",
                 cfg.state_dtype.name());
    }
    Ok(())
}

fn cmd_eval(args: &sm3::cli::Args) -> Result<()> {
    let mut cfg = TrainConfig::default();
    if let Some(m) = args.opt("model") {
        cfg.model = m.to_string();
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    cfg.steps = 1;
    let trainer = Trainer::new(cfg)?;
    let e = trainer.evaluate()?;
    println!("eval: loss {:.4}  metric {:?}", e.loss, e.metric);
    Ok(())
}

fn cmd_memory_report(args: &sm3::cli::Args) -> Result<()> {
    use sm3::optim::StateDtype;
    // Table 1: Transformer-Big on TPUv2 (8 GiB/core), batch 12 & 24 per core
    let m = MemoryModel::calibrate(
        inventory::transformer_big(),
        8.0 * GIB,
        ("adam", 12, 6.88 * GIB),
        ("sm3", 24, 7.02 * GIB),
    )?;
    println!("Table 1 — Transformer-Big (WMT'14 en→fr), GiB per TPUv2 core");
    println!("{:<12} {:>6} {:>10} {:>8}", "optimizer", "batch", "memory", "fits");
    let mut rows = Vec::new();
    for (opt, b) in [("adam", 12), ("adagrad", 12), ("adafactor", 12),
                     ("sm3", 12), ("adam", 24), ("adagrad", 24),
                     ("adafactor", 24), ("sm3", 24)] {
        let gib = m.gib_per_core(opt, b)?;
        let fits = m.fits(opt, b)?;
        println!("{opt:<12} {b:>6} {gib:>9.2} {:>8}",
                 if fits { "yes" } else { "OOM" });
        rows.push(format!("transformer_big,{opt},{b},{gib:.3},{fits}"));
    }
    // Table 2: BERT-Large on 8x8 TPUv2
    let bert = MemoryModel::calibrate(
        inventory::bert_large(),
        8.0 * GIB,
        ("adam", 8, 6.15 * GIB),
        ("sm3", 16, 6.02 * GIB),
    )?;
    println!("\nTable 2 — BERT-Large, GiB per TPUv2 core");
    for (opt, b) in [("adam", 8), ("sm3", 8), ("sm3", 16), ("adam", 16)] {
        let gib = bert.gib_per_core(opt, b)?;
        let fits = bert.fits(opt, b)?;
        println!("{opt:<12} {b:>6} {gib:>9.2} {:>8}",
                 if fits { "yes" } else { "OOM" });
        rows.push(format!("bert_large,{opt},{b},{gib:.3},{fits}"));
    }
    // Past the paper: the max-batch frontier with quantized optimizer
    // state (optim::qstate; --state-dtype on the train command)
    println!("\nQuantized-state max batch/core (8 GiB TPUv2)");
    println!("{:<16} {:<12} {:>6} {:>6} {:>6}",
             "model", "optimizer", "f32", "bf16", "q8");
    for (model, mm) in [("transformer_big", &m), ("bert_large", &bert)] {
        for opt in ["adam", "adagrad", "adafactor", "sm3"] {
            let mut cells = Vec::new();
            for dtype in StateDtype::ALL {
                cells.push(mm.max_batch_dtype(opt, dtype)?);
            }
            println!("{model:<16} {opt:<12} {:>6} {:>6} {:>6}",
                     cells[0], cells[1], cells[2]);
        }
    }
    if let Some(path) = args.opt("out") {
        let mut logger = RunLogger::new(
            Some(path), "model,optimizer,batch_per_core,gib,fits", false)?;
        for r in rows {
            logger.row(&[r])?;
        }
        logger.flush()?;
    }
    Ok(())
}

/// Validate `BENCH_*.json` telemetry documents (the CI gate behind
/// `make bench-telemetry`): every file must parse as JSON and satisfy
/// `telemetry::validate_bench_doc` — schema tag, internally consistent
/// span stats, numeric counters/gauges. With `--baseline`, gauge peaks
/// are additionally held to the committed ceilings (the peak-memory
/// regression gate): a budgeted gauge present in a checked document
/// must not exceed `ceiling × (1 + max_regress/100)`; documents that
/// don't carry a budgeted gauge skip that budget gracefully.
fn cmd_bench_check(args: &sm3::cli::Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("bench-check needs at least one BENCH_*.json path");
    }
    let budgets = match args.opt("baseline") {
        Some(path) => Some(load_bench_baseline(path)?),
        None => None,
    };
    let tol = args.opt_parse::<f64>("max-regress")?.unwrap_or(10.0);
    if tol < 0.0 || !tol.is_finite() {
        bail!("--max-regress must be a non-negative percentage");
    }
    let mut bad = 0usize;
    for path in &args.positional {
        let doc = std::fs::read_to_string(path)
            .map_err(|e| format!("read error: {e}"))
            .and_then(|text| {
                sm3::json::Json::parse(&text)
                    .map_err(|e| format!("parse error: {e}"))
            });
        let verdict = doc.as_ref().map_err(Clone::clone).and_then(
            sm3::telemetry::validate_bench_doc);
        match verdict {
            Ok(()) => println!("  {path}: ok"),
            Err(e) => {
                println!("  {path}: INVALID — {e}");
                bad += 1;
                continue;
            }
        }
        let Some(budgets) = &budgets else { continue };
        let doc = doc.expect("validated above");
        let gauges = doc.get("gauges").expect("validated above");
        for (gauge, ceiling) in budgets {
            let Some(peak) =
                gauges.get(gauge).and_then(|g| g.get("peak"))
                      .and_then(sm3::json::Json::as_f64)
            else {
                // e.g. a timing bench with no pool gauge: skip, don't
                // fail — the memory bench is the gate's real subject
                println!("  {path}: gauge `{gauge}` absent — budget \
                          skipped");
                continue;
            };
            let limit = ceiling * (1.0 + tol / 100.0);
            if peak > limit {
                println!("  {path}: REGRESSION — `{gauge}` peak {peak} \
                          exceeds baseline {ceiling} (+{tol}% = {limit})");
                bad += 1;
            } else {
                println!("  {path}: `{gauge}` peak {peak} within \
                          baseline {ceiling} (+{tol}%)");
            }
        }
    }
    if bad > 0 {
        bail!("{bad} invalid or over-budget telemetry document(s)");
    }
    Ok(())
}

/// Parse the committed baseline file: `{schema, budgets: {gauge: max}}`.
fn load_bench_baseline(
    path: &str,
) -> Result<std::collections::BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading baseline {path}: {e}"))?;
    let doc = sm3::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing baseline {path}: {e}"))?;
    match doc.get("schema").and_then(sm3::json::Json::as_str) {
        Some("sm3-bench-baseline-v1") => {}
        other => bail!("baseline {path}: unknown schema tag {other:?}"),
    }
    let budgets = doc
        .get("budgets")
        .and_then(sm3::json::Json::as_object)
        .ok_or_else(|| {
            anyhow::anyhow!("baseline {path}: missing object `budgets`")
        })?;
    let mut out = std::collections::BTreeMap::new();
    for (gauge, v) in budgets {
        let ceiling = v
            .as_f64()
            .filter(|c| c.is_finite() && *c >= 0.0)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "baseline {path}: budget `{gauge}` must be a \
                     non-negative number, got {v:?}")
            })?;
        out.insert(gauge.clone(), ceiling);
    }
    Ok(out)
}

fn cmd_list(args: &sm3::cli::Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let manifest = sm3::runtime::manifest::Manifest::load(dir)?;
    println!("models:");
    for (name, meta) in &manifest.models {
        println!("  {name:<12} kind={:<4} params={:.2}M batch={}",
                 meta.kind, meta.param_count as f64 / 1e6, meta.batch);
    }
    println!("artifacts:");
    for (name, a) in &manifest.artifacts {
        println!("  {name:<28} {:<14} {:>3} in / {:>3} out",
                 a.kind, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}
