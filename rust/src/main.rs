//! `sm3-train` — the SM3 training framework launcher.
//!
//! Subcommands:
//!   train          run a training job from a TOML config (+ overrides)
//!   eval           evaluate a model's held-out metrics at init
//!   memory-report  per-core memory table for the real model inventories
//!                  (reproduces paper Tables 1–2)
//!   list           list AOT artifacts and models in the manifest
//!
//! Examples:
//!   sm3-train train --config configs/mt_sm3.toml
//!   sm3-train train --model lm_small --optimizer sm3 --steps 100 --exec fused
//!   sm3-train memory-report

use anyhow::{bail, Result};
use sm3::cli::Command;
use sm3::config::TrainConfig;
use sm3::coordinator::Trainer;
use sm3::memory::{inventory, MemoryModel, GIB};
use sm3::metrics::RunLogger;

fn commands() -> Vec<Command> {
    vec![
        Command::new("train", "run a training job")
            .option("config", "TOML config file (configs/*.toml)")
            .option("model", "model key override (lm_small, mt_small, ...)")
            .option("optimizer", "optimizer override (sm3|sm3i|adagrad|adam|adafactor|sgdm)")
            .option("steps", "step-count override")
            .option("lr", "base learning-rate override")
            .option("eps", "Adam eps override (split path; default 1e-8)")
            .option("clip-norm", "clip gradients to this global L2 norm (split path)")
            .option("clip-value", "clamp each gradient entry to [-c, c] (split path)")
            .option("weight-decay", "decoupled (AdamW-style) weight decay rate (split path; [[optim.group]] in TOML for per-group overrides)")
            .option("exec", "execution path: split | fused")
            .option("workers", "data-parallel worker count")
            .option("step-threads", "host threads for the optimizer update (1 = serial; bitwise-identical results)")
            .option("state-dtype", "optimizer-state storage precision: f32 | bf16 | q8 (split path)")
            .option("step-chunk", "streaming tile for the chunked step kernels, in elements (multiple of 64; bitwise-identical results)")
            .option("comm-dtype", "wire precision of the gradient exchange: f32 | bf16 | q8 (split path; compressed dtypes carry error-feedback residuals)")
            .option("comm-threads", "host threads for the ring collectives (1 = serial; bitwise-identical results)")
            .option("comm-chunk", "wire tile for the ring collectives, in elements (multiple of 64; bitwise-identical results)")
            .option("comm-buckets", "64-aligned gradient buckets the exchange pipelines over (1 = monolithic; bitwise-identical results)")
            .option("comm-transport", "hop-edge payload path: direct | inproc (bitwise-identical results; default from SM3_COMM_TRANSPORT)")
            .flag("comm-overlap", "stage bucket k+1 while bucket k's ring hops are in flight (split path; bitwise-identical results)")
            .option("kernel-backend", "tile-kernel implementation: scalar | simd (split path; bitwise-identical results)")
            .flag("no-pool", "bypass the memory-pool runtime (plain heap buffers; split path; bitwise-identical results)")
            .option("grad-accum", "microbatches per step")
            .option("seed", "data/init RNG seed")
            .option("artifacts", "artifacts directory (default: artifacts)")
            .option("out", "CSV output path for the loss curve")
            .option("save", "write final params + optimizer state here (SM3CKPT2; split path)")
            .option("telemetry-jsonl", "stream per-step telemetry events to this JSONL file (implies --telemetry semantics must hold: split path)")
            .flag("telemetry", "measure per-phase spans / counters / gauges (split path; bitwise-invisible to the trajectory)")
            .option("trace-out", "write a Chrome-trace/Perfetto JSON timeline of every span and counter/gauge update here (implies --telemetry; split path; bitwise-invisible)")
            .option("health-action", "what an abort-class health verdict does: warn (log and continue; default) | abort (halt naming the tripped rule)")
            .flag("quiet", "suppress per-step output"),
        Command::new("eval", "evaluate at initialization")
            .option("model", "model key")
            .option("artifacts", "artifacts directory"),
        Command::new("memory-report", "reproduce paper Tables 1-2")
            .option("out", "CSV output path"),
        Command::new("list", "list artifacts in the manifest")
            .option("artifacts", "artifacts directory"),
        Command::new("bench-check",
                     "validate BENCH_*.json telemetry documents (positional \
                      file paths; exits non-zero on schema violations)")
            .option("baseline",
                    "budget file (ci/BENCH_*_baseline.json): budgeted \
                     metrics — gauge peaks, `span_mean_ns:NAME` span means, \
                     `counter:NAME` totals — must stay within the committed \
                     ceilings")
            .option("max-regress",
                    "extra headroom over each baseline ceiling, in percent \
                     (default 10)"),
        Command::new("report",
                     "run-health + performance report over a run's telemetry \
                      (positional BENCH_*.json paths join the report)")
            .option("jsonl",
                    "per-step telemetry JSONL stream from a training run \
                     ([train] telemetry_jsonl / --telemetry-jsonl): phase \
                     budget breakdown + health summary")
            .option("trace",
                    "Chrome-trace JSON from --trace-out: validated, then \
                     mined for the measured hop-vs-stage overlap efficiency")
            .option("baseline",
                    "budget file: regression verdicts for every budgeted \
                     metric found in the positional BENCH documents")
            .option("max-regress",
                    "extra headroom over each baseline ceiling, in percent \
                     (default 10)")
            .flag("check",
                  "CI gate: exit non-zero on a schema-invalid trace/bench \
                   document, an abort-class health verdict, or a budget \
                   regression"),
    ]
}

fn usage() -> String {
    let mut s = String::from(
        "sm3-train — Memory-Efficient Adaptive Optimization (SM3), \
         NeurIPS 2019 reproduction\n\nUSAGE: sm3-train <command> [options]\n\n");
    for c in commands() {
        s.push_str(&c.usage());
        s.push('\n');
    }
    s
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd_name) = argv.first() else {
        eprintln!("{}", usage());
        bail!("missing command");
    };
    let cmds = commands();
    let Some(cmd) = cmds.iter().find(|c| c.name == cmd_name.as_str()) else {
        eprintln!("{}", usage());
        bail!("unknown command {cmd_name:?}");
    };
    let args = cmd.parse(&argv[1..])?;
    match cmd_name.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "memory-report" => cmd_memory_report(&args),
        "list" => cmd_list(&args),
        "bench-check" => cmd_bench_check(&args),
        "report" => cmd_report(&args),
        _ => unreachable!(),
    }
}

fn build_config(args: &sm3::cli::Args) -> Result<TrainConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => TrainConfig::load(path)?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.opt("model") {
        cfg.model = m.to_string();
    }
    if let Some(o) = args.opt("optimizer") {
        cfg.optim.name = o.to_string();
    }
    if let Some(s) = args.opt_parse::<u64>("steps")? {
        cfg.steps = s;
    }
    if let Some(lr) = args.opt_parse::<f64>("lr")? {
        cfg.optim.lr = lr;
    }
    if let Some(e) = args.opt_parse::<f64>("eps")? {
        cfg.optim.eps = e;
    }
    if let Some(c) = args.opt_parse::<f64>("clip-norm")? {
        cfg.optim.clip_norm = Some(c);
    }
    if let Some(c) = args.opt_parse::<f64>("clip-value")? {
        cfg.optim.clip_value = Some(c);
    }
    if let Some(w) = args.opt_parse::<f64>("weight-decay")? {
        cfg.optim.weight_decay = w;
    }
    if let Some(e) = args.opt("exec") {
        cfg.exec = sm3::config::ExecMode::parse(e)?;
    }
    if let Some(w) = args.opt_parse::<usize>("workers")? {
        cfg.workers = w;
    }
    if let Some(t) = args.opt_count("step-threads")? {
        cfg.step_threads = t;
    }
    if let Some(d) = args.opt("state-dtype") {
        cfg.state_dtype = sm3::optim::StateDtype::parse(d)?;
    }
    if let Some(c) = args.opt_count("step-chunk")? {
        cfg.step_chunk = c; // cfg.validate() checks block alignment
    }
    if let Some(d) = args.opt("comm-dtype") {
        cfg.comm_dtype = sm3::optim::StateDtype::parse(d)?;
    }
    if let Some(t) = args.opt_count("comm-threads")? {
        cfg.comm_threads = t;
    }
    if let Some(c) = args.opt_count("comm-chunk")? {
        cfg.comm_chunk = c; // cfg.validate() checks block alignment
    }
    if let Some(b) = args.opt_count("comm-buckets")? {
        cfg.comm_buckets = b; // engine rejects untileable bucket counts
    }
    if args.has_flag("comm-overlap") {
        cfg.comm_overlap = true;
    }
    if let Some(t) = args.opt("comm-transport") {
        cfg.comm_transport = sm3::comms::TransportKind::parse(t)?;
    }
    if let Some(b) = args.opt("kernel-backend") {
        cfg.kernel_backend = sm3::optim::Backend::parse(b)?;
    }
    if args.has_flag("no-pool") {
        cfg.pool = false;
    }
    if let Some(g) = args.opt_parse::<u64>("grad-accum")? {
        cfg.grad_accum = g;
    }
    if let Some(s) = args.opt_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if args.has_flag("telemetry") {
        cfg.telemetry = true;
    }
    if let Some(p) = args.opt("telemetry-jsonl") {
        // the JSONL stream implies measurement (validate() enforces the
        // pairing for TOML configs; the CLI just does the obvious thing)
        cfg.telemetry = true;
        cfg.telemetry_jsonl = Some(p.to_string());
    }
    if let Some(p) = args.opt("trace-out") {
        // the trace rings record the telemetry spans, so tracing implies
        // measurement too
        cfg.telemetry = true;
        cfg.trace_out = Some(p.to_string());
    }
    if let Some(a) = args.opt("health-action") {
        cfg.health_action = a.parse()
            .map_err(|e| anyhow::anyhow!("--health-action: {e}"))?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &sm3::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let quiet = args.has_flag("quiet");
    // fail fast: a fused run cannot save (the artifact owns its optimizer
    // state), and learning that after the full run would discard the work
    if args.opt("save").is_some() && cfg.exec == sm3::config::ExecMode::Fused {
        bail!("--save needs the split path (the fused artifact owns its \
               optimizer state)");
    }
    println!(
        "sm3-train: model={} optimizer={} exec={:?} steps={} workers={} \
         grad_accum={} step_threads={} state_dtype={} step_chunk={}",
        cfg.model, cfg.optim.name, cfg.exec, cfg.steps, cfg.workers,
        cfg.grad_accum, cfg.step_threads, cfg.state_dtype.name(),
        cfg.step_chunk
    );
    if cfg.workers > 1 {
        println!(
            "  comms: dtype={} threads={} chunk={} buckets={} overlap={} \
             transport={} (ring all-reduce, error feedback {})",
            cfg.comm_dtype.name(), cfg.comm_threads, cfg.comm_chunk,
            cfg.comm_buckets, cfg.comm_overlap, cfg.comm_transport.name(),
            if cfg.comm_dtype == sm3::optim::StateDtype::F32 {
                "off"
            } else {
                "on"
            }
        );
    }
    if cfg.optim.has_transforms() {
        println!(
            "  pipeline: clip_value={} clip_norm={} weight_decay={} \
             groups={}",
            cfg.optim.clip_value.map_or("-".into(), |v| v.to_string()),
            cfg.optim.clip_norm.map_or("-".into(), |v| v.to_string()),
            cfg.optim.weight_decay, cfg.optim.groups.len()
        );
    }
    let mut trainer = Trainer::new(cfg.clone())?;
    println!("  platform: {}", trainer.runtime().platform());
    println!("  params:   {:.2}M", trainer.meta.param_count as f64 / 1e6);
    if let Some(opt) = trainer.optimizer() {
        println!("  opt state: {:.2}M floats / {:.2} MiB as {} ({})",
                 opt.state_floats() as f64 / 1e6,
                 opt.state_bytes() as f64 / (1024.0 * 1024.0),
                 opt.state_dtype().name(), opt.name());
    }
    let mut logger = RunLogger::new(
        args.opt("out"),
        "step,loss,loss_ema,lr,wall_ms,comm_ms,grad_ms,opt_ms,\
         comm_pack_ms,comm_hop_ms,comm_unpack_ms,ckpt_ms",
        false)?;
    let hist = trainer.train()?;
    for s in &hist.steps {
        logger.row(&[s.step.to_string(), format!("{:.6}", s.loss),
                     format!("{:.6}", s.loss_ema), format!("{:.6e}", s.lr),
                     format!("{:.2}", s.wall_ms),
                     format!("{:.4}", s.comm_ms),
                     format!("{:.4}", s.grad_ms),
                     format!("{:.4}", s.opt_ms),
                     format!("{:.4}", s.comm_pack_ms),
                     format!("{:.4}", s.comm_hop_ms),
                     format!("{:.4}", s.comm_unpack_ms),
                     format!("{:.4}", s.ckpt_ms)])?;
        if !quiet && (s.step % 10 == 0 || s.step == 1) {
            println!("  step {:>6}  loss {:.4}  (ema {:.4})  lr {:.3e}  {:.0} ms",
                     s.step, s.loss, s.loss_ema, s.lr, s.wall_ms);
        }
    }
    logger.flush()?;
    if cfg.telemetry {
        let reg = trainer.telemetry_registry();
        println!("  telemetry (per-phase, whole run):");
        for (name, s) in reg.spans() {
            println!("    {name:<18} n={:<6} total {:>9.3} ms  \
                      mean {:>9.1} us",
                     s.count, s.total_ns as f64 / 1e6, s.mean_ns() / 1e3);
        }
        for (name, v) in reg.counters() {
            println!("    {name:<18} {v}");
        }
        for (name, g) in reg.gauges() {
            println!("    {name:<18} last={} peak={}", g.last, g.peak);
        }
    }
    if let Some(path) = &cfg.trace_out {
        println!("  trace: {path} (load in chrome://tracing or \
                  ui.perfetto.dev; lanes = threads + worker replays)");
    }
    for e in &hist.evals {
        let metric = e.metric.map(|m| format!("  metric {m:.4}"))
            .unwrap_or_default();
        println!("  eval @ {:>6}: loss {:.4}{}", e.step, e.loss, metric);
    }
    if let Some(path) = args.opt("save") {
        trainer.save_checkpoint(path)?;
        println!("  checkpoint: {path} (params f32 + optimizer state as {})",
                 cfg.state_dtype.name());
    }
    Ok(())
}

fn cmd_eval(args: &sm3::cli::Args) -> Result<()> {
    let mut cfg = TrainConfig::default();
    if let Some(m) = args.opt("model") {
        cfg.model = m.to_string();
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    cfg.steps = 1;
    let trainer = Trainer::new(cfg)?;
    let e = trainer.evaluate()?;
    println!("eval: loss {:.4}  metric {:?}", e.loss, e.metric);
    Ok(())
}

fn cmd_memory_report(args: &sm3::cli::Args) -> Result<()> {
    use sm3::optim::StateDtype;
    // Table 1: Transformer-Big on TPUv2 (8 GiB/core), batch 12 & 24 per core
    let m = MemoryModel::calibrate(
        inventory::transformer_big(),
        8.0 * GIB,
        ("adam", 12, 6.88 * GIB),
        ("sm3", 24, 7.02 * GIB),
    )?;
    println!("Table 1 — Transformer-Big (WMT'14 en→fr), GiB per TPUv2 core");
    println!("{:<12} {:>6} {:>10} {:>8}", "optimizer", "batch", "memory", "fits");
    let mut rows = Vec::new();
    for (opt, b) in [("adam", 12), ("adagrad", 12), ("adafactor", 12),
                     ("sm3", 12), ("adam", 24), ("adagrad", 24),
                     ("adafactor", 24), ("sm3", 24)] {
        let gib = m.gib_per_core(opt, b)?;
        let fits = m.fits(opt, b)?;
        println!("{opt:<12} {b:>6} {gib:>9.2} {:>8}",
                 if fits { "yes" } else { "OOM" });
        rows.push(format!("transformer_big,{opt},{b},{gib:.3},{fits}"));
    }
    // Table 2: BERT-Large on 8x8 TPUv2
    let bert = MemoryModel::calibrate(
        inventory::bert_large(),
        8.0 * GIB,
        ("adam", 8, 6.15 * GIB),
        ("sm3", 16, 6.02 * GIB),
    )?;
    println!("\nTable 2 — BERT-Large, GiB per TPUv2 core");
    for (opt, b) in [("adam", 8), ("sm3", 8), ("sm3", 16), ("adam", 16)] {
        let gib = bert.gib_per_core(opt, b)?;
        let fits = bert.fits(opt, b)?;
        println!("{opt:<12} {b:>6} {gib:>9.2} {:>8}",
                 if fits { "yes" } else { "OOM" });
        rows.push(format!("bert_large,{opt},{b},{gib:.3},{fits}"));
    }
    // Past the paper: the max-batch frontier with quantized optimizer
    // state (optim::qstate; --state-dtype on the train command)
    println!("\nQuantized-state max batch/core (8 GiB TPUv2)");
    println!("{:<16} {:<12} {:>6} {:>6} {:>6}",
             "model", "optimizer", "f32", "bf16", "q8");
    for (model, mm) in [("transformer_big", &m), ("bert_large", &bert)] {
        for opt in ["adam", "adagrad", "adafactor", "sm3"] {
            let mut cells = Vec::new();
            for dtype in StateDtype::ALL {
                cells.push(mm.max_batch_dtype(opt, dtype)?);
            }
            println!("{model:<16} {opt:<12} {:>6} {:>6} {:>6}",
                     cells[0], cells[1], cells[2]);
        }
    }
    if let Some(path) = args.opt("out") {
        let mut logger = RunLogger::new(
            Some(path), "model,optimizer,batch_per_core,gib,fits", false)?;
        for r in rows {
            logger.row(&[r])?;
        }
        logger.flush()?;
    }
    Ok(())
}

/// Validate `BENCH_*.json` telemetry documents (the CI gate behind
/// `make bench-telemetry`): every file must parse as JSON and satisfy
/// `telemetry::validate_bench_doc` — schema tag, internally consistent
/// span stats, numeric counters/gauges. With `--baseline`, budgeted
/// metrics (gauge peaks, `span_mean_ns:NAME` means, `counter:NAME`
/// totals) are additionally held to the committed ceilings: a budgeted
/// metric present in a checked document must not exceed
/// `ceiling × (1 + max_regress/100)`; documents that don't carry a
/// budgeted metric skip that budget gracefully.
fn cmd_bench_check(args: &sm3::cli::Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("bench-check needs at least one BENCH_*.json path");
    }
    let budgets = match args.opt("baseline") {
        Some(path) => Some(load_bench_baseline(path)?),
        None => None,
    };
    let tol = args.opt_parse::<f64>("max-regress")?.unwrap_or(10.0);
    if tol < 0.0 || !tol.is_finite() {
        bail!("--max-regress must be a non-negative percentage");
    }
    let mut bad = 0usize;
    for path in &args.positional {
        let doc = std::fs::read_to_string(path)
            .map_err(|e| format!("read error: {e}"))
            .and_then(|text| {
                sm3::json::Json::parse(&text)
                    .map_err(|e| format!("parse error: {e}"))
            });
        let verdict = doc.as_ref().map_err(Clone::clone).and_then(
            sm3::telemetry::validate_bench_doc);
        match verdict {
            Ok(()) => println!("  {path}: ok"),
            Err(e) => {
                println!("  {path}: INVALID — {e}");
                bad += 1;
                continue;
            }
        }
        if let Some(budgets) = &budgets {
            bad += check_budgets(path, &doc.expect("validated above"),
                                 budgets, tol);
        }
    }
    if bad > 0 {
        bail!("{bad} invalid or over-budget telemetry document(s)");
    }
    Ok(())
}

/// Resolve a baseline budget key against a bench document. The key
/// names one of the three metric families of `Registry::to_json`:
///   `span_mean_ns:NAME` → `spans.NAME.mean_ns`
///   `counter:NAME`      → `counters.NAME`
///   `gauge_peak:NAME`   → `gauges.NAME.peak`
/// A bare name keeps its original meaning — a gauge peak — so the
/// first-generation memory baselines stay valid unchanged.
fn resolve_metric(doc: &sm3::json::Json, key: &str) -> Option<f64> {
    let (section, name, field) = match key.split_once(':') {
        Some(("span_mean_ns", n)) => ("spans", n, Some("mean_ns")),
        Some(("counter", n)) => ("counters", n, None),
        Some(("gauge_peak", n)) => ("gauges", n, Some("peak")),
        _ => ("gauges", key, Some("peak")),
    };
    let node = doc.get(section)?.get(name)?;
    match field {
        Some(f) => node.get(f)?.as_f64(),
        None => node.as_f64(),
    }
}

/// Hold every budgeted metric carried by `doc` to its committed
/// ceiling (+`tol`% headroom). Returns the number of regressions;
/// budgets whose metric is absent from the document are skipped — each
/// baseline file gates the bench that actually records its metrics.
fn check_budgets(
    path: &str,
    doc: &sm3::json::Json,
    budgets: &std::collections::BTreeMap<String, f64>,
    tol: f64,
) -> usize {
    let mut bad = 0usize;
    for (key, ceiling) in budgets {
        let Some(value) = resolve_metric(doc, key) else {
            println!("  {path}: metric `{key}` absent — budget skipped");
            continue;
        };
        let limit = ceiling * (1.0 + tol / 100.0);
        if value > limit {
            println!("  {path}: REGRESSION — `{key}` = {value} exceeds \
                      baseline {ceiling} (+{tol}% = {limit})");
            bad += 1;
        } else {
            println!("  {path}: `{key}` = {value} within baseline \
                      {ceiling} (+{tol}%)");
        }
    }
    bad
}

/// Parse the committed baseline file: `{schema, budgets: {gauge: max}}`.
fn load_bench_baseline(
    path: &str,
) -> Result<std::collections::BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading baseline {path}: {e}"))?;
    let doc = sm3::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing baseline {path}: {e}"))?;
    match doc.get("schema").and_then(sm3::json::Json::as_str) {
        Some("sm3-bench-baseline-v1") => {}
        other => bail!("baseline {path}: unknown schema tag {other:?}"),
    }
    let budgets = doc
        .get("budgets")
        .and_then(sm3::json::Json::as_object)
        .ok_or_else(|| {
            anyhow::anyhow!("baseline {path}: missing object `budgets`")
        })?;
    let mut out = std::collections::BTreeMap::new();
    for (gauge, v) in budgets {
        let ceiling = v
            .as_f64()
            .filter(|c| c.is_finite() && *c >= 0.0)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "baseline {path}: budget `{gauge}` must be a \
                     non-negative number, got {v:?}")
            })?;
        out.insert(gauge.clone(), ceiling);
    }
    Ok(out)
}

/// The run reporter (`make report`): joins a run's per-step telemetry
/// JSONL, its Chrome-trace timeline, and the standing `BENCH_*.json`
/// snapshots into one screenful — phase budgets, the measured
/// hop-vs-stage overlap efficiency, watchdog verdicts, and baseline
/// regression verdicts. With `--check` it is the CI gate: a
/// schema-invalid trace/bench document, an abort-class health verdict,
/// or a budget regression exits non-zero.
fn cmd_report(args: &sm3::cli::Args) -> Result<()> {
    use sm3::json::Json;
    let check = args.has_flag("check");
    let tol = args.opt_parse::<f64>("max-regress")?.unwrap_or(10.0);
    if tol < 0.0 || !tol.is_finite() {
        bail!("--max-regress must be a non-negative percentage");
    }
    if args.opt("jsonl").is_none() && args.opt("trace").is_none()
        && args.positional.is_empty()
    {
        bail!("report needs --jsonl, --trace, or BENCH_*.json paths");
    }
    let mut bad = 0usize;
    if let Some(path) = args.opt("jsonl") {
        bad += report_jsonl(path)?;
    }
    if let Some(path) = args.opt("trace") {
        bad += report_trace(path)?;
    }
    let budgets = match args.opt("baseline") {
        Some(path) => Some(load_bench_baseline(path)?),
        None => None,
    };
    if !args.positional.is_empty() {
        println!("bench documents:");
    }
    for path in &args.positional {
        let doc = std::fs::read_to_string(path)
            .map_err(|e| format!("read error: {e}"))
            .and_then(|text| {
                Json::parse(&text).map_err(|e| format!("parse error: {e}"))
            });
        let verdict = doc.as_ref().map_err(Clone::clone).and_then(
            sm3::telemetry::validate_bench_doc);
        match verdict {
            Ok(()) => {
                let doc = doc.expect("validated above");
                let bench = doc.get("bench").and_then(Json::as_str)
                    .unwrap_or("?");
                println!("  {path}: ok (bench `{bench}`)");
                if let Some(budgets) = &budgets {
                    bad += check_budgets(path, &doc, budgets, tol);
                }
            }
            Err(e) => {
                println!("  {path}: INVALID — {e}");
                bad += 1;
            }
        }
    }
    if bad > 0 {
        if check {
            bail!("report: {bad} failing check(s)");
        }
        println!("report: {bad} finding(s) — advisory without --check");
    }
    Ok(())
}

/// Phase-budget breakdown + run-health summary from the per-step
/// telemetry JSONL stream. Returns the number of failing checks (an
/// abort-class health verdict fails; warn-class trips are reported but
/// pass — mirroring `HealthAction`).
fn report_jsonl(path: &str) -> Result<usize> {
    use sm3::json::Json;
    use std::collections::BTreeMap;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let mut steps = 0usize;
    let mut verdicts: BTreeMap<&str, usize> = BTreeMap::new();
    // rule name -> (worst severity seen, steps it tripped on)
    let mut trips: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut summary: Option<Json> = None;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line).map_err(|e| {
            anyhow::anyhow!("{path}:{}: {e}", lineno + 1)
        })?;
        match ev.get("type").and_then(Json::as_str) {
            Some("step") => {
                steps += 1;
                let Some(h) = ev.get("health") else { continue };
                match h.get("verdict").and_then(Json::as_str) {
                    Some("ok") => *verdicts.entry("ok").or_insert(0) += 1,
                    Some("warn") => *verdicts.entry("warn").or_insert(0) += 1,
                    Some("abort") => {
                        *verdicts.entry("abort").or_insert(0) += 1
                    }
                    _ => *verdicts.entry("?").or_insert(0) += 1,
                }
                let rules = h.get("rules").and_then(Json::as_array)
                    .unwrap_or(&[]);
                for r in rules {
                    let rule = r.get("rule").and_then(Json::as_str)
                        .unwrap_or("?");
                    let sev = r.get("severity").and_then(Json::as_str)
                        .unwrap_or("?");
                    let slot = trips.entry(rule.to_string())
                        .or_insert_with(|| (sev.to_string(), 0));
                    if sev == "abort" {
                        slot.0 = "abort".to_string();
                    }
                    slot.1 += 1;
                }
            }
            Some("summary") => summary = ev.get("registry").cloned(),
            _ => {}
        }
    }
    println!("run {path}: {steps} step event(s)");
    match &summary {
        Some(reg) => report_registry(reg),
        None => println!("  (no summary event — phase tables unavailable)"),
    }
    let (ok, warn, abort) = (
        verdicts.get("ok").copied().unwrap_or(0),
        verdicts.get("warn").copied().unwrap_or(0),
        verdicts.get("abort").copied().unwrap_or(0),
    );
    println!("  health: ok {ok}, warn {warn}, abort {abort}");
    for (rule, (sev, n)) in &trips {
        println!("    tripped `{rule}` ({sev}) on {n} step(s)");
    }
    if abort > 0 {
        println!("    FAIL — abort-class verdict in the stream");
        return Ok(1);
    }
    Ok(0)
}

/// The phase-budget table from a summary event's registry JSON. The
/// share column apportions run time across the top-level phases;
/// sub-spans (`opt_worker` runs inside `opt_step`) print `-` so the
/// shares sum to 100%.
fn report_registry(reg: &sm3::json::Json) {
    use sm3::json::Json;
    const TOP: &[&str] = &[
        "grad", "opt_step", "comm/pack", "comm/feedback",
        "comm/hop_reduce", "comm/hop_encode", "comm/hop_gather",
        "comm/unpack", "eval", "ckpt_io",
    ];
    if let Some(spans) = reg.get("spans").and_then(Json::as_object) {
        let run_ns: f64 = TOP.iter()
            .filter_map(|p| spans.get(*p))
            .filter_map(|s| s.get("total_ns"))
            .filter_map(Json::as_f64)
            .sum();
        println!("  phase budget (whole run):");
        for (name, s) in spans {
            let total = s.get("total_ns").and_then(Json::as_f64)
                .unwrap_or(0.0);
            let count = s.get("count").and_then(Json::as_f64)
                .unwrap_or(0.0);
            let mean = s.get("mean_ns").and_then(Json::as_f64)
                .unwrap_or(0.0);
            let share = if run_ns > 0.0 && TOP.contains(&name.as_str()) {
                format!("{:>5.1}%", 100.0 * total / run_ns)
            } else {
                "     -".to_string()
            };
            println!("    {name:<18} {share}  n={count:<7} total \
                      {:>10.3} ms  mean {:>9.1} us",
                     total / 1e6, mean / 1e3);
        }
    }
    if let Some(counters) = reg.get("counters").and_then(Json::as_object) {
        for (name, v) in counters {
            println!("    {name:<18} {v}");
        }
    }
    if let Some(gauges) = reg.get("gauges").and_then(Json::as_object) {
        for (name, g) in gauges {
            let last = g.get("last").map(Json::to_string)
                .unwrap_or_default();
            let peak = g.get("peak").map(Json::to_string)
                .unwrap_or_default();
            println!("    {name:<18} last={last} peak={peak}");
        }
    }
}

/// Validate the Chrome-trace document, then mine it for the measured
/// hop-vs-stage overlap efficiency. Returns the number of failing
/// checks (a schema-invalid trace fails).
fn report_trace(path: &str) -> Result<usize> {
    use sm3::json::Json;
    let doc = std::fs::read_to_string(path)
        .map_err(|e| format!("read error: {e}"))
        .and_then(|text| {
            Json::parse(&text).map_err(|e| format!("parse error: {e}"))
        });
    let verdict = doc.as_ref().map_err(Clone::clone).and_then(
        sm3::telemetry::validate_trace_doc);
    match verdict {
        Err(e) => {
            println!("trace {path}: INVALID — {e}");
            Ok(1)
        }
        Ok(()) => {
            let doc = doc.expect("validated above");
            let events = doc.get("traceEvents").and_then(Json::as_array)
                .map_or(0, <[Json]>::len);
            let dropped = doc.get("dropped_events")
                .and_then(Json::as_usize).unwrap_or(0);
            println!("trace {path}: ok — {events} event(s), \
                      {dropped} dropped");
            match sm3::telemetry::trace_event::overlap_efficiency(&doc) {
                Some(x) => println!(
                    "  overlap efficiency: {:.1}% of ring-hop time ran \
                     concurrently with bucket staging", 100.0 * x),
                None => println!(
                    "  overlap efficiency: n/a (no hop/stage span pair \
                     in the trace)"),
            }
            Ok(0)
        }
    }
}

fn cmd_list(args: &sm3::cli::Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let manifest = sm3::runtime::manifest::Manifest::load(dir)?;
    println!("models:");
    for (name, meta) in &manifest.models {
        println!("  {name:<12} kind={:<4} params={:.2}M batch={}",
                 meta.kind, meta.param_count as f64 / 1e6, meta.batch);
    }
    println!("artifacts:");
    for (name, a) in &manifest.artifacts {
        println!("  {name:<28} {:<14} {:>3} in / {:>3} out",
                 a.kind, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}
