//! Corpus BLEU (Papineni et al.) — the paper's Table 1 / Fig. 6 metric.
//!
//! Standard BLEU-4: modified n-gram precision with per-sentence clipping
//! against the reference, geometric mean over n = 1..4 with +0 smoothing
//! (a precision of zero zeroes the score, as in the canonical definition),
//! and the corpus-level brevity penalty. Operates on token-id sequences —
//! the paper likewise reports tokenized BLEU.

use std::collections::HashMap;

/// Detailed corpus score.
#[derive(Debug, Clone)]
pub struct BleuScore {
    /// canonical BLEU-4, 0..100 (zero if any n-gram precision is zero)
    pub bleu: f64,
    /// add-one-smoothed BLEU-4 (Lin & Och smoothing for n ≥ 2) — finite
    /// and informative for partially-trained models where canonical
    /// BLEU-4 is degenerately 0
    pub bleu_smooth: f64,
    pub precisions: [f64; 4],
    pub brevity_penalty: f64,
    pub hyp_len: usize,
    pub ref_len: usize,
}

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus BLEU over aligned (hypothesis, reference) pairs.
pub fn corpus_bleu(hyps: &[Vec<i32>], refs: &[Vec<i32>]) -> BleuScore {
    assert_eq!(hyps.len(), refs.len(), "hyps/refs must align");
    let mut matched = [0usize; 4];
    let mut total = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (h, r) in hyps.iter().zip(refs) {
        hyp_len += h.len();
        ref_len += r.len();
        for n in 1..=4 {
            let hc = ngram_counts(h, n);
            let rc = ngram_counts(r, n);
            for (gram, &count) in &hc {
                total[n - 1] += count;
                let clip = rc.get(gram).copied().unwrap_or(0);
                matched[n - 1] += count.min(clip);
            }
        }
    }
    let mut precisions = [0.0f64; 4];
    for n in 0..4 {
        precisions[n] = if total[n] == 0 {
            0.0
        } else {
            matched[n] as f64 / total[n] as f64
        };
    }
    let bp = if hyp_len == 0 {
        0.0
    } else if hyp_len > ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    let bleu = if precisions.iter().any(|&p| p == 0.0) {
        0.0
    } else {
        let log_mean: f64 =
            precisions.iter().map(|p| p.ln()).sum::<f64>() / 4.0;
        bp * log_mean.exp() * 100.0
    };
    // add-one smoothing on n >= 2 (Lin & Och, "smoothing 1")
    let mut smooth = [0.0f64; 4];
    for n in 0..4 {
        smooth[n] = if n == 0 {
            precisions[0]
        } else {
            (matched[n] + 1) as f64 / (total[n] + 1) as f64
        };
    }
    let bleu_smooth = if smooth[0] == 0.0 {
        0.0
    } else {
        let log_mean: f64 = smooth.iter().map(|p| p.ln()).sum::<f64>() / 4.0;
        bp * log_mean.exp() * 100.0
    };
    BleuScore { bleu, bleu_smooth, precisions, brevity_penalty: bp,
                hyp_len, ref_len }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let seqs = vec![vec![1, 2, 3, 4, 5], vec![6, 7, 8, 9]];
        let s = corpus_bleu(&seqs, &seqs);
        assert!((s.bleu - 100.0).abs() < 1e-9);
        assert_eq!(s.brevity_penalty, 1.0);
    }

    #[test]
    fn disjoint_is_zero() {
        let h = vec![vec![1, 2, 3, 4, 5]];
        let r = vec![vec![6, 7, 8, 9, 10]];
        let s = corpus_bleu(&h, &r);
        assert_eq!(s.bleu, 0.0);
        // unigram precision 0 zeroes the smoothed score too
        assert_eq!(s.bleu_smooth, 0.0);
    }

    #[test]
    fn smoothed_is_finite_when_canonical_is_zero() {
        // some unigram overlap but no 4-gram match
        let h = vec![vec![1, 9, 3, 8, 5]];
        let r = vec![vec![1, 2, 3, 4, 5]];
        let s = corpus_bleu(&h, &r);
        assert_eq!(s.bleu, 0.0);
        assert!(s.bleu_smooth > 0.0 && s.bleu_smooth < 100.0);
    }

    #[test]
    fn smoothed_tracks_canonical_when_all_match() {
        let seqs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let s = corpus_bleu(&seqs, &seqs);
        assert!((s.bleu - 100.0).abs() < 1e-9);
        assert!(s.bleu_smooth > 90.0);
    }

    #[test]
    fn brevity_penalty_applies_to_short_hypotheses() {
        let h = vec![vec![1, 2, 3, 4]];
        let r = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let s = corpus_bleu(&h, &r);
        assert!(s.brevity_penalty < 1.0);
        // 4/8: bp = exp(1 - 2) = e^-1
        assert!((s.brevity_penalty - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn clipping_limits_repeated_ngrams() {
        // hypothesis repeats a unigram beyond its reference count
        let h = vec![vec![1, 1, 1, 1, 1, 1, 1]];
        let r = vec![vec![1, 2, 3, 4, 5, 6, 7]];
        let s = corpus_bleu(&h, &r);
        // unigram precision = 1/7 (clip at one occurrence)
        assert!((s.precisions[0] - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn partial_overlap_is_between() {
        let h = vec![vec![1, 2, 3, 9, 5, 6, 7, 8]];
        let r = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let b = corpus_bleu(&h, &r).bleu;
        assert!(b > 10.0 && b < 90.0, "bleu {b}");
    }

    #[test]
    fn corpus_pools_statistics() {
        // corpus BLEU is not the mean of sentence BLEUs: a zero-overlap
        // sentence does not zero the corpus score
        let h = vec![vec![1, 2, 3, 4, 5], vec![20, 21, 22, 23, 24]];
        let r = vec![vec![1, 2, 3, 4, 5], vec![6, 7, 8, 9, 10]];
        let s = corpus_bleu(&h, &r);
        assert!(s.bleu > 0.0 && s.bleu < 100.0);
    }
}
