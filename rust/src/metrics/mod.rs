//! Training/eval metrics: BLEU, perplexity, accuracy, EMA smoothing, and
//! CSV curve logging (the series behind every reproduced figure).

pub mod bleu;

pub use bleu::{corpus_bleu, BleuScore};

use std::io::Write;

/// Exponential moving average (loss-curve smoothing, as in the paper's
/// training plots).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * prev + (1.0 - self.alpha) * x,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Streaming mean/variance (Welford) for stable metric aggregation.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Perplexity from mean token NLL (the paper's Fig. 2 y-axis is
/// log-perplexity == the loss itself).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// A metrics logger writing one CSV per run (plus stdout echo).
pub struct RunLogger {
    out: Option<std::io::BufWriter<std::fs::File>>,
    pub echo: bool,
}

impl RunLogger {
    /// `path = None` logs to stdout only.
    pub fn new(path: Option<&str>, header: &str, echo: bool)
               -> std::io::Result<Self> {
        let out = match path {
            None => None,
            Some(p) => {
                if let Some(dir) = std::path::Path::new(p).parent() {
                    std::fs::create_dir_all(dir)?;
                }
                let mut f = std::io::BufWriter::new(std::fs::File::create(p)?);
                writeln!(f, "{header}")?;
                Some(f)
            }
        };
        Ok(Self { out, echo })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        let line = fields.join(",");
        if let Some(f) = &mut self.out {
            writeln!(f, "{line}")?;
        }
        if self.echo {
            println!("  {line}");
        }
        Ok(())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        if let Some(f) = &mut self.out {
            f.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges_to_constant() {
        let mut e = Ema::new(0.9);
        for _ in 0..200 {
            e.update(3.0);
        }
        assert!((e.get().unwrap() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn ema_first_value_passthrough() {
        let mut e = Ema::new(0.99);
        assert_eq!(e.update(7.0), 7.0);
    }

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn perplexity_of_uniform() {
        // uniform over 64 tokens: nll = ln 64 -> ppl = 64
        assert!((perplexity(64f64.ln()) - 64.0).abs() < 1e-9);
    }
}
