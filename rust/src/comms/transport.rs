//! Pluggable hop-edge transport for the ring executor.
//!
//! PR 5's executor moves every hop payload by reaching directly into the
//! peer rank's flat buffer (all ranks live in one address space). That
//! is the fastest possible in-process path, but it hard-wires the
//! assumption that a "link" is a shared-memory view — rank processes,
//! sockets, or RDMA can never slot in. This module splits the *data
//! movement* out of the executor behind [`Transport`]:
//!
//! * a hop edge is the directed ring link `src → (src+1) mod n`;
//! * the sender serializes one tile's **wire encoding** (the exact
//!   `comm_dtype` bytes the schedule accounts — q8 scale fields + codes,
//!   bf16 words, or raw f32) into a byte message and [`Transport::send`]s
//!   it;
//! * the receiver [`Transport::recv`]s the message and decodes it into
//!   its accumulate/copy lane.
//!
//! Because the wire serialization is exact little-endian bit transport
//! (`f32::to_le_bytes`/`from_le_bytes` round-trip every bit pattern),
//! `decode(serialize(encode(x)))` equals the direct path's
//! `decode(encode(x))` bit for bit — so swapping transports can never
//! change a trajectory, and the bitwise gates run at every
//! [`TransportKind`].
//!
//! Today's implementation is [`InprocTransport`]: one preallocated
//! message slab per ring edge behind a mutex, rendezvous discipline
//! (exactly one in-flight message per edge; the executor pairs each
//! `send` with its `recv`). Rank count is a property of the transport,
//! not of the executor's thread pool, so `ranks` may exceed
//! `comm_threads` on every path. A socket or shared-memory rank-process
//! transport implements the same two methods and inherits the whole
//! schedule, bucketing, and determinism argument unchanged.

use super::ring::{Phase, WireScratch};
use crate::optim::qstate::codec;
use crate::optim::{Backend, StateDtype};
use crate::pool::{Pool, PoolBuf, Tag};
use anyhow::{bail, ensure, Result};
use std::sync::Mutex;

/// Which transport the comm engine moves hop payloads through
/// (config key `comm_transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Zero-copy shared-memory fast path: the executor reads the peer's
    /// buffer directly (the PR 5 behaviour, and the default).
    Direct,
    /// In-process channel transport: payloads are serialized to wire
    /// bytes and move through per-edge message slabs ([`InprocTransport`]).
    Inproc,
}

impl TransportKind {
    /// Every selectable transport, for sweeps and gates.
    pub const ALL: [TransportKind; 2] =
        [TransportKind::Direct, TransportKind::Inproc];

    /// Stable config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Direct => "direct",
            TransportKind::Inproc => "inproc",
        }
    }

    /// Parse a config/CLI value (`direct` | `inproc`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "direct" => Ok(TransportKind::Direct),
            "inproc" => Ok(TransportKind::Inproc),
            other => bail!(
                "unknown comm_transport {other:?} (expected \"direct\" or \
                 \"inproc\")"
            ),
        }
    }

    /// Resolve the ambient default from `SM3_COMM_TRANSPORT` (unset or
    /// empty ⇒ [`TransportKind::Direct`]). CI matrixes the quick bench
    /// gates over this variable so every bitwise gate also executes with
    /// the channel transport as the ambient default.
    pub fn ambient() -> Result<Self> {
        Self::ambient_from(std::env::var("SM3_COMM_TRANSPORT").ok().as_deref())
    }

    /// [`TransportKind::ambient`] with the environment value injected
    /// (testable without process-global env mutation).
    pub fn ambient_from(v: Option<&str>) -> Result<Self> {
        match v {
            None | Some("") => Ok(TransportKind::Direct),
            Some(s) => Self::parse(s),
        }
    }
}

impl Default for TransportKind {
    /// The ambient default; an unparseable `SM3_COMM_TRANSPORT` falls
    /// back to `Direct` here (config parsing surfaces the error loudly
    /// via [`TransportKind::ambient`]).
    fn default() -> Self {
        Self::ambient().unwrap_or(TransportKind::Direct)
    }
}

/// A reliable, ordered message pipe per directed ring edge
/// `src → (src+1) mod ranks`.
///
/// Discipline: at most one message is in flight per edge; the executor
/// pairs every `send` with the matching `recv` before the next message
/// on that edge (one worker owns all of an edge's regions within a
/// step, so sends and recvs strictly alternate). In-process both sides
/// run on the same host; a rank-process transport splits them.
pub trait Transport: Send + Sync {
    /// Rank count of the pod this transport connects.
    fn ranks(&self) -> usize;
    /// Largest message (bytes) an edge can carry.
    fn max_message(&self) -> usize;
    /// Stage `bytes` on the edge `src → dst`. Errors if the edge is not
    /// a ring link, the message exceeds the slab, or a message is
    /// already in flight on the edge.
    fn send(&self, src: usize, dst: usize, bytes: &[u8]) -> Result<()>;
    /// Drain the pending message on edge `src → dst` into `out`;
    /// returns the byte count. Errors if no message is in flight.
    fn recv(&self, src: usize, dst: usize, out: &mut [u8]) -> Result<usize>;
}

/// One edge's preallocated message slab ([`Tag::TransportSlot`] when
/// the transport is pool-backed).
struct EdgeSlot {
    buf: PoolBuf<u8>,
    len: usize,
    full: bool,
}

/// In-process channel transport: per-edge mutex-protected slabs, sized
/// once at construction (steady-state sends/recvs allocate nothing).
pub struct InprocTransport {
    ranks: usize,
    cap: usize,
    /// indexed by sender rank (ring: the receiver is `(src+1) mod n`)
    edges: Vec<Mutex<EdgeSlot>>,
}

impl InprocTransport {
    /// Build the edge slabs for `ranks` ranks and messages of at most
    /// `cap` bytes (one tile's worst-case wire encoding).
    pub fn new(ranks: usize, cap: usize) -> Self {
        let edges = (0..ranks)
            .map(|_| {
                Mutex::new(EdgeSlot {
                    buf: PoolBuf::from_vec(Tag::TransportSlot,
                                           vec![0u8; cap]),
                    len: 0,
                    full: false,
                })
            })
            .collect();
        Self { ranks, cap, edges }
    }

    /// [`InprocTransport::new`] with the edge slabs leased from `pool`
    /// under [`Tag::TransportSlot`] (bitwise identical — placement only).
    pub fn new_in(pool: &Pool, ranks: usize, cap: usize) -> Self {
        let edges = (0..ranks)
            .map(|_| {
                Mutex::new(EdgeSlot {
                    buf: pool.take_u8(Tag::TransportSlot, cap),
                    len: 0,
                    full: false,
                })
            })
            .collect();
        Self { ranks, cap, edges }
    }

    /// Persistent slab bytes held by the edge buffers (the memory
    /// accountant's `comm_scratch_bytes` mirrors this).
    pub fn slab_bytes(&self) -> usize {
        self.ranks * self.cap
    }

    fn check_edge(&self, src: usize, dst: usize) -> Result<()> {
        ensure!(src < self.ranks && dst < self.ranks,
                "transport edge {src}->{dst} out of range for {} ranks",
                self.ranks);
        ensure!(dst == (src + 1) % self.ranks,
                "transport edge {src}->{dst} is not a ring link \
                 (expected {src}->{})",
                (src + 1) % self.ranks);
        Ok(())
    }
}

impl Transport for InprocTransport {
    fn ranks(&self) -> usize {
        self.ranks
    }

    fn max_message(&self) -> usize {
        self.cap
    }

    fn send(&self, src: usize, dst: usize, bytes: &[u8]) -> Result<()> {
        self.check_edge(src, dst)?;
        ensure!(bytes.len() <= self.cap,
                "transport message of {} bytes exceeds the {}-byte edge \
                 slab",
                bytes.len(), self.cap);
        let mut slot = self.edges[src].lock().unwrap();
        ensure!(!slot.full,
                "transport edge {src}->{dst} already carries an in-flight \
                 message");
        slot.buf[..bytes.len()].copy_from_slice(bytes);
        slot.len = bytes.len();
        slot.full = true;
        Ok(())
    }

    fn recv(&self, src: usize, dst: usize, out: &mut [u8]) -> Result<usize> {
        self.check_edge(src, dst)?;
        let mut slot = self.edges[src].lock().unwrap();
        ensure!(slot.full,
                "transport recv on edge {src}->{dst} with no in-flight \
                 message");
        let n = slot.len;
        ensure!(out.len() >= n,
                "transport recv buffer of {} bytes cannot hold a {n}-byte \
                 message",
                out.len());
        out[..n].copy_from_slice(&slot.buf[..n]);
        slot.full = false;
        Ok(n)
    }
}

/// Worst-case wire-message bytes for a `chunk`-element tile across all
/// dtypes (f32 dominates: 4 bytes/element; q8's scale fields stay well
/// under that). Sizes the edge slabs and the scratch byte slabs.
pub fn message_cap(chunk: usize) -> usize {
    4 * chunk
}

/// Serialize the wire encoding of `vals` into `out` (little-endian),
/// returning the byte count — exactly `wire_bytes_for(vals.len(), dtype)`.
/// Uses the scratch codec fields; `out` must be a disjoint slab.
pub fn encode_message(vals: &[f32], dtype: StateDtype, backend: Backend,
                      scratch_scales: &mut [f32], scratch_codes: &mut [u8],
                      scratch_half: &mut [u16], out: &mut [u8]) -> usize {
    let be = backend.imp();
    let n = vals.len();
    match dtype {
        StateDtype::F32 => {
            for (v, o) in vals.iter().zip(out.chunks_exact_mut(4)) {
                o.copy_from_slice(&v.to_le_bytes());
            }
            4 * n
        }
        StateDtype::Bf16 => {
            be.bf16_encode(vals, &mut scratch_half[..n]);
            for (h, o) in
                scratch_half[..n].iter().zip(out.chunks_exact_mut(2))
            {
                o.copy_from_slice(&h.to_le_bytes());
            }
            2 * n
        }
        StateDtype::Q8 => {
            let blocks = codec::q8_blocks(n);
            be.q8_encode(vals, &mut scratch_scales[..blocks],
                         &mut scratch_codes[..n]);
            for (s, o) in
                scratch_scales[..blocks].iter().zip(out.chunks_exact_mut(4))
            {
                o.copy_from_slice(&s.to_le_bytes());
            }
            out[4 * blocks..4 * blocks + n]
                .copy_from_slice(&scratch_codes[..n]);
            4 * blocks + n
        }
    }
}

/// Deserialize a wire message of `len` elements into `decode[..len]` —
/// bit-for-bit the values the direct path's `wire_roundtrip` produces
/// (little-endian byte transport is exact on every f32/u16 bit pattern).
pub fn decode_message(bytes: &[u8], len: usize, dtype: StateDtype,
                      backend: Backend, scratch_scales: &mut [f32],
                      scratch_codes: &mut [u8], scratch_half: &mut [u16],
                      decode: &mut [f32]) -> Result<()> {
    let be = backend.imp();
    let expect = super::wire_bytes_for(len, dtype);
    ensure!(bytes.len() == expect,
            "wire message of {} bytes for {len} {} elements (expected \
             {expect})",
            bytes.len(), dtype.name());
    match dtype {
        StateDtype::F32 => {
            for (b, d) in bytes.chunks_exact(4).zip(decode[..len].iter_mut())
            {
                *d = f32::from_le_bytes(b.try_into().unwrap());
            }
        }
        StateDtype::Bf16 => {
            for (b, h) in
                bytes.chunks_exact(2).zip(scratch_half[..len].iter_mut())
            {
                *h = u16::from_le_bytes(b.try_into().unwrap());
            }
            be.bf16_decode(&scratch_half[..len], &mut decode[..len]);
        }
        StateDtype::Q8 => {
            let blocks = codec::q8_blocks(len);
            for (b, s) in bytes[..4 * blocks]
                .chunks_exact(4)
                .zip(scratch_scales[..blocks].iter_mut())
            {
                *s = f32::from_le_bytes(b.try_into().unwrap());
            }
            scratch_codes[..len]
                .copy_from_slice(&bytes[4 * blocks..4 * blocks + len]);
            be.q8_decode(&scratch_scales[..blocks], &scratch_codes[..len],
                         &mut decode[..len]);
        }
    }
    Ok(())
}

/// Run one hop region through a transport in `chunk`-element tiles: per
/// tile, encode → send → recv → decode → accumulate/copy. Bitwise
/// identical to the direct `run_pair` at every dtype (the serialization
/// is exact), tiled on the same region-head-anchored grid.
#[allow(clippy::too_many_arguments)]
pub fn run_pair_via(phase: Phase, src: &[f32], dst: &mut [f32],
                    edge: (usize, usize), dtype: StateDtype, chunk: usize,
                    backend: Backend, scratch: &mut WireScratch,
                    transport: &dyn Transport) -> Result<()> {
    debug_assert_eq!(src.len(), dst.len());
    debug_assert_ne!(phase, Phase::Finalize, "finalize is always local");
    let be = backend.imp();
    let WireScratch { decode, scales, codes, half, wire_out, wire_in, .. } =
        scratch;
    let n = src.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let (s, d) = (&src[lo..hi], &mut dst[lo..hi]);
        let len = s.len();
        let msg = encode_message(s, dtype, backend, scales, codes, half,
                                 wire_out);
        transport.send(edge.0, edge.1, &wire_out[..msg])?;
        let got = transport.recv(edge.0, edge.1, wire_in)?;
        decode_message(&wire_in[..got], len, dtype, backend, scales, codes,
                       half, decode)?;
        match phase {
            Phase::Reduce => be.add_assign(d, &decode[..len]),
            Phase::Gather => d.copy_from_slice(&decode[..len]),
            Phase::Finalize => unreachable!(),
        }
        lo = hi;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::ring::{run_pair, wire_roundtrip};

    #[test]
    fn kind_parse_and_names_round_trip() {
        for k in TransportKind::ALL {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert!(TransportKind::parse("tcp").is_err());
        assert_eq!(TransportKind::ambient_from(None).unwrap(),
                   TransportKind::Direct);
        assert_eq!(TransportKind::ambient_from(Some("")).unwrap(),
                   TransportKind::Direct);
        assert_eq!(TransportKind::ambient_from(Some("inproc")).unwrap(),
                   TransportKind::Inproc);
        assert!(TransportKind::ambient_from(Some("bogus")).is_err());
    }

    #[test]
    fn inproc_edges_enforce_the_ring_and_rendezvous_discipline() {
        let t = InprocTransport::new(4, 64);
        assert_eq!(t.ranks(), 4);
        assert_eq!(t.max_message(), 64);
        assert_eq!(t.slab_bytes(), 4 * 64);
        // not a ring link
        assert!(t.send(0, 2, &[1]).is_err());
        assert!(t.send(0, 0, &[1]).is_err());
        assert!(t.send(5, 6, &[1]).is_err());
        // oversized message
        assert!(t.send(0, 1, &[0u8; 65]).is_err());
        // recv before send
        let mut out = [0u8; 64];
        assert!(t.recv(0, 1, &mut out).is_err());
        // happy path, including the wrap-around edge
        t.send(3, 0, &[7, 8, 9]).unwrap();
        // double-send on a full edge is an error, other edges unaffected
        assert!(t.send(3, 0, &[1]).is_err());
        t.send(0, 1, &[5]).unwrap();
        assert_eq!(t.recv(3, 0, &mut out).unwrap(), 3);
        assert_eq!(&out[..3], &[7, 8, 9]);
        assert_eq!(t.recv(0, 1, &mut out).unwrap(), 1);
        assert_eq!(out[0], 5);
        // drained edge: recv errors again
        assert!(t.recv(3, 0, &mut out).is_err());
        // too-small recv buffer
        t.send(1, 2, &[1, 2, 3, 4]).unwrap();
        assert!(t.recv(1, 2, &mut out[..2]).is_err());
    }

    /// The serialization contract: decode(serialize(encode(x))) equals
    /// the direct path's wire round-trip bit for bit, at every dtype ×
    /// backend, including negative zeros and denormals.
    #[test]
    fn message_codec_matches_wire_roundtrip_bitwise() {
        let mut rng = crate::rng::Rng::new(17);
        let mut vals: Vec<f32> =
            (0..200).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        vals[0] = -0.0;
        vals[1] = 1e-42; // denormal
        for backend in Backend::ALL {
            for dtype in StateDtype::ALL {
                let mut sc = WireScratch::new(256);
                wire_roundtrip(&vals, dtype, backend, &mut sc);
                let direct: Vec<f32> = sc.decode[..vals.len()].to_vec();
                let mut sc2 = WireScratch::new(256);
                let WireScratch {
                    decode, scales, codes, half, wire_out, ..
                } = &mut sc2;
                let msg = encode_message(&vals, dtype, backend, scales,
                                         codes, half, wire_out);
                assert_eq!(msg,
                           crate::comms::wire_bytes_for(vals.len(), dtype));
                let bytes: Vec<u8> = wire_out[..msg].to_vec();
                decode_message(&bytes, vals.len(), dtype, backend, scales,
                               codes, half, decode)
                    .unwrap();
                for (a, b) in direct.iter().zip(&decode[..vals.len()]) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{dtype:?} {}", backend.name());
                }
            }
        }
        // truncated / oversized messages are errors, not panics
        let mut sc = WireScratch::new(256);
        let WireScratch { decode, scales, codes, half, .. } = &mut sc;
        assert!(decode_message(&[0u8; 3], 1, StateDtype::F32,
                               Backend::Scalar, scales, codes, half, decode)
            .is_err());
        assert!(decode_message(&[0u8; 9], 1, StateDtype::Q8,
                               Backend::Scalar, scales, codes, half, decode)
            .is_err());
    }

    /// The transported hop equals the direct hop bitwise at every phase
    /// × dtype × chunk (the per-transport leg of the PR 8 gates).
    #[test]
    fn run_pair_via_matches_run_pair_bitwise() {
        let mut rng = crate::rng::Rng::new(23);
        let src: Vec<f32> =
            (0..333).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for dtype in StateDtype::ALL {
            for phase in [Phase::Reduce, Phase::Gather] {
                for chunk in [64usize, 256] {
                    let mut direct = vec![0.25f32; src.len()];
                    let mut sc = WireScratch::new(chunk);
                    run_pair(phase, &src, &mut direct, dtype, chunk,
                             Backend::Scalar, &mut sc);
                    let t = InprocTransport::new(2, message_cap(chunk));
                    let mut via = vec![0.25f32; src.len()];
                    let mut sc = WireScratch::new(chunk);
                    run_pair_via(phase, &src, &mut via, (0, 1), dtype,
                                 chunk, Backend::Scalar, &mut sc, &t)
                        .unwrap();
                    for (a, b) in direct.iter().zip(&via) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "{dtype:?} {phase:?} chunk {chunk}");
                    }
                }
            }
        }
    }
}
