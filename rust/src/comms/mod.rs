//! The communication subsystem: deterministic compressed ring collectives.
//!
//! The paper removes the optimizer-state Θ(model) memory term; in a
//! data-parallel run the *gradient exchange* is the other Θ(model)
//! per-step cost. This module replaces the ad-hoc serial path in
//! [`crate::collectives`] (kept as the reference oracle) with a real
//! subsystem:
//!
//! * **[`ring`]** — the chunked ring all-reduce schedule
//!   (reduce-scatter + all-gather, the classic 2(N−1)-step /
//!   2(N−1)/N-bytes plan) executed over persistent per-rank flat
//!   gradient buffers, optionally across `comm_threads` host threads.
//!   The reduction order is fixed by the schedule — chunk-ordered and
//!   thread-count-independent — so serial, 2-, and 4-thread exchanges
//!   are bitwise identical at every wire dtype, and the f32 path
//!   reproduces the pre-`comms` `collectives::allreduce_mean`
//!   trajectories bit for bit.
//! * **wire format** — payloads cross links as `comm_dtype ∈
//!   {f32, bf16, q8}` reusing the [`crate::optim::qstate`] codecs
//!   (q8: per-64-element-block f32 amax scales on the wire). Every
//!   hop's payload is wire-encoded, including forwarded partial sums,
//!   so a q8 exchange really moves ~3.7× fewer bytes than f32
//!   (`crate::memory::comm_wire_bytes` is the static mirror).
//! * **[`engine`]** — [`CommEngine`]: buffer lifecycle (zero per-step
//!   slot allocations in steady state), per-rank **error-feedback
//!   residuals** (MicroAdam-style: each rank sends
//!   `Q(grad + residual)` and carries `grad + residual − Q(…)` to the
//!   next step, so compressed runs converge), and the
//!   [`TimingModel`]-backed `comm_ms` estimate the trainer logs per
//!   step. Residuals are part of the `SM3CKPT2` checkpoint
//!   (`CommEngine::state`), so resume is bitwise.
//! * **[`bucket`]** — [`BucketPlan`]: the flat buffer cut into
//!   64-aligned buckets so the engine can pipeline staging of bucket
//!   `k+1` with bucket `k`'s in-flight hop steps (`comm_buckets` /
//!   `comm_overlap`), bitwise identical to the monolithic exchange.
//! * **[`transport`]** — the [`Transport`] hop-edge trait
//!   (`comm_transport`) that decouples payload movement from the
//!   executor: `direct` shared-memory, or `inproc` per-edge message
//!   channels carrying exact little-endian wire bytes.
//!
//! See DESIGN.md §12 for the schedule, the wire format, the residual
//! contract, and the full determinism argument, and §15 for the
//! bucketed overlap pipeline, the Transport contract, and the
//! calibrated timing model.

pub mod bucket;
pub mod engine;
pub mod ring;
pub mod transport;

pub use bucket::{BucketPlan, DEFAULT_COMM_BUCKETS};
pub use engine::{CommEngine, CommOpts, CommStats};
pub use transport::{InprocTransport, Transport, TransportKind};

use crate::optim::qstate::codec::Q8_BLOCK;
use crate::optim::StateDtype;

/// Default wire tile (`comm_chunk`): elements encoded/moved per task.
/// A multiple of the q8 block, so tile boundaries always fall on wire
/// block boundaries and the tiling is bitwise invisible.
pub const DEFAULT_COMM_CHUNK: usize = 16 * 1024;

/// Validate a `comm_chunk` value: positive multiple of [`Q8_BLOCK`]
/// (the q8 wire blocks must align with tile boundaries for the
/// chunking to stay bitwise invisible).
pub fn check_comm_chunk(chunk: usize) -> anyhow::Result<()> {
    anyhow::ensure!(chunk > 0 && chunk % Q8_BLOCK == 0,
                    "comm_chunk must be a positive multiple of {Q8_BLOCK} \
                     (the q8 wire block), got {chunk}");
    Ok(())
}

/// Interconnect timing model (TPU-v2 pod defaults) — the simulated cost
/// of the gradient exchange. Load-bearing since the `comms` subsystem:
/// [`CommEngine::allreduce_mean`] feeds its estimate into the trainer's
/// per-step `comm_ms` column. Since PR 8 the constants are no longer
/// hard-wired: [`TimingModel::from_measured`] refits them from the
/// telemetry `comm/hop_*` spans the engine records, and the added
/// staging term lets [`BucketPlan::modeled_seconds`] price the
/// overlapped pipeline.
#[derive(Debug, Clone)]
pub struct TimingModel {
    /// per-link bandwidth, bytes/s
    pub link_bandwidth: f64,
    /// per-hop latency, seconds
    pub hop_latency: f64,
    /// staging bandwidth (pack + error-feedback encode), bytes/s — the
    /// compute-side cost the overlapped pipeline hides behind hops
    pub stage_bandwidth: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        // TPU-v2 ICI: ~60 GB/s per link, ~1 µs hop latency; staging is
        // host-memory-bound, ~10 GB/s through pack + EF encode
        Self { link_bandwidth: 60e9, hop_latency: 1e-6, stage_bandwidth: 10e9 }
    }
}

/// Least-squares fit of `t = latency + bytes / bandwidth` over
/// `(bytes, seconds)` samples. Degenerate inputs (no samples, zero
/// byte variance, non-increasing trend) keep `default_bw` and fit only
/// the intercept, clamped non-negative.
fn fit_line(samples: &[(usize, f64)], default_bw: f64,
            default_lat: f64) -> (f64, f64) {
    if samples.is_empty() {
        return (default_bw, default_lat);
    }
    let n = samples.len() as f64;
    let mb = samples.iter().map(|&(b, _)| b as f64).sum::<f64>() / n;
    let mt = samples.iter().map(|&(_, t)| t).sum::<f64>() / n;
    let mut var_b = 0.0;
    let mut cov = 0.0;
    for &(b, t) in samples {
        let db = b as f64 - mb;
        var_b += db * db;
        cov += db * (t - mt);
    }
    let slope = if var_b > 0.0 { cov / var_b } else { 0.0 };
    if slope > 0.0 && slope.is_finite() {
        ((1.0 / slope), (mt - slope * mb).max(0.0))
    } else {
        (default_bw, (mt - mb / default_bw).max(0.0))
    }
}

impl TimingModel {
    /// Estimated wall time of a ring all-reduce of a `bytes`-sized wire
    /// buffer over `n` ranks: 2(n−1) steps, each moving `bytes/n` per
    /// link. `bytes` is the buffer size *in wire encoding*, so a q8
    /// exchange is proportionally cheaper than f32.
    pub fn allreduce_seconds(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64
            * (self.hop_latency + bytes as f64 / n as f64 / self.link_bandwidth)
    }

    /// Simulated wall time of one full exchange given its **total** wire
    /// bytes over both phases (`CommEngine::wire_bytes_per_exchange` /
    /// `memory::comm_wire_bytes`): the per-hop sweep is
    /// `total / 2(n−1)`, fed to [`TimingModel::allreduce_seconds`]. The
    /// one formula the trainer's `comm_ms` column and both benches use,
    /// so the CSVs cannot drift from the trainer.
    pub fn exchange_seconds(&self, total_wire_bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        self.allreduce_seconds(total_wire_bytes / (2 * (n - 1)), n)
    }

    /// Modeled staging time (pack + error-feedback encode) of `bytes`
    /// of host traffic — the term the overlapped pipeline hides behind
    /// in-flight hops.
    pub fn stage_seconds(&self, bytes: usize) -> f64 {
        bytes as f64 / self.stage_bandwidth
    }

    /// Calibrate a model from measured telemetry spans instead of the
    /// pod constants. `hops` are `(per_link_bytes, seconds)` samples of
    /// individual hop steps (the `comm/hop_reduce` / `comm/hop_gather`
    /// spans); `stages` are `(bytes, seconds)` samples of the staging
    /// phases (`comm/pack` + `comm/feedback`). The hop fit is least
    /// squares on `t = hop_latency + bytes / link_bandwidth`; with
    /// degenerate samples (a single step size gives zero byte variance)
    /// the default bandwidth is kept and only the latency intercept is
    /// fitted, so calibration degrades gracefully instead of producing
    /// a wild model. Staging fits the aggregate throughput
    /// `Σ bytes / Σ seconds`.
    pub fn from_measured(hops: &[(usize, f64)],
                         stages: &[(usize, f64)]) -> Self {
        let dflt = Self::default();
        let (link_bandwidth, hop_latency) =
            fit_line(hops, dflt.link_bandwidth, dflt.hop_latency);
        let (sb, ss) = stages.iter().fold((0.0f64, 0.0f64), |(b, s), &(bb, t)| {
            (b + bb as f64, s + t)
        });
        let stage_bandwidth = if sb > 0.0 && ss > 0.0 {
            sb / ss
        } else {
            dflt.stage_bandwidth
        };
        Self { link_bandwidth, hop_latency, stage_bandwidth }
    }
}

/// Exact wire bytes of one encoded region of `len` elements at `dtype`
/// (q8 counts its per-block scale fields; each wire message carries its
/// own block grid starting at the region head).
pub fn wire_bytes_for(len: usize, dtype: StateDtype) -> usize {
    dtype.bytes_for(len)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE 5 satellite: `allreduce_seconds` is load-bearing now — pin
    /// the n=1 short-circuit and the bytes/links arithmetic exactly.
    #[test]
    fn timing_n1_short_circuits_to_zero() {
        let t = TimingModel::default();
        assert_eq!(t.allreduce_seconds(1 << 30, 1), 0.0);
        assert_eq!(t.allreduce_seconds(0, 1), 0.0);
        // n = 0 must not underflow the step count
        assert_eq!(t.allreduce_seconds(1 << 20, 0), 0.0);
    }

    #[test]
    fn timing_bytes_links_arithmetic_is_exact() {
        // hand-checkable numbers: bw 100 B/s, latency 1 s, 400 B, 4 ranks:
        // 2(4-1) = 6 steps, each 1 s latency + (400/4)/100 = 1 s transfer
        let t = TimingModel { link_bandwidth: 100.0, hop_latency: 1.0,
                              ..TimingModel::default() };
        let s = t.allreduce_seconds(400, 4);
        assert!((s - 12.0).abs() < 1e-12, "{s}");
        // latency-free: pure bandwidth term 2(n-1)/n · bytes / bw
        let t = TimingModel { link_bandwidth: 50.0, hop_latency: 0.0,
                              ..TimingModel::default() };
        let s = t.allreduce_seconds(1000, 2);
        assert!((s - 2.0 * 500.0 / 50.0).abs() < 1e-12, "{s}");
        // exchange_seconds: total wire bytes of 2(n−1) hop sweeps
        // reduces to allreduce_seconds of one sweep
        let t = TimingModel { link_bandwidth: 100.0, hop_latency: 1.0,
                              ..TimingModel::default() };
        let total = 400 * 2 * 3; // sweep 400 B × 6 hops at n = 4
        assert!((t.exchange_seconds(total, 4)
                 - t.allreduce_seconds(400, 4)).abs() < 1e-12);
        assert_eq!(t.exchange_seconds(1 << 20, 1), 0.0);
    }

    #[test]
    fn timing_scales_with_ranks_and_bytes() {
        let t = TimingModel::default();
        let small = t.allreduce_seconds(1 << 20, 4);
        let big = t.allreduce_seconds(1 << 24, 4);
        assert!(big > small);
        // bandwidth-bound regime: time approaches 2·bytes/bw independent
        // of n for large n
        let t16 = t.allreduce_seconds(1 << 30, 16);
        let t64 = t.allreduce_seconds(1 << 30, 64);
        assert!((t16 / t64 - 1.0).abs() < 0.1, "{t16} vs {t64}");
    }

    /// `from_measured` recovers an exact synthetic (bandwidth, latency)
    /// pair from noiseless samples and degrades to the defaults when
    /// the samples cannot identify a slope.
    #[test]
    fn from_measured_fits_and_falls_back() {
        // t = 5 µs + bytes / 8 GB/s, three distinct sizes
        let (bw, lat) = (8e9f64, 5e-6f64);
        let hops: Vec<(usize, f64)> = [1usize << 16, 1 << 18, 1 << 20]
            .iter()
            .map(|&b| (b, lat + b as f64 / bw))
            .collect();
        let stages = [(1usize << 20, 1e-4), (1 << 21, 2e-4)];
        let t = TimingModel::from_measured(&hops, &stages);
        assert!((t.link_bandwidth / bw - 1.0).abs() < 1e-9, "{}", t.link_bandwidth);
        assert!((t.hop_latency / lat - 1.0).abs() < 1e-9, "{}", t.hop_latency);
        // stage fit: (2^20 + 2^21) bytes over 3e-4 s
        let want = (3.0 * (1 << 20) as f64) / 3e-4;
        assert!((t.stage_bandwidth / want - 1.0).abs() < 1e-9);

        // zero byte variance (every hop the same size): keep default
        // bandwidth, fit the intercept only, clamped non-negative
        let d = TimingModel::default();
        let t = TimingModel::from_measured(&[(1 << 20, 1e-3); 4], &[]);
        assert_eq!(t.link_bandwidth, d.link_bandwidth);
        let want = (1e-3 - (1 << 20) as f64 / d.link_bandwidth).max(0.0);
        assert!((t.hop_latency - want).abs() < 1e-12);
        assert_eq!(t.stage_bandwidth, d.stage_bandwidth);

        // no samples at all: the defaults verbatim
        let t = TimingModel::from_measured(&[], &[]);
        assert_eq!(t.link_bandwidth, d.link_bandwidth);
        assert_eq!(t.hop_latency, d.hop_latency);
        assert_eq!(t.stage_bandwidth, d.stage_bandwidth);

        // decreasing time with size (noise-dominated): fall back, never
        // a negative bandwidth or latency
        let t = TimingModel::from_measured(&[(1 << 10, 2e-3), (1 << 20, 1e-3)],
                                           &[(0, 0.0)]);
        assert_eq!(t.link_bandwidth, d.link_bandwidth);
        assert!(t.hop_latency >= 0.0);
        assert_eq!(t.stage_bandwidth, d.stage_bandwidth);
    }

    #[test]
    fn stage_seconds_is_bytes_over_bandwidth() {
        let t = TimingModel { stage_bandwidth: 100.0,
                              ..TimingModel::default() };
        assert_eq!(t.stage_seconds(0), 0.0);
        assert!((t.stage_seconds(250) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn comm_chunk_validation() {
        assert!(check_comm_chunk(DEFAULT_COMM_CHUNK).is_ok());
        assert!(check_comm_chunk(64).is_ok());
        assert!(check_comm_chunk(0).is_err());
        assert!(check_comm_chunk(100).is_err());
    }

    #[test]
    fn wire_bytes_per_dtype() {
        assert_eq!(wire_bytes_for(64, StateDtype::F32), 256);
        assert_eq!(wire_bytes_for(64, StateDtype::Bf16), 128);
        // one scale field + 64 codes
        assert_eq!(wire_bytes_for(64, StateDtype::Q8), 4 + 64);
        // partial trailing block still carries a full scale field
        assert_eq!(wire_bytes_for(65, StateDtype::Q8), 8 + 65);
    }
}
