//! The communication subsystem: deterministic compressed ring collectives.
//!
//! The paper removes the optimizer-state Θ(model) memory term; in a
//! data-parallel run the *gradient exchange* is the other Θ(model)
//! per-step cost. This module replaces the ad-hoc serial path in
//! [`crate::collectives`] (kept as the reference oracle) with a real
//! subsystem:
//!
//! * **[`ring`]** — the chunked ring all-reduce schedule
//!   (reduce-scatter + all-gather, the classic 2(N−1)-step /
//!   2(N−1)/N-bytes plan) executed over persistent per-rank flat
//!   gradient buffers, optionally across `comm_threads` host threads.
//!   The reduction order is fixed by the schedule — chunk-ordered and
//!   thread-count-independent — so serial, 2-, and 4-thread exchanges
//!   are bitwise identical at every wire dtype, and the f32 path
//!   reproduces the pre-`comms` `collectives::allreduce_mean`
//!   trajectories bit for bit.
//! * **wire format** — payloads cross links as `comm_dtype ∈
//!   {f32, bf16, q8}` reusing the [`crate::optim::qstate`] codecs
//!   (q8: per-64-element-block f32 amax scales on the wire). Every
//!   hop's payload is wire-encoded, including forwarded partial sums,
//!   so a q8 exchange really moves ~3.7× fewer bytes than f32
//!   (`crate::memory::comm_wire_bytes` is the static mirror).
//! * **[`engine`]** — [`CommEngine`]: buffer lifecycle (zero per-step
//!   slot allocations in steady state), per-rank **error-feedback
//!   residuals** (MicroAdam-style: each rank sends
//!   `Q(grad + residual)` and carries `grad + residual − Q(…)` to the
//!   next step, so compressed runs converge), and the
//!   [`TimingModel`]-backed `comm_ms` estimate the trainer logs per
//!   step. Residuals are part of the `SM3CKPT2` checkpoint
//!   (`CommEngine::state`), so resume is bitwise.
//!
//! See DESIGN.md §12 for the schedule, the wire format, the residual
//! contract, and the full determinism argument.

pub mod engine;
pub mod ring;

pub use engine::{CommEngine, CommStats};

use crate::optim::qstate::codec::Q8_BLOCK;
use crate::optim::StateDtype;

/// Default wire tile (`comm_chunk`): elements encoded/moved per task.
/// A multiple of the q8 block, so tile boundaries always fall on wire
/// block boundaries and the tiling is bitwise invisible.
pub const DEFAULT_COMM_CHUNK: usize = 16 * 1024;

/// Validate a `comm_chunk` value: positive multiple of [`Q8_BLOCK`]
/// (the q8 wire blocks must align with tile boundaries for the
/// chunking to stay bitwise invisible).
pub fn check_comm_chunk(chunk: usize) -> anyhow::Result<()> {
    anyhow::ensure!(chunk > 0 && chunk % Q8_BLOCK == 0,
                    "comm_chunk must be a positive multiple of {Q8_BLOCK} \
                     (the q8 wire block), got {chunk}");
    Ok(())
}

/// Interconnect timing model (TPU-v2 pod defaults) — the simulated cost
/// of the gradient exchange. Load-bearing since the `comms` subsystem:
/// [`CommEngine::allreduce_mean`] feeds its estimate into the trainer's
/// per-step `comm_ms` column.
#[derive(Debug, Clone)]
pub struct TimingModel {
    /// per-link bandwidth, bytes/s
    pub link_bandwidth: f64,
    /// per-hop latency, seconds
    pub hop_latency: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        // TPU-v2 ICI: ~60 GB/s per link, ~1 µs hop latency
        Self { link_bandwidth: 60e9, hop_latency: 1e-6 }
    }
}

impl TimingModel {
    /// Estimated wall time of a ring all-reduce of a `bytes`-sized wire
    /// buffer over `n` ranks: 2(n−1) steps, each moving `bytes/n` per
    /// link. `bytes` is the buffer size *in wire encoding*, so a q8
    /// exchange is proportionally cheaper than f32.
    pub fn allreduce_seconds(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64
            * (self.hop_latency + bytes as f64 / n as f64 / self.link_bandwidth)
    }

    /// Simulated wall time of one full exchange given its **total** wire
    /// bytes over both phases (`CommEngine::wire_bytes_per_exchange` /
    /// `memory::comm_wire_bytes`): the per-hop sweep is
    /// `total / 2(n−1)`, fed to [`TimingModel::allreduce_seconds`]. The
    /// one formula the trainer's `comm_ms` column and both benches use,
    /// so the CSVs cannot drift from the trainer.
    pub fn exchange_seconds(&self, total_wire_bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        self.allreduce_seconds(total_wire_bytes / (2 * (n - 1)), n)
    }
}

/// Exact wire bytes of one encoded region of `len` elements at `dtype`
/// (q8 counts its per-block scale fields; each wire message carries its
/// own block grid starting at the region head).
pub fn wire_bytes_for(len: usize, dtype: StateDtype) -> usize {
    dtype.bytes_for(len)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE 5 satellite: `allreduce_seconds` is load-bearing now — pin
    /// the n=1 short-circuit and the bytes/links arithmetic exactly.
    #[test]
    fn timing_n1_short_circuits_to_zero() {
        let t = TimingModel::default();
        assert_eq!(t.allreduce_seconds(1 << 30, 1), 0.0);
        assert_eq!(t.allreduce_seconds(0, 1), 0.0);
        // n = 0 must not underflow the step count
        assert_eq!(t.allreduce_seconds(1 << 20, 0), 0.0);
    }

    #[test]
    fn timing_bytes_links_arithmetic_is_exact() {
        // hand-checkable numbers: bw 100 B/s, latency 1 s, 400 B, 4 ranks:
        // 2(4-1) = 6 steps, each 1 s latency + (400/4)/100 = 1 s transfer
        let t = TimingModel { link_bandwidth: 100.0, hop_latency: 1.0 };
        let s = t.allreduce_seconds(400, 4);
        assert!((s - 12.0).abs() < 1e-12, "{s}");
        // latency-free: pure bandwidth term 2(n-1)/n · bytes / bw
        let t = TimingModel { link_bandwidth: 50.0, hop_latency: 0.0 };
        let s = t.allreduce_seconds(1000, 2);
        assert!((s - 2.0 * 500.0 / 50.0).abs() < 1e-12, "{s}");
        // exchange_seconds: total wire bytes of 2(n−1) hop sweeps
        // reduces to allreduce_seconds of one sweep
        let t = TimingModel { link_bandwidth: 100.0, hop_latency: 1.0 };
        let total = 400 * 2 * 3; // sweep 400 B × 6 hops at n = 4
        assert!((t.exchange_seconds(total, 4)
                 - t.allreduce_seconds(400, 4)).abs() < 1e-12);
        assert_eq!(t.exchange_seconds(1 << 20, 1), 0.0);
    }

    #[test]
    fn timing_scales_with_ranks_and_bytes() {
        let t = TimingModel::default();
        let small = t.allreduce_seconds(1 << 20, 4);
        let big = t.allreduce_seconds(1 << 24, 4);
        assert!(big > small);
        // bandwidth-bound regime: time approaches 2·bytes/bw independent
        // of n for large n
        let t16 = t.allreduce_seconds(1 << 30, 16);
        let t64 = t.allreduce_seconds(1 << 30, 64);
        assert!((t16 / t64 - 1.0).abs() < 0.1, "{t16} vs {t64}");
    }

    #[test]
    fn comm_chunk_validation() {
        assert!(check_comm_chunk(DEFAULT_COMM_CHUNK).is_ok());
        assert!(check_comm_chunk(64).is_ok());
        assert!(check_comm_chunk(0).is_err());
        assert!(check_comm_chunk(100).is_err());
    }

    #[test]
    fn wire_bytes_per_dtype() {
        assert_eq!(wire_bytes_for(64, StateDtype::F32), 256);
        assert_eq!(wire_bytes_for(64, StateDtype::Bf16), 128);
        // one scale field + 64 codes
        assert_eq!(wire_bytes_for(64, StateDtype::Q8), 4 + 64);
        // partial trailing block still carries a full scale field
        assert_eq!(wire_bytes_for(65, StateDtype::Q8), 8 + 65);
    }
}
