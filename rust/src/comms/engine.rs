//! [`CommEngine`] — buffer lifecycle, error feedback, and the exchange
//! entry point the trainer drives.
//!
//! One engine is built per training run from the model's parameter
//! inventory. It owns, per rank: a persistent flat f32 gradient buffer
//! (leaves packed contiguously in spec order) and — for compressed wire
//! dtypes — a persistent flat **error-feedback residual**. Every
//! exchange runs, per 64-aligned bucket of the flat buffer
//! ([`BucketPlan`]; one bucket by default):
//!
//! 1. **pack** — each rank's leaf tensors are copied into the bucket's
//!    range of its flat buffer (no allocation; the buffers are sized at
//!    construction).
//! 2. **error feedback** (compressed dtypes only) — per rank,
//!    `u = grad + residual` is wire round-tripped to `v = Q(u)`; the
//!    buffer continues with `v` and the residual becomes `u − v`
//!    exactly (f32 subtraction). What one step's quantizer drops, the
//!    next step's send re-injects — the MicroAdam-style error-feedback
//!    contract that keeps compressed training convergent. The q8 block
//!    grid here is the global 64-aligned grid of the flat buffer, so
//!    the tiling (`comm_chunk`), the thread count, and the bucket
//!    bounds never shift a block boundary.
//! 3. **ring exchange** — the bucket's slice of the precomputed
//!    [`ring::Schedule`], serial or across `comm_threads` workers,
//!    through the configured [`Transport`] (bitwise identical every
//!    way).
//! 4. **unpack** — after all buckets drain, each rank's buffer is
//!    written back to its leaf tensors times `1/ranks` (the
//!    data-parallel mean), exactly the historical
//!    `collectives::allreduce_mean` arithmetic.
//!
//! With `comm_overlap` (and ≥ 2 ranks) steps 1–2 for bucket `k+1` run
//! on the calling thread **while** bucket `k`'s hop steps are in flight
//! on a persistent hop-worker thread — the double-buffered pipeline
//! (two persistent wire-scratch slabs: the caller's stager and the
//! worker's hop codec). The bucket bounds make the concurrent ranges
//! provably disjoint (see [`super::bucket`]), so the overlapped
//! exchange is *bitwise identical* to the serial one, and the steady
//! state still allocates nothing on the calling thread (the handshake
//! is a mutex/condvar pair, both allocation-free).
//!
//! At `comm_dtype = f32` step 2 is skipped entirely and the wire is
//! the identity, so the whole path reproduces pre-`comms` trajectories
//! bit for bit. Residuals are exposed through [`CommEngine::state`] /
//! [`CommEngine::load_state`] and ride the `SM3CKPT2` checkpoint as
//! f32-tagged tensors (they must stay exact for resume to be bitwise).

use super::bucket::{BucketPlan, DEFAULT_COMM_BUCKETS};
use super::ring::{self, Phase, RankBufs, WireScratch};
use super::transport::{self, InprocTransport, Transport, TransportKind};
use super::{check_comm_chunk, TimingModel, DEFAULT_COMM_CHUNK};
use crate::optim::{Backend, ParamSpec, StateDtype};
use crate::pool::{Pool, PoolBuf, Tag};
use crate::telemetry::{self, trace_event, Counter, Gauge, Probe};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// What one exchange cost: exact wire bytes moved and the simulated pod
/// interconnect time from the engine's [`TimingModel`].
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    /// bytes that crossed links (wire-encoded payloads, both phases)
    pub wire_bytes: usize,
    /// simulated hop-only exchange wall time (0.0 for a single rank) —
    /// the historical PR 5 figure, kept for trend comparability
    pub sim_seconds: f64,
    /// simulated wall time of the full staged pipeline
    /// ([`BucketPlan::modeled_seconds`] as configured): staging + hops,
    /// with staging hidden behind in-flight hops when `comm_overlap`
    /// is on. This is what `StepRecord::comm_ms` reports.
    pub sim_overlap_seconds: f64,
}

/// Exchange-path knobs of a [`CommEngine`], mirroring the
/// `comm_*` config keys. `Default` is the PR 5 behaviour: f32 wire,
/// one bucket, no overlap, serial, ambient transport
/// (`SM3_COMM_TRANSPORT`, direct unless overridden).
#[derive(Debug, Clone, Copy)]
pub struct CommOpts {
    /// wire dtype (`comm_dtype`)
    pub dtype: StateDtype,
    /// tile size in elements (`comm_chunk`)
    pub chunk: usize,
    /// worker threads for the non-overlapped hop sweep and error
    /// feedback (`comm_threads`); the overlapped pipeline runs hops on
    /// its dedicated worker regardless
    pub threads: usize,
    /// 64-aligned flat buckets the exchange pipelines over
    /// (`comm_buckets`)
    pub buckets: usize,
    /// stage bucket `k+1` while bucket `k`'s hops are in flight
    /// (`comm_overlap`)
    pub overlap: bool,
    /// hop-edge payload path (`comm_transport`)
    pub transport: TransportKind,
}

impl Default for CommOpts {
    fn default() -> Self {
        Self {
            dtype: StateDtype::F32,
            chunk: DEFAULT_COMM_CHUNK,
            threads: 1,
            buckets: DEFAULT_COMM_BUCKETS,
            overlap: false,
            transport: TransportKind::default(),
        }
    }
}

/// Command slot of the hop-worker handshake. One in-flight bucket at a
/// time: the caller flips `Idle → Run`, the worker flips
/// `Run → Done`, the caller's wait flips `Done → Idle`.
enum HopCmd {
    Idle,
    Run { bucket: usize, backend: Backend, tele: bool },
    Done(Option<String>),
    Exit,
}

/// State shared with the persistent hop worker. The mutex/condvar pair
/// is the whole protocol (both allocation-free in steady state); hop
/// nanoseconds accumulate in atomics and are folded into the telemetry
/// probes by the owning thread after the pipeline drains.
struct HopShared {
    cmd: Mutex<HopCmd>,
    cv: Condvar,
    /// per-phase hop time: [reduce, finalize-encode, gather]
    hop_ns: [AtomicU64; 3],
}

struct HopWorker {
    shared: Arc<HopShared>,
    handle: std::thread::JoinHandle<()>,
}

/// The communication engine: persistent buffers + residuals + plan.
pub struct CommEngine {
    /// per-leaf flat lengths, in pack order
    lens: Vec<usize>,
    /// total flat elements per rank
    total: usize,
    ranks: usize,
    dtype: StateDtype,
    chunk: usize,
    threads: usize,
    overlap: bool,
    transport_kind: TransportKind,
    /// kernel backend for the wire codec, reduce, and unpack lanes
    /// (bitwise identical across backends — DESIGN.md §13); pack stays a
    /// plain memcpy in every backend
    backend: Backend,
    /// per-rank flat gradient staging buffers (empty when ranks == 1);
    /// leased from the pool under `Tag::CommFlat` when one is given
    bufs: Vec<PoolBuf<f32>>,
    /// per-rank error-feedback residuals (empty at f32 or ranks == 1);
    /// `Tag::CommResidual` leases when pooled
    residual: Vec<PoolBuf<f32>>,
    /// per-thread wire scratch (the caller-side persistent slab(s))
    scratch: Vec<WireScratch>,
    /// the bucketed schedule (one bucket ⇒ the PR 5 monolith)
    plan: Arc<BucketPlan>,
    /// hop-edge payload path (None ⇒ direct shared-memory)
    channel: Option<Arc<InprocTransport>>,
    /// raw rank-buffer pointers shared with the hop worker
    shared_bufs: Option<Arc<RankBufs>>,
    worker: Option<HopWorker>,
    timing: TimingModel,
}

impl CommEngine {
    /// Build an engine for `ranks` data-parallel workers exchanging
    /// gradients over the given parameter inventory with default
    /// bucketing/overlap/transport (the PR 5 constructor, kept
    /// source-compatible).
    pub fn new(specs: &[ParamSpec], ranks: usize, dtype: StateDtype,
               chunk: usize, threads: usize) -> Result<Self> {
        Self::with_opts(specs, ranks,
                        CommOpts { dtype, chunk, threads,
                                   ..CommOpts::default() })
    }

    /// Build an engine with the full option set.
    pub fn with_opts(specs: &[ParamSpec], ranks: usize, opts: CommOpts)
                     -> Result<Self> {
        let lens: Vec<usize> = specs.iter().map(ParamSpec::numel).collect();
        Self::with_lens_opts(lens, ranks, opts)
    }

    /// Build an engine whose staging buffers, residuals, wire scratch,
    /// and transport slabs are all leased from `pool` (tags
    /// `CommFlat`/`CommResidual`/`CommWire`/`TransportSlot`). Bitwise
    /// identical to [`CommEngine::with_opts`] — the pool only changes
    /// where the bytes live.
    pub fn with_opts_in(specs: &[ParamSpec], ranks: usize, opts: CommOpts,
                        pool: &Pool) -> Result<Self> {
        let lens: Vec<usize> = specs.iter().map(ParamSpec::numel).collect();
        Self::build(lens, ranks, opts, Some(pool))
    }

    /// Core constructor over raw per-leaf flat lengths (PR 5 knobs).
    pub fn with_lens(lens: Vec<usize>, ranks: usize, dtype: StateDtype,
                     chunk: usize, threads: usize) -> Result<Self> {
        Self::with_lens_opts(lens, ranks,
                             CommOpts { dtype, chunk, threads,
                                        ..CommOpts::default() })
    }

    /// Core constructor over raw per-leaf flat lengths and full options.
    pub fn with_lens_opts(lens: Vec<usize>, ranks: usize, opts: CommOpts)
                          -> Result<Self> {
        Self::build(lens, ranks, opts, None)
    }

    fn build(lens: Vec<usize>, ranks: usize, opts: CommOpts,
             pool: Option<&Pool>) -> Result<Self> {
        ensure!(ranks >= 1, "comm engine needs at least one rank");
        ensure!(opts.threads >= 1, "comm_threads must be >= 1 (1 = serial)");
        check_comm_chunk(opts.chunk)?;
        let total: usize = lens.iter().sum();
        let plan =
            Arc::new(BucketPlan::build(&lens, ranks, opts.dtype,
                                       opts.buckets)?);
        let flat = |tag: Tag| match pool {
            Some(p) => p.take_f32(tag, total),
            None => PoolBuf::from_vec(tag, vec![0.0f32; total]),
        };
        let (bufs, residual, scratch) = if ranks > 1 {
            (
                (0..ranks).map(|_| flat(Tag::CommFlat)).collect(),
                if opts.dtype != StateDtype::F32 {
                    (0..ranks).map(|_| flat(Tag::CommResidual)).collect()
                } else {
                    Vec::new()
                },
                (0..opts.threads)
                    .map(|_| match pool {
                        Some(p) => WireScratch::new_in(p, opts.chunk),
                        None => WireScratch::new(opts.chunk),
                    })
                    .collect::<Vec<_>>(),
            )
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        let channel = if ranks > 1 && opts.transport == TransportKind::Inproc
        {
            let cap = transport::message_cap(opts.chunk);
            Some(Arc::new(match pool {
                Some(p) => InprocTransport::new_in(p, ranks, cap),
                None => InprocTransport::new(ranks, cap),
            }))
        } else {
            None
        };
        let mut eng = Self {
            lens,
            total,
            ranks,
            dtype: opts.dtype,
            chunk: opts.chunk,
            threads: opts.threads,
            overlap: opts.overlap,
            transport_kind: opts.transport,
            backend: Backend::default(),
            bufs,
            residual,
            scratch,
            plan,
            channel,
            shared_bufs: None,
            worker: None,
            timing: TimingModel::default(),
        };
        if opts.overlap && ranks > 1 {
            eng.start_worker(pool.cloned())?;
        }
        Ok(eng)
    }

    /// Spawn the persistent hop worker and publish the (stable) rank
    /// buffer pointers it drives. Called once, at construction. The
    /// worker's own wire slab leases from `pool` when one is given.
    fn start_worker(&mut self, pool: Option<Pool>) -> Result<()> {
        let shared = Arc::new(HopShared {
            cmd: Mutex::new(HopCmd::Idle),
            cv: Condvar::new(),
            hop_ns: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
        });
        // Vec data pointers are stable under moves of the owning struct,
        // so capturing them here is safe for the engine's lifetime; Drop
        // joins the worker before the buffers are freed.
        let bufs = Arc::new(RankBufs::new(&mut self.bufs));
        let (plan, dtype, chunk) =
            (Arc::clone(&self.plan), self.dtype, self.chunk);
        let channel = self.channel.clone();
        let (ws, wb) = (Arc::clone(&shared), Arc::clone(&bufs));
        let handle = std::thread::Builder::new()
            .name("sm3-comm-hop".into())
            .spawn(move || {
                hop_worker_loop(ws, wb, plan, channel, dtype, chunk, pool)
            })
            .map_err(|e| anyhow::anyhow!("spawn comm hop worker: {e}"))?;
        self.shared_bufs = Some(bufs);
        self.worker = Some(HopWorker { shared, handle });
        Ok(())
    }

    /// Override the interconnect model (defaults to the TPU-v2 pod;
    /// the trainer refits it from measured hop spans via
    /// [`TimingModel::from_measured`] when telemetry is on).
    pub fn set_timing(&mut self, timing: TimingModel) {
        self.timing = timing;
    }

    /// The interconnect model currently in force (defaults or the
    /// trainer's measured refit) — the health watchdogs' expected-hop
    /// baseline.
    pub fn timing(&self) -> &TimingModel {
        &self.timing
    }

    /// Route the wire codec, reduce, and unpack lanes through `backend`
    /// (config `kernel_backend`; bitwise identical across backends).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
    }

    /// Configured rank count.
    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Wire dtype of every link payload.
    pub fn dtype(&self) -> StateDtype {
        self.dtype
    }

    /// Configured bucket count (1 = the monolithic exchange).
    pub fn buckets(&self) -> usize {
        self.plan.buckets()
    }

    /// Whether the overlapped pipeline is active (requires ≥ 2 ranks).
    pub fn overlap_enabled(&self) -> bool {
        self.worker.is_some()
    }

    /// Configured hop-edge transport.
    pub fn transport_kind(&self) -> TransportKind {
        self.transport_kind
    }

    /// The bucketed exchange plan (bench/tooling: feed
    /// [`BucketPlan::modeled_seconds`] with a calibrated model).
    pub fn plan(&self) -> &BucketPlan {
        &self.plan
    }

    /// Exact bytes crossing links in one full exchange (0 for one rank).
    /// `crate::memory::comm_wire_bytes` is the static mirror.
    pub fn wire_bytes_per_exchange(&self) -> usize {
        self.plan.total_wire_bytes
    }

    /// Persistent per-run comm buffer bytes: staging + residuals
    /// (excludes the Θ(comm_chunk) scratch — see
    /// [`CommEngine::scratch_bytes`]).
    /// `crate::memory::comm_buffer_bytes` is the static mirror.
    pub fn buffer_bytes(&self) -> usize {
        (self.bufs.len() + self.residual.len()) * self.total * 4
    }

    /// Persistent Θ(comm_chunk) scratch bytes: per-thread wire slabs,
    /// the hop worker's slab when overlapped, and the in-process
    /// transport's per-edge message slabs.
    /// `crate::memory::comm_scratch_bytes` is the static mirror.
    pub fn scratch_bytes(&self) -> usize {
        let per = self.scratch.first().map_or(0, WireScratch::bytes);
        self.scratch.len() * per
            + if self.worker.is_some() { per } else { 0 }
            + self.channel.as_ref().map_or(0, |t| t.slab_bytes())
    }

    /// Error-feedback residual scalars carried across steps.
    pub fn residual_floats(&self) -> usize {
        self.residual.len() * self.total
    }

    /// The full staged-pipeline model at the engine's current timing —
    /// what one exchange costs as configured, with staging hidden
    /// behind in-flight hops when overlapped (0.0 for a single rank).
    /// The trainer re-reads this after refitting the timing from
    /// measured spans so `StepRecord::comm_ms` tracks the calibrated
    /// model.
    pub fn modeled_overlap_seconds(&self) -> f64 {
        if self.ranks <= 1 {
            return 0.0;
        }
        self.plan
            .modeled_seconds(&self.timing, self.ranks, self.worker.is_some())
    }

    /// All-reduce every rank's gradient leaves to their data-parallel
    /// mean, in place, through the compressed ring. Validates the rank
    /// and leaf geometry and the bucket tiling (mismatches are errors,
    /// not panics — the trainer propagates them like every other step
    /// failure).
    pub fn allreduce_mean(&mut self, ranks: &mut [Vec<Tensor>])
                          -> Result<CommStats> {
        ensure!(ranks.len() == self.ranks,
                "comm engine built for {} ranks, got {}",
                self.ranks, ranks.len());
        for (r, leaves) in ranks.iter().enumerate() {
            ensure!(leaves.len() == self.lens.len(),
                    "rank {r}: {} gradient leaves, engine expects {}",
                    leaves.len(), self.lens.len());
            for (i, t) in leaves.iter().enumerate() {
                ensure!(t.len() == self.lens[i],
                        "rank {r} leaf {i}: {} elements, engine expects {}",
                        t.len(), self.lens[i]);
            }
        }
        // the bucket bounds must still tile the flat buffer exactly —
        // a violated plan is an error naming the bucket, never a panic
        self.plan.check(self.total)?;
        if self.ranks == 1 {
            return Ok(CommStats::default());
        }
        let tele = telemetry::enabled();
        if self.worker.is_some() {
            self.exchange_overlapped(ranks, tele)?;
        } else {
            self.exchange_bucketed(ranks, tele)?;
        }
        let unpack_span = telemetry::span(Probe::CommUnpack);
        self.unpack(ranks);
        drop(unpack_span);
        if tele {
            telemetry::count(Counter::CommWireBytes,
                             self.plan.total_wire_bytes as u64);
            telemetry::count(Counter::CommExchanges, 1);
            // live memory gauges; the static accountant
            // (memory::comm_buffer_bytes) must agree — cross-checked in
            // the tests below
            telemetry::gauge(Gauge::CommBufferBytes,
                             self.buffer_bytes() as u64);
            telemetry::gauge(Gauge::CommResidualBytes,
                             (self.residual_floats() * 4) as u64);
        }
        Ok(CommStats {
            wire_bytes: self.plan.total_wire_bytes,
            sim_seconds: self
                .timing
                .exchange_seconds(self.plan.total_wire_bytes, self.ranks),
            sim_overlap_seconds: self.modeled_overlap_seconds(),
        })
    }

    /// The non-overlapped path: stage everything, then sweep each
    /// bucket's steps serially or across `comm_threads` workers. With
    /// one bucket this is exactly the PR 5 exchange.
    fn exchange_bucketed(&mut self, ranks: &mut [Vec<Tensor>], tele: bool)
                         -> Result<()> {
        let pack_span = telemetry::span(Probe::CommPack);
        self.pack(ranks, tele);
        drop(pack_span);
        if self.dtype != StateDtype::F32 {
            let fb_span = telemetry::span(Probe::CommFeedback);
            self.apply_error_feedback();
            drop(fb_span);
        }
        for k in 0..self.plan.buckets() {
            if tele {
                telemetry::gauge(Gauge::CommInflightBuckets, 1);
            }
            for si in 0..self.plan.steps[k].len() {
                // split-borrow the plan away from the buffers
                let (phase, regions) = {
                    let (p, r) = &self.plan.steps[k][si];
                    (*p, r)
                };
                // hop timing on the calling thread: one span per bucket
                // step, classified by phase. These measured latencies
                // are the calibration source for TimingModel
                // (TimingModel::from_measured; bench_collectives reports
                // measured-vs-modeled).
                let _hop = telemetry::span(match phase {
                    Phase::Reduce => Probe::CommHopReduce,
                    Phase::Finalize => Probe::CommHopEncode,
                    Phase::Gather => Probe::CommHopGather,
                });
                let via =
                    self.channel.as_deref().map(|t| t as &dyn Transport);
                if self.threads <= 1 {
                    ring::run_step_serial(&mut self.bufs, phase, regions,
                                          self.dtype, self.chunk,
                                          self.backend,
                                          &mut self.scratch[0], via)?;
                } else {
                    ring::run_step_threaded(&mut self.bufs, phase, regions,
                                            self.dtype, self.chunk,
                                            self.backend, self.threads,
                                            &mut self.scratch, via)?;
                }
            }
        }
        Ok(())
    }

    /// The overlapped pipeline: stage bucket 0, then keep exactly one
    /// bucket's hops in flight on the worker while the calling thread
    /// stages the next one. Bitwise identical to
    /// [`CommEngine::exchange_bucketed`] — the concurrent flat ranges
    /// are disjoint by the bucket-bound argument (`super::bucket`).
    fn exchange_overlapped(&mut self, ranks: &mut [Vec<Tensor>], tele: bool)
                           -> Result<()> {
        let nb = self.plan.buckets();
        self.stage_bucket(ranks, 0);
        for k in 0..nb {
            if tele {
                // hop lane holds bucket k; the stager holds k+1 if any
                telemetry::gauge(Gauge::CommInflightBuckets,
                                 if k + 1 < nb { 2 } else { 1 });
            }
            self.submit_bucket(k, tele);
            if k + 1 < nb {
                self.stage_bucket(ranks, k + 1);
            }
            self.wait_bucket()?;
        }
        if tele {
            // fold the worker's hop time into the per-phase probes (one
            // record per phase per exchange), worker-order-independent
            let w = self.worker.as_ref().expect("overlap worker");
            for (slot, probe) in [(0, Probe::CommHopReduce),
                                  (1, Probe::CommHopEncode),
                                  (2, Probe::CommHopGather)]
            {
                let ns = w.shared.hop_ns[slot].swap(0, Ordering::Relaxed);
                if ns > 0 {
                    telemetry::record_ns(probe, ns);
                }
            }
        }
        Ok(())
    }

    /// Pack + error-feedback one bucket's flat range on the calling
    /// thread. Writes go through the shared raw pointers (the same
    /// provenance the hop worker uses), touching only
    /// `[bounds[k], bounds[k+1])` — disjoint from any in-flight hops.
    fn stage_bucket(&mut self, ranks: &[Vec<Tensor>], k: usize) {
        let (lo, hi) = self.plan.stage_range(k);
        let shared = self.shared_bufs.as_ref().expect("overlap bufs");
        let tele = telemetry::enabled();
        {
            let _s = telemetry::span(Probe::CommPack);
            for (r, leaves) in ranks.iter().enumerate() {
                // SAFETY: the staged range is disjoint from every range
                // the hop worker currently reads or writes (bucket-bound
                // argument, super::bucket), and `r` is in range by the
                // geometry checks in allreduce_mean.
                let buf = unsafe { shared.range_mut(r, lo, hi) };
                let mut off = 0usize;
                for t in leaves {
                    let n = t.len();
                    let (a, b) = (off.max(lo), (off + n).min(hi));
                    if b > a {
                        buf[a - lo..b - lo]
                            .copy_from_slice(&t.data()[a - off..b - off]);
                    }
                    off += n;
                    if off >= hi {
                        break;
                    }
                }
                if tele {
                    scan_pack_nonfinite(r, buf);
                }
            }
        }
        if self.dtype != StateDtype::F32 {
            let _s = telemetry::span(Probe::CommFeedback);
            let (dtype, chunk, backend) =
                (self.dtype, self.chunk, self.backend);
            let sc = &mut self.scratch[0];
            for (r, res) in self.residual.iter_mut().enumerate() {
                // SAFETY: as above — same bucket range, same provenance.
                let buf = unsafe { shared.range_mut(r, lo, hi) };
                // `lo` is a bucket bound (64-aligned), so tiling from
                // the slice head keeps the global q8 block grid
                error_feedback_rank(buf, &mut res[lo..hi], dtype, chunk,
                                    backend, sc);
            }
        }
    }

    /// Hand bucket `k` to the hop worker (non-blocking).
    fn submit_bucket(&self, k: usize, tele: bool) {
        let w = self.worker.as_ref().expect("overlap worker");
        let mut g = w.shared.cmd.lock().unwrap();
        debug_assert!(matches!(&*g, HopCmd::Idle));
        *g = HopCmd::Run { bucket: k, backend: self.backend, tele };
        w.shared.cv.notify_all();
    }

    /// Block until the in-flight bucket's hops complete.
    fn wait_bucket(&self) -> Result<()> {
        let w = self.worker.as_ref().expect("overlap worker");
        let mut g = w.shared.cmd.lock().unwrap();
        loop {
            match &*g {
                HopCmd::Done(_) => break,
                _ => g = w.shared.cv.wait(g).unwrap(),
            }
        }
        match std::mem::replace(&mut *g, HopCmd::Idle) {
            HopCmd::Done(None) => Ok(()),
            HopCmd::Done(Some(e)) => bail!("comm hop worker failed: {e}"),
            _ => unreachable!("wait loop exits only on Done"),
        }
    }

    /// Copy every rank's leaves into its flat staging buffer. With
    /// telemetry on, each rank's staged gradients are scanned for
    /// non-finite values (the comm-pack wiring of the health-counter
    /// contract) — a read-only pass, so on == off stays bitwise.
    fn pack(&mut self, ranks: &[Vec<Tensor>], tele: bool) {
        for (r, (buf, leaves)) in
            self.bufs.iter_mut().zip(ranks).enumerate()
        {
            let mut off = 0;
            for t in leaves {
                buf[off..off + t.len()].copy_from_slice(t.data());
                off += t.len();
            }
            if tele {
                scan_pack_nonfinite(r, buf);
            }
        }
    }

    /// Write the summed buffers back as the mean (`· 1/ranks` — the
    /// historical `collectives::allreduce_mean` arithmetic, verbatim).
    fn unpack(&self, ranks: &mut [Vec<Tensor>]) {
        let inv = 1.0 / self.ranks as f32;
        let be = self.backend.imp();
        for (buf, leaves) in self.bufs.iter().zip(ranks.iter_mut()) {
            let mut off = 0;
            for t in leaves {
                let dst = t.data_mut();
                let n = dst.len();
                be.scale_into(dst, &buf[off..off + n], inv);
                off += n;
            }
        }
    }

    /// Per rank: `u = grad + residual`, send `v = Q(u)`, carry
    /// `u − v`. Tiled on the flat buffer's global `comm_chunk` grid
    /// (64-aligned, so the q8 block grid is tiling- and
    /// thread-invariant); rank tasks round-robin over threads.
    fn apply_error_feedback(&mut self) {
        let (dtype, chunk, backend) = (self.dtype, self.chunk, self.backend);
        if self.threads <= 1 {
            let sc = &mut self.scratch[0];
            for (buf, res) in self.bufs.iter_mut().zip(&mut self.residual) {
                error_feedback_rank(buf, res, dtype, chunk, backend, sc);
            }
            return;
        }
        let threads = self.threads;
        let mut buckets: Vec<Vec<(&mut PoolBuf<f32>, &mut PoolBuf<f32>)>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (r, (b, q)) in self
            .bufs
            .iter_mut()
            .zip(self.residual.iter_mut())
            .enumerate()
        {
            buckets[r % threads].push((b, q));
        }
        std::thread::scope(|scope| {
            for (bucket, sc) in
                buckets.into_iter().zip(self.scratch.iter_mut())
            {
                scope.spawn(move || {
                    for (buf, res) in bucket {
                        error_feedback_rank(buf, res, dtype, chunk, backend,
                                            sc);
                    }
                });
            }
        });
    }

    /// Error-feedback residual tensors for checkpointing, one flat
    /// `[total]` tensor per rank (empty at f32 / single rank — the
    /// checkpoint layout of an uncompressed run is unchanged). Tagged
    /// f32 by the trainer: residuals must round-trip exactly for resume
    /// to be bitwise.
    pub fn state(&self) -> Vec<(usize, Tensor)> {
        self.residual
            .iter()
            .enumerate()
            .map(|(r, q)| (r, Tensor::from_vec(&[q.len()], q.to_vec())))
            .collect()
    }

    /// Restore residuals saved by [`CommEngine::state`] (same order).
    pub fn load_state(&mut self, state: Vec<Tensor>) -> Result<()> {
        ensure!(state.len() == self.residual.len(),
                "comm residual state has {} tensors, engine expects {} \
                 (ranks × compressed dtype)",
                state.len(), self.residual.len());
        for (r, (res, t)) in
            self.residual.iter_mut().zip(&state).enumerate()
        {
            if t.len() != res.len() {
                bail!("comm residual {r}: {} elements, engine expects {}",
                      t.len(), res.len());
            }
            res.copy_from_slice(t.data());
        }
        Ok(())
    }
}

impl Drop for CommEngine {
    /// Join the hop worker (if any) before the buffers it points into
    /// are freed.
    fn drop(&mut self) {
        if let Some(HopWorker { shared, handle }) = self.worker.take() {
            {
                let mut g = shared.cmd.lock().unwrap();
                *g = HopCmd::Exit;
                shared.cv.notify_all();
            }
            let _ = handle.join();
        }
    }
}

/// Scan one rank's freshly staged gradient range for non-finite values
/// and feed the `grad/nonfinite` health counter, tagging the trace
/// instant with the comm rank. Read-only on the staged data, counting
/// only — telemetry on == off stays bitwise (the crate-wide contract).
fn scan_pack_nonfinite(rank: usize, staged: &[f32]) {
    let bad = staged.iter().filter(|x| !x.is_finite()).count() as u64;
    if bad > 0 {
        trace_event::set_rank(rank as u32);
        telemetry::count(Counter::GradNonFinite, bad);
        trace_event::clear_rank();
    }
}

/// The persistent hop worker: waits for a bucket, runs its schedule
/// steps serially with its own scratch slab, reports back. Phase times
/// land in the shared atomics so the owner can fold them into the
/// telemetry probes (worker threads have their own telemetry cells —
/// same idiom as `optim::parallel`'s worker spans); being a persistent
/// thread, it also records its hop spans straight into its own trace
/// ring, so the overlapped pipeline shows up as a real `comm-hop` lane
/// alongside the coordinator's staging spans.
fn hop_worker_loop(shared: Arc<HopShared>, bufs: Arc<RankBufs>,
                   plan: Arc<BucketPlan>,
                   channel: Option<Arc<InprocTransport>>,
                   dtype: StateDtype, chunk: usize, pool: Option<Pool>) {
    trace_event::set_thread_label("comm-hop");
    let mut scratch = match &pool {
        Some(p) => WireScratch::new_in(p, chunk),
        None => WireScratch::new(chunk),
    };
    loop {
        let cmd = {
            let mut g = shared.cmd.lock().unwrap();
            loop {
                match &*g {
                    HopCmd::Run { .. } | HopCmd::Exit => break,
                    _ => g = shared.cv.wait(g).unwrap(),
                }
            }
            std::mem::replace(&mut *g, HopCmd::Idle)
        };
        let (bucket, backend, tele) = match cmd {
            HopCmd::Exit => return,
            HopCmd::Run { bucket, backend, tele } => (bucket, backend, tele),
            _ => unreachable!("wait loop exits only on Run/Exit"),
        };
        let mut err: Option<String> = None;
        for (phase, regions) in &plan.steps[bucket] {
            let t0 = if tele { telemetry::now_ns() } else { 0 };
            let via = channel.as_deref().map(|t| t as &dyn Transport);
            // SAFETY: pipeline disjointness (super::bucket): any
            // concurrent staging touches only flat ranges at or past
            // the next bucket bound, while this bucket's regions stay
            // strictly below it. The pointers outlive this thread —
            // the engine joins it on drop.
            let r = unsafe {
                ring::run_step_raw(&bufs, *phase, regions, 0, 1, dtype,
                                   chunk, backend, &mut scratch, via)
            };
            if tele {
                let dur = telemetry::now_ns().saturating_sub(t0);
                let (slot, probe) = match phase {
                    Phase::Reduce => (0, Probe::CommHopReduce),
                    Phase::Finalize => (1, Probe::CommHopEncode),
                    Phase::Gather => (2, Probe::CommHopGather),
                };
                shared.hop_ns[slot].fetch_add(dur, Ordering::Relaxed);
                // trace-only record on this thread's own lane: the
                // registry fold stays with the owner (no double count)
                trace_event::complete(probe, t0, dur);
            }
            if let Err(e) = r {
                err = Some(format!("{e:#}"));
                break;
            }
        }
        let mut g = shared.cmd.lock().unwrap();
        *g = HopCmd::Done(err);
        shared.cv.notify_all();
    }
}

/// One rank's error-feedback pass (see [`CommEngine`] docs).
fn error_feedback_rank(buf: &mut [f32], res: &mut [f32], dtype: StateDtype,
                       chunk: usize, backend: Backend,
                       scratch: &mut WireScratch) {
    let be = backend.imp();
    let n = buf.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let len = hi - lo;
        // u = grad + residual, staged through the backend's add lane
        // (same element order as the historical zip loop)
        scratch.stage[..len].copy_from_slice(&buf[lo..hi]);
        be.add_assign(&mut scratch.stage[..len], &res[lo..hi]);
        ring::wire_roundtrip_staged(scratch, len, dtype, backend);
        for k in 0..len {
            let v = scratch.decode[k];
            res[lo + k] = scratch.stage[k] - v;
            buf[lo + k] = v;
        }
        lo = hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives;
    use crate::rng::Rng;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("embed", &[30, 7]),
            ParamSpec::new("w", &[11, 5]),
            ParamSpec::new("b", &[70]),
        ]
    }

    fn grads(specs: &[ParamSpec], ranks: usize, seed: u64)
             -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..ranks)
            .map(|_| {
                specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect()
            })
            .collect()
    }

    fn assert_bitwise(a: &[Vec<Tensor>], b: &[Vec<Tensor>], what: &str) {
        for (ra, rb) in a.iter().zip(b) {
            for (ta, tb) in ra.iter().zip(rb) {
                for (x, y) in ta.data().iter().zip(tb.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} != {y}");
                }
            }
        }
    }

    fn assert_residuals_bitwise(a: &CommEngine, b: &CommEngine, what: &str) {
        for ((_, ta), (_, tb)) in a.state().iter().zip(&b.state()) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what} residual");
            }
        }
    }

    /// The acceptance line: the f32 engine reproduces the pre-`comms`
    /// `collectives::allreduce_mean` bit for bit.
    #[test]
    fn f32_path_matches_legacy_collectives_bitwise() {
        let specs = specs();
        for ranks in [2usize, 3, 4, 7] {
            let mut legacy = grads(&specs, ranks, 42);
            let mut new = legacy.clone();
            collectives::allreduce_mean(&mut legacy).unwrap();
            let mut eng = CommEngine::new(&specs, ranks, StateDtype::F32,
                                          64, 1).unwrap();
            let stats = eng.allreduce_mean(&mut new).unwrap();
            assert_bitwise(&legacy, &new, &format!("ranks {ranks}"));
            assert!(stats.wire_bytes > 0 && stats.sim_seconds > 0.0);
            assert!(stats.sim_overlap_seconds > stats.sim_seconds,
                    "pipeline model adds the staging term");
        }
    }

    /// serial == 2 == 4 comm threads, bitwise, at every wire dtype —
    /// gradients AND carried residuals.
    #[test]
    fn thread_count_is_bitwise_invisible() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            for ranks in [2usize, 4] {
                let base = grads(&specs, ranks, 7);
                let mut ref_out = base.clone();
                let mut ref_eng = CommEngine::new(&specs, ranks, dtype,
                                                  64, 1).unwrap();
                ref_eng.allreduce_mean(&mut ref_out).unwrap();
                for threads in [2usize, 4] {
                    let mut out = base.clone();
                    let mut eng = CommEngine::new(&specs, ranks, dtype, 64,
                                                  threads).unwrap();
                    eng.allreduce_mean(&mut out).unwrap();
                    assert_bitwise(&ref_out, &out,
                                   &format!("{dtype:?} x{threads}"));
                    assert_residuals_bitwise(&ref_eng, &eng,
                                             &format!("{dtype:?} x{threads}"));
                }
            }
        }
    }

    /// `comm_chunk` is a tiling knob only — any multiple of 64 yields
    /// identical bits.
    #[test]
    fn comm_chunk_is_bitwise_invisible() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            let base = grads(&specs, 3, 11);
            let mut ref_out = base.clone();
            CommEngine::new(&specs, 3, dtype, 64, 1)
                .unwrap()
                .allreduce_mean(&mut ref_out)
                .unwrap();
            for chunk in [128usize, 4096, super::super::DEFAULT_COMM_CHUNK] {
                let mut out = base.clone();
                CommEngine::new(&specs, 3, dtype, chunk, 2)
                    .unwrap()
                    .allreduce_mean(&mut out)
                    .unwrap();
                assert_bitwise(&ref_out, &out,
                               &format!("{dtype:?} chunk {chunk}"));
            }
        }
    }

    /// Every rank leaves the exchange with identical values — the pod
    /// sync contract (the finalize step makes this hold under
    /// compression too).
    #[test]
    fn all_ranks_agree_after_exchange() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            for ranks in [2usize, 3, 5] {
                let mut g = grads(&specs, ranks, 23);
                CommEngine::new(&specs, ranks, dtype, 64, 1)
                    .unwrap()
                    .allreduce_mean(&mut g)
                    .unwrap();
                for r in 1..ranks {
                    for (a, b) in g[0].iter().zip(&g[r]) {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            assert_eq!(x.to_bits(), y.to_bits(),
                                       "{dtype:?} rank {r} diverged");
                        }
                    }
                }
            }
        }
    }

    /// The error-feedback identity: after an exchange,
    /// `residual == (grad + old_residual) − sent`, exactly — so no
    /// gradient mass is ever silently dropped.
    #[test]
    fn residual_carries_exactly_what_the_wire_dropped() {
        let specs = specs();
        let ranks = 2;
        let g0 = grads(&specs, ranks, 31);
        let mut eng =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        // two exchanges: the second starts from a non-zero residual
        let mut g = g0.clone();
        eng.allreduce_mean(&mut g).unwrap();
        let res1: Vec<Tensor> =
            eng.state().into_iter().map(|(_, t)| t).collect();
        let g1 = grads(&specs, ranks, 32);
        let mut g = g1.clone();
        eng.allreduce_mean(&mut g).unwrap();
        let res2: Vec<Tensor> =
            eng.state().into_iter().map(|(_, t)| t).collect();
        // replay rank 0's feedback by hand on the flat layout
        let flat = |leaves: &[Tensor]| -> Vec<f32> {
            leaves.iter().flat_map(|t| t.data().to_vec()).collect()
        };
        let (f1, r1) = (flat(&g1[0]), res1[0].data());
        let mut sc = WireScratch::new(64);
        let mut expect = vec![0.0f32; f1.len()];
        let mut lo = 0;
        while lo < f1.len() {
            let hi = (lo + 64).min(f1.len());
            for k in lo..hi {
                sc.stage[k - lo] = f1[k] + r1[k];
            }
            ring::wire_roundtrip_staged(&mut sc, hi - lo, StateDtype::Q8,
                                        Backend::Scalar);
            for k in lo..hi {
                expect[k] = sc.stage[k - lo] - sc.decode[k - lo];
            }
            lo = hi;
        }
        for (x, y) in expect.iter().zip(res2[0].data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
        }
    }

    /// Compressed means stay close to the exact mean (per-block q8 error
    /// bound propagated through the ring), and f32 is exact.
    #[test]
    fn compressed_mean_is_close_to_exact() {
        let specs = specs();
        let ranks = 4;
        let base = grads(&specs, ranks, 5);
        let mut exact = base.clone();
        collectives::allreduce_mean(&mut exact).unwrap();
        for dtype in [StateDtype::Bf16, StateDtype::Q8] {
            let mut out = base.clone();
            CommEngine::new(&specs, ranks, dtype, 64, 1)
                .unwrap()
                .allreduce_mean(&mut out)
                .unwrap();
            for (le, lo) in exact[0].iter().zip(&out[0]) {
                for (&e, &o) in le.data().iter().zip(lo.data()) {
                    // blocks see |v| up to ~4σ; a handful of per-hop
                    // roundings each ≤ step/2 ≈ 4/254
                    assert!((e - o).abs() < 0.2,
                            "{dtype:?}: mean {o} vs exact {e}");
                }
            }
        }
    }

    /// Residual state round-trips through save/restore and the restored
    /// engine continues bitwise (the checkpoint-resume contract; the
    /// SM3CKPT2 file round-trip lives in `crate::proptest`).
    #[test]
    fn residual_state_roundtrip_continues_bitwise() {
        let specs = specs();
        let ranks = 3;
        let mut a =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        let mut g = grads(&specs, ranks, 51);
        a.allreduce_mean(&mut g).unwrap();
        let saved: Vec<Tensor> =
            a.state().into_iter().map(|(_, t)| t).collect();
        let mut b =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        b.load_state(saved).unwrap();
        let g2 = grads(&specs, ranks, 52);
        let mut ga = g2.clone();
        let mut gb = g2;
        a.allreduce_mean(&mut ga).unwrap();
        b.allreduce_mean(&mut gb).unwrap();
        assert_bitwise(&ga, &gb, "restored engine");
        // f32 engines carry no residual state
        let e = CommEngine::new(&specs, ranks, StateDtype::F32, 64, 1)
            .unwrap();
        assert!(e.state().is_empty());
        assert_eq!(e.residual_floats(), 0);
    }

    /// Geometry mismatches are errors, not panics (ISSUE 5 satellite,
    /// same contract as the reworked `collectives`).
    #[test]
    fn geometry_mismatches_are_errors() {
        let specs = specs();
        let mut eng =
            CommEngine::new(&specs, 2, StateDtype::F32, 64, 1).unwrap();
        // wrong rank count
        let mut g = grads(&specs, 3, 1);
        assert!(eng.allreduce_mean(&mut g).is_err());
        // wrong leaf count
        let mut g = grads(&specs, 2, 1);
        g[1].pop();
        assert!(eng.allreduce_mean(&mut g).is_err());
        // wrong leaf length
        let mut g = grads(&specs, 2, 1);
        g[1][0] = Tensor::zeros(&[3]);
        let err = eng.allreduce_mean(&mut g).unwrap_err();
        assert!(err.to_string().contains("leaf 0"), "{err}");
        // bad construction parameters
        assert!(CommEngine::new(&specs, 0, StateDtype::F32, 64, 1).is_err());
        assert!(CommEngine::new(&specs, 2, StateDtype::F32, 0, 1).is_err());
        assert!(CommEngine::new(&specs, 2, StateDtype::F32, 100, 1).is_err());
        assert!(CommEngine::new(&specs, 2, StateDtype::F32, 64, 0).is_err());
        // residual load with the wrong shape
        let mut eng =
            CommEngine::new(&specs, 2, StateDtype::Q8, 64, 1).unwrap();
        assert!(eng.load_state(vec![Tensor::zeros(&[1])]).is_err());
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        assert!(eng
            .load_state(vec![Tensor::zeros(&[total]), Tensor::zeros(&[3])])
            .is_err());
        assert!(eng
            .load_state(vec![Tensor::zeros(&[total]);2])
            .is_ok());
    }

    /// Single rank: a no-op with zero cost (and no buffers held).
    #[test]
    fn single_rank_is_a_free_noop() {
        let specs = specs();
        let mut eng =
            CommEngine::new(&specs, 1, StateDtype::Q8, 64, 4).unwrap();
        let mut g = grads(&specs, 1, 3);
        let before = g.clone();
        let stats = eng.allreduce_mean(&mut g).unwrap();
        assert_eq!(stats.wire_bytes, 0);
        assert_eq!(stats.sim_seconds, 0.0);
        assert_eq!(stats.sim_overlap_seconds, 0.0);
        assert_eq!(eng.buffer_bytes(), 0);
        assert_eq!(eng.scratch_bytes(), 0);
        assert_bitwise(&before, &g, "single rank");
    }

    /// ISSUE 5 tentpole: the steady-state exchange performs zero
    /// allocations on the serial path (buffers, residuals, scratch, and
    /// the schedule are all construction-time) — asserted with the
    /// counting allocator like the step kernels.
    #[test]
    fn steady_state_exchange_is_allocation_free() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            let mut eng =
                CommEngine::new(&specs, 4, dtype, 64, 1).unwrap();
            let mut g = grads(&specs, 4, 9);
            for _ in 0..2 {
                eng.allreduce_mean(&mut g).unwrap(); // warm
            }
            let before = crate::alloc_count::thread_allocs();
            for _ in 0..3 {
                eng.allreduce_mean(&mut g).unwrap();
            }
            let allocs = crate::alloc_count::thread_allocs() - before;
            assert_eq!(allocs, 0,
                       "{dtype:?}: {allocs} allocations in steady-state \
                        exchanges");
        }
    }

    /// ISSUE 7: the live telemetry gauges agree with the object's own
    /// accounting AND the static accountant AND the counting
    /// allocator's live-byte view — the three-way memory cross-check.
    #[test]
    fn telemetry_gauges_match_static_accountant_and_allocator() {
        let specs = specs();
        let ranks = 4;
        let _g = telemetry::enable();
        telemetry::reset_thread();
        let live0 = crate::alloc_count::thread_live_bytes();
        let mut eng =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        let held = crate::alloc_count::thread_live_bytes() - live0;
        let mut g = grads(&specs, ranks, 13);
        let before = telemetry::thread_totals();
        eng.allreduce_mean(&mut g).unwrap();
        let after = telemetry::thread_totals();

        // gauge == engine == static accountant
        let buf_gauge = telemetry::thread_gauge(Gauge::CommBufferBytes);
        assert_eq!(buf_gauge.last as usize, eng.buffer_bytes());
        assert_eq!(buf_gauge.last as usize,
                   crate::memory::comm_buffer_bytes(&specs, ranks,
                                                    StateDtype::Q8));
        let res_gauge = telemetry::thread_gauge(Gauge::CommResidualBytes);
        assert_eq!(res_gauge.last as usize, eng.residual_floats() * 4);
        assert_eq!(buf_gauge.peak, buf_gauge.last);

        // the non-overlapped path keeps exactly one bucket in flight
        let inflight = telemetry::thread_gauge(Gauge::CommInflightBuckets);
        assert_eq!(inflight.last, 1);
        assert_eq!(inflight.peak, 1);

        // the allocator actually saw those buffers get allocated:
        // construction grew live bytes by at least the gauge (plus
        // schedule/scratch overhead), and the peak brackets the live
        assert!(held as u64 >= buf_gauge.last,
                "allocator saw {held} live bytes, gauge claims {}",
                buf_gauge.last);
        assert!(crate::alloc_count::thread_peak_bytes()
                    >= crate::alloc_count::thread_live_bytes());

        // wire counter advanced by exactly the schedule's wire bytes,
        // matching the static accountant's mirror
        let wire =
            after.counter(telemetry::Counter::CommWireBytes)
                - before.counter(telemetry::Counter::CommWireBytes);
        assert_eq!(wire as usize, eng.wire_bytes_per_exchange());
        assert_eq!(wire as usize,
                   crate::memory::comm_wire_bytes(&specs, ranks,
                                                  StateDtype::Q8));
        assert_eq!(after.counter(telemetry::Counter::CommExchanges)
                       - before.counter(telemetry::Counter::CommExchanges),
                   1);

        // per-hop spans landed under the right probes (q8 schedules
        // carry reduce, finalize-encode, and gather sweeps)
        for p in [Probe::CommPack, Probe::CommFeedback,
                  Probe::CommHopReduce, Probe::CommHopEncode,
                  Probe::CommHopGather, Probe::CommUnpack] {
            assert!(after.spans(p) > before.spans(p),
                    "{p:?} recorded no span");
        }
        telemetry::reset_thread();
    }

    /// ISSUE 10: the comm-pack path feeds the `grad/nonfinite` health
    /// counter — one count per non-finite staged value — and a clean
    /// exchange counts nothing.
    #[test]
    fn pack_path_counts_nonfinite_gradients() {
        let specs = specs();
        let _g = telemetry::enable();
        let mut eng =
            CommEngine::new(&specs, 2, StateDtype::F32, 64, 1).unwrap();
        let mut g = grads(&specs, 2, 3);

        let before = telemetry::thread_totals();
        eng.allreduce_mean(&mut g).unwrap();
        let clean = telemetry::thread_totals();
        assert_eq!(clean.counter(Counter::GradNonFinite)
                       - before.counter(Counter::GradNonFinite), 0);

        let mut g = grads(&specs, 2, 3);
        g[0][0].data_mut()[1] = f32::NAN;
        g[1][1].data_mut()[2] = f32::INFINITY;
        eng.allreduce_mean(&mut g).unwrap();
        let after = telemetry::thread_totals();
        assert_eq!(after.counter(Counter::GradNonFinite)
                       - clean.counter(Counter::GradNonFinite), 2);
    }

    /// Wire bytes shrink with the dtype; q8 clears the ≥ 3.5× line on
    /// realistically-sized leaves (tiny chunk classes pay more per-block
    /// scale overhead — the tiny-leaf sets above stay under it).
    #[test]
    fn wire_bytes_shrink_with_dtype() {
        let specs = vec![
            ParamSpec::new("embed", &[128, 64]),
            ParamSpec::new("w", &[64, 64]),
            ParamSpec::new("b", &[257]),
        ];
        let by = |d: StateDtype| {
            CommEngine::new(&specs, 4, d, 64, 1)
                .unwrap()
                .wire_bytes_per_exchange()
        };
        let (f, b, q) = (by(StateDtype::F32), by(StateDtype::Bf16),
                         by(StateDtype::Q8));
        assert_eq!(f, 2 * b);
        assert!(f as f64 / q as f64 >= 3.5, "q8 wire reduction {f}/{q}");
        // buffer accounting: staging per rank, residuals only compressed
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        let eng = CommEngine::new(&specs, 4, StateDtype::F32, 64, 1)
            .unwrap();
        assert_eq!(eng.buffer_bytes(), 4 * total * 4);
        let eng =
            CommEngine::new(&specs, 4, StateDtype::Q8, 64, 1).unwrap();
        assert_eq!(eng.buffer_bytes(), 2 * 4 * total * 4);
        assert_eq!(eng.residual_floats(), 4 * total);
    }

    // ───────────────────────── ISSUE 8 gates ─────────────────────────

    fn opts(dtype: StateDtype, buckets: usize, overlap: bool,
            threads: usize, transport: TransportKind) -> CommOpts {
        CommOpts { dtype, chunk: 64, threads, buckets, overlap, transport }
    }

    /// The PR 8 hard contract, engine level: bucketed exchanges equal
    /// the monolithic exchange bitwise at every dtype × bucket count ×
    /// thread count — gradients AND carried residuals, over two
    /// consecutive exchanges (the second starts from live residuals).
    #[test]
    fn bucketed_exchange_is_bitwise_invisible() {
        let specs = specs();
        let ranks = 4;
        for dtype in StateDtype::ALL {
            let g1 = grads(&specs, ranks, 61);
            let g2 = grads(&specs, ranks, 62);
            let mut ref_eng =
                CommEngine::new(&specs, ranks, dtype, 64, 1).unwrap();
            let mut ref_a = g1.clone();
            ref_eng.allreduce_mean(&mut ref_a).unwrap();
            let mut ref_b = g2.clone();
            ref_eng.allreduce_mean(&mut ref_b).unwrap();
            for buckets in [2usize, 3, 5] {
                for threads in [1usize, 2] {
                    let mut eng = CommEngine::with_opts(
                        &specs, ranks,
                        opts(dtype, buckets, false, threads,
                             TransportKind::Direct))
                        .unwrap();
                    assert_eq!(eng.buckets(), buckets);
                    let mut a = g1.clone();
                    eng.allreduce_mean(&mut a).unwrap();
                    let mut b = g2.clone();
                    eng.allreduce_mean(&mut b).unwrap();
                    let what = format!("{dtype:?} b{buckets} x{threads}");
                    assert_bitwise(&ref_a, &a, &what);
                    assert_bitwise(&ref_b, &b, &what);
                    assert_residuals_bitwise(&ref_eng, &eng, &what);
                }
            }
        }
    }

    /// ...and the overlapped pipeline equals the serial exchange
    /// bitwise at every dtype × bucket count × transport, residuals
    /// included.
    #[test]
    fn overlapped_exchange_matches_serial_bitwise() {
        let specs = specs();
        let ranks = 3;
        for dtype in StateDtype::ALL {
            let g1 = grads(&specs, ranks, 71);
            let g2 = grads(&specs, ranks, 72);
            let mut ref_eng =
                CommEngine::new(&specs, ranks, dtype, 64, 1).unwrap();
            let mut ref_a = g1.clone();
            ref_eng.allreduce_mean(&mut ref_a).unwrap();
            let mut ref_b = g2.clone();
            ref_eng.allreduce_mean(&mut ref_b).unwrap();
            for buckets in [1usize, 2, 3] {
                for transport in TransportKind::ALL {
                    let mut eng = CommEngine::with_opts(
                        &specs, ranks,
                        opts(dtype, buckets, true, 1, transport))
                        .unwrap();
                    assert!(eng.overlap_enabled());
                    assert_eq!(eng.transport_kind(), transport);
                    let mut a = g1.clone();
                    eng.allreduce_mean(&mut a).unwrap();
                    let mut b = g2.clone();
                    eng.allreduce_mean(&mut b).unwrap();
                    let what = format!("{dtype:?} b{buckets} {}",
                                       transport.name());
                    assert_bitwise(&ref_a, &a, &what);
                    assert_bitwise(&ref_b, &b, &what);
                    assert_residuals_bitwise(&ref_eng, &eng, &what);
                }
            }
        }
    }

    /// The in-process channel transport is bitwise invisible on the
    /// non-overlapped path too, at every thread count (edges are keyed
    /// to one worker per sending rank).
    #[test]
    fn inproc_transport_matches_direct_bitwise() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            for ranks in [2usize, 5] {
                let base = grads(&specs, ranks, 81);
                let mut ref_out = base.clone();
                let mut ref_eng = CommEngine::with_opts(
                    &specs, ranks,
                    opts(dtype, 1, false, 1, TransportKind::Direct))
                    .unwrap();
                ref_eng.allreduce_mean(&mut ref_out).unwrap();
                for threads in [1usize, 2, 4] {
                    let mut eng = CommEngine::with_opts(
                        &specs, ranks,
                        opts(dtype, 1, false, threads,
                             TransportKind::Inproc))
                        .unwrap();
                    let mut out = base.clone();
                    eng.allreduce_mean(&mut out).unwrap();
                    assert_bitwise(&ref_out, &out,
                                   &format!("{dtype:?} inproc x{threads}"));
                    assert_residuals_bitwise(&ref_eng, &eng,
                                             &format!("{dtype:?} inproc"));
                }
            }
        }
    }

    /// ISSUE 8 tentpole: the overlapped pipeline allocates nothing on
    /// the calling thread in steady state — the double-buffered slabs,
    /// rank pointers, transport edges, and the worker handshake are all
    /// construction-time.
    #[test]
    fn overlapped_steady_state_is_allocation_free() {
        let specs = specs();
        for transport in TransportKind::ALL {
            let mut eng = CommEngine::with_opts(
                &specs, 4,
                opts(StateDtype::Q8, 3, true, 1, transport))
                .unwrap();
            let mut g = grads(&specs, 4, 91);
            for _ in 0..2 {
                eng.allreduce_mean(&mut g).unwrap(); // warm
            }
            let before = crate::alloc_count::thread_allocs();
            for _ in 0..3 {
                eng.allreduce_mean(&mut g).unwrap();
            }
            let allocs = crate::alloc_count::thread_allocs() - before;
            assert_eq!(allocs, 0,
                       "{}: {allocs} allocations in steady-state \
                        overlapped exchanges",
                       transport.name());
        }
    }

    /// Bucket geometries that cannot tile the flat buffer are
    /// construction/hot-path errors naming the offending bucket — never
    /// panics (ISSUE 8 satellite).
    #[test]
    fn bucket_geometry_errors_name_the_bucket() {
        // 64 flat elements cannot feed 2 buckets on the 64 grid
        let err = CommEngine::with_lens_opts(
            vec![64], 2,
            opts(StateDtype::F32, 2, false, 1, TransportKind::Direct))
            .unwrap_err()
            .to_string();
        assert!(err.contains("bucket 0"), "{err}");
        // zero buckets is rejected outright
        assert!(CommEngine::with_lens_opts(
            vec![256], 2,
            opts(StateDtype::F32, 0, false, 1, TransportKind::Direct))
            .is_err());
        // more buckets than 64-blocks: names a bucket, not a panic
        let err = CommEngine::with_lens_opts(
            vec![128], 2,
            opts(StateDtype::F32, 5, true, 1, TransportKind::Direct))
            .unwrap_err()
            .to_string();
        assert!(err.contains("bucket"), "{err}");
    }

    /// The stats surface the overlap model: hop-only `sim_seconds` is
    /// unchanged by bucketing, while `sim_overlap_seconds` prices the
    /// staged pipeline and drops when overlap turns on.
    #[test]
    fn stats_price_the_overlap_pipeline() {
        let specs = specs();
        let ranks = 4;
        let run = |buckets: usize, overlap: bool| -> CommStats {
            let mut eng = CommEngine::with_opts(
                &specs, ranks,
                opts(StateDtype::Q8, buckets, overlap, 1,
                     TransportKind::Direct))
                .unwrap();
            let mut g = grads(&specs, ranks, 99);
            eng.allreduce_mean(&mut g).unwrap()
        };
        let serial = run(3, false);
        let ovl = run(3, true);
        assert_eq!(serial.wire_bytes, ovl.wire_bytes);
        assert_eq!(serial.sim_seconds, ovl.sim_seconds);
        assert!(ovl.sim_overlap_seconds < serial.sim_overlap_seconds,
                "overlap {} !< serial {}",
                ovl.sim_overlap_seconds, serial.sim_overlap_seconds);
        // the pipeline figure always includes staging, so it dominates
        // the hop-only model
        assert!(serial.sim_overlap_seconds > serial.sim_seconds);
        assert!(ovl.sim_overlap_seconds > ovl.sim_seconds);
    }

    /// The overlapped pipeline reports two in-flight buckets mid-run
    /// (hop lane + stager) and drains to one; hop spans are folded from
    /// the worker into the usual probes.
    #[test]
    fn overlap_telemetry_gauges_and_spans() {
        let specs = specs();
        let _g = telemetry::enable();
        telemetry::reset_thread();
        let mut eng = CommEngine::with_opts(
            &specs, 3,
            opts(StateDtype::Q8, 3, true, 1, TransportKind::Direct))
            .unwrap();
        let mut g = grads(&specs, 3, 101);
        let before = telemetry::thread_totals();
        eng.allreduce_mean(&mut g).unwrap();
        let after = telemetry::thread_totals();
        let inflight = telemetry::thread_gauge(Gauge::CommInflightBuckets);
        assert_eq!(inflight.peak, 2, "pipeline never double-buffered");
        assert_eq!(inflight.last, 1, "pipeline did not drain");
        for p in [Probe::CommPack, Probe::CommFeedback,
                  Probe::CommHopReduce, Probe::CommHopEncode,
                  Probe::CommHopGather, Probe::CommUnpack] {
            assert!(after.spans(p) > before.spans(p),
                    "{p:?} recorded no span under overlap");
        }
        telemetry::reset_thread();
    }

    /// `scratch_bytes` accounts every persistent Θ(chunk) slab: caller
    /// scratch per thread, the worker slab under overlap, and the
    /// transport's per-edge messages.
    #[test]
    fn scratch_accounting_tracks_slabs() {
        let specs = specs();
        let per = WireScratch::new(64).bytes();
        let eng = |b, o, t, tr| {
            CommEngine::with_opts(&specs, 4,
                                  opts(StateDtype::Q8, b, o, t, tr))
                .unwrap()
        };
        let base = eng(1, false, 1, TransportKind::Direct);
        assert_eq!(base.scratch_bytes(), per);
        let threaded = eng(1, false, 3, TransportKind::Direct);
        assert_eq!(threaded.scratch_bytes(), 3 * per);
        let ovl = eng(2, true, 1, TransportKind::Direct);
        assert_eq!(ovl.scratch_bytes(), 2 * per);
        let chan = eng(1, false, 1, TransportKind::Inproc);
        assert_eq!(chan.scratch_bytes(),
                   per + 4 * transport::message_cap(64));
    }
}
