//! [`CommEngine`] — buffer lifecycle, error feedback, and the exchange
//! entry point the trainer drives.
//!
//! One engine is built per training run from the model's parameter
//! inventory. It owns, per rank: a persistent flat f32 gradient buffer
//! (leaves packed contiguously in spec order) and — for compressed wire
//! dtypes — a persistent flat **error-feedback residual**. Every
//! exchange runs:
//!
//! 1. **pack** — each rank's leaf tensors are copied into its flat
//!    buffer (no allocation; the buffers are sized at construction).
//! 2. **error feedback** (compressed dtypes only) — per rank,
//!    `u = grad + residual` is wire round-tripped to `v = Q(u)`; the
//!    buffer continues with `v` and the residual becomes `u − v`
//!    exactly (f32 subtraction). What one step's quantizer drops, the
//!    next step's send re-injects — the MicroAdam-style error-feedback
//!    contract that keeps compressed training convergent. The q8 block
//!    grid here is the global 64-aligned grid of the flat buffer, so
//!    the tiling (`comm_chunk`) and the thread count never shift a
//!    block boundary.
//! 3. **ring exchange** — the precomputed [`ring::Schedule`], serial or
//!    across `comm_threads` workers (bitwise identical either way).
//! 4. **unpack** — each rank's buffer is written back to its leaf
//!    tensors times `1/ranks` (the data-parallel mean), exactly the
//!    historical `collectives::allreduce_mean` arithmetic.
//!
//! At `comm_dtype = f32` steps 2 is skipped entirely and the wire is
//! the identity, so the whole path reproduces pre-`comms` trajectories
//! bit for bit. Residuals are exposed through [`CommEngine::state`] /
//! [`CommEngine::load_state`] and ride the `SM3CKPT2` checkpoint as
//! f32-tagged tensors (they must stay exact for resume to be bitwise).

use super::ring::{self, Phase, Schedule, WireScratch};
use super::{check_comm_chunk, TimingModel};
use crate::optim::{Backend, ParamSpec, StateDtype};
use crate::telemetry::{self, Counter, Gauge, Probe};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// What one exchange cost: exact wire bytes moved and the simulated pod
/// interconnect time from the engine's [`TimingModel`].
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    /// bytes that crossed links (wire-encoded payloads, both phases)
    pub wire_bytes: usize,
    /// simulated exchange wall time (0.0 for a single rank)
    pub sim_seconds: f64,
}

/// The communication engine: persistent buffers + residuals + schedule.
pub struct CommEngine {
    /// per-leaf flat lengths, in pack order
    lens: Vec<usize>,
    /// total flat elements per rank
    total: usize,
    ranks: usize,
    dtype: StateDtype,
    chunk: usize,
    threads: usize,
    /// kernel backend for the wire codec, reduce, and unpack lanes
    /// (bitwise identical across backends — DESIGN.md §13); pack stays a
    /// plain memcpy in every backend
    backend: Backend,
    /// per-rank flat gradient staging buffers (empty when ranks == 1)
    bufs: Vec<Vec<f32>>,
    /// per-rank error-feedback residuals (empty at f32 or ranks == 1)
    residual: Vec<Vec<f32>>,
    /// per-thread wire scratch
    scratch: Vec<WireScratch>,
    schedule: Schedule,
    timing: TimingModel,
}

impl CommEngine {
    /// Build an engine for `ranks` data-parallel workers exchanging
    /// gradients over the given parameter inventory.
    pub fn new(specs: &[ParamSpec], ranks: usize, dtype: StateDtype,
               chunk: usize, threads: usize) -> Result<Self> {
        let lens: Vec<usize> = specs.iter().map(ParamSpec::numel).collect();
        Self::with_lens(lens, ranks, dtype, chunk, threads)
    }

    /// Core constructor over raw per-leaf flat lengths.
    pub fn with_lens(lens: Vec<usize>, ranks: usize, dtype: StateDtype,
                     chunk: usize, threads: usize) -> Result<Self> {
        ensure!(ranks >= 1, "comm engine needs at least one rank");
        ensure!(threads >= 1, "comm_threads must be >= 1 (1 = serial)");
        check_comm_chunk(chunk)?;
        let total: usize = lens.iter().sum();
        let (bufs, residual, scratch) = if ranks > 1 {
            (
                (0..ranks).map(|_| vec![0.0f32; total]).collect(),
                if dtype != StateDtype::F32 {
                    (0..ranks).map(|_| vec![0.0f32; total]).collect()
                } else {
                    Vec::new()
                },
                (0..threads).map(|_| WireScratch::new(chunk)).collect(),
            )
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        let schedule = Schedule::build(&lens, ranks, dtype);
        Ok(Self {
            lens,
            total,
            ranks,
            dtype,
            chunk,
            threads,
            backend: Backend::default(),
            bufs,
            residual,
            scratch,
            schedule,
            timing: TimingModel::default(),
        })
    }

    /// Override the interconnect model (defaults to the TPU-v2 pod).
    pub fn set_timing(&mut self, timing: TimingModel) {
        self.timing = timing;
    }

    /// Route the wire codec, reduce, and unpack lanes through `backend`
    /// (config `kernel_backend`; bitwise identical across backends).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
    }

    /// Configured rank count.
    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Wire dtype of every link payload.
    pub fn dtype(&self) -> StateDtype {
        self.dtype
    }

    /// Exact bytes crossing links in one full exchange (0 for one rank).
    /// `crate::memory::comm_wire_bytes` is the static mirror.
    pub fn wire_bytes_per_exchange(&self) -> usize {
        self.schedule.wire_bytes
    }

    /// Persistent per-run comm buffer bytes: staging + residuals
    /// (excludes the Θ(comm_chunk) per-thread scratch).
    /// `crate::memory::comm_buffer_bytes` is the static mirror.
    pub fn buffer_bytes(&self) -> usize {
        (self.bufs.len() + self.residual.len()) * self.total * 4
    }

    /// Error-feedback residual scalars carried across steps.
    pub fn residual_floats(&self) -> usize {
        self.residual.len() * self.total
    }

    /// All-reduce every rank's gradient leaves to their data-parallel
    /// mean, in place, through the compressed ring. Validates the rank
    /// and leaf geometry (mismatches are errors, not panics — the
    /// trainer propagates them like every other step failure).
    pub fn allreduce_mean(&mut self, ranks: &mut [Vec<Tensor>])
                          -> Result<CommStats> {
        ensure!(ranks.len() == self.ranks,
                "comm engine built for {} ranks, got {}",
                self.ranks, ranks.len());
        for (r, leaves) in ranks.iter().enumerate() {
            ensure!(leaves.len() == self.lens.len(),
                    "rank {r}: {} gradient leaves, engine expects {}",
                    leaves.len(), self.lens.len());
            for (i, t) in leaves.iter().enumerate() {
                ensure!(t.len() == self.lens[i],
                        "rank {r} leaf {i}: {} elements, engine expects {}",
                        t.len(), self.lens[i]);
            }
        }
        if self.ranks == 1 {
            return Ok(CommStats::default());
        }
        let pack_span = telemetry::span(Probe::CommPack);
        self.pack(ranks);
        drop(pack_span);
        if self.dtype != StateDtype::F32 {
            let fb_span = telemetry::span(Probe::CommFeedback);
            self.apply_error_feedback();
            drop(fb_span);
        }
        for si in 0..self.schedule.steps.len() {
            // split-borrow the schedule away from the buffers
            let (phase, regions) = {
                let (p, r) = &self.schedule.steps[si];
                (*p, r)
            };
            // hop timing on the calling thread: one span per schedule
            // step (a full ring sweep), classified by phase. These
            // measured latencies are the calibration source for
            // TimingModel (DESIGN.md §14; bench_collectives reports
            // measured-vs-modeled).
            let _hop = telemetry::span(match phase {
                Phase::Reduce => Probe::CommHopReduce,
                Phase::Finalize => Probe::CommHopEncode,
                Phase::Gather => Probe::CommHopGather,
            });
            if self.threads <= 1 {
                ring::run_step_serial(&mut self.bufs, phase, regions,
                                      self.dtype, self.chunk, self.backend,
                                      &mut self.scratch[0]);
            } else {
                ring::run_step_threaded(&mut self.bufs, phase, regions,
                                        self.dtype, self.chunk, self.backend,
                                        self.threads, &mut self.scratch);
            }
        }
        let unpack_span = telemetry::span(Probe::CommUnpack);
        self.unpack(ranks);
        drop(unpack_span);
        if telemetry::enabled() {
            telemetry::count(Counter::CommWireBytes,
                             self.schedule.wire_bytes as u64);
            telemetry::count(Counter::CommExchanges, 1);
            // live memory gauges; the static accountant
            // (memory::comm_buffer_bytes) must agree — cross-checked in
            // the tests below
            telemetry::gauge(Gauge::CommBufferBytes,
                             self.buffer_bytes() as u64);
            telemetry::gauge(Gauge::CommResidualBytes,
                             (self.residual_floats() * 4) as u64);
        }
        Ok(CommStats {
            wire_bytes: self.schedule.wire_bytes,
            sim_seconds: self
                .timing
                .exchange_seconds(self.schedule.wire_bytes, self.ranks),
        })
    }

    /// Copy every rank's leaves into its flat staging buffer.
    fn pack(&mut self, ranks: &[Vec<Tensor>]) {
        for (buf, leaves) in self.bufs.iter_mut().zip(ranks) {
            let mut off = 0;
            for t in leaves {
                buf[off..off + t.len()].copy_from_slice(t.data());
                off += t.len();
            }
        }
    }

    /// Write the summed buffers back as the mean (`· 1/ranks` — the
    /// historical `collectives::allreduce_mean` arithmetic, verbatim).
    fn unpack(&self, ranks: &mut [Vec<Tensor>]) {
        let inv = 1.0 / self.ranks as f32;
        let be = self.backend.imp();
        for (buf, leaves) in self.bufs.iter().zip(ranks.iter_mut()) {
            let mut off = 0;
            for t in leaves {
                let dst = t.data_mut();
                let n = dst.len();
                be.scale_into(dst, &buf[off..off + n], inv);
                off += n;
            }
        }
    }

    /// Per rank: `u = grad + residual`, send `v = Q(u)`, carry
    /// `u − v`. Tiled on the flat buffer's global `comm_chunk` grid
    /// (64-aligned, so the q8 block grid is tiling- and
    /// thread-invariant); rank tasks round-robin over threads.
    fn apply_error_feedback(&mut self) {
        let (dtype, chunk, backend) = (self.dtype, self.chunk, self.backend);
        if self.threads <= 1 {
            let sc = &mut self.scratch[0];
            for (buf, res) in self.bufs.iter_mut().zip(&mut self.residual) {
                error_feedback_rank(buf, res, dtype, chunk, backend, sc);
            }
            return;
        }
        let threads = self.threads;
        let mut buckets: Vec<Vec<(&mut Vec<f32>, &mut Vec<f32>)>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (r, (b, q)) in self
            .bufs
            .iter_mut()
            .zip(self.residual.iter_mut())
            .enumerate()
        {
            buckets[r % threads].push((b, q));
        }
        std::thread::scope(|scope| {
            for (bucket, sc) in
                buckets.into_iter().zip(self.scratch.iter_mut())
            {
                scope.spawn(move || {
                    for (buf, res) in bucket {
                        error_feedback_rank(buf, res, dtype, chunk, backend,
                                            sc);
                    }
                });
            }
        });
    }

    /// Error-feedback residual tensors for checkpointing, one flat
    /// `[total]` tensor per rank (empty at f32 / single rank — the
    /// checkpoint layout of an uncompressed run is unchanged). Tagged
    /// f32 by the trainer: residuals must round-trip exactly for resume
    /// to be bitwise.
    pub fn state(&self) -> Vec<(usize, Tensor)> {
        self.residual
            .iter()
            .enumerate()
            .map(|(r, q)| (r, Tensor::from_vec(&[q.len()], q.clone())))
            .collect()
    }

    /// Restore residuals saved by [`CommEngine::state`] (same order).
    pub fn load_state(&mut self, state: Vec<Tensor>) -> Result<()> {
        ensure!(state.len() == self.residual.len(),
                "comm residual state has {} tensors, engine expects {} \
                 (ranks × compressed dtype)",
                state.len(), self.residual.len());
        for (r, (res, t)) in
            self.residual.iter_mut().zip(&state).enumerate()
        {
            if t.len() != res.len() {
                bail!("comm residual {r}: {} elements, engine expects {}",
                      t.len(), res.len());
            }
            res.copy_from_slice(t.data());
        }
        Ok(())
    }
}

/// One rank's error-feedback pass (see [`CommEngine`] docs).
fn error_feedback_rank(buf: &mut [f32], res: &mut [f32], dtype: StateDtype,
                       chunk: usize, backend: Backend,
                       scratch: &mut WireScratch) {
    let be = backend.imp();
    let n = buf.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let len = hi - lo;
        // u = grad + residual, staged through the backend's add lane
        // (same element order as the historical zip loop)
        scratch.stage[..len].copy_from_slice(&buf[lo..hi]);
        be.add_assign(&mut scratch.stage[..len], &res[lo..hi]);
        ring::wire_roundtrip_staged(scratch, len, dtype, backend);
        for k in 0..len {
            let v = scratch.decode[k];
            res[lo + k] = scratch.stage[k] - v;
            buf[lo + k] = v;
        }
        lo = hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives;
    use crate::rng::Rng;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("embed", &[30, 7]),
            ParamSpec::new("w", &[11, 5]),
            ParamSpec::new("b", &[70]),
        ]
    }

    fn grads(specs: &[ParamSpec], ranks: usize, seed: u64)
             -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..ranks)
            .map(|_| {
                specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect()
            })
            .collect()
    }

    fn assert_bitwise(a: &[Vec<Tensor>], b: &[Vec<Tensor>], what: &str) {
        for (ra, rb) in a.iter().zip(b) {
            for (ta, tb) in ra.iter().zip(rb) {
                for (x, y) in ta.data().iter().zip(tb.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} != {y}");
                }
            }
        }
    }

    /// The acceptance line: the f32 engine reproduces the pre-`comms`
    /// `collectives::allreduce_mean` bit for bit.
    #[test]
    fn f32_path_matches_legacy_collectives_bitwise() {
        let specs = specs();
        for ranks in [2usize, 3, 4, 7] {
            let mut legacy = grads(&specs, ranks, 42);
            let mut new = legacy.clone();
            collectives::allreduce_mean(&mut legacy).unwrap();
            let mut eng = CommEngine::new(&specs, ranks, StateDtype::F32,
                                          64, 1).unwrap();
            let stats = eng.allreduce_mean(&mut new).unwrap();
            assert_bitwise(&legacy, &new, &format!("ranks {ranks}"));
            assert!(stats.wire_bytes > 0 && stats.sim_seconds > 0.0);
        }
    }

    /// serial == 2 == 4 comm threads, bitwise, at every wire dtype —
    /// gradients AND carried residuals.
    #[test]
    fn thread_count_is_bitwise_invisible() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            for ranks in [2usize, 4] {
                let base = grads(&specs, ranks, 7);
                let mut ref_out = base.clone();
                let mut ref_eng = CommEngine::new(&specs, ranks, dtype,
                                                  64, 1).unwrap();
                ref_eng.allreduce_mean(&mut ref_out).unwrap();
                for threads in [2usize, 4] {
                    let mut out = base.clone();
                    let mut eng = CommEngine::new(&specs, ranks, dtype, 64,
                                                  threads).unwrap();
                    eng.allreduce_mean(&mut out).unwrap();
                    assert_bitwise(&ref_out, &out,
                                   &format!("{dtype:?} x{threads}"));
                    for ((_, a), (_, b)) in
                        ref_eng.state().iter().zip(&eng.state())
                    {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            assert_eq!(x.to_bits(), y.to_bits(),
                                       "{dtype:?} x{threads} residual");
                        }
                    }
                }
            }
        }
    }

    /// `comm_chunk` is a tiling knob only — any multiple of 64 yields
    /// identical bits.
    #[test]
    fn comm_chunk_is_bitwise_invisible() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            let base = grads(&specs, 3, 11);
            let mut ref_out = base.clone();
            CommEngine::new(&specs, 3, dtype, 64, 1)
                .unwrap()
                .allreduce_mean(&mut ref_out)
                .unwrap();
            for chunk in [128usize, 4096, super::super::DEFAULT_COMM_CHUNK] {
                let mut out = base.clone();
                CommEngine::new(&specs, 3, dtype, chunk, 2)
                    .unwrap()
                    .allreduce_mean(&mut out)
                    .unwrap();
                assert_bitwise(&ref_out, &out,
                               &format!("{dtype:?} chunk {chunk}"));
            }
        }
    }

    /// Every rank leaves the exchange with identical values — the pod
    /// sync contract (the finalize step makes this hold under
    /// compression too).
    #[test]
    fn all_ranks_agree_after_exchange() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            for ranks in [2usize, 3, 5] {
                let mut g = grads(&specs, ranks, 23);
                CommEngine::new(&specs, ranks, dtype, 64, 1)
                    .unwrap()
                    .allreduce_mean(&mut g)
                    .unwrap();
                for r in 1..ranks {
                    for (a, b) in g[0].iter().zip(&g[r]) {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            assert_eq!(x.to_bits(), y.to_bits(),
                                       "{dtype:?} rank {r} diverged");
                        }
                    }
                }
            }
        }
    }

    /// The error-feedback identity: after an exchange,
    /// `residual == (grad + old_residual) − sent`, exactly — so no
    /// gradient mass is ever silently dropped.
    #[test]
    fn residual_carries_exactly_what_the_wire_dropped() {
        let specs = specs();
        let ranks = 2;
        let g0 = grads(&specs, ranks, 31);
        let mut eng =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        // two exchanges: the second starts from a non-zero residual
        let mut g = g0.clone();
        eng.allreduce_mean(&mut g).unwrap();
        let res1: Vec<Tensor> =
            eng.state().into_iter().map(|(_, t)| t).collect();
        let g1 = grads(&specs, ranks, 32);
        let mut g = g1.clone();
        eng.allreduce_mean(&mut g).unwrap();
        let res2: Vec<Tensor> =
            eng.state().into_iter().map(|(_, t)| t).collect();
        // replay rank 0's feedback by hand on the flat layout
        let flat = |leaves: &[Tensor]| -> Vec<f32> {
            leaves.iter().flat_map(|t| t.data().to_vec()).collect()
        };
        let (f1, r1) = (flat(&g1[0]), res1[0].data());
        let mut sc = WireScratch::new(64);
        let mut expect = vec![0.0f32; f1.len()];
        let mut lo = 0;
        while lo < f1.len() {
            let hi = (lo + 64).min(f1.len());
            for k in lo..hi {
                sc.stage[k - lo] = f1[k] + r1[k];
            }
            ring::wire_roundtrip_staged(&mut sc, hi - lo, StateDtype::Q8,
                                        Backend::Scalar);
            for k in lo..hi {
                expect[k] = sc.stage[k - lo] - sc.decode[k - lo];
            }
            lo = hi;
        }
        for (x, y) in expect.iter().zip(res2[0].data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
        }
    }

    /// Compressed means stay close to the exact mean (per-block q8 error
    /// bound propagated through the ring), and f32 is exact.
    #[test]
    fn compressed_mean_is_close_to_exact() {
        let specs = specs();
        let ranks = 4;
        let base = grads(&specs, ranks, 5);
        let mut exact = base.clone();
        collectives::allreduce_mean(&mut exact).unwrap();
        for dtype in [StateDtype::Bf16, StateDtype::Q8] {
            let mut out = base.clone();
            CommEngine::new(&specs, ranks, dtype, 64, 1)
                .unwrap()
                .allreduce_mean(&mut out)
                .unwrap();
            for (le, lo) in exact[0].iter().zip(&out[0]) {
                for (&e, &o) in le.data().iter().zip(lo.data()) {
                    // blocks see |v| up to ~4σ; a handful of per-hop
                    // roundings each ≤ step/2 ≈ 4/254
                    assert!((e - o).abs() < 0.2,
                            "{dtype:?}: mean {o} vs exact {e}");
                }
            }
        }
    }

    /// Residual state round-trips through save/restore and the restored
    /// engine continues bitwise (the checkpoint-resume contract; the
    /// SM3CKPT2 file round-trip lives in `crate::proptest`).
    #[test]
    fn residual_state_roundtrip_continues_bitwise() {
        let specs = specs();
        let ranks = 3;
        let mut a =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        let mut g = grads(&specs, ranks, 51);
        a.allreduce_mean(&mut g).unwrap();
        let saved: Vec<Tensor> =
            a.state().into_iter().map(|(_, t)| t).collect();
        let mut b =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        b.load_state(saved).unwrap();
        let g2 = grads(&specs, ranks, 52);
        let mut ga = g2.clone();
        let mut gb = g2;
        a.allreduce_mean(&mut ga).unwrap();
        b.allreduce_mean(&mut gb).unwrap();
        assert_bitwise(&ga, &gb, "restored engine");
        // f32 engines carry no residual state
        let e = CommEngine::new(&specs, ranks, StateDtype::F32, 64, 1)
            .unwrap();
        assert!(e.state().is_empty());
        assert_eq!(e.residual_floats(), 0);
    }

    /// Geometry mismatches are errors, not panics (ISSUE 5 satellite,
    /// same contract as the reworked `collectives`).
    #[test]
    fn geometry_mismatches_are_errors() {
        let specs = specs();
        let mut eng =
            CommEngine::new(&specs, 2, StateDtype::F32, 64, 1).unwrap();
        // wrong rank count
        let mut g = grads(&specs, 3, 1);
        assert!(eng.allreduce_mean(&mut g).is_err());
        // wrong leaf count
        let mut g = grads(&specs, 2, 1);
        g[1].pop();
        assert!(eng.allreduce_mean(&mut g).is_err());
        // wrong leaf length
        let mut g = grads(&specs, 2, 1);
        g[1][0] = Tensor::zeros(&[3]);
        let err = eng.allreduce_mean(&mut g).unwrap_err();
        assert!(err.to_string().contains("leaf 0"), "{err}");
        // bad construction parameters
        assert!(CommEngine::new(&specs, 0, StateDtype::F32, 64, 1).is_err());
        assert!(CommEngine::new(&specs, 2, StateDtype::F32, 0, 1).is_err());
        assert!(CommEngine::new(&specs, 2, StateDtype::F32, 100, 1).is_err());
        assert!(CommEngine::new(&specs, 2, StateDtype::F32, 64, 0).is_err());
        // residual load with the wrong shape
        let mut eng =
            CommEngine::new(&specs, 2, StateDtype::Q8, 64, 1).unwrap();
        assert!(eng.load_state(vec![Tensor::zeros(&[1])]).is_err());
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        assert!(eng
            .load_state(vec![Tensor::zeros(&[total]), Tensor::zeros(&[3])])
            .is_err());
        assert!(eng
            .load_state(vec![Tensor::zeros(&[total]);2])
            .is_ok());
    }

    /// Single rank: a no-op with zero cost (and no buffers held).
    #[test]
    fn single_rank_is_a_free_noop() {
        let specs = specs();
        let mut eng =
            CommEngine::new(&specs, 1, StateDtype::Q8, 64, 4).unwrap();
        let mut g = grads(&specs, 1, 3);
        let before = g.clone();
        let stats = eng.allreduce_mean(&mut g).unwrap();
        assert_eq!(stats.wire_bytes, 0);
        assert_eq!(stats.sim_seconds, 0.0);
        assert_eq!(eng.buffer_bytes(), 0);
        assert_bitwise(&before, &g, "single rank");
    }

    /// ISSUE 5 tentpole: the steady-state exchange performs zero
    /// allocations on the serial path (buffers, residuals, scratch, and
    /// the schedule are all construction-time) — asserted with the
    /// counting allocator like the step kernels.
    #[test]
    fn steady_state_exchange_is_allocation_free() {
        let specs = specs();
        for dtype in StateDtype::ALL {
            let mut eng =
                CommEngine::new(&specs, 4, dtype, 64, 1).unwrap();
            let mut g = grads(&specs, 4, 9);
            for _ in 0..2 {
                eng.allreduce_mean(&mut g).unwrap(); // warm
            }
            let before = crate::alloc_count::thread_allocs();
            for _ in 0..3 {
                eng.allreduce_mean(&mut g).unwrap();
            }
            let allocs = crate::alloc_count::thread_allocs() - before;
            assert_eq!(allocs, 0,
                       "{dtype:?}: {allocs} allocations in steady-state \
                        exchanges");
        }
    }

    /// ISSUE 7: the live telemetry gauges agree with the object's own
    /// accounting AND the static accountant AND the counting
    /// allocator's live-byte view — the three-way memory cross-check.
    #[test]
    fn telemetry_gauges_match_static_accountant_and_allocator() {
        let specs = specs();
        let ranks = 4;
        let _g = telemetry::enable();
        telemetry::reset_thread();
        let live0 = crate::alloc_count::thread_live_bytes();
        let mut eng =
            CommEngine::new(&specs, ranks, StateDtype::Q8, 64, 1).unwrap();
        let held = crate::alloc_count::thread_live_bytes() - live0;
        let mut g = grads(&specs, ranks, 13);
        let before = telemetry::thread_totals();
        eng.allreduce_mean(&mut g).unwrap();
        let after = telemetry::thread_totals();

        // gauge == engine == static accountant
        let buf_gauge = telemetry::thread_gauge(Gauge::CommBufferBytes);
        assert_eq!(buf_gauge.last as usize, eng.buffer_bytes());
        assert_eq!(buf_gauge.last as usize,
                   crate::memory::comm_buffer_bytes(&specs, ranks,
                                                    StateDtype::Q8));
        let res_gauge = telemetry::thread_gauge(Gauge::CommResidualBytes);
        assert_eq!(res_gauge.last as usize, eng.residual_floats() * 4);
        assert_eq!(buf_gauge.peak, buf_gauge.last);

        // the allocator actually saw those buffers get allocated:
        // construction grew live bytes by at least the gauge (plus
        // schedule/scratch overhead), and the peak brackets the live
        assert!(held as u64 >= buf_gauge.last,
                "allocator saw {held} live bytes, gauge claims {}",
                buf_gauge.last);
        assert!(crate::alloc_count::thread_peak_bytes()
                    >= crate::alloc_count::thread_live_bytes());

        // wire counter advanced by exactly the schedule's wire bytes,
        // matching the static accountant's mirror
        let wire =
            after.counter(telemetry::Counter::CommWireBytes)
                - before.counter(telemetry::Counter::CommWireBytes);
        assert_eq!(wire as usize, eng.wire_bytes_per_exchange());
        assert_eq!(wire as usize,
                   crate::memory::comm_wire_bytes(&specs, ranks,
                                                  StateDtype::Q8));
        assert_eq!(after.counter(telemetry::Counter::CommExchanges)
                       - before.counter(telemetry::Counter::CommExchanges),
                   1);

        // per-hop spans landed under the right probes (q8 schedules
        // carry reduce, finalize-encode, and gather sweeps)
        for p in [Probe::CommPack, Probe::CommFeedback,
                  Probe::CommHopReduce, Probe::CommHopEncode,
                  Probe::CommHopGather, Probe::CommUnpack] {
            assert!(after.spans(p) > before.spans(p),
                    "{p:?} recorded no span");
        }
        telemetry::reset_thread();
    }

    /// Wire bytes shrink with the dtype; q8 clears the ≥ 3.5× line on
    /// realistically-sized leaves (tiny chunk classes pay more per-block
    /// scale overhead — the tiny-leaf sets above stay under it).
    #[test]
    fn wire_bytes_shrink_with_dtype() {
        let specs = vec![
            ParamSpec::new("embed", &[128, 64]),
            ParamSpec::new("w", &[64, 64]),
            ParamSpec::new("b", &[257]),
        ];
        let by = |d: StateDtype| {
            CommEngine::new(&specs, 4, d, 64, 1)
                .unwrap()
                .wire_bytes_per_exchange()
        };
        let (f, b, q) = (by(StateDtype::F32), by(StateDtype::Bf16),
                         by(StateDtype::Q8));
        assert_eq!(f, 2 * b);
        assert!(f as f64 / q as f64 >= 3.5, "q8 wire reduction {f}/{q}");
        // buffer accounting: staging per rank, residuals only compressed
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        let eng = CommEngine::new(&specs, 4, StateDtype::F32, 64, 1)
            .unwrap();
        assert_eq!(eng.buffer_bytes(), 4 * total * 4);
        let eng =
            CommEngine::new(&specs, 4, StateDtype::Q8, 64, 1).unwrap();
        assert_eq!(eng.buffer_bytes(), 2 * 4 * total * 4);
        assert_eq!(eng.residual_floats(), 4 * total);
    }
}
