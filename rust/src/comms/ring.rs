//! The chunked ring all-reduce schedule and its tiled executor.
//!
//! A ring all-reduce over `n` ranks splits every leaf into `n` chunk
//! classes (class `c` of a leaf of `len` elements covers
//! `[c·len/n, (c+1)·len/n)` — the exact partition the pre-`comms`
//! `collectives::ring_allreduce` used) and runs two phases of `n−1`
//! steps each:
//!
//! * **reduce-scatter** — at step `s`, rank `r` sends class `(r−s) mod n`
//!   to rank `r+1`, which accumulates it (`dst += wire(src)`). After
//!   `n−1` steps rank `(c−1) mod n` holds the complete sum of class `c`.
//! * **all-gather** — at step `s`, rank `r` sends its completed class
//!   `(r+1−s) mod n` onward; receivers overwrite
//!   (`dst = wire(src)`).
//!
//! Between the phases, compressed schedules insert a **finalize** step:
//! each owner replaces its completed class by the wire round-trip
//! `decode(encode(·))` of itself. The all-gather then forwards a
//! wire-exact value, and — because the qstate codecs are idempotent
//! (`encode∘decode == id` on codec outputs) — every hop re-encodes to
//! the *identical* bytes, so all `n` ranks finish with bitwise-equal
//! buffers. (At f32 the wire is the identity and the step is elided.)
//!
//! # Determinism
//!
//! Within one step every region's reads and writes are disjoint (the
//! written class and the forwarded class differ by one position around
//! the ring), and all arithmetic is element-independent, so regions may
//! be tiled into `comm_chunk`-element pieces and distributed over any
//! number of worker threads without changing a single bit. Tile
//! boundaries are multiples of the q8 wire block *relative to the
//! region head*, so per-block codec purity makes the tiled encode
//! byte-identical to a whole-region encode — the same argument as the
//! step-kernel tile cursor (DESIGN.md §10), applied to the wire.

use super::transport::Transport;
use super::wire_bytes_for;
use crate::optim::qstate::codec;
use crate::optim::{Backend, StateDtype};
use crate::pool::{Pool, PoolBuf, Tag};

/// Which operation a schedule step applies to its regions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `dst += wire(src)` (reduce-scatter hop)
    Reduce,
    /// `buf = wire(buf)` on the owner (compressed schedules only)
    Finalize,
    /// `dst = wire(src)` (all-gather hop)
    Gather,
}

/// One contiguous flat-buffer range moving between two ranks in a step.
/// `src == dst` only in [`Phase::Finalize`].
#[derive(Clone, Copy, Debug)]
pub struct Region {
    /// sending rank
    pub src: usize,
    /// receiving rank
    pub dst: usize,
    /// absolute flat-buffer start (inclusive)
    pub lo: usize,
    /// absolute flat-buffer end (exclusive)
    pub hi: usize,
}

/// The full, precomputed exchange plan for a fixed
/// (leaf lengths, ranks, wire dtype) triple.
pub struct Schedule {
    /// steps in execution order; regions within a step are disjoint
    pub steps: Vec<(Phase, Vec<Region>)>,
    /// total bytes crossing links in one exchange (finalize is local)
    pub wire_bytes: usize,
}

/// Chunk-class bounds of one leaf: class `c` covers
/// `[bounds(c), bounds(c+1))` — the historical
/// `collectives::ring_allreduce` partition, kept verbatim so the f32
/// path reproduces pre-`comms` trajectories bitwise.
#[inline]
pub fn class_lo(len: usize, n: usize, c: usize) -> usize {
    c * len / n
}

impl Schedule {
    /// Build the plan. `lens` are the per-leaf flat lengths, laid out
    /// contiguously in order in every rank's flat buffer.
    pub fn build(lens: &[usize], ranks: usize, dtype: StateDtype) -> Self {
        let n = ranks;
        if n <= 1 {
            return Self { steps: Vec::new(), wire_bytes: 0 };
        }
        // leaf base offsets in the flat buffer
        let mut offsets = Vec::with_capacity(lens.len());
        let mut total = 0usize;
        for &l in lens {
            offsets.push(total);
            total += l;
        }
        let mut wire_bytes = 0usize;
        let mut steps = Vec::with_capacity(2 * (n - 1) + 1);
        let mut push_hops = |steps: &mut Vec<(Phase, Vec<Region>)>,
                             phase: Phase,
                             class_of: &dyn Fn(usize, usize) -> usize| {
            for s in 0..n - 1 {
                let mut regs = Vec::new();
                for r in 0..n {
                    let dst = (r + 1) % n;
                    let c = class_of(r, s);
                    for (leaf, &len) in lens.iter().enumerate() {
                        let (lo, hi) =
                            (class_lo(len, n, c), class_lo(len, n, c + 1));
                        if hi > lo {
                            wire_bytes += wire_bytes_for(hi - lo, dtype);
                            regs.push(Region {
                                src: r,
                                dst,
                                lo: offsets[leaf] + lo,
                                hi: offsets[leaf] + hi,
                            });
                        }
                    }
                }
                steps.push((phase, regs));
            }
        };
        // reduce-scatter: step s, rank r forwards class (r − s) mod n
        push_hops(&mut steps, Phase::Reduce, &|r, s| (r + n - s) % n);
        if dtype != StateDtype::F32 {
            // owners self-quantize their completed class (r + 1) mod n so
            // the all-gather forwards a wire-exact value everywhere
            let mut regs = Vec::new();
            for r in 0..n {
                let c = (r + 1) % n;
                for (leaf, &len) in lens.iter().enumerate() {
                    let (lo, hi) =
                        (class_lo(len, n, c), class_lo(len, n, c + 1));
                    if hi > lo {
                        regs.push(Region {
                            src: r,
                            dst: r,
                            lo: offsets[leaf] + lo,
                            hi: offsets[leaf] + hi,
                        });
                    }
                }
            }
            steps.push((Phase::Finalize, regs));
        }
        // all-gather: step s, rank r forwards class (r + 1 − s) mod n
        push_hops(&mut steps, Phase::Gather, &|r, s| (r + 1 + n - s) % n);
        Self { steps, wire_bytes }
    }
}

/// Reusable per-thread wire scratch, sized for one `comm_chunk` tile.
/// All buffers are allocated once at engine construction, so the
/// steady-state exchange path performs zero allocations (serial path;
/// thread *spawns* on the multi-thread path allocate, as in
/// `optim::parallel`).
pub struct WireScratch {
    /// staging copy (finalize / error-feedback sum)
    pub stage: PoolBuf<f32>,
    /// decoded wire values
    pub decode: PoolBuf<f32>,
    /// q8 per-block scale fields
    pub scales: PoolBuf<f32>,
    /// q8 codes
    pub codes: PoolBuf<u8>,
    /// bf16 wire words
    pub half: PoolBuf<u16>,
    /// serialized outbound wire message (transport sends)
    pub wire_out: PoolBuf<u8>,
    /// received wire message (transport recvs)
    pub wire_in: PoolBuf<u8>,
}

impl WireScratch {
    /// Scratch for tiles of at most `chunk` elements, on the plain heap
    /// (tests, standalone executors).
    pub fn new(chunk: usize) -> Self {
        let cap = super::transport::message_cap(chunk);
        Self {
            stage: PoolBuf::from_vec(Tag::CommWire, vec![0.0; chunk]),
            decode: PoolBuf::from_vec(Tag::CommWire, vec![0.0; chunk]),
            scales: PoolBuf::from_vec(Tag::CommWire,
                                      vec![0.0; codec::q8_blocks(chunk)]),
            codes: PoolBuf::from_vec(Tag::CommWire, vec![0; chunk]),
            half: PoolBuf::from_vec(Tag::CommWire, vec![0; chunk]),
            wire_out: PoolBuf::from_vec(Tag::CommWire, vec![0; cap]),
            wire_in: PoolBuf::from_vec(Tag::CommWire, vec![0; cap]),
        }
    }

    /// Like [`WireScratch::new`], leasing every buffer from `pool` under
    /// [`Tag::CommWire`] (bitwise identical — placement only).
    pub fn new_in(pool: &Pool, chunk: usize) -> Self {
        let cap = super::transport::message_cap(chunk);
        Self {
            stage: pool.take_f32(Tag::CommWire, chunk),
            decode: pool.take_f32(Tag::CommWire, chunk),
            scales: pool.take_f32(Tag::CommWire, codec::q8_blocks(chunk)),
            codes: pool.take_u8(Tag::CommWire, chunk),
            half: pool.take_u16(Tag::CommWire, chunk),
            wire_out: pool.take_u8(Tag::CommWire, cap),
            wire_in: pool.take_u8(Tag::CommWire, cap),
        }
    }

    /// Persistent bytes one scratch slab set holds (sized once at
    /// construction; the memory accountant's `comm_scratch_bytes`
    /// mirrors this).
    pub fn bytes(&self) -> usize {
        4 * (self.stage.len() + self.decode.len() + self.scales.len())
            + self.codes.len()
            + 2 * self.half.len()
            + self.wire_out.len()
            + self.wire_in.len()
    }
}

/// Encode `vals` at `dtype` and decode the wire bytes back into
/// `scratch.decode[..vals.len()]` — the value the receiving side of a
/// link observes. `vals.len()` must not exceed the scratch tile size.
/// (The f32 wire is the identity; callers skip the call entirely.)
/// Codec lanes dispatch through `backend` (bitwise identical across
/// backends — DESIGN.md §13).
pub fn wire_roundtrip(vals: &[f32], dtype: StateDtype, backend: Backend,
                      scratch: &mut WireScratch) {
    let n = vals.len();
    debug_assert!(n <= scratch.decode.len(), "tile exceeds scratch");
    let be = backend.imp();
    match dtype {
        StateDtype::F32 => scratch.decode[..n].copy_from_slice(vals),
        StateDtype::Bf16 => {
            be.bf16_encode(vals, &mut scratch.half[..n]);
            be.bf16_decode(&scratch.half[..n], &mut scratch.decode[..n]);
        }
        StateDtype::Q8 => {
            let blocks = codec::q8_blocks(n);
            be.q8_encode(vals, &mut scratch.scales[..blocks],
                         &mut scratch.codes[..n]);
            be.q8_decode(&scratch.scales[..blocks], &scratch.codes[..n],
                         &mut scratch.decode[..n]);
        }
    }
}

/// Like [`wire_roundtrip`], but reading the input from
/// `scratch.stage[..len]` (field-disjoint borrows let a caller fill the
/// stage from sums it is still holding mutably — the error-feedback
/// path). Output lands in `scratch.decode[..len]`.
pub fn wire_roundtrip_staged(scratch: &mut WireScratch, len: usize,
                             dtype: StateDtype, backend: Backend) {
    let be = backend.imp();
    let WireScratch { stage, decode, scales, codes, half, .. } = scratch;
    match dtype {
        StateDtype::F32 => decode[..len].copy_from_slice(&stage[..len]),
        StateDtype::Bf16 => {
            be.bf16_encode(&stage[..len], &mut half[..len]);
            be.bf16_decode(&half[..len], &mut decode[..len]);
        }
        StateDtype::Q8 => {
            let blocks = codec::q8_blocks(len);
            be.q8_encode(&stage[..len], &mut scales[..blocks],
                         &mut codes[..len]);
            be.q8_decode(&scales[..blocks], &codes[..len],
                         &mut decode[..len]);
        }
    }
}

/// Run one region through the wire in `chunk`-element tiles, given the
/// sender's and receiver's views of the range. `src` and `dst` must be
/// the same length (the region length); `phase` must not be
/// [`Phase::Finalize`] (which has one buffer — see [`run_finalize`]).
pub fn run_pair(phase: Phase, src: &[f32], dst: &mut [f32],
                dtype: StateDtype, chunk: usize, backend: Backend,
                scratch: &mut WireScratch) {
    debug_assert_eq!(src.len(), dst.len());
    debug_assert_ne!(phase, Phase::Finalize);
    let be = backend.imp();
    let n = src.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let (s, d) = (&src[lo..hi], &mut dst[lo..hi]);
        match (phase, dtype) {
            // f32 wire is the identity — accumulate / copy directly
            // (this is the historical `collectives` arithmetic verbatim)
            (Phase::Reduce, StateDtype::F32) => be.add_assign(d, s),
            (Phase::Gather, StateDtype::F32) => d.copy_from_slice(s),
            (Phase::Reduce, _) => {
                wire_roundtrip(s, dtype, backend, scratch);
                be.add_assign(d, &scratch.decode[..s.len()]);
            }
            (Phase::Gather, _) => {
                wire_roundtrip(s, dtype, backend, scratch);
                d.copy_from_slice(&scratch.decode[..s.len()]);
            }
            (Phase::Finalize, _) => unreachable!("finalize has one buffer"),
        }
        lo = hi;
    }
}

/// In-place wire round-trip of an owner's completed class (the finalize
/// step of compressed schedules), tiled like [`run_pair`].
pub fn run_finalize(buf: &mut [f32], dtype: StateDtype, chunk: usize,
                    backend: Backend, scratch: &mut WireScratch) {
    debug_assert_ne!(dtype, StateDtype::F32, "f32 schedules elide finalize");
    let be = backend.imp();
    let n = buf.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let len = hi - lo;
        scratch.stage[..len].copy_from_slice(&buf[lo..hi]);
        // field-disjoint borrows: stage is the input, scales/codes/half
        // the wire bytes, buf the output
        let stage = &scratch.stage[..len];
        match dtype {
            StateDtype::F32 => unreachable!(),
            StateDtype::Bf16 => {
                be.bf16_encode(stage, &mut scratch.half[..len]);
                be.bf16_decode(&scratch.half[..len], &mut buf[lo..hi]);
            }
            StateDtype::Q8 => {
                let blocks = codec::q8_blocks(len);
                be.q8_encode(stage, &mut scratch.scales[..blocks],
                             &mut scratch.codes[..len]);
                be.q8_decode(&scratch.scales[..blocks],
                             &scratch.codes[..len], &mut buf[lo..hi]);
            }
        }
        lo = hi;
    }
}

/// Raw per-rank buffer pointers for the multi-thread executor. Safety
/// rests on the schedule invariant: within one step, every region's
/// write range is touched by exactly one task, and no task reads a
/// range any task writes (forwarded and written classes differ by one
/// ring position, finalize regions are per-owner). The engine asserts
/// the invariant over every built schedule in debug builds.
pub struct RankBufs {
    ptrs: Vec<*mut f32>,
    len: usize,
}

unsafe impl Send for RankBufs {}
unsafe impl Sync for RankBufs {}

impl RankBufs {
    /// Capture the (stable) data pointers of every rank's flat buffer.
    pub fn new(bufs: &mut [PoolBuf<f32>]) -> Self {
        let len = bufs.first().map_or(0, |b| b.len());
        debug_assert!(bufs.iter().all(|b| b.len() == len));
        Self {
            ptrs: bufs
                .iter_mut()
                .map(|b| b.as_mut_slice().as_mut_ptr())
                .collect(),
            len,
        }
    }

    /// # Safety
    /// `[lo, hi)` must be in bounds and disjoint from every concurrently
    /// written range (schedule invariant above).
    pub unsafe fn range(&self, rank: usize, lo: usize, hi: usize) -> &[f32] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts(self.ptrs[rank].add(lo), hi - lo)
    }

    /// # Safety
    /// `[lo, hi)` must be in bounds, written by this task only, and
    /// disjoint from every concurrently read range (schedule invariant).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, rank: usize, lo: usize, hi: usize)
                            -> &mut [f32] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptrs[rank].add(lo), hi - lo)
    }
}

/// Execute the regions of one schedule step assigned to worker `tid`
/// of `threads` through raw rank-buffer pointers — the shared core of
/// the threaded executor and the overlap hop worker. Tasks round-robin
/// over region index; when a [`Transport`] is supplied they key on the
/// sending rank instead, so each ring edge's send/recv pairs stay on
/// one worker (the one-in-flight-message rendezvous discipline). The
/// assignment is bitwise-irrelevant either way — regions within a step
/// commute.
///
/// # Safety
/// The schedule invariant ([`RankBufs`] docs) must hold for `regions`,
/// the pointers must outlive the call, and no concurrent task may
/// touch any range this task reads or writes (for the overlap pipeline
/// that is the bucket-bound disjointness argument in
/// [`super::bucket`]).
#[allow(clippy::too_many_arguments)]
pub unsafe fn run_step_raw(bufs: &RankBufs, phase: Phase, regions: &[Region],
                           tid: usize, threads: usize, dtype: StateDtype,
                           chunk: usize, backend: Backend,
                           scratch: &mut WireScratch,
                           transport: Option<&dyn Transport>)
                           -> anyhow::Result<()> {
    for (i, reg) in regions.iter().enumerate() {
        let key = if transport.is_some() { reg.src } else { i };
        if key % threads != tid {
            continue;
        }
        if phase == Phase::Finalize {
            // finalize is an owner-local re-encode — never transported
            let b = bufs.range_mut(reg.src, reg.lo, reg.hi);
            run_finalize(b, dtype, chunk, backend, scratch);
            continue;
        }
        let s = bufs.range(reg.src, reg.lo, reg.hi);
        let d = bufs.range_mut(reg.dst, reg.lo, reg.hi);
        match transport {
            None => run_pair(phase, s, d, dtype, chunk, backend, scratch),
            Some(t) => super::transport::run_pair_via(
                phase, s, d, (reg.src, reg.dst), dtype, chunk, backend,
                scratch, t)?,
        }
    }
    Ok(())
}

/// Execute one schedule step's regions with `threads` workers, bitwise
/// identical at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn run_step_threaded(bufs: &mut [PoolBuf<f32>], phase: Phase,
                         regions: &[Region], dtype: StateDtype,
                         chunk: usize, backend: Backend, threads: usize,
                         scratch: &mut [WireScratch],
                         transport: Option<&dyn Transport>)
                         -> anyhow::Result<()> {
    let shared = RankBufs::new(bufs);
    let results: Vec<anyhow::Result<()>> = std::thread::scope(|scope| {
        let handles: Vec<_> = scratch
            .iter_mut()
            .enumerate()
            .take(threads)
            .map(|(tid, sc)| {
                let shared = &shared;
                scope.spawn(move || {
                    // SAFETY: schedule invariant — each task exclusively
                    // owns its write ranges; read ranges are never
                    // written in the same step (see RankBufs docs).
                    unsafe {
                        run_step_raw(shared, phase, regions, tid, threads,
                                     dtype, chunk, backend, sc, transport)
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

/// Execute one schedule step serially with safe split borrows (the
/// steady-state allocation-free path; bitwise identical to
/// [`run_step_threaded`]).
pub fn run_step_serial(bufs: &mut [PoolBuf<f32>], phase: Phase,
                       regions: &[Region], dtype: StateDtype, chunk: usize,
                       backend: Backend, scratch: &mut WireScratch,
                       transport: Option<&dyn Transport>)
                       -> anyhow::Result<()> {
    for reg in regions {
        if phase == Phase::Finalize {
            run_finalize(&mut bufs[reg.src][reg.lo..reg.hi], dtype, chunk,
                         backend, scratch);
            continue;
        }
        // split-borrow src and dst rank buffers (always distinct ranks)
        let (a, b) = if reg.src < reg.dst {
            let (left, right) = bufs.split_at_mut(reg.dst);
            (&left[reg.src], &mut right[0])
        } else {
            let (left, right) = bufs.split_at_mut(reg.src);
            (&right[0], &mut left[reg.dst])
        };
        let (s, d) = (&a[reg.lo..reg.hi], &mut b[reg.lo..reg.hi]);
        match transport {
            None => run_pair(phase, s, d, dtype, chunk, backend, scratch),
            Some(t) => super::transport::run_pair_via(
                phase, s, d, (reg.src, reg.dst), dtype, chunk, backend,
                scratch, t)?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_bounds_match_the_historical_partition() {
        // same arithmetic as the pre-comms collectives starts vector
        for (len, n) in [(100usize, 4usize), (7, 3), (64, 8), (5, 8)] {
            let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
            for c in 0..=n {
                assert_eq!(class_lo(len, n, c), starts[c]);
            }
            assert_eq!(class_lo(len, n, 0), 0);
            assert_eq!(class_lo(len, n, n), len);
        }
    }

    #[test]
    fn schedule_shape_and_wire_bytes() {
        let lens = [100usize, 37];
        for n in [2usize, 3, 4, 8] {
            let s = Schedule::build(&lens, n, StateDtype::F32);
            // 2(n-1) hop steps, no finalize at f32
            assert_eq!(s.steps.len(), 2 * (n - 1));
            assert!(s.steps.iter().all(|(p, _)| *p != Phase::Finalize));
            // every hop step forwards each class once ⇒ full buffer bytes
            let per_sweep: usize = 4 * (100 + 37);
            assert_eq!(s.wire_bytes, 2 * (n - 1) * per_sweep);

            let b = Schedule::build(&lens, n, StateDtype::Bf16);
            assert_eq!(2 * b.wire_bytes, s.wire_bytes, "bf16 halves f32");
            let q = Schedule::build(&lens, n, StateDtype::Q8);
            assert_eq!(q.steps.len(), 2 * (n - 1) + 1);
            assert!(q.steps.iter().any(|(p, _)| *p == Phase::Finalize));
            // small chunk classes pay proportionally more per-block
            // scale overhead — the ≥ 3.5× line is asserted on real
            // (large-leaf) inventories in crate::memory / bench_memory
            assert!(q.wire_bytes < b.wire_bytes,
                    "q8 {} vs bf16 {}", q.wire_bytes, b.wire_bytes);
        }
        // single rank: nothing to exchange
        let s = Schedule::build(&lens, 1, StateDtype::Q8);
        assert!(s.steps.is_empty());
        assert_eq!(s.wire_bytes, 0);
    }

    /// The safety contract of the threaded executor: within any step, no
    /// write range overlaps another write range or any read range.
    #[test]
    fn schedule_steps_have_disjoint_reads_and_writes() {
        for dtype in StateDtype::ALL {
            for n in [2usize, 3, 4, 8] {
                let s = Schedule::build(&[130, 7, 64], n, dtype);
                for (phase, regs) in &s.steps {
                    let mut writes: Vec<(usize, usize, usize)> = Vec::new();
                    for r in regs {
                        let w = if *phase == Phase::Finalize {
                            (r.src, r.lo, r.hi)
                        } else {
                            (r.dst, r.lo, r.hi)
                        };
                        for &(wr, lo, hi) in &writes {
                            assert!(wr != w.0 || hi <= w.1 || w.2 <= lo,
                                    "overlapping writes in {phase:?}");
                        }
                        writes.push(w);
                    }
                    if *phase != Phase::Finalize {
                        for r in regs {
                            for &(wr, lo, hi) in &writes {
                                assert!(wr != r.src || hi <= r.lo
                                        || r.hi <= lo,
                                        "read/write overlap in {phase:?}");
                            }
                        }
                    }
                }
            }
        }
    }

    /// A wire round-trip is idempotent at every dtype and backend (the
    /// finalize / all-gather stability argument).
    #[test]
    fn wire_roundtrip_is_idempotent() {
        let mut rng = crate::rng::Rng::new(3);
        let vals: Vec<f32> =
            (0..200).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for backend in Backend::ALL {
            for dtype in StateDtype::ALL {
                let mut sc = WireScratch::new(256);
                wire_roundtrip(&vals, dtype, backend, &mut sc);
                let once: Vec<f32> = sc.decode[..vals.len()].to_vec();
                wire_roundtrip(&once, dtype, backend, &mut sc);
                for (a, b) in once.iter().zip(&sc.decode[..vals.len()]) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{dtype:?} {}", backend.name());
                }
            }
        }
    }

    /// Tiling is bitwise invisible: any block-aligned chunk produces the
    /// same receiver-side values as one whole-region pass — and the
    /// backend never shows through either.
    #[test]
    fn run_pair_chunking_is_bitwise_invisible() {
        let mut rng = crate::rng::Rng::new(9);
        let src: Vec<f32> =
            (0..333).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for dtype in StateDtype::ALL {
            for phase in [Phase::Reduce, Phase::Gather] {
                let mut whole = vec![0.5f32; src.len()];
                let mut sc = WireScratch::new(512);
                run_pair(phase, &src, &mut whole, dtype, 512,
                         Backend::Scalar, &mut sc);
                for chunk in [64usize, 128, 256] {
                    for backend in Backend::ALL {
                        let mut tiled = vec![0.5f32; src.len()];
                        let mut sc = WireScratch::new(chunk);
                        run_pair(phase, &src, &mut tiled, dtype, chunk,
                                 backend, &mut sc);
                        for (a, b) in whole.iter().zip(&tiled) {
                            assert_eq!(a.to_bits(), b.to_bits(),
                                       "{dtype:?} {phase:?} chunk {chunk} {}",
                                       backend.name());
                        }
                    }
                }
            }
        }
    }
}
