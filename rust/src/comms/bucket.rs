//! Bucketed exchange plans: the flat buffer split into 64-aligned
//! buckets so staging and hop traffic can pipeline.
//!
//! PR 5's engine runs the whole exchange as one monolith: pack the full
//! flat buffer, stage the full error-feedback sum, then sweep every
//! schedule step. [`BucketPlan`] cuts that monolith into `comm_buckets`
//! flat ranges so the overlapped path can stage bucket `k+1` while
//! bucket `k`'s hop steps are in flight (the double-buffered global
//! loader idea from kubecl's matmul pipeline, applied to the ring).
//!
//! # Why splitting is bitwise invisible
//!
//! Every schedule region is tiled from its own head on the
//! `comm_chunk` grid, and `comm_chunk` is a multiple of the q8 wire
//! block (64). A bucket bound β intersects region `[lo, hi)` at the
//! **region-head-relative** down-snapped offset
//! `a(β) = ⌊(β − lo)/64⌋ · 64`, so every piece starts at
//! `lo + 64·j` — on the exact same tile/block grid the unsplit region
//! uses. Per-block codec purity (each q8 block encodes independently;
//! bf16/f32 are element-local) then makes piece-by-piece execution
//! byte-identical to the whole-region pass, which is the same argument
//! as `run_pair` chunking (DESIGN.md §12), extended to 64-aligned start
//! offsets. The pieces of consecutive buckets meet exactly (bucket
//! `k`'s piece ends where bucket `k+1`'s begins), so the per-bucket
//! sweep is a *partition* of the schedule — nothing is dropped or done
//! twice.
//!
//! # Why the pipeline is race-free
//!
//! Bucket bounds β_k are 64-aligned in **flat** coordinates, and a
//! piece's end `lo + a(β_{k+1}) ≤ β_{k+1}` (down-snapping never crosses
//! the bound), so every read and write of bucket `k`'s hops stays
//! strictly below β_{k+1}. Staging bucket `k+1` touches exactly
//! `[β_{k+1}, β_{k+2})` — disjoint. A piece may *start* up to 63
//! elements below β_k, but that range was staged in round `k−1` and its
//! hops completed with bucket `k−1` (the pieces partition), so the
//! overlap window never sees a torn value. Error-feedback staging per
//! 64-aligned flat bucket equals whole-buffer staging bitwise for the
//! same block-grid reason.

use super::ring::{Phase, Region, Schedule};
use super::TimingModel;
use crate::optim::qstate::codec::Q8_BLOCK;
use crate::optim::StateDtype;
use anyhow::{bail, ensure, Result};

/// Default bucket count (`comm_buckets`): one bucket reproduces the
/// PR 5 monolithic exchange exactly.
pub const DEFAULT_COMM_BUCKETS: usize = 1;

#[inline]
fn snap_down(x: usize) -> usize {
    x / Q8_BLOCK * Q8_BLOCK
}

/// The bucketed exchange plan for a fixed
/// (leaf lengths, ranks, wire dtype, bucket count) tuple: per-bucket
/// schedule-step pieces plus per-bucket wire-byte totals for the
/// overlap timing model.
pub struct BucketPlan {
    /// flat bucket bounds, `buckets + 1` entries, `bounds[0] == 0`,
    /// `bounds[buckets] == total`; interior bounds are 64-aligned
    pub bounds: Vec<usize>,
    /// per bucket: the schedule steps restricted to the bucket's pieces
    /// (same step order and phases as the unsplit schedule)
    pub steps: Vec<Vec<(Phase, Vec<Region>)>>,
    /// per bucket: link bytes its hop pieces move in one exchange
    pub wire_bytes: Vec<usize>,
    /// link bytes of the whole exchange (all buckets; equals the
    /// unsplit schedule's figure — splitting moves no extra bytes)
    pub total_wire_bytes: usize,
}

impl BucketPlan {
    /// Build the plan by splitting [`Schedule::build`]'s regions at the
    /// snapped bucket bounds. Fails if any bucket snaps empty — the
    /// error names the offending bucket so config errors are
    /// actionable. With `ranks <= 1` (or an empty inventory) there is
    /// nothing to exchange and the plan collapses to one empty bucket
    /// regardless of `buckets`.
    pub fn build(lens: &[usize], ranks: usize, dtype: StateDtype,
                 buckets: usize) -> Result<Self> {
        ensure!(buckets >= 1, "comm_buckets must be >= 1, got {buckets}");
        let total: usize = lens.iter().sum();
        let schedule = Schedule::build(lens, ranks, dtype);
        if schedule.steps.is_empty() {
            return Ok(Self {
                bounds: vec![0, total],
                steps: vec![Vec::new()],
                wire_bytes: vec![0],
                total_wire_bytes: 0,
            });
        }
        let mut bounds = Vec::with_capacity(buckets + 1);
        for k in 0..=buckets {
            bounds.push(if k == buckets {
                total
            } else {
                snap_down(k * total / buckets)
            });
        }
        for k in 0..buckets {
            if bounds[k + 1] <= bounds[k] {
                bail!(
                    "comm_buckets = {buckets} cannot tile {total} flat \
                     elements: bucket {k} would be empty \
                     ([{}..{}) after snapping bounds to the {Q8_BLOCK}-\
                     element wire-block grid)",
                    bounds[k], bounds[k + 1]
                );
            }
        }
        // region-head-relative offset of flat bound `b` inside a region
        let cut = |b: usize, lo: usize, hi: usize| -> usize {
            if b <= lo {
                0
            } else if b >= hi {
                hi - lo
            } else {
                snap_down(b - lo)
            }
        };
        let mut steps = Vec::with_capacity(buckets);
        let mut wire = Vec::with_capacity(buckets);
        for k in 0..buckets {
            let (blo, bhi) = (bounds[k], bounds[k + 1]);
            let mut bucket_steps = Vec::with_capacity(schedule.steps.len());
            let mut bucket_wire = 0usize;
            for (phase, regs) in &schedule.steps {
                let pieces: Vec<Region> = regs
                    .iter()
                    .filter_map(|r| {
                        let a0 = cut(blo, r.lo, r.hi);
                        let a1 = cut(bhi, r.lo, r.hi);
                        (a1 > a0).then(|| Region {
                            src: r.src,
                            dst: r.dst,
                            lo: r.lo + a0,
                            hi: r.lo + a1,
                        })
                    })
                    .collect();
                if *phase != Phase::Finalize {
                    bucket_wire += pieces
                        .iter()
                        .map(|p| super::wire_bytes_for(p.hi - p.lo, dtype))
                        .sum::<usize>();
                }
                bucket_steps.push((*phase, pieces));
            }
            steps.push(bucket_steps);
            wire.push(bucket_wire);
        }
        // Splitting on the region-head 64 grid never adds partial-block
        // scale fields, so the per-bucket bytes must re-sum to the
        // unsplit schedule's total exactly.
        let total_wire: usize = wire.iter().sum();
        debug_assert_eq!(total_wire, schedule.wire_bytes);
        Ok(Self { bounds, steps, wire_bytes: wire, total_wire_bytes: total_wire })
    }

    /// Number of buckets in the plan.
    pub fn buckets(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Flat range `[lo, hi)` bucket `k` stages (pack + error feedback).
    pub fn stage_range(&self, k: usize) -> (usize, usize) {
        (self.bounds[k], self.bounds[k + 1])
    }

    /// Hot-path geometry check (no panics): the bucket bounds must tile
    /// `[0, total)` with 64-aligned interior cuts. Errors name the
    /// offending bucket, mirroring the engine's rank-geometry errors.
    pub fn check(&self, total: usize) -> Result<()> {
        ensure!(self.bounds.first() == Some(&0)
                    && self.bounds.last() == Some(&total),
                "bucket plan spans [{:?}..{:?}) but the flat buffer is \
                 [0..{total})",
                self.bounds.first(), self.bounds.last());
        for k in 0..self.buckets() {
            let (lo, hi) = (self.bounds[k], self.bounds[k + 1]);
            if hi < lo || (hi == lo && self.buckets() > 1) {
                bail!("bucket {k} of {} is empty or inverted: [{lo}..{hi})",
                      self.buckets());
            }
            if k > 0 && lo % Q8_BLOCK != 0 {
                bail!("bucket {k} starts at {lo}, off the {Q8_BLOCK}-element \
                       wire-block grid");
            }
        }
        Ok(())
    }

    /// Modeled wall time of one exchange under `t`: per bucket `k`, a
    /// staging term `s_k` (pack + error-feedback traffic over all
    /// ranks' bucket bytes) and a hop term `h_k`
    /// ([`TimingModel::exchange_seconds`] of the bucket's wire bytes).
    /// Serial (`overlap == false`) pays `Σ (s_k + h_k)`; the pipelined
    /// path stages bucket `k+1` while bucket `k`'s hops fly, paying
    /// `s_0 + Σ max(h_k, s_{k+1})` — strictly less whenever there are
    /// ≥ 2 buckets, ≥ 2 ranks, and nonzero terms. This is the
    /// overlap-adjusted figure `StepRecord::comm_ms` reports.
    pub fn modeled_seconds(&self, t: &TimingModel, ranks: usize,
                           overlap: bool) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let b = self.buckets();
        let stage = |k: usize| {
            let elems = self.bounds[k + 1] - self.bounds[k];
            t.stage_seconds(ranks * elems * 4)
        };
        let hop = |k: usize| t.exchange_seconds(self.wire_bytes[k], ranks);
        let mut secs = stage(0);
        for k in 0..b {
            let next = if k + 1 < b { stage(k + 1) } else { 0.0 };
            secs += if overlap { hop(k).max(next) } else { hop(k) + next };
        }
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LENS: [usize; 3] = [700, 37, 301]; // 1038 elements, odd leaves

    #[test]
    fn bounds_are_snapped_and_tile_the_buffer() {
        for buckets in [1usize, 2, 3, 5] {
            let p = BucketPlan::build(&LENS, 4, StateDtype::Q8, buckets)
                .unwrap();
            assert_eq!(p.buckets(), buckets);
            assert_eq!(p.bounds[0], 0);
            assert_eq!(*p.bounds.last().unwrap(), 1038);
            for k in 1..buckets {
                assert_eq!(p.bounds[k] % Q8_BLOCK, 0);
                assert!(p.bounds[k] > p.bounds[k - 1]);
            }
            p.check(1038).unwrap();
            assert!(p.check(1039).is_err());
        }
    }

    #[test]
    fn empty_bucket_errors_name_the_bucket() {
        // 64 elements over 2 buckets: bounds[1] snaps to 0 ⇒ bucket 0 empty
        let err = BucketPlan::build(&[64], 2, StateDtype::F32, 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bucket 0"), "{err}");
        assert!(err.contains("comm_buckets = 2"), "{err}");
        assert!(BucketPlan::build(&[64], 2, StateDtype::F32, 0).is_err());
    }

    #[test]
    fn single_rank_collapses_to_one_empty_bucket() {
        let p = BucketPlan::build(&LENS, 1, StateDtype::Q8, 4).unwrap();
        assert_eq!(p.buckets(), 1);
        assert!(p.steps[0].is_empty());
        assert_eq!(p.total_wire_bytes, 0);
        assert_eq!(p.modeled_seconds(&TimingModel::default(), 1, true), 0.0);
    }

    /// The pieces partition every schedule region exactly, on the
    /// region-head-relative 64 grid, and per-bucket wire bytes re-sum
    /// to the unsplit schedule's total at every dtype.
    #[test]
    fn pieces_partition_regions_on_the_block_grid() {
        for dtype in StateDtype::ALL {
            for n in [2usize, 3, 8] {
                for buckets in [1usize, 2, 3, 5] {
                    let s = Schedule::build(&LENS, n, dtype);
                    let p = BucketPlan::build(&LENS, n, dtype, buckets)
                        .unwrap();
                    assert_eq!(p.total_wire_bytes, s.wire_bytes);
                    assert_eq!(p.wire_bytes.iter().sum::<usize>(),
                               s.wire_bytes);
                    for (si, (phase, regs)) in s.steps.iter().enumerate() {
                        for reg in regs {
                            // collect this region's pieces across buckets
                            let mut cursor = reg.lo;
                            for k in 0..buckets {
                                let (ph, pieces) = &p.steps[k][si];
                                assert_eq!(ph, phase);
                                for piece in pieces.iter().filter(|x| {
                                    x.src == reg.src && x.dst == reg.dst
                                        && x.lo >= reg.lo && x.hi <= reg.hi
                                }) {
                                    assert_eq!(piece.lo, cursor,
                                               "gap or overlap in pieces");
                                    assert_eq!((piece.lo - reg.lo) % Q8_BLOCK,
                                               0, "piece off the block grid");
                                    // pipeline safety: bucket-k work ends
                                    // at or before the next bucket bound
                                    assert!(piece.hi <= p.bounds[k + 1]);
                                    cursor = piece.hi;
                                }
                            }
                            assert_eq!(cursor, reg.hi,
                                       "pieces do not cover the region");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn modeled_overlap_beats_serial_for_multi_bucket_multi_rank() {
        let t = TimingModel::default();
        for n in [2usize, 4, 8] {
            for buckets in [2usize, 3, 5] {
                let p = BucketPlan::build(&[4096, 1111], n, StateDtype::Q8,
                                          buckets)
                    .unwrap();
                let serial = p.modeled_seconds(&t, n, false);
                let ovl = p.modeled_seconds(&t, n, true);
                assert!(ovl < serial,
                        "overlap {ovl} !< serial {serial} (n={n}, b={buckets})");
                // ...and overlap can never beat the hop critical path
                let hops: f64 = p
                    .wire_bytes
                    .iter()
                    .map(|&w| t.exchange_seconds(w, n))
                    .sum();
                assert!(ovl >= hops);
            }
        }
        // single bucket: the two figures coincide (nothing to overlap)
        let p = BucketPlan::build(&[4096], 4, StateDtype::F32, 1).unwrap();
        let s = p.modeled_seconds(&TimingModel::default(), 4, false);
        let o = p.modeled_seconds(&TimingModel::default(), 4, true);
        assert_eq!(s, o);
    }

    #[test]
    fn modeled_seconds_hand_numbers() {
        // bw 100 B/s, lat 0, stage 50 B/s; 2 ranks, 2 buckets of 64
        // elements each. hop_k = wire/(n·bw) = 512/200; stage_k =
        // 2·64·4/50 = 10.24
        let t = TimingModel {
            link_bandwidth: 100.0,
            hop_latency: 0.0,
            stage_bandwidth: 50.0,
        };
        let p = BucketPlan::build(&[128], 2, StateDtype::F32, 2).unwrap();
        assert_eq!(p.wire_bytes, vec![512, 512]);
        let h = 512.0 / 200.0;
        let s = 10.24;
        let serial = p.modeled_seconds(&t, 2, false);
        assert!((serial - (s + h + s + h)).abs() < 1e-9, "{serial}");
        let ovl = p.modeled_seconds(&t, 2, true);
        assert!((ovl - (s + h.max(s) + h)).abs() < 1e-9, "{ovl}");
    }
}
