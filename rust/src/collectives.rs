//! Simulated collectives for the data-parallel coordinator.
//!
//! The paper trains data-parallel on 4×4 / 8×8 TPU-v2 pods; gradients are
//! all-reduced across cores every step. This environment has one CPU, so
//! the coordinator runs workers as threads and reduces their gradients
//! through this module, which implements a *real chunked ring all-reduce*
//! (reduce-scatter + all-gather over N ranks, the classic 2(N−1)/N-bytes
//! schedule) rather than a naive sum — both so the arithmetic matches a
//! pod run (same reduction order ⇒ same floating-point result every run)
//! and so the attached [`TimingModel`] can report what each step *would*
//! cost on TPU-pod interconnect for the wall-time experiments.

use crate::tensor::Tensor;

/// Ring all-reduce (sum) over per-rank flat gradient buffers, in place.
/// All buffers must be the same length. After the call every rank holds
/// the elementwise sum.
pub fn ring_allreduce(ranks: &mut [Vec<f32>]) {
    let n = ranks.len();
    assert!(n > 0);
    if n == 1 {
        return;
    }
    let len = ranks[0].len();
    assert!(ranks.iter().all(|r| r.len() == len));
    // chunk boundaries (chunk c: [starts[c], starts[c+1]))
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    // reduce-scatter: step s, rank r sends chunk (r - s) to rank r+1
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            // dst += src  on chunk c — split borrow via split_at_mut
            let (a, b) = if src < dst {
                let (left, right) = ranks.split_at_mut(dst);
                (&left[src], &mut right[0])
            } else {
                let (left, right) = ranks.split_at_mut(src);
                (&right[0], &mut left[dst])
            };
            for k in lo..hi {
                b[k] += a[k];
            }
        }
    }
    // all-gather: step s, rank r sends its completed chunk (r + 1 - s)
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = if src < dst {
                let (left, right) = ranks.split_at_mut(dst);
                (&left[src], &mut right[0])
            } else {
                let (left, right) = ranks.split_at_mut(src);
                (&right[0], &mut left[dst])
            };
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
}

/// All-reduce tensors leaf-by-leaf and average (data-parallel gradient
/// combine). Every rank's tensor list is updated to the mean.
pub fn allreduce_mean(ranks: &mut [Vec<Tensor>]) {
    let n = ranks.len();
    if n == 1 {
        return;
    }
    let leaves = ranks[0].len();
    for leaf in 0..leaves {
        let mut flat: Vec<Vec<f32>> = ranks
            .iter()
            .map(|r| r[leaf].data().to_vec())
            .collect();
        ring_allreduce(&mut flat);
        let inv = 1.0 / n as f32;
        for (r, f) in ranks.iter_mut().zip(flat) {
            let dst = r[leaf].data_mut();
            for (d, s) in dst.iter_mut().zip(f) {
                *d = s * inv;
            }
        }
    }
}

/// Interconnect timing model (TPU-v2 pod defaults).
#[derive(Debug, Clone)]
pub struct TimingModel {
    /// per-link bandwidth, bytes/s
    pub link_bandwidth: f64,
    /// per-hop latency, seconds
    pub hop_latency: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        // TPU-v2 ICI: ~60 GB/s per link, ~1 µs hop latency
        Self { link_bandwidth: 60e9, hop_latency: 1e-6 }
    }
}

impl TimingModel {
    /// Estimated wall time of a ring all-reduce of `bytes` over `n` ranks:
    /// 2(n−1) steps, each moving `bytes/n` per link.
    pub fn allreduce_seconds(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64
            * (self.hop_latency + bytes as f64 / n as f64 / self.link_bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn allreduce_sums_exactly() {
        for n in [2usize, 3, 4, 7] {
            for len in [1usize, 5, 16, 33] {
                let mut rng = Rng::new(42);
                let data: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect();
                let expect: Vec<f32> = (0..len)
                    .map(|k| data.iter().map(|r| r[k]).sum())
                    .collect();
                let mut ranks = data.clone();
                ring_allreduce(&mut ranks);
                for r in &ranks {
                    for (a, e) in r.iter().zip(&expect) {
                        assert!((a - e).abs() < 1e-4,
                                "n={n} len={len}: {a} vs {e}");
                    }
                }
            }
        }
    }

    #[test]
    fn allreduce_deterministic_order() {
        // same inputs => bitwise identical outputs across calls
        let mut rng = Rng::new(1);
        let data: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..100).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut a = data.clone();
        let mut b = data;
        ring_allreduce(&mut a);
        ring_allreduce(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn mean_combine() {
        let t = |v: f32| Tensor::full(&[3], v);
        let mut ranks = vec![vec![t(1.0)], vec![t(3.0)]];
        allreduce_mean(&mut ranks);
        for r in &ranks {
            assert_eq!(r[0], t(2.0));
        }
    }

    #[test]
    fn single_rank_is_noop() {
        let mut ranks = vec![vec![1.0f32, 2.0]];
        ring_allreduce(&mut ranks);
        assert_eq!(ranks[0], vec![1.0, 2.0]);
    }

    #[test]
    fn timing_scales_with_ranks_and_bytes() {
        let t = TimingModel::default();
        let small = t.allreduce_seconds(1 << 20, 4);
        let big = t.allreduce_seconds(1 << 24, 4);
        assert!(big > small);
        // bandwidth-bound regime: time approaches 2·bytes/bw independent
        // of n for large n
        let t16 = t.allreduce_seconds(1 << 30, 16);
        let t64 = t.allreduce_seconds(1 << 30, 64);
        assert!((t16 / t64 - 1.0).abs() < 0.1, "{t16} vs {t64}");
    }
}
