//! Reference collectives: the serial, clone-per-leaf ring all-reduce.
//!
//! This module is the *oracle*, not the production path. The trainer's
//! gradient exchange goes through [`crate::comms`] — persistent flat
//! buffers, compressed wire payloads, error feedback, thread-parallel
//! execution — whose f32 path is property-tested bitwise against the
//! functions here (the two share the exact chunk partition and
//! accumulation order, so they cannot drift apart silently).
//!
//! [`ring_allreduce`] implements the classic chunked ring schedule
//! (reduce-scatter + all-gather over N ranks, the 2(N−1)/N-bytes plan)
//! rather than a naive sum, both so the arithmetic matches a pod run
//! (fixed reduction order ⇒ same floating-point result every run) and
//! so tests have an independently-written reference for the `comms`
//! ring. The [`TimingModel`] that estimates pod interconnect cost lives
//! in `comms` now (where it is load-bearing: it feeds the trainer's
//! `comm_ms` column) and is re-exported here for compatibility.
//!
//! Mismatched rank geometries are **errors**, not panics — a worker
//! handing over a short gradient list surfaces as a step failure the
//! trainer propagates, like every other `anyhow::Result` on that path.

use crate::tensor::Tensor;
use anyhow::{ensure, Result};

pub use crate::comms::TimingModel;

/// Ring all-reduce (sum) over per-rank flat gradient buffers, in place.
/// All buffers must be the same length; after the call every rank holds
/// the elementwise sum. Errors on an empty rank list or mismatched
/// buffer lengths.
pub fn ring_allreduce(ranks: &mut [Vec<f32>]) -> Result<()> {
    let n = ranks.len();
    ensure!(n > 0, "ring_allreduce needs at least one rank");
    if n == 1 {
        return Ok(());
    }
    let len = ranks[0].len();
    for (r, buf) in ranks.iter().enumerate() {
        ensure!(buf.len() == len,
                "rank {r} buffer has {} elements, rank 0 has {len}",
                buf.len());
    }
    // chunk boundaries (chunk c: [starts[c], starts[c+1]))
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    // reduce-scatter: step s, rank r sends chunk (r - s) to rank r+1
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            // dst += src  on chunk c — split borrow via split_at_mut
            let (a, b) = if src < dst {
                let (left, right) = ranks.split_at_mut(dst);
                (&left[src], &mut right[0])
            } else {
                let (left, right) = ranks.split_at_mut(src);
                (&right[0], &mut left[dst])
            };
            for k in lo..hi {
                b[k] += a[k];
            }
        }
    }
    // all-gather: step s, rank r sends its completed chunk (r + 1 - s)
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = if src < dst {
                let (left, right) = ranks.split_at_mut(dst);
                (&left[src], &mut right[0])
            } else {
                let (left, right) = ranks.split_at_mut(src);
                (&right[0], &mut left[dst])
            };
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
    Ok(())
}

/// All-reduce tensors leaf-by-leaf and average (data-parallel gradient
/// combine). Every rank's tensor list is updated to the mean. Errors on
/// mismatched leaf counts or leaf lengths across ranks; all geometry is
/// validated **before** any leaf is reduced, so an error leaves every
/// buffer untouched (the same contract as `ring_allreduce`).
pub fn allreduce_mean(ranks: &mut [Vec<Tensor>]) -> Result<()> {
    let n = ranks.len();
    ensure!(n > 0, "allreduce_mean needs at least one rank");
    if n == 1 {
        return Ok(());
    }
    let leaves = ranks[0].len();
    for (r, list) in ranks.iter().enumerate() {
        ensure!(list.len() == leaves,
                "rank {r} has {} gradient leaves, rank 0 has {leaves}",
                list.len());
        for (leaf, t) in list.iter().enumerate() {
            ensure!(t.len() == ranks[0][leaf].len(),
                    "rank {r} leaf {leaf} has {} elements, rank 0 has {}",
                    t.len(), ranks[0][leaf].len());
        }
    }
    for leaf in 0..leaves {
        let mut flat: Vec<Vec<f32>> = ranks
            .iter()
            .map(|r| r[leaf].data().to_vec())
            .collect();
        ring_allreduce(&mut flat)
            .map_err(|e| e.context(format!("leaf {leaf}")))?;
        let inv = 1.0 / n as f32;
        for (r, f) in ranks.iter_mut().zip(flat) {
            let dst = r[leaf].data_mut();
            for (d, s) in dst.iter_mut().zip(f) {
                *d = s * inv;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn allreduce_sums_exactly() {
        for n in [2usize, 3, 4, 7] {
            for len in [1usize, 5, 16, 33] {
                let mut rng = Rng::new(42);
                let data: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect();
                let expect: Vec<f32> = (0..len)
                    .map(|k| data.iter().map(|r| r[k]).sum())
                    .collect();
                let mut ranks = data.clone();
                ring_allreduce(&mut ranks).unwrap();
                for r in &ranks {
                    for (a, e) in r.iter().zip(&expect) {
                        assert!((a - e).abs() < 1e-4,
                                "n={n} len={len}: {a} vs {e}");
                    }
                }
            }
        }
    }

    #[test]
    fn allreduce_deterministic_order() {
        // same inputs => bitwise identical outputs across calls
        let mut rng = Rng::new(1);
        let data: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..100).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut a = data.clone();
        let mut b = data;
        ring_allreduce(&mut a).unwrap();
        ring_allreduce(&mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn mean_combine() {
        let t = |v: f32| Tensor::full(&[3], v);
        let mut ranks = vec![vec![t(1.0)], vec![t(3.0)]];
        allreduce_mean(&mut ranks).unwrap();
        for r in &ranks {
            assert_eq!(r[0], t(2.0));
        }
    }

    #[test]
    fn single_rank_is_noop() {
        let mut ranks = vec![vec![1.0f32, 2.0]];
        ring_allreduce(&mut ranks).unwrap();
        assert_eq!(ranks[0], vec![1.0, 2.0]);
    }

    /// Regression (ISSUE 5 satellite): mismatched geometries are errors
    /// with a message naming the offender — not assert panics.
    #[test]
    fn mismatched_rank_geometry_is_an_error() {
        let mut empty: Vec<Vec<f32>> = Vec::new();
        assert!(ring_allreduce(&mut empty).is_err());
        let mut ranks = vec![vec![1.0f32, 2.0], vec![3.0f32]];
        let err = ring_allreduce(&mut ranks).unwrap_err();
        assert!(err.to_string().contains("rank 1"), "{err}");
        // the original buffers must be untouched on error
        assert_eq!(ranks[0], vec![1.0, 2.0]);

        let mut empty: Vec<Vec<Tensor>> = Vec::new();
        assert!(allreduce_mean(&mut empty).is_err());
        let mut ranks = vec![vec![Tensor::full(&[2], 1.0)],
                             vec![Tensor::full(&[2], 1.0),
                                  Tensor::full(&[2], 1.0)]];
        let err = allreduce_mean(&mut ranks).unwrap_err();
        assert!(err.to_string().contains("rank 1"), "{err}");
        // mismatched leaf *lengths* inside matching leaf counts —
        // detected up front, so EVERY leaf (including well-formed ones
        // ordered before the offender) is left untouched
        let mut ranks = vec![vec![Tensor::full(&[2], 1.0),
                                  Tensor::full(&[2], 1.0)],
                             vec![Tensor::full(&[2], 3.0),
                                  Tensor::full(&[3], 3.0)]];
        let err = allreduce_mean(&mut ranks).unwrap_err();
        assert!(format!("{err:#}").contains("leaf 1"), "{err:#}");
        assert_eq!(ranks[0][0], Tensor::full(&[2], 1.0));
        assert_eq!(ranks[1][0], Tensor::full(&[2], 3.0));
    }
}
