//! Checkpointing: a self-describing binary format for named tensors.
//!
//! Used for (a) the initial parameters exported by `aot.py` (so Rust and
//! JAX train from bit-identical initializations), and (b) training
//! save/restore of params + optimizer state.
//!
//! Two on-disk versions (DESIGN.md §8):
//!
//! ```text
//! v1  magic   8 bytes   "SM3CKPT1"
//!     count   u32
//!     entry*  name_len u32, name bytes (utf-8),
//!             rank u32, dims u64 × rank,
//!             f32 data × Π dims
//!
//! v2  magic   8 bytes   "SM3CKPT2"
//!     count   u32
//!     entry*  name_len u32, name bytes (utf-8),
//!             dtype u8 (0 = f32, 1 = bf16, 2 = q8),
//!             rank u32, dims u64 × rank,
//!             payload:
//!               f32  → 4·n bytes (f32 LE)
//!               bf16 → 2·n bytes (u16 LE)
//!               q8   → ⌈n/64⌉ f32 LE block scales, then n u8 codes
//! ```
//!
//! Loading always yields f32 tensors (quantized payloads are decoded);
//! [`load_tagged`] additionally reports each entry's storage dtype. v1
//! files keep loading forever — [`load`] sniffs the magic. Saving an
//! already-quantized tensor (one read out of a `QSlot`) with its own
//! dtype tag is lossless: the codecs are idempotent (`optim::qstate`),
//! so save→load→save round-trips bit-for-bit. Integer-valued scalar
//! slots (Adam's `t`) should be tagged f32 by the caller.
//!
//! The parser reads the whole file once and validates every entry's
//! declared size against the bytes actually present *before* allocating
//! tensor storage — a truncated or corrupt file fails with a message
//! instead of requesting an absurd allocation. (Deliberate tradeoff: the
//! slurp doubles transient peak memory during the one-shot load vs the
//! old streaming reader; a streaming validator against the file-metadata
//! length can restore that if checkpoint sizes ever make it matter.)

use crate::optim::qstate::{codec, StateDtype};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

const MAGIC_V1: &[u8; 8] = b"SM3CKPT1";
const MAGIC_V2: &[u8; 8] = b"SM3CKPT2";

/// Longest accepted tensor name (matches the v1 format's historic cap).
const MAX_NAME_LEN: usize = 4096;
/// Highest accepted tensor rank (SM3 axis slots rely on this cap).
const MAX_RANK: usize = 8;

/// Write named tensors to `path` in the v1 (all-f32) format — the
/// interchange format `aot.py` produces.
pub fn save(path: impl AsRef<Path>, entries: &[(String, &Tensor)])
            -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("{path:?}"))?);
    w.write_all(MAGIC_V1)?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, t) in entries {
        write_entry_header(&mut w, name, t)?;
        for &v in t.data() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Write named tensors to `path` in the v2 format, encoding each entry's
/// payload at its tag's precision.
pub fn save_v2(path: impl AsRef<Path>,
               entries: &[(String, &Tensor, StateDtype)]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("{path:?}"))?);
    w.write_all(MAGIC_V2)?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    let (mut scales, mut codes) = (Vec::new(), Vec::new());
    for (name, t, dtype) in entries {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&[dtype.tag()])?;
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        match dtype {
            StateDtype::F32 => {
                for &v in t.data() {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            StateDtype::Bf16 => {
                for &v in t.data() {
                    w.write_all(&codec::f32_to_bf16(v).to_le_bytes())?;
                }
            }
            StateDtype::Q8 => {
                codec::q8_encode_into(t.data(), &mut scales, &mut codes);
                for &s in &scales {
                    w.write_all(&s.to_le_bytes())?;
                }
                w.write_all(&codes)?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

fn write_entry_header(w: &mut impl Write, name: &str, t: &Tensor)
                      -> Result<()> {
    let nb = name.as_bytes();
    w.write_all(&(nb.len() as u32).to_le_bytes())?;
    w.write_all(nb)?;
    w.write_all(&(t.rank() as u32).to_le_bytes())?;
    for &d in t.shape() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

/// Byte-slice cursor: every read is bounds-checked against the bytes the
/// file actually contains, so declared sizes can never drive allocation.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("corrupt checkpoint: {what} needs {n} bytes but only {} \
                   remain in the file", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6],
                               b[7]]))
    }
}

/// Load all named tensors from `path` (in file order), v1 or v2; v2
/// payloads are dequantized to f32.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    Ok(load_tagged(path)?
        .into_iter()
        .map(|(name, t, _)| (name, t))
        .collect())
}

/// Load with each entry's storage dtype (always `F32` for v1 files).
pub fn load_tagged(path: impl AsRef<Path>)
                   -> Result<Vec<(String, Tensor, StateDtype)>> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("{path:?}"))?;
    parse(&bytes).with_context(|| format!("{path:?}"))
}

fn parse(bytes: &[u8]) -> Result<Vec<(String, Tensor, StateDtype)>> {
    let mut cur = Cursor { buf: bytes, pos: 0 };
    let magic = cur.take(8, "magic")?;
    let versioned = if magic == MAGIC_V1 {
        false
    } else if magic == MAGIC_V2 {
        true
    } else {
        bail!("bad magic (not an SM3 checkpoint)");
    };
    let count = cur.u32("entry count")? as usize;
    // each entry needs at least name_len + rank (+ dtype tag in v2)
    let min_entry = if versioned { 9 } else { 8 };
    if count.saturating_mul(min_entry) > cur.remaining() {
        bail!("corrupt checkpoint: {count} entries declared but only {} \
               bytes follow the header", cur.remaining());
    }
    let mut out = Vec::with_capacity(count);
    for e in 0..count {
        let (name, tensor, dtype) = parse_entry(&mut cur, versioned)
            .with_context(|| format!("entry {e}"))?;
        out.push((name, tensor, dtype));
    }
    Ok(out)
}

fn parse_entry(cur: &mut Cursor, versioned: bool)
               -> Result<(String, Tensor, StateDtype)> {
    let name_len = cur.u32("name length")? as usize;
    if name_len > MAX_NAME_LEN {
        bail!("corrupt checkpoint: name length {name_len}");
    }
    let name = String::from_utf8(cur.take(name_len, "tensor name")?.to_vec())
        .context("tensor name not utf-8")?;
    let dtype = if versioned {
        StateDtype::from_tag(cur.u8("dtype tag")?)?
    } else {
        StateDtype::F32
    };
    let rank = cur.u32("rank")? as usize;
    if rank > MAX_RANK {
        bail!("corrupt checkpoint: rank {rank}");
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        let d = cur.u64("dimension")?;
        // explicit narrowing: `as usize` would silently truncate a corrupt
        // dim like 2^32+2 to 2 on a 32-bit target and dodge the checks
        let d: usize = d.try_into().map_err(|_| anyhow::anyhow!(
            "corrupt checkpoint: dimension {d} exceeds this platform's \
             address space"))?;
        shape.push(d);
    }
    // Validate the declared element count against the bytes actually
    // present BEFORE allocating anything: a corrupt dims vector must not
    // drive a huge (or overflowing) allocation request.
    let n = shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!(
            "corrupt checkpoint: dims {shape:?} overflow the element count"))?;
    let payload = payload_bytes(n, dtype).ok_or_else(|| anyhow::anyhow!(
        "corrupt checkpoint: dims {shape:?} overflow the payload size"))?;
    if payload > cur.remaining() {
        bail!("corrupt checkpoint: tensor {name:?} ({n} elements as {}) \
               declares {payload} payload bytes but only {} remain",
              dtype.name(), cur.remaining());
    }
    let data = match dtype {
        StateDtype::F32 => cur.take(payload, "f32 payload")?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
        StateDtype::Bf16 => cur.take(payload, "bf16 payload")?
            .chunks_exact(2)
            .map(|c| codec::bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect(),
        StateDtype::Q8 => {
            let nblocks = codec::q8_blocks(n);
            let scales: Vec<f32> = cur.take(nblocks * 4, "q8 scales")?
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let codes = cur.take(n, "q8 codes")?;
            let mut vals = Vec::new();
            codec::q8_decode_into(&scales, codes, &mut vals);
            vals
        }
    };
    Ok((name, Tensor::from_vec(&shape, data), dtype))
}

/// Payload bytes for `n` elements at `dtype`, `None` on overflow.
fn payload_bytes(n: usize, dtype: StateDtype) -> Option<usize> {
    match dtype {
        StateDtype::F32 => n.checked_mul(4),
        StateDtype::Bf16 => n.checked_mul(2),
        StateDtype::Q8 => codec::q8_blocks(n).checked_mul(4)?.checked_add(n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::qstate::QSlot;
    use crate::proptest::{forall, gen};
    use crate::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sm3_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let b = Tensor::randn(&[7], 1.0, &mut rng);
        let scalar = Tensor::from_vec(&[], vec![42.0]);
        let path = tmpfile("roundtrip.ckpt");
        save(&path, &[("a".into(), &a), ("b/c".into(), &b),
                      ("t".into(), &scalar)]).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, "a");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].0, "b/c");
        assert_eq!(loaded[1].1, b);
        assert_eq!(loaded[2].1.shape(), &[] as &[usize]);
    }

    #[test]
    fn v2_roundtrip_all_dtypes_on_quantized_data() {
        // values that already live in a QSlot (i.e. one quantization deep)
        // must round-trip through save_v2/load bit-for-bit
        let mut rng = Rng::new(5);
        let raw = Tensor::randn(&[6, 21], 2.0, &mut rng);
        let path = tmpfile("v2_roundtrip.ckpt");
        for dtype in StateDtype::ALL {
            let slot = QSlot::from_f32(dtype, raw.data());
            let t = Tensor::from_vec(&[6, 21], slot.to_vec());
            save_v2(&path, &[("x".into(), &t, dtype)]).unwrap();
            let loaded = load_tagged(&path).unwrap();
            assert_eq!(loaded.len(), 1);
            assert_eq!(loaded[0].2, dtype);
            assert_eq!(loaded[0].1.shape(), &[6, 21]);
            for (a, b) in t.data().iter().zip(loaded[0].1.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}");
            }
        }
    }

    /// ISSUE 4 acceptance: a clip + weight-decay pipeline over Adam
    /// (q8 state) round-trips through an `SM3CKPT2` file exactly the way
    /// the trainer writes one — transform slots (`tx_step`/`tx_norm`)
    /// lead the layout as f32-tagged scalars, slot tensors carry the
    /// engine dtype, and a fresh pipeline restored from the file
    /// continues bit-identically to the original.
    #[test]
    fn transform_pipeline_roundtrips_through_v2() {
        use crate::optim::{OptimSpec, Optimizer, ParamSpec};
        let specs = vec![ParamSpec::new("emb", &[12, 6]),
                        ParamSpec::new("b", &[70])];
        let build = || {
            OptimSpec::named("adam").unwrap()
                .clip_by_global_norm(1.0)
                .weight_decay(0.01)
                .state_dtype(StateDtype::Q8)
                .build(&specs)
                .unwrap()
        };
        let mut opt = build();
        let mut rng = Rng::new(31);
        let mut params: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        for _ in 0..3 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            opt.step(&mut params, &grads, 0.1);
        }
        // trainer tagging rule: scalar slots f32, the rest engine dtype
        let dtype = opt.state_dtype();
        let state = opt.state();
        assert_eq!((state[0].0, state[0].1), (0, "tx_step"));
        assert_eq!((state[1].0, state[1].1), (0, "tx_norm"));
        let named: Vec<(String, Tensor, StateDtype)> = state
            .into_iter()
            .map(|(leaf, slot, t)| {
                let tag = if t.len() <= 1 { StateDtype::F32 } else { dtype };
                (format!("opt/{leaf}/{slot}"), t, tag)
            })
            .collect();
        let entries: Vec<(String, &Tensor, StateDtype)> = named
            .iter()
            .map(|(n, t, d)| (n.clone(), t, *d))
            .collect();
        let path = tmpfile("pipeline_v2.ckpt");
        save_v2(&path, &entries).unwrap();
        let loaded = load_tagged(&path).unwrap();
        assert_eq!(loaded.len(), entries.len());
        assert_eq!(loaded[0].0, "opt/0/tx_step");
        // scalar slots (tx_step, tx_norm, Adam's t) stay f32; the real
        // state tensors carry the engine dtype
        for (n, t, d) in &loaded {
            let expect = if t.len() <= 1 { StateDtype::F32 }
                         else { StateDtype::Q8 };
            assert_eq!(*d, expect, "{n}");
        }
        // restore into a fresh pipeline; trajectories must not diverge
        let mut fresh = build();
        fresh.load_state(loaded.into_iter().map(|(_, t, _)| t).collect())
            .unwrap();
        let mut pb = params.clone();
        for _ in 0..2 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            opt.step(&mut params, &grads, 0.1);
            fresh.step(&mut pb, &grads, 0.1);
        }
        for (a, b) in params.iter().zip(&pb) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
            }
        }
    }

    /// ISSUE 9 acceptance: a checkpoint whose stitched split-leaf slot
    /// carries the wrong geometry must surface an `anyhow` error naming
    /// the leaf and the expected element count — not panic — and must do
    /// so through a real `SM3CKPT2` file, exactly the path the trainer's
    /// restore takes.
    #[test]
    fn malformed_stitched_geometry_is_an_error_not_a_panic() {
        use crate::optim::{OptimSpec, Optimizer, ParamSpec};
        // `emb` dominates the total, so the IntraLeaf default splits it
        // across the 4 workers; `b` stays whole.
        let specs = vec![ParamSpec::new("emb", &[4096]),
                        ParamSpec::new("b", &[64])];
        let build = || {
            OptimSpec::named("adagrad").unwrap()
                .threads(4)
                .build(&specs)
                .unwrap()
        };
        let mut opt = build();
        let mut rng = Rng::new(17);
        let mut params: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        opt.step(&mut params, &grads, 0.1);
        // save exactly the way the trainer does (scalar slots f32)
        let dtype = opt.state_dtype();
        let named: Vec<(String, Tensor, StateDtype)> = opt
            .state()
            .into_iter()
            .map(|(leaf, slot, t)| {
                let tag = if t.len() <= 1 { StateDtype::F32 } else { dtype };
                (format!("opt/{leaf}/{slot}"), t, tag)
            })
            .collect();
        let entries: Vec<(String, &Tensor, StateDtype)> = named
            .iter()
            .map(|(n, t, d)| (n.clone(), t, *d))
            .collect();
        let path = tmpfile("malformed_stitch.ckpt");
        save_v2(&path, &entries).unwrap();
        let mut loaded = load_tagged(&path).unwrap();
        assert_eq!(loaded.len(), entries.len());
        // tamper: swap the stitched 4096-element slot for a 7-element
        // tensor. The tensor COUNT stays right, so the fast pre-count
        // check passes and the per-slot geometry ensure must fire.
        let idx = loaded
            .iter()
            .position(|(_, t, _)| t.len() == specs[0].numel())
            .expect("stitched emb slot present in checkpoint");
        loaded[idx].1 = Tensor::zeros(&[7]);
        let mut fresh = build();
        let err = fresh
            .load_state(loaded.into_iter().map(|(_, t, _)| t).collect())
            .unwrap_err()
            .to_string();
        assert!(err.contains("split leaf"), "unexpected error: {err}");
        assert!(err.contains("emb"), "error must name the leaf: {err}");
        assert!(err.contains("4096"),
                "error must name the expected layout: {err}");
        // and the wrong-count shape still fails fast with the layout error
        let mut fresh2 = build();
        let err2 = fresh2.load_state(Vec::new()).unwrap_err().to_string();
        assert!(err2.contains("state layout mismatch"),
                "unexpected error: {err2}");
    }

    /// SM3CKPT1 → SM3CKPT2 cross-version round-trip: a state saved v1
    /// loads, re-saves as v2 (f32 tags), and loads bit-identically.
    #[test]
    fn cross_version_roundtrip() {
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[70], 1.0, &mut rng);
        let p1 = tmpfile("cross_v1.ckpt");
        let p2 = tmpfile("cross_v2.ckpt");
        save(&p1, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let v1 = load_tagged(&p1).unwrap();
        assert!(v1.iter().all(|(_, _, d)| *d == StateDtype::F32));
        let entries: Vec<(String, &Tensor, StateDtype)> = v1
            .iter()
            .map(|(n, t, d)| (n.clone(), t, *d))
            .collect();
        save_v2(&p2, &entries).unwrap();
        let v2 = load_tagged(&p2).unwrap();
        assert_eq!(v1.len(), v2.len());
        for ((n1, t1, d1), (n2, t2, d2)) in v1.iter().zip(&v2) {
            assert_eq!(n1, n2);
            assert_eq!(d1, d2);
            assert_eq!(t1.shape(), t2.shape());
            for (x, y) in t1.data().iter().zip(t2.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// Property: cross-version equality over random shapes/payloads, and
    /// v2 q8 save→load→save is byte-stable on disk.
    #[test]
    fn prop_cross_version_and_q8_stability() {
        let p1 = tmpfile("prop_v1.ckpt");
        let p2 = tmpfile("prop_v2.ckpt");
        let p3 = tmpfile("prop_v2b.ckpt");
        forall("ckpt v1 == v2(f32), q8 stable", |rng| {
            let shape = gen::shape(rng, 3, 9);
            let n: usize = shape.iter().product();
            (shape, gen::grad_vec(rng, n, 1.0))
        }, |(shape, vals)| {
            let t = Tensor::from_vec(shape, vals.clone());
            let run = || -> Result<()> {
                save(&p1, &[("w".into(), &t)])?;
                save_v2(&p2, &[("w".into(), &t, StateDtype::F32)])?;
                let a = load(&p1)?;
                let b = load(&p2)?;
                for (x, y) in a[0].1.data().iter().zip(b[0].1.data()) {
                    if x.to_bits() != y.to_bits() {
                        bail!("v1/v2 f32 mismatch: {x} vs {y}");
                    }
                }
                // q8: one save→load cycle, then a second save must emit
                // the identical bytes (codec idempotence end to end)
                save_v2(&p2, &[("w".into(), &t, StateDtype::Q8)])?;
                let q = load(&p2)?;
                save_v2(&p3, &[("w".into(), &q[0].1, StateDtype::Q8)])?;
                if std::fs::read(&p2)? != std::fs::read(&p3)? {
                    bail!("q8 re-save changed bytes");
                }
                Ok(())
            };
            run().map_err(|e| e.to_string())
        });
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad.ckpt");
        std::fs::write(&path, b"NOTAMAGIC???").unwrap();
        assert!(load(&path).is_err());
        // too short for any magic
        std::fs::write(&path, b"SM3").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        for (name, v2) in [("trunc1.ckpt", false), ("trunc2.ckpt", true)] {
            let path = tmpfile(name);
            if v2 {
                save_v2(&path, &[("a".into(), &a, StateDtype::Q8)]).unwrap();
            } else {
                save(&path, &[("a".into(), &a)]).unwrap();
            }
            let bytes = std::fs::read(&path).unwrap();
            std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
            let err = load(&path).unwrap_err();
            assert!(format!("{err:#}").contains("corrupt checkpoint"),
                    "{err:#}");
        }
    }

    /// Regression (ISSUE 2 satellite): a corrupt rank field fails with a
    /// message instead of running off the format.
    #[test]
    fn rejects_bad_rank() {
        let path = tmpfile("bad_rank.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SM3CKPT1");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one entry
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len 1
        bytes.push(b'w');
        bytes.extend_from_slice(&9u32.to_le_bytes()); // rank 9 > cap 8
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("rank 9"), "{err:#}");
    }

    /// Regression (ISSUE 2 satellite): oversized dims must be rejected by
    /// the byte-budget check before any allocation happens — both the
    /// overflow case and the "huge but representable" case.
    #[test]
    fn rejects_oversized_dims_without_allocating() {
        for dims in [
            // product overflows usize
            vec![u64::MAX / 2, 16],
            // representable product (2^40 elements ⇒ 4 TiB payload)
            vec![1u64 << 20, 1 << 20],
        ] {
            let path = tmpfile("oversized.ckpt");
            let mut bytes = Vec::new();
            bytes.extend_from_slice(b"SM3CKPT1");
            bytes.extend_from_slice(&1u32.to_le_bytes());
            bytes.extend_from_slice(&1u32.to_le_bytes());
            bytes.push(b'w');
            bytes.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in &dims {
                bytes.extend_from_slice(&d.to_le_bytes());
            }
            // a little trailing data so only the size check can reject
            bytes.extend_from_slice(&[0u8; 64]);
            std::fs::write(&path, &bytes).unwrap();
            let err = load(&path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("corrupt checkpoint"), "{dims:?}: {msg}");
        }
        // the v2 q8 path must reject too (its block arithmetic is the
        // overflow-prone one: dim near usize::MAX exercises q8_blocks)
        let path = tmpfile("oversized_q8.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SM3CKPT2");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.push(2); // q8 tag
        bytes.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 64]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt checkpoint"), "{err:#}");
    }

    #[test]
    fn rejects_bad_dtype_tag() {
        let path = tmpfile("bad_tag.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SM3CKPT2");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.push(7); // unknown dtype tag
        bytes.extend_from_slice(&0u32.to_le_bytes()); // rank 0
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("dtype tag"), "{err:#}");
    }

    /// An absurd declared entry count must fail the up-front budget check.
    #[test]
    fn rejects_absurd_entry_count() {
        let path = tmpfile("bad_count.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SM3CKPT1");
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("entries declared"), "{err:#}");
    }

    #[test]
    fn empty_checkpoint() {
        let path = tmpfile("empty.ckpt");
        save(&path, &[]).unwrap();
        assert!(load(&path).unwrap().is_empty());
        let path2 = tmpfile("empty_v2.ckpt");
        save_v2(&path2, &[]).unwrap();
        assert!(load(&path2).unwrap().is_empty());
    }

    /// The v2 q8 encoding actually shrinks the file (~4× for payloads).
    #[test]
    fn v2_q8_file_is_smaller() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let pf = tmpfile("size_f32.ckpt");
        let pq = tmpfile("size_q8.ckpt");
        save_v2(&pf, &[("a".into(), &a, StateDtype::F32)]).unwrap();
        save_v2(&pq, &[("a".into(), &a, StateDtype::Q8)]).unwrap();
        let sf = std::fs::metadata(&pf).unwrap().len() as f64;
        let sq = std::fs::metadata(&pq).unwrap().len() as f64;
        assert!(sf / sq > 3.0, "f32 {sf} vs q8 {sq}");
    }
}
