//! Checkpointing: a self-describing binary format for named tensors.
//!
//! Used for (a) the initial parameters exported by `aot.py` (so Rust and
//! JAX train from bit-identical initializations), and (b) training
//! save/restore of params + optimizer state.
//!
//! Format (little-endian):
//! ```text
//! magic   8 bytes   "SM3CKPT1"
//! count   u32
//! entry*  name_len u32, name bytes (utf-8),
//!         rank u32, dims u64 × rank,
//!         f32 data × Π dims
//! ```

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SM3CKPT1";

/// Write named tensors to `path`.
pub fn save(path: impl AsRef<Path>, entries: &[(String, &Tensor)])
            -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("{path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, t) in entries {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load all named tensors from `path` (in file order).
pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    let path = path.as_ref();
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("{path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic (not an SM3 checkpoint)");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut nb = vec![0u8; name_len];
        r.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name not utf-8")?;
        let rank = read_u32(&mut r)? as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut r)? as usize);
        }
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, Tensor::from_vec(&shape, data)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sm3_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let b = Tensor::randn(&[7], 1.0, &mut rng);
        let scalar = Tensor::from_vec(&[], vec![42.0]);
        let path = tmpfile("roundtrip.ckpt");
        save(&path, &[("a".into(), &a), ("b/c".into(), &b),
                      ("t".into(), &scalar)]).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, "a");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].0, "b/c");
        assert_eq!(loaded[1].1, b);
        assert_eq!(loaded[2].1.shape(), &[] as &[usize]);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad.ckpt");
        std::fs::write(&path, b"NOTAMAGIC???").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let path = tmpfile("trunc.ckpt");
        save(&path, &[("a".into(), &a)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn empty_checkpoint() {
        let path = tmpfile("empty.ckpt");
        save(&path, &[]).unwrap();
        assert!(load(&path).unwrap().is_empty());
    }
}
