//! Minimal dense f32 tensor substrate.
//!
//! The optimizer bank, trace capture, and checkpointing operate on host
//! tensors; this module provides exactly the operations they need (shape
//! bookkeeping, elementwise ops, axis reductions, the broadcast-min over
//! co-dim-1 accumulators) without an external ndarray dependency (the
//! registry is offline). Row-major (C) layout throughout, matching XLA's
//! default literal layout so buffers round-trip with zero copies.

use std::fmt;

/// A dense, row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Build from parts; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        Self { shape: shape.to_vec(), data }
    }

    /// N(0, std) random tensor.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rng::Rng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D accessor (debug/test use).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Elementwise in-place map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    /// Elementwise binary op into a new tensor.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| f(a, b))
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Sum of squares (for grad-norm diagnostics).
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Maximum over all axes except `axis` — the co-dim-1 slice reduction.
    /// Returns a vector of length `shape[axis]`.
    pub fn max_over_codim1(&self, axis: usize, f: impl Fn(f32, f32) -> f32,
                           init: f32) -> Vec<f32> {
        assert!(axis < self.rank());
        let n = self.shape[axis];
        let mut out = vec![init; n];
        // stride of `axis` and size of the inner block
        let inner: usize = self.shape[axis + 1..].iter().product();
        let outer: usize = self.shape[..axis].iter().product();
        for o in 0..outer {
            for a in 0..n {
                let base = (o * n + a) * inner;
                let acc = &mut out[a];
                for v in &self.data[base..base + inner] {
                    *acc = f(*acc, *v);
                }
            }
        }
        out
    }

    /// Convenience: max |g| entry (diagnostics).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }
}

/// Index iterator helper: flat index -> index along `axis` for a given shape.
/// Used by the generic-cover code path.
pub fn axis_index(shape: &[usize], flat: usize, axis: usize) -> usize {
    let inner: usize = shape[axis + 1..].iter().product();
    (flat / inner) % shape[axis]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn row_major_at2() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn max_over_codim1_matrix() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 5., 2., 7., 0., 3.]);
        let rows = t.max_over_codim1(0, f32::max, f32::NEG_INFINITY);
        assert_eq!(rows, vec![5.0, 7.0]);
        let cols = t.max_over_codim1(1, f32::max, f32::NEG_INFINITY);
        assert_eq!(cols, vec![7.0, 5.0, 3.0]);
    }

    #[test]
    fn max_over_codim1_rank3() {
        // shape (2,2,2): values 0..8
        let t = Tensor::from_vec(&[2, 2, 2],
                                 (0..8).map(|v| v as f32).collect());
        let a0 = t.max_over_codim1(0, f32::max, f32::NEG_INFINITY);
        assert_eq!(a0, vec![3.0, 7.0]);
        let a1 = t.max_over_codim1(1, f32::max, f32::NEG_INFINITY);
        assert_eq!(a1, vec![5.0, 7.0]);
        let a2 = t.max_over_codim1(2, f32::max, f32::NEG_INFINITY);
        assert_eq!(a2, vec![6.0, 7.0]);
    }

    #[test]
    fn axis_index_math() {
        let shape = [2, 3, 4];
        // flat 17 -> (1, 1, 1)
        assert_eq!(axis_index(&shape, 17, 0), 1);
        assert_eq!(axis_index(&shape, 17, 1), 1);
        assert_eq!(axis_index(&shape, 17, 2), 1);
    }

    #[test]
    fn zip_elementwise() {
        let a = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let b = Tensor::from_vec(&[3], vec![4., 5., 6.]);
        assert_eq!(a.zip(&b, |x, y| x + y).data(), &[5., 7., 9.]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = crate::rng::Rng::new(1);
        let mut r2 = crate::rng::Rng::new(1);
        let a = Tensor::randn(&[4, 4], 1.0, &mut r1);
        let b = Tensor::randn(&[4, 4], 1.0, &mut r2);
        assert_eq!(a, b);
    }
}
