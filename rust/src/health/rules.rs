//! The standard watchdog rules (DESIGN.md §17).
//!
//! Each rule watches one failure mode the telemetry layer can already
//! observe, keeps only plain bookkeeping state, and names itself in the
//! [`Trip`] it returns so reports and abort messages are actionable.

use super::{Severity, StepObs, Trip, WatchdogRule};
use std::collections::VecDeque;

/// Any non-finite gradient or update value this step is an immediate
/// abort-class trip: NaN contamination spreads through the optimizer
/// state and is never survivable. Fed by the `grad/nonfinite` and
/// `opt/update_nonfinite` counters scanned in the chunk-kernel and
/// comm-pack paths.
#[derive(Default)]
pub struct NonFiniteRule;

impl WatchdogRule for NonFiniteRule {
    fn name(&self) -> &'static str {
        "non_finite"
    }

    fn check(&mut self, obs: &StepObs) -> Option<Trip> {
        let total = obs.grad_nonfinite + obs.update_nonfinite;
        if total == 0 {
            return None;
        }
        Some(Trip {
            rule: self.name(),
            severity: Severity::Abort,
            detail: format!(
                "{} non-finite gradient values, {} non-finite updates",
                obs.grad_nonfinite, obs.update_nonfinite
            ),
        })
    }
}

/// Loss divergence over a sliding window: trips when the current loss
/// exceeds `factor` times the window median (and the window is full, so
/// noisy warm-up steps cannot trip it). Median rather than mean keeps a
/// single earlier spike from masking a real blow-up.
pub struct LossDivergenceRule {
    window: VecDeque<f64>,
    capacity: usize,
    factor: f64,
}

impl Default for LossDivergenceRule {
    fn default() -> Self {
        Self::new(20, 3.0)
    }
}

impl LossDivergenceRule {
    /// Window of `capacity` recent losses; trip at `factor` × median.
    pub fn new(capacity: usize, factor: f64) -> Self {
        assert!(capacity >= 2 && factor > 1.0);
        LossDivergenceRule { window: VecDeque::new(), capacity, factor }
    }

    fn median(&self) -> f64 {
        let mut v: Vec<f64> = self.window.iter().copied().collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let mid = v.len() / 2;
        if v.len() % 2 == 1 {
            v[mid]
        } else {
            0.5 * (v[mid - 1] + v[mid])
        }
    }
}

impl WatchdogRule for LossDivergenceRule {
    fn name(&self) -> &'static str {
        "loss_divergence"
    }

    fn check(&mut self, obs: &StepObs) -> Option<Trip> {
        // Non-finite loss is divergence regardless of window state.
        if !obs.loss.is_finite() {
            return Some(Trip {
                rule: self.name(),
                severity: Severity::Abort,
                detail: format!("loss is {}", obs.loss),
            });
        }
        let trip = if self.window.len() == self.capacity {
            let med = self.median();
            // Guard near-zero medians: a loss that small fluctuating is
            // converged noise, not a blow-up.
            if med > 1e-12 && obs.loss > self.factor * med {
                Some(Trip {
                    rule: self.name(),
                    severity: Severity::Abort,
                    detail: format!(
                        "loss {:.4e} exceeds {:.1}x window median {:.4e}",
                        obs.loss, self.factor, med
                    ),
                })
            } else {
                None
            }
        } else {
            None
        };
        // Divergent samples stay out of the window so a sustained
        // blow-up keeps tripping instead of re-normalizing itself.
        if trip.is_none() {
            if self.window.len() == self.capacity {
                self.window.pop_front();
            }
            self.window.push_back(obs.loss);
        }
        trip
    }
}

/// Per-hop stall detection against the calibrated
/// [`TimingModel::from_measured`](crate::comms::TimingModel::from_measured)
/// fit: trips when the step's measured mean hop takes `factor` times the
/// model's prediction. An absolute floor keeps microsecond-scale
/// predictions (tiny quick-run buckets) from tripping on scheduler
/// jitter. Warn-class: a slow link degrades throughput but the math is
/// still right.
pub struct HopStallRule {
    factor: f64,
    floor_ns: f64,
}

impl Default for HopStallRule {
    fn default() -> Self {
        Self::new(8.0, 50_000.0)
    }
}

impl HopStallRule {
    /// Trip when `measured > factor * expected` and
    /// `measured > expected + floor_ns`.
    pub fn new(factor: f64, floor_ns: f64) -> Self {
        assert!(factor > 1.0 && floor_ns >= 0.0);
        HopStallRule { factor, floor_ns }
    }
}

impl WatchdogRule for HopStallRule {
    fn name(&self) -> &'static str {
        "hop_stall"
    }

    fn check(&mut self, obs: &StepObs) -> Option<Trip> {
        let (measured, expected) =
            match (obs.hop_mean_ns, obs.hop_expect_ns) {
                (Some(m), Some(e)) if e > 0.0 => (m, e),
                _ => return None,
            };
        if measured > self.factor * expected
            && measured > expected + self.floor_ns
        {
            return Some(Trip {
                rule: self.name(),
                severity: Severity::Warn,
                detail: format!(
                    "mean hop {:.0}ns exceeds {:.1}x expected {:.0}ns",
                    measured, self.factor, expected
                ),
            });
        }
        None
    }
}

/// Pool-occupancy drift against the static accountant: the PR 9 pool
/// enforces live == accounted at steady state, so occupancy beyond the
/// accountant total plus a tolerance means a leak or an unplanned
/// allocation path. Warn-class: the pool's own debug assertions are the
/// hard gate; this rule makes drift visible on release runs.
pub struct PoolDriftRule {
    tolerance: f64,
}

impl Default for PoolDriftRule {
    fn default() -> Self {
        Self::new(0.25)
    }
}

impl PoolDriftRule {
    /// Trip when `pool > accountant * (1 + tolerance)`.
    pub fn new(tolerance: f64) -> Self {
        assert!(tolerance >= 0.0);
        PoolDriftRule { tolerance }
    }
}

impl WatchdogRule for PoolDriftRule {
    fn name(&self) -> &'static str {
        "pool_drift"
    }

    fn check(&mut self, obs: &StepObs) -> Option<Trip> {
        let (pool, accounted) =
            match (obs.pool_bytes, obs.accountant_bytes) {
                (Some(p), Some(a)) if a > 0 => (p, a),
                _ => return None,
            };
        let ceiling = (accounted as f64) * (1.0 + self.tolerance);
        if (pool as f64) > ceiling {
            return Some(Trip {
                rule: self.name(),
                severity: Severity::Warn,
                detail: format!(
                    "pool occupancy {pool}B exceeds accountant \
                     {accounted}B by more than {:.0}%",
                    self.tolerance * 100.0
                ),
            });
        }
        None
    }
}

/// The standard rule set, in evaluation order.
pub fn standard_rules() -> Vec<Box<dyn WatchdogRule>> {
    vec![
        Box::new(NonFiniteRule),
        Box::new(LossDivergenceRule::default()),
        Box::new(HopStallRule::default()),
        Box::new(PoolDriftRule::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(step: u64, loss: f64) -> StepObs {
        StepObs { step, loss, ..StepObs::default() }
    }

    #[test]
    fn divergence_needs_a_full_window() {
        let mut rule = LossDivergenceRule::new(4, 3.0);
        // Window not yet full: even a huge loss cannot trip.
        assert!(rule.check(&obs(1, 1.0)).is_none());
        assert!(rule.check(&obs(2, 100.0)).is_none());
        assert!(rule.check(&obs(3, 1.0)).is_none());
        assert!(rule.check(&obs(4, 1.0)).is_none());
        // Window [1, 100, 1, 1], median 1.0: 3.5 > 3x trips.
        let trip = rule.check(&obs(5, 3.5)).expect("should trip");
        assert_eq!(trip.rule, "loss_divergence");
        assert_eq!(trip.severity, Severity::Abort);
        // A sustained blow-up keeps tripping (divergent samples are
        // excluded from the window).
        assert!(rule.check(&obs(6, 3.5)).is_some());
    }

    #[test]
    fn nan_loss_trips_divergence_immediately() {
        let mut rule = LossDivergenceRule::default();
        let trip =
            rule.check(&obs(1, f64::NAN)).expect("NaN loss must trip");
        assert_eq!(trip.rule, "loss_divergence");
        assert_eq!(trip.severity, Severity::Abort);
    }

    #[test]
    fn hop_stall_respects_factor_and_floor() {
        let mut rule = HopStallRule::new(8.0, 50_000.0);
        let mut o = obs(1, 1.0);
        // 5x expected: below the factor, no trip.
        o.hop_mean_ns = Some(5_000_000.0);
        o.hop_expect_ns = Some(1_000_000.0);
        assert!(rule.check(&o).is_none());
        // 10x a tiny expected hop: above the factor but inside the
        // jitter floor, no trip.
        o.hop_mean_ns = Some(10_000.0);
        o.hop_expect_ns = Some(1_000.0);
        assert!(rule.check(&o).is_none());
        // 10x a real hop: trips.
        o.hop_mean_ns = Some(10_000_000.0);
        o.hop_expect_ns = Some(1_000_000.0);
        let trip = rule.check(&o).expect("should trip");
        assert_eq!(trip.rule, "hop_stall");
        // No measurements this step: silent.
        o.hop_mean_ns = None;
        assert!(rule.check(&o).is_none());
    }

    #[test]
    fn pool_drift_tolerates_small_overshoot() {
        let mut rule = PoolDriftRule::new(0.25);
        let mut o = obs(1, 1.0);
        o.accountant_bytes = Some(1000);
        o.pool_bytes = Some(1200);
        assert!(rule.check(&o).is_none(), "20% is inside tolerance");
        o.pool_bytes = Some(1300);
        let trip = rule.check(&o).expect("30% should trip");
        assert_eq!(trip.rule, "pool_drift");
        // Missing either side: silent.
        o.accountant_bytes = None;
        assert!(rule.check(&o).is_none());
    }
}
