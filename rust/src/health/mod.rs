//! Run-health watchdogs: pluggable rules evaluated at step boundaries
//! from telemetry the trainer already collects (DESIGN.md §17).
//!
//! The paper's memory-efficient family is exactly the kind of run that
//! must detect instability *online* — Shazeer & Stern (2018) document
//! out-of-date second-moment estimators producing outsized updates —
//! and the ROADMAP's endurance-run scenario (fault injection, rank
//! kill/restore) is blocked on detecting divergence, NaN contamination,
//! and stalls at all. This module closes that gap:
//!
//! * a [`WatchdogRule`] sees one [`StepObs`] per step — loss, the
//!   non-finite counters wired into the chunk-kernel and comm-pack
//!   paths, the step's measured mean ring-hop time against the
//!   [`TimingModel`](crate::comms::TimingModel) fit's prediction, and
//!   live pool occupancy against the static accountant — and returns a
//!   [`Trip`] naming itself when its invariant breaks;
//! * the [`HealthMonitor`] folds every rule's answer into a per-step
//!   [`RunHealth`] verdict that the trainer logs, emits into the JSONL
//!   stream, and — under `[train] health_action = abort` — turns into a
//!   halt with a report naming the tripped rule.
//!
//! Determinism: rules read observations and keep plain bookkeeping
//! (a sliding loss window); they never touch training arithmetic, so a
//! run with health monitoring on is bitwise identical to one with it
//! off, as the proptest gate asserts alongside tracing.

mod rules;

pub use rules::{
    standard_rules, HopStallRule, LossDivergenceRule, NonFiniteRule,
    PoolDriftRule,
};

use crate::json::Json;
use std::collections::BTreeMap;

/// What one step looked like to the watchdogs. Built by the trainer
/// from per-step telemetry snapshot deltas; every field is observable
/// without touching training arithmetic.
#[derive(Clone, Debug, Default)]
pub struct StepObs {
    /// 1-based step index.
    pub step: u64,
    /// The step's training loss.
    pub loss: f64,
    /// `grad/nonfinite` counter delta this step (chunk-kernel tile scan
    /// + comm-pack scan).
    pub grad_nonfinite: u64,
    /// `opt/update_nonfinite` counter delta this step (post-update
    /// parameter tile scan).
    pub update_nonfinite: u64,
    /// Measured mean ring-hop duration this step, ns (reduce + encode +
    /// gather sweeps), when the step exchanged gradients.
    pub hop_mean_ns: Option<f64>,
    /// The calibrated timing model's predicted per-hop duration, ns.
    pub hop_expect_ns: Option<f64>,
    /// Live pool occupancy at the step boundary, bytes.
    pub pool_bytes: Option<u64>,
    /// The static accountant's steady-state total for the same buffers.
    pub accountant_bytes: Option<u64>,
}

/// How bad a tripped rule is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Degraded but survivable (a stalled hop, pool drift) — log and
    /// continue under either action.
    Warn,
    /// The run is producing garbage (NaN contamination, divergence) —
    /// halts the run under `health_action = abort`.
    Abort,
}

impl Severity {
    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Abort => "abort",
        }
    }
}

/// One tripped rule: who, how bad, and the measured detail.
#[derive(Clone, Debug)]
pub struct Trip {
    /// The rule's [`WatchdogRule::name`].
    pub rule: &'static str,
    /// The rule's severity class.
    pub severity: Severity,
    /// Human-readable measurement that tripped it.
    pub detail: String,
}

/// A pluggable per-step invariant. `check` runs once per step in
/// registration order; returning `Some` trips the rule for this step
/// (rules are stateful — e.g. a sliding loss window — and stay armed
/// after tripping).
pub trait WatchdogRule {
    /// Stable rule name, used in verdicts, JSONL events, and reports.
    fn name(&self) -> &'static str;
    /// Inspect one step; `Some(trip)` if the invariant broke.
    fn check(&mut self, obs: &StepObs) -> Option<Trip>;
}

/// Per-step verdict: which rules tripped, if any.
#[derive(Clone, Debug, Default)]
pub struct RunHealth {
    /// The step this verdict describes.
    pub step: u64,
    /// Every rule that tripped this step (empty = healthy).
    pub trips: Vec<Trip>,
}

impl RunHealth {
    /// True when no rule tripped.
    pub fn ok(&self) -> bool {
        self.trips.is_empty()
    }

    /// The worst severity among the trips, if any.
    pub fn worst(&self) -> Option<Severity> {
        self.trips.iter().map(|t| t.severity).max()
    }

    /// `"ok"`, `"warn"`, or `"abort"` — the verdict the trainer logs
    /// per step.
    pub fn verdict(&self) -> &'static str {
        match self.worst() {
            None => "ok",
            Some(s) => s.name(),
        }
    }

    /// JSON form for the JSONL stream:
    /// `{verdict, rules: [{rule, severity, detail}]}`.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("verdict".into(), Json::String(self.verdict().into()));
        let rules: Vec<Json> = self
            .trips
            .iter()
            .map(|t| {
                let mut r = BTreeMap::new();
                r.insert("rule".into(), Json::String(t.rule.into()));
                r.insert("severity".into(),
                         Json::String(t.severity.name().into()));
                r.insert("detail".into(), Json::String(t.detail.clone()));
                Json::Object(r)
            })
            .collect();
        o.insert("rules".into(), Json::Array(rules));
        Json::Object(o)
    }

    /// One-line report naming the tripped rules (the abort message).
    pub fn report(&self) -> String {
        if self.ok() {
            return format!("step {}: healthy", self.step);
        }
        let rules: Vec<String> = self
            .trips
            .iter()
            .map(|t| format!("{} [{}]: {}", t.rule, t.severity.name(),
                             t.detail))
            .collect();
        format!("step {}: {}", self.step, rules.join("; "))
    }
}

/// What the trainer does with an abort-class verdict
/// (`[train] health_action`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum HealthAction {
    /// Log the verdict and keep training.
    #[default]
    Warn,
    /// Halt the run with a report naming the tripped rule.
    Abort,
}

impl HealthAction {
    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            HealthAction::Warn => "warn",
            HealthAction::Abort => "abort",
        }
    }
}

impl std::str::FromStr for HealthAction {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "warn" => Ok(HealthAction::Warn),
            "abort" => Ok(HealthAction::Abort),
            other => anyhow::bail!(
                "health_action must be `warn` or `abort`, got `{other}`"),
        }
    }
}

/// The monitor: a rule set plus the configured action.
pub struct HealthMonitor {
    rules: Vec<Box<dyn WatchdogRule>>,
    action: HealthAction,
}

impl HealthMonitor {
    /// The standard rule set ([`standard_rules`]) under `action`.
    pub fn standard(action: HealthAction) -> Self {
        Self::with_rules(standard_rules(), action)
    }

    /// A custom rule set under `action`.
    pub fn with_rules(rules: Vec<Box<dyn WatchdogRule>>,
                      action: HealthAction) -> Self {
        HealthMonitor { rules, action }
    }

    /// The configured action.
    pub fn action(&self) -> HealthAction {
        self.action
    }

    /// Evaluate every rule against one step's observations.
    pub fn observe(&mut self, obs: &StepObs) -> RunHealth {
        let trips =
            self.rules.iter_mut().filter_map(|r| r.check(obs)).collect();
        RunHealth { step: obs.step, trips }
    }

    /// True when `health` must halt the run: an abort-class trip under
    /// [`HealthAction::Abort`].
    pub fn must_abort(&self, health: &RunHealth) -> bool {
        self.action == HealthAction::Abort
            && health.worst() == Some(Severity::Abort)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn healthy(step: u64) -> StepObs {
        StepObs { step, loss: 1.0, ..StepObs::default() }
    }

    #[test]
    fn healthy_steps_stay_ok_under_the_standard_set() {
        let mut mon = HealthMonitor::standard(HealthAction::Abort);
        for step in 1..=50 {
            let h = mon.observe(&healthy(step));
            assert!(h.ok(), "step {step}: {}", h.report());
            assert_eq!(h.verdict(), "ok");
            assert!(!mon.must_abort(&h));
        }
    }

    /// Synthetic NaN-gradient stream: the non-finite rule (and only it)
    /// trips, by name, with abort severity.
    #[test]
    fn nan_gradient_stream_trips_exactly_the_nonfinite_rule() {
        let mut mon = HealthMonitor::standard(HealthAction::Abort);
        let mut obs = healthy(3);
        obs.grad_nonfinite = 7;
        let h = mon.observe(&obs);
        assert_eq!(h.trips.len(), 1, "{}", h.report());
        assert_eq!(h.trips[0].rule, "non_finite");
        assert_eq!(h.trips[0].severity, Severity::Abort);
        assert_eq!(h.verdict(), "abort");
        assert!(mon.must_abort(&h));
        assert!(h.report().contains("non_finite"), "{}", h.report());
        // under warn the verdict stands but nothing halts
        let mut warn = HealthMonitor::standard(HealthAction::Warn);
        let h = warn.observe(&obs);
        assert_eq!(h.verdict(), "abort");
        assert!(!warn.must_abort(&h));
    }

    /// Synthetic divergent-loss stream: steady losses, then a blow-up —
    /// the divergence rule trips by name.
    #[test]
    fn divergent_loss_stream_trips_exactly_the_divergence_rule() {
        let mut mon = HealthMonitor::standard(HealthAction::Abort);
        for step in 1..=30 {
            let mut obs = healthy(step);
            obs.loss = 2.0 - (step as f64) * 0.01;
            assert!(mon.observe(&obs).ok(), "warm-up must stay healthy");
        }
        let mut obs = healthy(31);
        obs.loss = 50.0;
        let h = mon.observe(&obs);
        assert_eq!(h.trips.len(), 1, "{}", h.report());
        assert_eq!(h.trips[0].rule, "loss_divergence");
        assert!(mon.must_abort(&h));
    }

    /// Synthetic stalled-hop stream: measured hops far above the
    /// calibrated prediction — the stall rule trips by name, at warn
    /// severity (a slow link is survivable).
    #[test]
    fn stalled_hop_stream_trips_exactly_the_stall_rule() {
        let mut mon = HealthMonitor::standard(HealthAction::Abort);
        let mut obs = healthy(5);
        obs.hop_mean_ns = Some(50_000_000.0);
        obs.hop_expect_ns = Some(1_000_000.0);
        let h = mon.observe(&obs);
        assert_eq!(h.trips.len(), 1, "{}", h.report());
        assert_eq!(h.trips[0].rule, "hop_stall");
        assert_eq!(h.trips[0].severity, Severity::Warn);
        assert_eq!(h.verdict(), "warn");
        assert!(!mon.must_abort(&h), "warn-class trips never halt");
    }

    #[test]
    fn pool_drift_trips_the_drift_rule() {
        let mut mon = HealthMonitor::standard(HealthAction::Abort);
        let mut obs = healthy(2);
        obs.pool_bytes = Some(10 << 20);
        obs.accountant_bytes = Some(1 << 20);
        let h = mon.observe(&obs);
        assert_eq!(h.trips.len(), 1, "{}", h.report());
        assert_eq!(h.trips[0].rule, "pool_drift");
        assert_eq!(h.trips[0].severity, Severity::Warn);
    }

    #[test]
    fn verdict_json_round_trips_rule_names() {
        let mut mon = HealthMonitor::standard(HealthAction::Warn);
        let mut obs = healthy(9);
        obs.update_nonfinite = 1;
        let h = mon.observe(&obs);
        let j = h.to_json();
        assert_eq!(j.get("verdict").and_then(Json::as_str), Some("abort"));
        let rules = match j.get("rules") {
            Some(Json::Array(a)) => a.clone(),
            _ => panic!("rules array missing"),
        };
        assert_eq!(rules[0].get("rule").and_then(Json::as_str),
                   Some("non_finite"));
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("verdict").and_then(Json::as_str),
                   Some("abort"));
    }

    #[test]
    fn health_action_parses_strictly() {
        assert_eq!("warn".parse::<HealthAction>().unwrap(),
                   HealthAction::Warn);
        assert_eq!("abort".parse::<HealthAction>().unwrap(),
                   HealthAction::Abort);
        assert!("on".parse::<HealthAction>().is_err());
        assert!("Abort".parse::<HealthAction>().is_err());
    }
}
