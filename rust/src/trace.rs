//! Optimizer-statistics tracing — the machinery behind Fig. 1 (activation
//! patterns), Fig. 5 (accumulator tightness) and Fig. 7 (conv patterns).
//!
//! Runs replicate the paper's probes: train with Adagrad and capture its
//! elementwise γ_t statistics per weight matrix (heatmaps), and run
//! SM3-I/SM3-II on the *same* gradient sequence to compare their implied
//! ν against γ (top-k tightness curves).

use crate::tensor::Tensor;
use anyhow::Result;
use std::io::Write;

/// Dump a matrix as CSV (one row per line) — heatmap source data.
pub fn write_heatmap_csv(path: &str, t: &Tensor) -> Result<()> {
    assert_eq!(t.rank(), 2, "heatmaps are 2-D");
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let (m, n) = (t.shape()[0], t.shape()[1]);
    for i in 0..m {
        let row: Vec<String> = (0..n)
            .map(|j| format!("{:.6e}", t.at2(i, j)))
            .collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Top-k values of a tensor, sorted descending — Fig. 5's x-axis is the
/// rank of the k largest Adagrad accumulators.
pub fn top_k(t: &Tensor, k: usize) -> Vec<f32> {
    let mut v: Vec<f32> = t.data().to_vec();
    // total_cmp: NaN accumulators (a diverged probe run) order
    // deterministically (+NaN above +inf) instead of panicking mid-sort
    v.sort_by(|a, b| b.total_cmp(a));
    v.truncate(k);
    v
}

/// Indices of the top-k entries (descending) — used to read the SM3 ν at
/// the same coordinates as Adagrad's largest γ.
pub fn top_k_indices(t: &Tensor, k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..t.len()).collect();
    let d = t.data();
    idx.sort_by(|&a, &b| d[b].total_cmp(&d[a]));
    idx.truncate(k);
    idx
}

/// Row/column structure score of a statistics matrix: the fraction of
/// total variance explained by the best rank-1 row/col decomposition —
/// high values are exactly the "activation patterns" of Fig. 1.
/// Computed as 1 − ||G − r·cᵀ||² / ||G||² after one power-iteration sweep.
pub fn activation_pattern_score(t: &Tensor) -> f64 {
    assert_eq!(t.rank(), 2);
    let (m, n) = (t.shape()[0], t.shape()[1]);
    // power iteration for the dominant singular pair
    let mut v = vec![1.0f64; n];
    let mut u = vec![0.0f64; m];
    for _ in 0..20 {
        for i in 0..m {
            u[i] = (0..n).map(|j| t.at2(i, j) as f64 * v[j]).sum();
        }
        let nu = u.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
        u.iter_mut().for_each(|x| *x /= nu);
        for j in 0..n {
            v[j] = (0..m).map(|i| t.at2(i, j) as f64 * u[i]).sum();
        }
        let nv = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
        v.iter_mut().for_each(|x| *x /= nv);
    }
    let sigma: f64 = (0..m)
        .map(|i| u[i] * (0..n).map(|j| t.at2(i, j) as f64 * v[j]).sum::<f64>())
        .sum();
    let total: f64 = t.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
    if total <= 0.0 {
        return 1.0;
    }
    (sigma * sigma / total).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn top_k_sorted_desc() {
        let t = Tensor::from_vec(&[5], vec![3.0, 1.0, 4.0, 1.5, 9.0]);
        assert_eq!(top_k(&t, 3), vec![9.0, 4.0, 3.0]);
        assert_eq!(top_k_indices(&t, 2), vec![4, 2]);
    }

    #[test]
    fn top_k_survives_nan_accumulators() {
        // Regression: these sorts used `partial_cmp().unwrap()` and
        // panicked the moment a diverged run produced a NaN statistic.
        // total_cmp is a total order: +NaN sorts above +inf, so a NaN
        // accumulator surfaces at the top of the ranking (visibly
        // broken) rather than aborting the trace.
        let t = Tensor::from_vec(
            &[5], vec![1.0, f32::NAN, 3.0, f32::NEG_INFINITY, 2.0]);
        let top = top_k(&t, 3);
        assert!(top[0].is_nan());
        assert_eq!(&top[1..], &[3.0, 2.0]);
        let idx = top_k_indices(&t, 3);
        assert_eq!(idx, vec![1, 2, 4]);
        // all-NaN input is ordered, not a panic
        let all = top_k(&Tensor::from_vec(&[2], vec![f32::NAN, f32::NAN]), 2);
        assert!(all.iter().all(|x| x.is_nan()));
    }

    #[test]
    fn rank1_matrix_scores_high() {
        // γ = r·cᵀ exactly (a perfect activation pattern)
        let r = [1.0f32, 2.0, 3.0];
        let c = [0.5f32, 1.0, 1.5, 2.0];
        let mut data = Vec::new();
        for &ri in &r {
            for &cj in &c {
                data.push(ri * cj);
            }
        }
        let t = Tensor::from_vec(&[3, 4], data);
        assert!(activation_pattern_score(&t) > 0.999);
    }

    #[test]
    fn random_matrix_scores_lower() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[24, 24], 1.0, &mut rng);
        let s = activation_pattern_score(&t);
        assert!(s < 0.6, "score {s}");
    }

    #[test]
    fn heatmap_csv_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let dir = std::env::temp_dir().join("sm3_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("h.csv");
        write_heatmap_csv(p.to_str().unwrap(), &t).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().contains(','));
    }
}
