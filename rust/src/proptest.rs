//! In-repo property-testing harness.
//!
//! The external `proptest` crate is unavailable offline, so this module
//! provides the subset the test-suite needs: seeded generators, `forall`
//! runners with case counts, and failure reporting that prints the seed so
//! a failing case can be replayed deterministically. (No shrinking — cases
//! are small enough to debug directly; the seed is the repro handle.)

use crate::rng::Rng;

/// Number of random cases per property (override with SM3_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("SM3_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` seeded inputs produced by `gen`.
/// Panics with the offending seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n\
                 input: {input:?}\n{msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Rng;

    /// A random shape with `rank` in [1, max_rank] and dims in [1, max_dim].
    pub fn shape(rng: &mut Rng, max_rank: usize, max_dim: usize) -> Vec<usize> {
        let rank = 1 + rng.index(max_rank);
        (0..rank).map(|_| 1 + rng.index(max_dim)).collect()
    }

    /// A random matrix shape.
    pub fn matrix(rng: &mut Rng, max_dim: usize) -> (usize, usize) {
        (1 + rng.index(max_dim), 1 + rng.index(max_dim))
    }

    /// Random f32 vector with entries from N(0, scale), occasionally sparse
    /// or exactly zero — exercising the 0/0=0 path.
    pub fn grad_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        let sparsity = if rng.bernoulli(0.3) { rng.next_f64() } else { 0.0 };
        (0..n)
            .map(|_| {
                if rng.bernoulli(sparsity) {
                    0.0
                } else {
                    rng.normal_f32(0.0, scale)
                }
            })
            .collect()
    }

    /// A mixed-rank parameter list: 1..=max_leaves leaves with ranks in
    /// [1, max_rank] and dims in [1, max_dim] (exercises the vector,
    /// matrix, and generic-tensor optimizer paths together).
    pub fn param_specs(rng: &mut Rng, max_leaves: usize, max_rank: usize,
                       max_dim: usize) -> Vec<crate::optim::ParamSpec> {
        let n = 1 + rng.index(max_leaves);
        (0..n)
            .map(|i| crate::optim::ParamSpec::new(
                format!("p{i}"), &shape(rng, max_rank, max_dim)))
            .collect()
    }

    /// A random cover of [d]: random sets + a repair pass guaranteeing
    /// every index is covered.
    pub fn cover(rng: &mut Rng, d: usize, max_sets: usize) -> Vec<Vec<usize>> {
        let k = 1 + rng.index(max_sets);
        let mut sets: Vec<Vec<usize>> = Vec::with_capacity(k + 1);
        for _ in 0..k {
            let size = 1 + rng.index(d);
            let mut s: Vec<usize> = (0..d).collect();
            rng.shuffle(&mut s);
            s.truncate(size);
            s.sort_unstable();
            sets.push(s);
        }
        let mut covered = vec![false; d];
        for s in &sets {
            for &i in s {
                covered[i] = true;
            }
        }
        let missing: Vec<usize> =
            (0..d).filter(|&i| !covered[i]).collect();
        if !missing.is_empty() {
            sets.push(missing);
        }
        sets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 is u64", |rng| rng.next_u64(), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn forall_reports_failures() {
        forall("fails", |rng| rng.next_u64(),
               |_| Err("always fails".to_string()));
    }

    #[test]
    fn generated_covers_are_valid() {
        forall("covers cover", |rng| {
            let d = 1 + rng.index(20);
            (d, gen::cover(rng, d, 6))
        }, |(d, sets)| {
            let mut covered = vec![false; *d];
            for s in sets {
                if s.is_empty() {
                    return Err("empty set".into());
                }
                for &i in s {
                    if i >= *d {
                        return Err(format!("index {i} out of range"));
                    }
                    covered[i] = true;
                }
            }
            if covered.iter().all(|&c| c) {
                Ok(())
            } else {
                Err("not a cover".into())
            }
        });
    }

    /// ParallelStep must be *bitwise* identical to the serial optimizer —
    /// for every registry optimizer, over mixed-rank parameter lists, at
    /// 1, 2, and 4 threads, across multiple steps.
    #[test]
    fn parallel_step_is_bit_identical_to_serial() {
        use crate::optim::{self, parallel::ParallelStep, Optimizer};
        use crate::tensor::Tensor;
        forall("ParallelStep == serial, bitwise", |rng| {
            (gen::param_specs(rng, 5, 4, 6), rng.next_u64())
        }, |(specs, seed)| {
            for name in optim::ALL {
                for threads in [1usize, 2, 4] {
                    let mut serial = optim::OptimSpec::named(name)
                        .and_then(|s| s.build(specs))
                        .map_err(|e| e.to_string())?;
                    let mut par = ParallelStep::from_registry(
                        name, specs, 0.9, 0.98, threads)
                        .map_err(|e| e.to_string())?;
                    let mut rng = crate::rng::Rng::new(*seed);
                    let init: Vec<Tensor> = specs
                        .iter()
                        .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                        .collect();
                    let mut pa = init.clone();
                    let mut pb = init;
                    for step in 0..3 {
                        let grads: Vec<Tensor> = specs
                            .iter()
                            .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                            .collect();
                        serial.step(&mut pa, &grads, 0.1);
                        par.step(&mut pb, &grads, 0.1);
                        for (leaf, (a, b)) in
                            pa.iter().zip(&pb).enumerate()
                        {
                            for (x, y) in a.data().iter().zip(b.data()) {
                                if x.to_bits() != y.to_bits() {
                                    return Err(format!(
                                        "{name} x{threads} step {step} \
                                         leaf {leaf}: {x} != {y}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Gradients for the equivalence property: normal entries with
    /// occasional sparsity/zeros (the 0/0 = 0 path must also agree).
    fn gen_grad_tensor(shape: &[usize],
                       rng: &mut crate::rng::Rng) -> crate::tensor::Tensor {
        let n: usize = shape.iter().product();
        crate::tensor::Tensor::from_vec(shape, gen::grad_vec(rng, n, 1.0))
    }

    /// ISSUE 2 satellite: the bitwise serial == sharded guarantee must
    /// survive quantized state. Quantization happens per slot vector of
    /// one leaf and shards are whole leaves, so block boundaries never
    /// straddle shard boundaries — every registry optimizer, q8 state,
    /// 1/2/4 threads, multiple steps.
    #[test]
    fn parallel_step_is_bit_identical_to_serial_with_q8_state() {
        use crate::optim::{self, parallel::ParallelStep, Optimizer,
                           StateDtype};
        use crate::tensor::Tensor;
        forall("ParallelStep == serial @ q8, bitwise", |rng| {
            (gen::param_specs(rng, 5, 4, 6), rng.next_u64())
        }, |(specs, seed)| {
            for name in optim::ALL {
                for threads in [1usize, 2, 4] {
                    let mut serial = optim::OptimSpec::named(name)
                        .and_then(|s| s.state_dtype(StateDtype::Q8)
                            .build(specs))
                        .map_err(|e| e.to_string())?;
                    let mut par = ParallelStep::from_registry_dtype(
                        name, specs, 0.9, 0.98, threads, StateDtype::Q8)
                        .map_err(|e| e.to_string())?;
                    let mut rng = crate::rng::Rng::new(*seed);
                    let init: Vec<Tensor> = specs
                        .iter()
                        .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                        .collect();
                    let mut pa = init.clone();
                    let mut pb = init;
                    for step in 0..3 {
                        let grads: Vec<Tensor> = specs
                            .iter()
                            .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                            .collect();
                        serial.step(&mut pa, &grads, 0.1);
                        par.step(&mut pb, &grads, 0.1);
                        for (leaf, (a, b)) in
                            pa.iter().zip(&pb).enumerate()
                        {
                            for (x, y) in a.data().iter().zip(b.data()) {
                                if x.to_bits() != y.to_bits() {
                                    return Err(format!(
                                        "{name} x{threads} q8 step {step} \
                                         leaf {leaf}: {x} != {y}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 3 tentpole: the chunked streaming kernels are bitwise
    /// identical to the whole-slot path — all five registry optimizers ×
    /// {f32, bf16, q8} × slot lengths that are NOT multiples of the tile
    /// (odd vectors longer than one tile, plus matrix/tensor leaves).
    /// "Whole-slot" is the same engine at a single tile covering any
    /// slot, which performs exactly one decode → full update → one
    /// encode per slot, i.e. the pre-tiling semantics.
    #[test]
    fn chunked_kernels_match_whole_slot_bitwise() {
        use crate::optim::{self, Optimizer, StateDtype};
        use crate::tensor::Tensor;
        const WHOLE: usize = 1 << 30; // one tile spans every slot
        forall("chunked == whole-slot, bitwise", |rng| {
            // an odd-length vector spanning several tiles, plus a couple
            // of random leaves (any rank: matrix/tensor paths ride along)
            let mut specs = vec![crate::optim::ParamSpec::new(
                "v", &[65 + rng.index(140)])];
            specs.extend(gen::param_specs(rng, 2, 3, 6));
            (specs, rng.next_u64())
        }, |(specs, seed)| {
            for dtype in StateDtype::ALL {
                for name in optim::ALL {
                    for chunk in [64usize, 128] {
                        let mut tiled = optim::OptimSpec::named(name)
                            .and_then(|s| s.state_dtype(dtype)
                                .step_chunk(chunk).build(specs))
                            .map_err(|e| e.to_string())?;
                        let mut whole = optim::OptimSpec::named(name)
                            .and_then(|s| s.state_dtype(dtype)
                                .step_chunk(WHOLE).build(specs))
                            .map_err(|e| e.to_string())?;
                        let mut rng = crate::rng::Rng::new(*seed);
                        let init: Vec<Tensor> = specs
                            .iter()
                            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                            .collect();
                        let mut pa = init.clone();
                        let mut pb = init;
                        for step in 0..3 {
                            let grads: Vec<Tensor> = specs
                                .iter()
                                .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                                .collect();
                            tiled.step(&mut pa, &grads, 0.1);
                            whole.step(&mut pb, &grads, 0.1);
                            for (leaf, (a, b)) in
                                pa.iter().zip(&pb).enumerate()
                            {
                                for (x, y) in a.data().iter().zip(b.data()) {
                                    if x.to_bits() != y.to_bits() {
                                        return Err(format!(
                                            "{name} @ {dtype:?} chunk \
                                             {chunk} step {step} leaf \
                                             {leaf}: {x} != {y}"));
                                    }
                                }
                            }
                        }
                        // the carried state must agree too, not just the
                        // visible parameters
                        for ((_, sa, ta), (_, sb, tb)) in
                            tiled.state().iter().zip(&whole.state())
                        {
                            if sa != sb || ta != tb {
                                return Err(format!(
                                    "{name} @ {dtype:?} chunk {chunk}: \
                                     state slot {sa} diverged"));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 3 tentpole: intra-leaf sharded `ParallelStep` == serial,
    /// bitwise, at 1/2/4 threads on a skewed spec set whose dominant
    /// embedding leaf actually gets split (asserted) — all five registry
    /// optimizers, f32 and q8 state, small tiles inside the ranges.
    #[test]
    fn intra_leaf_sharded_step_is_bit_identical_to_serial() {
        use crate::optim::{self, parallel::ParallelStep, Optimizer,
                           SplitPolicy, StateDtype};
        use crate::tensor::Tensor;
        forall("intra-leaf ParallelStep == serial, bitwise", |rng| {
            // one dominant embedding + a few small leaves
            let rows = 120 + rng.index(80);
            let mut specs =
                vec![crate::optim::ParamSpec::new("embed", &[rows, 3])];
            specs.extend(gen::param_specs(rng, 3, 2, 6));
            (specs, rng.next_u64())
        }, |(specs, seed)| {
            for dtype in [StateDtype::F32, StateDtype::Q8] {
                for name in optim::ALL {
                    for threads in [1usize, 2, 4] {
                        let mut serial = optim::OptimSpec::named(name)
                            .and_then(|s| s.state_dtype(dtype).build(specs))
                            .map_err(|e| e.to_string())?;
                        let mut par = ParallelStep::from_registry_opts(
                            name, specs, 0.9, 0.98, threads, dtype, 64,
                            SplitPolicy::IntraLeaf)
                            .map_err(|e| e.to_string())?;
                        // the planner must really split the dominant leaf
                        // for element-wise optimizers at threads > 1
                        let split = par.parts_per_leaf()[0] > 1;
                        let expect = threads > 1
                            && crate::optim::kernel::elementwise(name, 2);
                        if split != expect {
                            return Err(format!(
                                "{name} x{threads}: embedding split = \
                                 {split}, expected {expect}"));
                        }
                        let mut rng = crate::rng::Rng::new(*seed);
                        let init: Vec<Tensor> = specs
                            .iter()
                            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                            .collect();
                        let mut pa = init.clone();
                        let mut pb = init;
                        for step in 0..3 {
                            let grads: Vec<Tensor> = specs
                                .iter()
                                .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                                .collect();
                            serial.step(&mut pa, &grads, 0.1);
                            par.step(&mut pb, &grads, 0.1);
                            for (leaf, (a, b)) in
                                pa.iter().zip(&pb).enumerate()
                            {
                                for (x, y) in a.data().iter().zip(b.data()) {
                                    if x.to_bits() != y.to_bits() {
                                        return Err(format!(
                                            "{name} x{threads} @ {dtype:?} \
                                             step {step} leaf {leaf}: \
                                             {x} != {y}"));
                                    }
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 4 acceptance: a `clip_by_global_norm(1.0)` +
    /// `decoupled_weight_decay(0.01)` pipeline over Adam (and SM3)
    /// trains under `ParallelStep` bitwise identical to serial — f32 and
    /// q8 state, 1/2/4 threads, whole-leaf and intra-leaf split plans,
    /// on spec sets whose dominant leaf really splits. The global-norm
    /// clip's two-phase reduce uses a thread-count-independent tile
    /// partition, so the clip factor (and the whole trajectory) cannot
    /// drift across engines.
    #[test]
    fn transform_pipeline_is_bit_identical_serial_vs_sharded() {
        use crate::optim::{self, Optimizer, SplitPolicy, StateDtype};
        use crate::tensor::Tensor;
        forall("clip+decay pipeline == serial, bitwise", |rng| {
            let rows = 120 + rng.index(80);
            let mut specs =
                vec![crate::optim::ParamSpec::new("embed", &[rows, 3])];
            specs.extend(gen::param_specs(rng, 3, 2, 6));
            (specs, rng.next_u64())
        }, |(specs, seed)| {
            let build = |name: &str, dtype: StateDtype, threads: usize,
                         policy: SplitPolicy|
             -> Result<Box<dyn Optimizer>, String> {
                optim::OptimSpec::named(name)
                    .and_then(|s| {
                        s.state_dtype(dtype)
                            .step_chunk(64)
                            .threads(threads)
                            .split_policy(policy)
                            .clip_by_global_norm(1.0)
                            .weight_decay(0.01)
                            .build(specs)
                    })
                    .map_err(|e| e.to_string())
            };
            for name in ["adam", "sm3"] {
                for dtype in [StateDtype::F32, StateDtype::Q8] {
                    let mut serial = build(name, dtype, 1,
                                           SplitPolicy::IntraLeaf)?;
                    for threads in [2usize, 4] {
                        for policy in [SplitPolicy::WholeLeaf,
                                       SplitPolicy::IntraLeaf] {
                            let mut par =
                                build(name, dtype, threads, policy)?;
                            let mut rng = crate::rng::Rng::new(*seed);
                            let init: Vec<Tensor> = specs
                                .iter()
                                .map(|s| Tensor::randn(&s.shape, 0.5,
                                                       &mut rng))
                                .collect();
                            let mut pa = init.clone();
                            let mut pb = init;
                            for step in 0..3 {
                                let grads: Vec<Tensor> = specs
                                    .iter()
                                    .map(|s| gen_grad_tensor(&s.shape,
                                                             &mut rng))
                                    .collect();
                                serial.step(&mut pa, &grads, 0.1);
                                par.step(&mut pb, &grads, 0.1);
                                for (leaf, (a, b)) in
                                    pa.iter().zip(&pb).enumerate()
                                {
                                    for (x, y) in
                                        a.data().iter().zip(b.data())
                                    {
                                        if x.to_bits() != y.to_bits() {
                                            return Err(format!(
                                                "{name} x{threads} \
                                                 {policy:?} @ {dtype:?} \
                                                 step {step} leaf {leaf}: \
                                                 {x} != {y}"));
                                        }
                                    }
                                }
                            }
                            // reset the serial reference for the next
                            // (threads, policy) combination
                            serial = build(name, dtype, 1,
                                           SplitPolicy::IntraLeaf)?;
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// The pipeline is exactly "hand-applied transforms + bare
    /// optimizer": clamp, two-phase-norm rescale, and decoupled decay
    /// applied manually with the same arithmetic reproduce the pipeline
    /// trajectory bit-for-bit. (This is the semantic contract the bench
    /// uses as its fairness baseline.)
    #[test]
    fn pipeline_equals_hand_applied_transforms() {
        use crate::optim::{self, transform, Optimizer};
        use crate::tensor::Tensor;
        forall("pipeline == manual transforms, bitwise", |rng| {
            (gen::param_specs(rng, 4, 3, 7), rng.next_u64())
        }, |(specs, seed)| {
            let (cv, cn, wd, lr) = (0.5f32, 1.0f32, 0.01f32, 0.1f32);
            for name in ["adam", "sm3", "adafactor"] {
                let mut pipe = optim::OptimSpec::named(name)
                    .and_then(|s| {
                        s.clip_by_value(cv)
                            .clip_by_global_norm(cn)
                            .weight_decay(wd)
                            .build(specs)
                    })
                    .map_err(|e| e.to_string())?;
                let mut bare = optim::OptimSpec::named(name)
                    .and_then(|s| s.build(specs))
                    .map_err(|e| e.to_string())?;
                let mut rng = crate::rng::Rng::new(*seed);
                let init: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                    .collect();
                let mut pa = init.clone();
                let mut pb = init;
                for step in 0..3 {
                    let grads: Vec<Tensor> = specs
                        .iter()
                        .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                        .collect();
                    pipe.step(&mut pa, &grads, lr);
                    // manual: clamp → norm-rescale → decay → bare step,
                    // with the pipeline's own helpers and f32 op order
                    let mut tg: Vec<Tensor> = grads
                        .iter()
                        .map(|t| {
                            let mut t = t.clone();
                            t.map_inplace(|v| v.clamp(-cv, cv));
                            t
                        })
                        .collect();
                    if let Some(s) = transform::clip_scale(
                        transform::global_sq_norm(&tg), cn)
                    {
                        for t in tg.iter_mut() {
                            t.map_inplace(|v| v * s);
                        }
                    }
                    let f = 1.0 - lr * 1.0 * wd;
                    for t in pb.iter_mut() {
                        t.map_inplace(|v| v * f);
                    }
                    bare.step(&mut pb, &tg, lr);
                    for (leaf, (a, b)) in pa.iter().zip(&pb).enumerate() {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            if x.to_bits() != y.to_bits() {
                                return Err(format!(
                                    "{name} step {step} leaf {leaf}: \
                                     {x} != {y}"));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 5 satellite: encode∘reduce determinism — the compressed
    /// ring produces bitwise-identical means AND residuals at 1/2/4
    /// comm threads and any block-aligned comm_chunk, for every wire
    /// dtype, over ranks ∈ {1, 2, 3, 4, 8}.
    #[test]
    fn compressed_ring_is_thread_and_chunk_invariant() {
        use crate::comms::CommEngine;
        use crate::optim::StateDtype;
        use crate::tensor::Tensor;
        forall("comm ring thread/chunk invariance", |rng| {
            (gen::param_specs(rng, 4, 3, 7), rng.next_u64())
        }, |(specs, seed)| {
            for ranks in [1usize, 2, 3, 4, 8] {
                for dtype in StateDtype::ALL {
                    let mut rng = crate::rng::Rng::new(*seed);
                    let base: Vec<Vec<Tensor>> = (0..ranks)
                        .map(|_| specs.iter()
                            .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                            .collect())
                        .collect();
                    let mut ref_eng =
                        CommEngine::new(specs, ranks, dtype, 64, 1)
                            .map_err(|e| e.to_string())?;
                    let mut ref_out = base.clone();
                    ref_eng.allreduce_mean(&mut ref_out)
                        .map_err(|e| e.to_string())?;
                    for (threads, chunk) in
                        [(2usize, 64usize), (4, 64), (2, 128), (4, 4096)]
                    {
                        let mut eng = CommEngine::new(
                            specs, ranks, dtype, chunk, threads)
                            .map_err(|e| e.to_string())?;
                        let mut out = base.clone();
                        eng.allreduce_mean(&mut out)
                            .map_err(|e| e.to_string())?;
                        for (r, (la, lb)) in
                            ref_out.iter().zip(&out).enumerate()
                        {
                            for (a, b) in la.iter().zip(lb) {
                                for (x, y) in
                                    a.data().iter().zip(b.data())
                                {
                                    if x.to_bits() != y.to_bits() {
                                        return Err(format!(
                                            "{dtype:?} x{ranks} t{threads} \
                                             c{chunk} rank {r}: {x} != {y}"));
                                    }
                                }
                            }
                        }
                        for ((_, a), (_, b)) in
                            ref_eng.state().iter().zip(&eng.state())
                        {
                            for (x, y) in a.data().iter().zip(b.data()) {
                                if x.to_bits() != y.to_bits() {
                                    return Err(format!(
                                        "{dtype:?} x{ranks} t{threads}: \
                                         residual {x} != {y}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 5 satellite: the f32 ring is bitwise equal to a naive sum
    /// oracle — for each element, fold the ranks left-to-right starting
    /// at its chunk class's origin (for class-0 elements that IS the
    /// plain rank-0-first sum; f32 addition commutes, so the ring's
    /// `dst += src` order telescopes to exactly this fold) — and to the
    /// legacy `collectives::allreduce_mean` reference.
    #[test]
    fn f32_ring_matches_rank0_sum_oracle() {
        use crate::comms::CommEngine;
        use crate::optim::StateDtype;
        use crate::tensor::Tensor;
        forall("f32 ring == rank-0 sum oracle", |rng| {
            (gen::param_specs(rng, 4, 3, 7),
             2 + rng.index(7), // ranks in [2, 8]
             rng.next_u64())
        }, |(specs, ranks, seed)| {
            let n = *ranks;
            let mut rng = crate::rng::Rng::new(*seed);
            let base: Vec<Vec<Tensor>> = (0..n)
                .map(|_| specs.iter()
                    .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                    .collect())
                .collect();
            let mut out = base.clone();
            CommEngine::new(specs, n, StateDtype::F32, 64, 1)
                .and_then(|mut e| e.allreduce_mean(&mut out))
                .map_err(|e| e.to_string())?;
            // the legacy reference must agree bitwise
            let mut legacy = base.clone();
            crate::collectives::allreduce_mean(&mut legacy)
                .map_err(|e| e.to_string())?;
            let inv = 1.0 / n as f32;
            for (leaf, spec) in specs.iter().enumerate() {
                let len = spec.numel();
                for k in 0..len {
                    // chunk class of element k: largest c with
                    // c·len/n <= k (the historical partition)
                    let c = (0..n)
                        .rfind(|&c| c * len / n <= k)
                        .expect("class 0 starts at 0");
                    let mut acc = base[c][leaf].data()[k];
                    for i in 1..n {
                        acc = base[(c + i) % n][leaf].data()[k] + acc;
                    }
                    let expect = acc * inv;
                    if c == 0 {
                        // class 0 is literally the rank-0-first naive sum
                        let mut naive = base[0][leaf].data()[k];
                        for r in base.iter().take(n).skip(1) {
                            naive += r[leaf].data()[k];
                        }
                        if (naive * inv).to_bits() != expect.to_bits() {
                            return Err(format!(
                                "oracle self-check leaf {leaf} elem {k}"));
                        }
                    }
                    for (r, rank_out) in out.iter().enumerate() {
                        let got = rank_out[leaf].data()[k];
                        if got.to_bits() != expect.to_bits() {
                            return Err(format!(
                                "x{n} leaf {leaf} elem {k} (class {c}) \
                                 rank {r}: {got} != oracle {expect}"));
                        }
                    }
                    let leg = legacy[0][leaf].data()[k];
                    if leg.to_bits() != expect.to_bits() {
                        return Err(format!(
                            "legacy mismatch leaf {leaf} elem {k}"));
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 5 satellite: error-feedback residuals round-trip through an
    /// `SM3CKPT2` file exactly as the trainer writes them (f32-tagged),
    /// and the restored engine continues bit-identically to the
    /// uninterrupted one.
    #[test]
    fn comm_residuals_roundtrip_through_sm3ckpt2() {
        use crate::comms::CommEngine;
        use crate::optim::StateDtype;
        use crate::tensor::Tensor;
        let dir = std::env::temp_dir().join("sm3_comm_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("residuals.ckpt");
        forall("comm residual SM3CKPT2 round-trip", |rng| {
            (gen::param_specs(rng, 3, 3, 7), rng.next_u64())
        }, |(specs, seed)| {
            for dtype in [StateDtype::Bf16, StateDtype::Q8] {
                let ranks = 3;
                let mut rng = crate::rng::Rng::new(*seed);
                let mut gen_round = |rng: &mut crate::rng::Rng| {
                    (0..ranks)
                        .map(|_| specs.iter()
                            .map(|s| gen_grad_tensor(&s.shape, rng))
                            .collect::<Vec<Tensor>>())
                        .collect::<Vec<_>>()
                };
                let mut a = CommEngine::new(specs, ranks, dtype, 64, 1)
                    .map_err(|e| e.to_string())?;
                for _ in 0..2 {
                    let mut g = gen_round(&mut rng);
                    a.allreduce_mean(&mut g)
                        .map_err(|e| e.to_string())?;
                }
                // save exactly the way the trainer does: f32-tagged
                let named: Vec<(String, Tensor)> = a
                    .state()
                    .into_iter()
                    .map(|(r, t)| (format!("comm/residual/{r}"), t))
                    .collect();
                let entries: Vec<(String, &Tensor, StateDtype)> = named
                    .iter()
                    .map(|(n, t)| (n.clone(), t, StateDtype::F32))
                    .collect();
                crate::checkpoint::save_v2(&path, &entries)
                    .map_err(|e| e.to_string())?;
                let loaded = crate::checkpoint::load_tagged(&path)
                    .map_err(|e| e.to_string())?;
                if loaded.len() != ranks {
                    return Err("entry count".into());
                }
                let mut b = CommEngine::new(specs, ranks, dtype, 64, 1)
                    .map_err(|e| e.to_string())?;
                b.load_state(
                    loaded.into_iter().map(|(_, t, _)| t).collect())
                    .map_err(|e| e.to_string())?;
                // both engines must continue bitwise from here
                for round in 0..2 {
                    let g = gen_round(&mut rng);
                    let mut ga = g.clone();
                    let mut gb = g;
                    a.allreduce_mean(&mut ga)
                        .map_err(|e| e.to_string())?;
                    b.allreduce_mean(&mut gb)
                        .map_err(|e| e.to_string())?;
                    for (la, lb) in ga.iter().zip(&gb) {
                        for (ta, tb) in la.iter().zip(lb) {
                            for (x, y) in
                                ta.data().iter().zip(tb.data())
                            {
                                if x.to_bits() != y.to_bits() {
                                    return Err(format!(
                                        "{dtype:?} round {round}: \
                                         {x} != {y}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 8 satellite: the bucketed / overlapped / channel-transport
    /// ring equals the monolithic serial exchange (the PR 5 oracle)
    /// bitwise — outputs AND carried residuals over two consecutive
    /// exchanges — for random inventories, ranks in [1, 8], every wire
    /// dtype, 1/2/4 comm threads, and bucket counts both on and off the
    /// 64-element tiling grid. Bucket counts the flat buffer cannot
    /// tile must error naming a bucket, never panic.
    #[test]
    fn bucketed_overlapped_ring_matches_serial_oracle() {
        use crate::comms::{CommEngine, CommOpts, TransportKind};
        use crate::optim::{ParamSpec, StateDtype};
        use crate::tensor::Tensor;
        // (buckets, threads, overlap, transport): overlap forces one hop
        // worker, so threads vary only on the non-overlapped rows
        const CONFIGS: [(usize, usize, bool, TransportKind); 6] = [
            (2, 1, false, TransportKind::Direct),
            (3, 2, false, TransportKind::Inproc),
            (5, 4, false, TransportKind::Direct),
            (2, 1, true, TransportKind::Direct),
            (3, 1, true, TransportKind::Inproc),
            (4, 1, true, TransportKind::Direct),
        ];
        forall("bucketed/overlapped ring == serial oracle", |rng| {
            (gen::param_specs(rng, 3, 3, 7),
             1 + rng.index(8), // ranks in [1, 8]
             rng.next_u64())
        }, |(specs, ranks, seed)| {
            let n = *ranks;
            let total: usize = specs.iter().map(ParamSpec::numel).sum();
            for dtype in StateDtype::ALL {
                let mut rng = crate::rng::Rng::new(*seed);
                let mut gen_round = |rng: &mut crate::rng::Rng| {
                    (0..n)
                        .map(|_| specs.iter()
                            .map(|s| gen_grad_tensor(&s.shape, rng))
                            .collect::<Vec<Tensor>>())
                        .collect::<Vec<_>>()
                };
                let g1 = gen_round(&mut rng);
                let g2 = gen_round(&mut rng);
                let mut ref_eng = CommEngine::new(specs, n, dtype, 64, 1)
                    .map_err(|e| e.to_string())?;
                let mut ref_a = g1.clone();
                let mut ref_b = g2.clone();
                ref_eng.allreduce_mean(&mut ref_a)
                    .map_err(|e| e.to_string())?;
                ref_eng.allreduce_mean(&mut ref_b)
                    .map_err(|e| e.to_string())?;
                for &(buckets, threads, overlap, transport) in &CONFIGS {
                    let built = CommEngine::with_opts(
                        specs, n,
                        CommOpts { dtype, chunk: 64, threads, buckets,
                                   overlap, transport });
                    // multi-rank engines need every bucket non-empty on
                    // the 64 grid; total >= 64·buckets guarantees it —
                    // below that line, a tiling error naming a bucket is
                    // the contract (single-rank engines never tile)
                    let mut eng = match built {
                        Ok(e) => e,
                        Err(e) if n > 1 && total < 64 * buckets => {
                            let msg = e.to_string();
                            if !msg.contains("bucket") {
                                return Err(format!(
                                    "geometry error must name a bucket: \
                                     {msg}"));
                            }
                            continue;
                        }
                        Err(e) => {
                            return Err(format!(
                                "x{n} b{buckets} (total {total}): {e:#}"));
                        }
                    };
                    for (round, (g, want)) in
                        [(&g1, &ref_a), (&g2, &ref_b)].iter().enumerate()
                    {
                        let mut out = (*g).clone();
                        eng.allreduce_mean(&mut out)
                            .map_err(|e| e.to_string())?;
                        for (la, lb) in want.iter().zip(&out) {
                            for (ta, tb) in la.iter().zip(lb) {
                                for (x, y) in
                                    ta.data().iter().zip(tb.data())
                                {
                                    if x.to_bits() != y.to_bits() {
                                        return Err(format!(
                                            "{dtype:?} x{n} b{buckets} \
                                             t{threads} overlap={overlap} \
                                             {} round {round}: {x} != {y}",
                                            transport.name()));
                                    }
                                }
                            }
                        }
                    }
                    for ((_, a), (_, b)) in
                        ref_eng.state().iter().zip(&eng.state())
                    {
                        for (x, y) in a.data().iter().zip(b.data()) {
                            if x.to_bits() != y.to_bits() {
                                return Err(format!(
                                    "{dtype:?} x{n} b{buckets}: residual \
                                     {x} != {y}"));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 8 satellite: error-feedback residuals written to an
    /// `SM3CKPT2` checkpoint mid-trajectory restore into an engine with
    /// *different* bucketing/overlap/transport, and the resumed run
    /// continues bit-identically — the pipeline knobs are invisible to
    /// the checkpoint contract.
    #[test]
    fn bucketed_residuals_resume_mid_trajectory_bitwise() {
        use crate::comms::{CommEngine, CommOpts, TransportKind};
        use crate::optim::{ParamSpec, StateDtype};
        use crate::tensor::Tensor;
        let dir = std::env::temp_dir().join("sm3_comm_bucket_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("residuals.ckpt");
        forall("bucketed comm residual mid-trajectory resume", |rng| {
            (gen::param_specs(rng, 3, 3, 7), rng.next_u64())
        }, |(specs, seed)| {
            let total: usize = specs.iter().map(ParamSpec::numel).sum();
            for dtype in [StateDtype::Bf16, StateDtype::Q8] {
                let ranks = 3;
                // resume into the most different pipeline that still
                // tiles this inventory
                let buckets = (total / 64).clamp(1, 3);
                let mut rng = crate::rng::Rng::new(*seed);
                let mut gen_round = |rng: &mut crate::rng::Rng| {
                    (0..ranks)
                        .map(|_| specs.iter()
                            .map(|s| gen_grad_tensor(&s.shape, rng))
                            .collect::<Vec<Tensor>>())
                        .collect::<Vec<_>>()
                };
                // trajectory A: monolithic serial direct, 2 warm steps
                let mut a = CommEngine::new(specs, ranks, dtype, 64, 1)
                    .map_err(|e| e.to_string())?;
                for _ in 0..2 {
                    let mut g = gen_round(&mut rng);
                    a.allreduce_mean(&mut g)
                        .map_err(|e| e.to_string())?;
                }
                // checkpoint exactly the way the trainer does
                let named: Vec<(String, Tensor)> = a
                    .state()
                    .into_iter()
                    .map(|(r, t)| (format!("comm/residual/{r}"), t))
                    .collect();
                let entries: Vec<(String, &Tensor, StateDtype)> = named
                    .iter()
                    .map(|(n, t)| (n.clone(), t, StateDtype::F32))
                    .collect();
                crate::checkpoint::save_v2(&path, &entries)
                    .map_err(|e| e.to_string())?;
                let loaded = crate::checkpoint::load_tagged(&path)
                    .map_err(|e| e.to_string())?;
                // trajectory B resumes bucketed + overlapped + inproc
                let mut b = CommEngine::with_opts(
                    specs, ranks,
                    CommOpts { dtype, chunk: 64, threads: 1, buckets,
                               overlap: true,
                               transport: TransportKind::Inproc })
                    .map_err(|e| e.to_string())?;
                b.load_state(
                    loaded.into_iter().map(|(_, t, _)| t).collect())
                    .map_err(|e| e.to_string())?;
                for round in 0..2 {
                    let g = gen_round(&mut rng);
                    let mut ga = g.clone();
                    let mut gb = g;
                    a.allreduce_mean(&mut ga)
                        .map_err(|e| e.to_string())?;
                    b.allreduce_mean(&mut gb)
                        .map_err(|e| e.to_string())?;
                    for (la, lb) in ga.iter().zip(&gb) {
                        for (ta, tb) in la.iter().zip(lb) {
                            for (x, y) in ta.data().iter().zip(tb.data())
                            {
                                if x.to_bits() != y.to_bits() {
                                    return Err(format!(
                                        "{dtype:?} b{buckets} round \
                                         {round}: {x} != {y}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Values for the backend-equivalence properties: normals plus the
    /// edge cases the codec lanes care about — ±0, f32 denormals, and
    /// huge magnitudes (never NaN/∞: the trait contract is NaN-free).
    fn special_vec(rng: &mut crate::rng::Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match rng.index(8) {
                0 => 0.0,
                1 => -0.0,
                2 => f32::from_bits(1 + rng.index(0x007f_ffff) as u32),
                3 => -f32::from_bits(1 + rng.index(0x007f_ffff) as u32),
                4 => rng.normal_f32(0.0, 1e30),
                _ => rng.normal_f32(0.0, 1.0),
            })
            .collect()
    }

    fn bits_eq(what: &str, a: &[f32], b: &[f32]) -> Result<(), String> {
        for (k, (x, y)) in a.iter().zip(b).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{what} elem {k}: {x} != {y}"));
            }
        }
        Ok(())
    }

    /// ISSUE 6 satellite: every [`crate::optim::KernelBackend`] primitive
    /// is bitwise identical across the backends — random lengths
    /// straddling the 8-lane and 64-block boundaries (including
    /// non-multiples of both), denormals, ±0, and huge magnitudes.
    #[test]
    fn backend_primitives_agree_bitwise() {
        use crate::optim::qstate::codec::Q8_BLOCK;
        use crate::optim::Backend;
        forall("SimdBackend == ScalarBackend per primitive", |rng| {
            let n = 1 + rng.index(200); // covers n % 8 != 0, n % 64 != 0
            (special_vec(rng, n), special_vec(rng, n),
             special_vec(rng, n), special_vec(rng, n))
        }, |(w0, g, acc0, mom0)| {
            let n = w0.len();
            let (sc, si) = (Backend::Scalar.imp(), Backend::Simd.imp());
            // adagrad lanes
            let (mut wa, mut aa, mut ma) =
                (w0.clone(), acc0.clone(), mom0.clone());
            let (mut wb, mut ab, mut mb) =
                (w0.clone(), acc0.clone(), mom0.clone());
            sc.adagrad_update(0.9, 0.1, &mut wa, g, &mut aa, &mut ma);
            si.adagrad_update(0.9, 0.1, &mut wb, g, &mut ab, &mut mb);
            bits_eq("adagrad w", &wa, &wb)?;
            bits_eq("adagrad acc", &aa, &ab)?;
            bits_eq("adagrad mom", &ma, &mb)?;
            // adam lanes (bc1/bc2 as the step-1 bias corrections)
            let (bc1, bc2) = (1.0 / (1.0 - 0.9f32), 1.0 / (1.0 - 0.98f32));
            let (mut wa, mut ma2, mut va) =
                (w0.clone(), mom0.clone(), acc0.clone());
            let (mut wb, mut mb2, mut vb) =
                (w0.clone(), mom0.clone(), acc0.clone());
            sc.adam_update(0.9, 0.98, 1e-8, bc1, bc2, 0.1, &mut wa, g,
                           &mut ma2, &mut va);
            si.adam_update(0.9, 0.98, 1e-8, bc1, bc2, 0.1, &mut wb, g,
                           &mut mb2, &mut vb);
            bits_eq("adam w", &wa, &wb)?;
            bits_eq("adam m", &ma2, &mb2)?;
            bits_eq("adam v", &va, &vb)?;
            // sgdm lanes
            let (mut wa, mut ma3) = (w0.clone(), mom0.clone());
            let (mut wb, mut mb3) = (w0.clone(), mom0.clone());
            sc.sgdm_update(0.9, 0.1, &mut wa, g, &mut ma3);
            si.sgdm_update(0.9, 0.1, &mut wb, g, &mut mb3);
            bits_eq("sgdm w", &wa, &wb)?;
            bits_eq("sgdm mom", &ma3, &mb3)?;
            // reduce / unpack lanes
            let (mut da, mut db) = (w0.clone(), w0.clone());
            sc.add_assign(&mut da, g);
            si.add_assign(&mut db, g);
            bits_eq("add_assign", &da, &db)?;
            sc.scale_into(&mut da, g, 1.0 / 3.0);
            si.scale_into(&mut db, g, 1.0 / 3.0);
            bits_eq("scale_into", &da, &db)?;
            // block amax (order-invariant reduce)
            if sc.block_amax(g).to_bits() != si.block_amax(g).to_bits() {
                return Err(format!("block_amax: {} != {}",
                                   sc.block_amax(g), si.block_amax(g)));
            }
            // q8 codec (one scale per 64-block, one code per element)
            // ceil-div by hand: usize::div_ceil needs 1.73, MSRV is 1.70
            let blocks = n / Q8_BLOCK + usize::from(n % Q8_BLOCK != 0);
            let (mut sa2, mut ca) = (vec![0.0f32; blocks], vec![0u8; n]);
            let (mut sb2, mut cb) = (vec![0.0f32; blocks], vec![0u8; n]);
            sc.q8_encode(g, &mut sa2, &mut ca);
            si.q8_encode(g, &mut sb2, &mut cb);
            bits_eq("q8 scales", &sa2, &sb2)?;
            if ca != cb {
                return Err("q8 codes diverged".into());
            }
            let (mut oa, mut ob) = (vec![0.0f32; n], vec![0.0f32; n]);
            sc.q8_decode(&sa2, &ca, &mut oa);
            si.q8_decode(&sb2, &cb, &mut ob);
            bits_eq("q8 decode", &oa, &ob)?;
            // bf16 codec
            let (mut ha, mut hb) = (vec![0u16; n], vec![0u16; n]);
            sc.bf16_encode(g, &mut ha);
            si.bf16_encode(g, &mut hb);
            if ha != hb {
                return Err("bf16 words diverged".into());
            }
            sc.bf16_decode(&ha, &mut oa);
            si.bf16_decode(&hb, &mut ob);
            bits_eq("bf16 decode", &oa, &ob)?;
            // f64 sum-of-squares partial (sequential in both backends)
            if sc.sq_norm_partial(g).to_bits()
                != si.sq_norm_partial(g).to_bits()
            {
                return Err(format!("sq_norm_partial: {} != {}",
                                   sc.sq_norm_partial(g),
                                   si.sq_norm_partial(g)));
            }
            Ok(())
        });
    }

    /// ISSUE 6 acceptance: the backend knob is bitwise invisible end to
    /// end — every registry optimizer (f32 and q8 state, with the
    /// global-norm clip pipeline riding along so the f64 partials are
    /// exercised) and the compressed comm ring (wire codec + reduce +
    /// unpack + error-feedback residuals) produce identical results
    /// under `scalar` and `simd`.
    #[test]
    fn kernel_backend_is_bitwise_invisible_end_to_end() {
        use crate::comms::CommEngine;
        use crate::optim::{self, Backend, Optimizer, StateDtype};
        use crate::tensor::Tensor;
        forall("simd == scalar end-to-end", |rng| {
            (gen::param_specs(rng, 4, 3, 7), rng.next_u64())
        }, |(specs, seed)| {
            for name in optim::ALL {
                for dtype in [StateDtype::F32, StateDtype::Q8] {
                    let build = |backend: Backend| {
                        optim::OptimSpec::named(name)
                            .and_then(|s| s.state_dtype(dtype)
                                .kernel_backend(backend)
                                .clip_by_global_norm(1.0)
                                .build(specs))
                            .map_err(|e| e.to_string())
                    };
                    let mut sc = build(Backend::Scalar)?;
                    let mut si = build(Backend::Simd)?;
                    let mut rng = crate::rng::Rng::new(*seed);
                    let init: Vec<Tensor> = specs
                        .iter()
                        .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                        .collect();
                    let mut pa = init.clone();
                    let mut pb = init;
                    for step in 0..3 {
                        let grads: Vec<Tensor> = specs
                            .iter()
                            .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                            .collect();
                        sc.step(&mut pa, &grads, 0.1);
                        si.step(&mut pb, &grads, 0.1);
                        for (leaf, (a, b)) in
                            pa.iter().zip(&pb).enumerate()
                        {
                            bits_eq(&format!(
                                "{name} @ {dtype:?} step {step} leaf \
                                 {leaf}"), a.data(), b.data())?;
                        }
                    }
                    for ((_, sa, ta), (_, sb, tb)) in
                        sc.state().iter().zip(&si.state())
                    {
                        if sa != sb || ta != tb {
                            return Err(format!(
                                "{name} @ {dtype:?}: state slot {sa} \
                                 diverged across backends"));
                        }
                    }
                }
            }
            // the comm ring, 2 threads so the scoped-thread path carries
            // the backend token too; two rounds over the same inputs so
            // round 2 consumes round 1's residuals
            for dtype in StateDtype::ALL {
                let ranks = 3;
                let mut rng = crate::rng::Rng::new(*seed);
                let base: Vec<Vec<Tensor>> = (0..ranks)
                    .map(|_| specs.iter()
                        .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                        .collect())
                    .collect();
                let run = |backend: Backend| {
                    let mut eng =
                        CommEngine::new(specs, ranks, dtype, 64, 2)
                            .map_err(|e| e.to_string())?;
                    eng.set_backend(backend);
                    let mut out = base.clone();
                    for _ in 0..2 {
                        let mut g = base.clone();
                        eng.allreduce_mean(&mut g)
                            .map_err(|e| e.to_string())?;
                        out = g;
                    }
                    Ok::<_, String>((out, eng.state()))
                };
                let (oa, ra) = run(Backend::Scalar)?;
                let (ob, rb) = run(Backend::Simd)?;
                for (r, (la, lb)) in oa.iter().zip(&ob).enumerate() {
                    for (leaf, (a, b)) in la.iter().zip(lb).enumerate() {
                        bits_eq(&format!(
                            "{dtype:?} ring rank {r} leaf {leaf}"),
                            a.data(), b.data())?;
                    }
                }
                for ((_, a), (_, b)) in ra.iter().zip(&rb) {
                    bits_eq(&format!("{dtype:?} ring residuals"),
                            a.data(), b.data())?;
                }
            }
            Ok(())
        });
    }

    /// PR 7 tentpole gate: telemetry is bitwise invisible. The same
    /// seeded trajectory — every registry optimizer × {f32, q8} state ×
    /// {serial, whole-leaf sharded, intra-leaf sharded} engines ×
    /// {scalar, simd} backends, and the compressed comm ring at every
    /// wire dtype (outputs AND error-feedback residuals) — produces
    /// identical bits with telemetry enabled and disabled. Telemetry
    /// only reads clocks and writes integer cells, so this holds
    /// structurally; the property pins it against regressions.
    #[test]
    fn telemetry_is_bitwise_invisible() {
        use crate::comms::CommEngine;
        use crate::optim::{self, parallel::ParallelStep, Backend,
                           Optimizer, SplitPolicy, StateDtype};
        use crate::telemetry;
        use crate::tensor::Tensor;
        forall("telemetry on == off, bitwise", |rng| {
            (gen::param_specs(rng, 3, 3, 6), rng.next_u64())
        }, |(specs, seed)| {
            let bits = |params: &[Tensor]| -> Vec<u32> {
                params
                    .iter()
                    .flat_map(|t| t.data().iter().map(|v| v.to_bits()))
                    .collect()
            };
            // mode 0: serial (honouring `backend`); 1: whole-leaf
            // sharded; 2: intra-leaf sharded (default backend)
            let traj = |name: &str, dtype: StateDtype, backend: Backend,
                        mode: u8, tele: bool| -> Result<Vec<u32>, String> {
                let _guard = tele.then(telemetry::enable);
                let mut serial: Option<Box<dyn Optimizer>> = None;
                let mut par: Option<ParallelStep> = None;
                if mode == 0 {
                    serial = Some(
                        optim::OptimSpec::named(name)
                            .and_then(|s| s.state_dtype(dtype)
                                .kernel_backend(backend).build(specs))
                            .map_err(|e| e.to_string())?);
                } else {
                    let policy = if mode == 1 {
                        SplitPolicy::WholeLeaf
                    } else {
                        SplitPolicy::IntraLeaf
                    };
                    par = Some(ParallelStep::from_registry_opts(
                        name, specs, 0.9, 0.98, 2, dtype, 64, policy)
                        .map_err(|e| e.to_string())?);
                }
                let mut rng = crate::rng::Rng::new(*seed);
                let mut params: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                    .collect();
                for _step in 0..2 {
                    let grads: Vec<Tensor> = specs
                        .iter()
                        .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                        .collect();
                    if let Some(o) = serial.as_mut() {
                        o.step(&mut params, &grads, 0.1);
                    }
                    if let Some(p) = par.as_mut() {
                        p.step(&mut params, &grads, 0.1);
                    }
                }
                Ok(bits(&params))
            };
            for name in optim::ALL {
                for dtype in [StateDtype::F32, StateDtype::Q8] {
                    for (backend, mode) in [(Backend::Scalar, 0u8),
                                            (Backend::Simd, 0),
                                            (Backend::Scalar, 1),
                                            (Backend::Scalar, 2)] {
                        let off = traj(name, dtype, backend, mode, false)?;
                        let on = traj(name, dtype, backend, mode, true)?;
                        if off != on {
                            return Err(format!(
                                "{name} @ {dtype:?} mode {mode} \
                                 {backend:?}: telemetry changed the \
                                 trajectory"));
                        }
                    }
                }
            }
            // the comm ring: outputs and carried residuals, 2 comm
            // threads so the hop spans run on the instrumented path
            for dtype in StateDtype::ALL {
                let ranks = 3;
                let run = |tele: bool|
                 -> Result<(Vec<u32>, Vec<u32>), String> {
                    let _guard = tele.then(telemetry::enable);
                    let mut rng = crate::rng::Rng::new(*seed);
                    let base: Vec<Vec<Tensor>> = (0..ranks)
                        .map(|_| specs.iter()
                            .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                            .collect())
                        .collect();
                    let mut eng =
                        CommEngine::new(specs, ranks, dtype, 64, 2)
                            .map_err(|e| e.to_string())?;
                    let mut out = base.clone();
                    for _round in 0..2 {
                        let mut g = base.clone();
                        eng.allreduce_mean(&mut g)
                            .map_err(|e| e.to_string())?;
                        out = g;
                    }
                    let out_bits = out
                        .iter()
                        .flat_map(|rank| bits(rank))
                        .collect();
                    let res_bits = eng
                        .state()
                        .iter()
                        .flat_map(|(_, t)| {
                            t.data()
                                .iter()
                                .map(|v| v.to_bits())
                                .collect::<Vec<u32>>()
                        })
                        .collect();
                    Ok((out_bits, res_bits))
                };
                if run(false)? != run(true)? {
                    return Err(format!(
                        "{dtype:?} ring: telemetry changed the exchange \
                         or its residuals"));
                }
            }
            Ok(())
        });
    }

    /// ISSUE 10 tentpole gate: tracing + health are bitwise invisible.
    /// The PR 7 property re-run with the trace rings recording, the
    /// non-finite scans live, and the watchdog monitor observing every
    /// step: every registry optimizer × {f32, q8} state × {serial,
    /// whole-leaf sharded, intra-leaf sharded} engines, and the comm
    /// ring at every wire dtype over both transports (direct, inproc)
    /// — identical bits with tracing/health on and off. The scans and
    /// rings only read the f32 stream and write integer cells, so this
    /// holds structurally; the property pins it.
    #[test]
    fn tracing_and_health_are_bitwise_invisible() {
        use crate::comms::{CommEngine, CommOpts, TransportKind};
        use crate::health::{HealthAction, HealthMonitor, StepObs};
        use crate::optim::{self, parallel::ParallelStep, Optimizer,
                           SplitPolicy, StateDtype};
        use crate::telemetry;
        use crate::tensor::Tensor;
        forall("tracing/health on == off, bitwise", |rng| {
            (gen::param_specs(rng, 3, 3, 6), rng.next_u64())
        }, |(specs, seed)| {
            let bits = |params: &[Tensor]| -> Vec<u32> {
                params
                    .iter()
                    .flat_map(|t| t.data().iter().map(|v| v.to_bits()))
                    .collect()
            };
            // mode 0: serial; 1: whole-leaf sharded; 2: intra-leaf
            let traj = |name: &str, dtype: StateDtype, mode: u8,
                        on: bool| -> Result<Vec<u32>, String> {
                let _tele = on.then(telemetry::enable);
                let _rings = on.then(telemetry::enable_tracing);
                let mut health = on
                    .then(|| HealthMonitor::standard(HealthAction::Warn));
                let mut serial: Option<Box<dyn Optimizer>> = None;
                let mut par: Option<ParallelStep> = None;
                if mode == 0 {
                    serial = Some(
                        optim::OptimSpec::named(name)
                            .and_then(|s| s.state_dtype(dtype).build(specs))
                            .map_err(|e| e.to_string())?);
                } else {
                    let policy = if mode == 1 {
                        SplitPolicy::WholeLeaf
                    } else {
                        SplitPolicy::IntraLeaf
                    };
                    par = Some(ParallelStep::from_registry_opts(
                        name, specs, 0.9, 0.98, 2, dtype, 64, policy)
                        .map_err(|e| e.to_string())?);
                }
                let mut rng = crate::rng::Rng::new(*seed);
                let mut params: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                    .collect();
                for step in 0..2u64 {
                    let grads: Vec<Tensor> = specs
                        .iter()
                        .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                        .collect();
                    if let Some(o) = serial.as_mut() {
                        o.step(&mut params, &grads, 0.1);
                    }
                    if let Some(p) = par.as_mut() {
                        p.step(&mut params, &grads, 0.1);
                    }
                    if let Some(mon) = health.as_mut() {
                        // the monitor only reads observations; verdicts
                        // must not feed back into the trajectory
                        let verdict = mon.observe(&StepObs {
                            step: step + 1,
                            loss: 1.0,
                            ..StepObs::default()
                        });
                        if !verdict.ok() {
                            return Err(format!(
                                "clean run tripped {}", verdict.report()));
                        }
                    }
                }
                Ok(bits(&params))
            };
            for name in optim::ALL {
                for dtype in [StateDtype::F32, StateDtype::Q8] {
                    for mode in 0u8..3 {
                        let off = traj(name, dtype, mode, false)?;
                        let on = traj(name, dtype, mode, true)?;
                        if off != on {
                            return Err(format!(
                                "{name} @ {dtype:?} mode {mode}: \
                                 tracing/health changed the trajectory"));
                        }
                    }
                }
            }
            // the comm ring across both transports: outputs and carried
            // residuals, 2 comm threads so the hop spans + pack scans
            // run on the instrumented paths
            for dtype in StateDtype::ALL {
                for transport in TransportKind::ALL {
                    let ranks = 3;
                    let run = |on: bool|
                     -> Result<(Vec<u32>, Vec<u32>), String> {
                        let _tele = on.then(telemetry::enable);
                        let _rings = on.then(telemetry::enable_tracing);
                        let mut rng = crate::rng::Rng::new(*seed);
                        let base: Vec<Vec<Tensor>> = (0..ranks)
                            .map(|_| specs.iter()
                                .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                                .collect())
                            .collect();
                        let mut eng = CommEngine::with_opts(
                            specs, ranks,
                            CommOpts { dtype, chunk: 64, threads: 2,
                                       transport, ..CommOpts::default() })
                            .map_err(|e| e.to_string())?;
                        let mut out = base.clone();
                        for _round in 0..2 {
                            let mut g = base.clone();
                            eng.allreduce_mean(&mut g)
                                .map_err(|e| e.to_string())?;
                            out = g;
                        }
                        let out_bits = out
                            .iter()
                            .flat_map(|rank| bits(rank))
                            .collect();
                        let res_bits = eng
                            .state()
                            .iter()
                            .flat_map(|(_, t)| {
                                t.data()
                                    .iter()
                                    .map(|v| v.to_bits())
                                    .collect::<Vec<u32>>()
                            })
                            .collect();
                        Ok((out_bits, res_bits))
                    };
                    if run(false)? != run(true)? {
                        return Err(format!(
                            "{dtype:?} ring over {transport:?}: \
                             tracing/health changed the exchange or its \
                             residuals"));
                    }
                }
            }
            Ok(())
        });
    }

    /// ISSUE 9 tentpole gate: the memory pool is bitwise invisible.
    /// The same seeded trajectory — every registry optimizer × {f32,
    /// q8} state × {1, 2, 4} threads, and the compressed comm ring with
    /// error-feedback residuals at every wire dtype — produces
    /// identical bits across all three placement modes: legacy heap
    /// (no pool), `Pool::disabled` (accounted, not recycled), and
    /// `Pool::new` (recycled slabs). Acquire zero-fills either way, so
    /// this holds structurally; the property pins it.
    #[test]
    fn memory_pool_is_bitwise_invisible() {
        use crate::comms::{CommEngine, CommOpts};
        use crate::optim::{self, Optimizer, StateDtype};
        use crate::pool::Pool;
        use crate::tensor::Tensor;
        forall("pool on == off == legacy, bitwise", |rng| {
            (gen::param_specs(rng, 3, 3, 6), rng.next_u64())
        }, |(specs, seed)| {
            let bits = |params: &[Tensor]| -> Vec<u32> {
                params
                    .iter()
                    .flat_map(|t| t.data().iter().map(|v| v.to_bits()))
                    .collect()
            };
            // pool mode: None = legacy heap; Some(pool) = leased
            let traj = |name: &str, dtype: StateDtype, threads: usize,
                        pool: Option<Pool>| -> Result<Vec<u32>, String> {
                let mut spec = optim::OptimSpec::named(name)
                    .map_err(|e| e.to_string())?
                    .state_dtype(dtype)
                    .threads(threads);
                if let Some(p) = &pool {
                    spec = spec.pool(p);
                }
                let mut opt =
                    spec.build(specs).map_err(|e| e.to_string())?;
                let mut rng = crate::rng::Rng::new(*seed);
                let mut params: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                    .collect();
                for _step in 0..3 {
                    let grads: Vec<Tensor> = specs
                        .iter()
                        .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                        .collect();
                    opt.step(&mut params, &grads, 0.1);
                }
                if let Some(p) = &pool {
                    // recycling must not leak: steady state re-leases
                    if p.is_enabled() && p.bytes_in_use() == 0 {
                        return Err(format!(
                            "{name} @ {dtype:?}: pooled build holds no \
                             leases"));
                    }
                }
                Ok(bits(&params))
            };
            for name in optim::ALL {
                for dtype in [StateDtype::F32, StateDtype::Q8] {
                    for threads in [1usize, 2, 4] {
                        let legacy = traj(name, dtype, threads, None)?;
                        let off = traj(name, dtype, threads,
                                       Some(Pool::disabled()))?;
                        let on = traj(name, dtype, threads,
                                      Some(Pool::new()))?;
                        if legacy != off || off != on {
                            return Err(format!(
                                "{name} @ {dtype:?} x{threads}: the \
                                 pool changed the trajectory"));
                        }
                    }
                }
            }
            // the comm ring: outputs AND carried error-feedback
            // residuals, two rounds so round 2 consumes round 1's
            // residuals out of pooled buffers
            for dtype in StateDtype::ALL {
                let ranks = 3;
                let run = |pool: Option<Pool>|
                 -> Result<(Vec<u32>, Vec<u32>), String> {
                    let opts = CommOpts { dtype, chunk: 64, threads: 2,
                                          ..CommOpts::default() };
                    let mut eng = match &pool {
                        Some(p) => CommEngine::with_opts_in(
                            specs, ranks, opts, p),
                        None => CommEngine::with_opts(specs, ranks, opts),
                    }
                    .map_err(|e| e.to_string())?;
                    let mut rng = crate::rng::Rng::new(*seed);
                    let base: Vec<Vec<Tensor>> = (0..ranks)
                        .map(|_| specs.iter()
                            .map(|s| gen_grad_tensor(&s.shape, &mut rng))
                            .collect())
                        .collect();
                    let mut out = base.clone();
                    for _round in 0..2 {
                        let mut g = base.clone();
                        eng.allreduce_mean(&mut g)
                            .map_err(|e| e.to_string())?;
                        out = g;
                    }
                    let out_bits = out
                        .iter()
                        .flat_map(|rank| bits(rank))
                        .collect();
                    let res_bits = eng
                        .state()
                        .iter()
                        .flat_map(|(_, t)| {
                            t.data()
                                .iter()
                                .map(|v| v.to_bits())
                                .collect::<Vec<u32>>()
                        })
                        .collect();
                    Ok((out_bits, res_bits))
                };
                let legacy = run(None)?;
                let off = run(Some(Pool::disabled()))?;
                let on = run(Some(Pool::new()))?;
                if legacy != off || off != on {
                    return Err(format!(
                        "{dtype:?} ring: the pool changed the exchange \
                         or its residuals"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn shapes_in_bounds() {
        forall("shape bounds", |rng| gen::shape(rng, 4, 9), |s| {
            if s.is_empty() || s.len() > 4 || s.iter().any(|&d| d == 0 || d > 9) {
                Err(format!("bad shape {s:?}"))
            } else {
                Ok(())
            }
        });
    }
}
