//! The memory accountant — reproduces the paper's Tables 1 and 2, and
//! extends them past the paper with quantized-state columns.
//!
//! Per-core training memory is modeled as
//!
//! ```text
//! bytes/core = overhead                      (runtime + program constants)
//!            + 4·P/cores_model               (fp32 parameters, replicated*)
//!            + 4·P/cores_model               (fp32 gradients)
//!            + B(dtype)·S_opt/cores_model    (optimizer slots — the paper's
//!                                            term; B(f32) = 4)
//!            + A·batch_per_core              (activations, per example)
//! ```
//!
//! The optimizer-slot arithmetic `S_opt` is *exact* (same slot layout as
//! the optimizer bank, cross-checked in tests); `overhead` and the
//! per-example activation cost `A` are calibrated once against two
//! published cells of Table 1 (Adam@384 and SM3@768) and then *predict*
//! the remaining cells and all of Table 2. Calibration always runs at
//! f32 — the published cells are f32 runs — so the f32 columns are
//! unchanged by the qstate subsystem and the bf16/q8 columns (and their
//! recomputed max-batch frontier) are pure predictions past the paper.
//!
//! (*) the paper's runs are data-parallel: parameters are replicated per
//! core, so `cores_model = 1`.

pub mod inventory;

use crate::optim::{ParamSpec, StateDtype};
use anyhow::{bail, Result};

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Storage bytes per optimizer-state scalar at `dtype` (amortized; the
/// table arithmetic below uses the exact per-slot-vector accounting).
pub fn bytes_per_slot(dtype: StateDtype) -> f64 {
    dtype.bytes_per_slot()
}

/// The slot-vector layout of one optimizer over an inventory: lengths of
/// every second-moment vector and every momentum vector, mirroring
/// exactly how the live optimizer bank partitions its `QuantizedSlots`
/// store (one q8 block sequence per vector — partial trailing blocks
/// make per-vector granularity matter for exact byte accounting).
pub struct SlotLayout {
    /// second-moment statistics vectors (γ / v / covers / factored stats)
    pub second_moment: Vec<usize>,
    /// momentum vectors (and Adam's first moment)
    pub momentum: Vec<usize>,
}

impl SlotLayout {
    /// Slot-vector layout for a registry optimizer. Errors on unknown
    /// names so config typos surface as messages, not panics.
    pub fn for_optimizer(opt: &str, specs: &[ParamSpec]) -> Result<Self> {
        let moms = |specs: &[ParamSpec]| -> Vec<usize> {
            specs.iter().map(ParamSpec::numel).collect()
        };
        Ok(match opt {
            // m + v, both elementwise
            "adam" => Self { second_moment: moms(specs),
                             momentum: moms(specs) },
            // elementwise γ + momentum
            "adagrad" => Self { second_moment: moms(specs),
                                momentum: moms(specs) },
            // momentum only
            "sgdm" => Self { second_moment: Vec::new(),
                             momentum: moms(specs) },
            // co-dim-1 slice accumulators (per axis) + momentum
            "sm3" | "sm3i" => {
                let mut sm = Vec::new();
                for s in specs {
                    if s.shape.len() <= 1 {
                        sm.push(s.numel()); // singleton cover == full vector
                    } else {
                        sm.extend(s.shape.iter().copied());
                    }
                }
                Self { second_moment: sm, momentum: moms(specs) }
            }
            // factored row/col stats (full for vectors) + momentum
            "adafactor" => {
                let mut sm = Vec::new();
                for s in specs {
                    if s.shape.len() >= 2 {
                        let cols = *s.shape.last().unwrap();
                        sm.push(s.numel() / cols);
                        sm.push(cols);
                    } else {
                        sm.push(s.numel());
                    }
                }
                Self { second_moment: sm, momentum: moms(specs) }
            }
            other => bail!("unknown optimizer {other:?} in the memory \
                            accountant (known: {:?})", crate::optim::ALL),
        })
    }

    pub fn total_floats(&self) -> usize {
        self.second_moment.iter().sum::<usize>()
            + self.momentum.iter().sum::<usize>()
    }

    pub fn total_bytes(&self, dtype: StateDtype) -> usize {
        self.second_moment_bytes(dtype)
            + self.momentum.iter().map(|&n| dtype.bytes_for(n)).sum::<usize>()
    }

    pub fn second_moment_floats(&self) -> usize {
        self.second_moment.iter().sum()
    }

    pub fn second_moment_bytes(&self, dtype: StateDtype) -> usize {
        self.second_moment.iter().map(|&n| dtype.bytes_for(n)).sum()
    }
}

/// Optimizer-state scalars a transform pipeline adds on top of the bare
/// method (the `tx_step` / `tx_norm` slots) — re-exported from the one
/// definition next to the pipeline so the live engine and the static
/// accountant cannot drift.
pub use crate::optim::transform::TRANSFORM_STATE_FLOATS;

/// Exact optimizer-state scalar count for a parameter inventory —
/// the static mirror of `Optimizer::state_floats`.
pub fn opt_state_floats(opt: &str, specs: &[ParamSpec]) -> Result<usize> {
    Ok(SlotLayout::for_optimizer(opt, specs)?.total_floats())
}

/// Exact optimizer-state storage bytes at `dtype` — the static mirror of
/// `Optimizer::state_bytes` (per-slot-vector q8 block accounting).
pub fn opt_state_bytes(opt: &str, specs: &[ParamSpec],
                       dtype: StateDtype) -> Result<usize> {
    Ok(SlotLayout::for_optimizer(opt, specs)?.total_bytes(dtype))
}

/// Exact bytes crossing pod links in ONE ring all-reduce of the model's
/// gradients over `ranks` workers with `dtype` wire payloads — the
/// static mirror of `comms::CommEngine::wire_bytes_per_exchange`
/// (cross-checked in tests). Per hop step every chunk class of every
/// leaf is forwarded once in wire encoding (q8: per-64-block scale
/// fields included, partial trailing blocks rounded up per region);
/// there are `2(ranks − 1)` hop steps.
pub fn comm_wire_bytes(specs: &[ParamSpec], ranks: usize,
                       dtype: StateDtype) -> usize {
    if ranks <= 1 {
        return 0;
    }
    let per_sweep: usize = specs
        .iter()
        .map(|s| {
            let len = s.numel();
            (0..ranks)
                .map(|c| {
                    let (lo, hi) =
                        (c * len / ranks, (c + 1) * len / ranks);
                    dtype.bytes_for(hi - lo)
                })
                .sum::<usize>()
        })
        .sum();
    2 * (ranks - 1) * per_sweep
}

/// Persistent comm-subsystem buffer bytes per run: one flat f32 staging
/// buffer per rank, plus — for compressed wire dtypes — one flat f32
/// error-feedback residual per rank. The static mirror of
/// `comms::CommEngine::buffer_bytes` (the Θ(comm_chunk) per-thread wire
/// scratch is excluded, as the step-kernel accounting excludes its
/// tiles).
pub fn comm_buffer_bytes(specs: &[ParamSpec], ranks: usize,
                         dtype: StateDtype) -> usize {
    if ranks <= 1 {
        return 0;
    }
    let total: usize = specs.iter().map(ParamSpec::numel).sum();
    let copies = if dtype == StateDtype::F32 { 1 } else { 2 };
    copies * ranks * total * 4
}

/// Persistent comm-subsystem *scratch* bytes per run — the Θ(comm_chunk)
/// slabs that PR 8 pins for the exchange's lifetime: one wire-scratch
/// slab set per comm thread, one more for the dedicated hop worker when
/// `comm_overlap` is on (the double buffer), and the in-process
/// transport's per-edge message slots. The static mirror of
/// `comms::CommEngine::scratch_bytes` (cross-checked in tests).
///
/// One wire-scratch slab set holds, for tiles of `chunk` elements:
/// f32 stage + decode + q8 scale fields, u8 codes, u16 halves, and the
/// two serialized-message buffers (out + in) of `message_cap(chunk)`
/// bytes each.
pub fn comm_scratch_bytes(ranks: usize, chunk: usize, threads: usize,
                          overlap: bool,
                          transport: crate::comms::TransportKind) -> usize {
    use crate::comms::transport::message_cap;
    use crate::optim::qstate::codec::q8_blocks;
    if ranks <= 1 {
        return 0;
    }
    let per = 4 * (2 * chunk + q8_blocks(chunk)) // stage + decode + scales
        + chunk                                  // codes
        + 2 * chunk                              // halves
        + 2 * message_cap(chunk);                // wire out + in
    let slabs = threads + usize::from(overlap);
    let edges = match transport {
        crate::comms::TransportKind::Direct => 0,
        crate::comms::TransportKind::Inproc => ranks * message_cap(chunk),
    };
    slabs * per + edges
}

/// Calibrated activation/overhead model for one hardware+model setting.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// parameter inventory of the model
    pub specs: Vec<ParamSpec>,
    /// fixed per-core overhead, bytes
    pub overhead: f64,
    /// activation bytes per example
    pub act_per_example: f64,
    /// device memory per core, bytes (TPUv2: 8 GiB; TPUv3: 16 GiB)
    pub core_limit: f64,
}

impl MemoryModel {
    /// Per-core usage in bytes for `opt` at `batch_per_core`, f32 state.
    pub fn bytes_per_core(&self, opt: &str,
                          batch_per_core: usize) -> Result<f64> {
        self.bytes_per_core_dtype(opt, batch_per_core, StateDtype::F32)
    }

    /// Per-core usage with the optimizer slots stored at `dtype`
    /// (params/grads/activations stay f32 — only the qstate store
    /// changes precision).
    pub fn bytes_per_core_dtype(&self, opt: &str, batch_per_core: usize,
                                dtype: StateDtype) -> Result<f64> {
        let p: usize = self.specs.iter().map(ParamSpec::numel).sum();
        let slot_bytes = opt_state_bytes(opt, &self.specs, dtype)?;
        Ok(self.overhead
            + 4.0 * p as f64          // params
            + 4.0 * p as f64          // grads
            + slot_bytes as f64       // optimizer state
            + self.act_per_example * batch_per_core as f64)
    }

    pub fn gib_per_core(&self, opt: &str,
                        batch_per_core: usize) -> Result<f64> {
        Ok(self.bytes_per_core(opt, batch_per_core)? / GIB)
    }

    pub fn gib_per_core_dtype(&self, opt: &str, batch_per_core: usize,
                              dtype: StateDtype) -> Result<f64> {
        Ok(self.bytes_per_core_dtype(opt, batch_per_core, dtype)? / GIB)
    }

    /// Does (optimizer, batch/core) fit on the device? (f32 state)
    pub fn fits(&self, opt: &str, batch_per_core: usize) -> Result<bool> {
        Ok(self.bytes_per_core(opt, batch_per_core)? <= self.core_limit)
    }

    /// Largest batch/core that fits (0 if even batch 1 does not), f32.
    pub fn max_batch(&self, opt: &str) -> Result<usize> {
        self.max_batch_dtype(opt, StateDtype::F32)
    }

    /// Largest batch/core that fits with quantized optimizer state — the
    /// frontier the qstate subsystem moves (bench_memory reports it).
    pub fn max_batch_dtype(&self, opt: &str,
                           dtype: StateDtype) -> Result<usize> {
        let fixed = self.bytes_per_core_dtype(opt, 0, dtype)?;
        if fixed > self.core_limit {
            return Ok(0);
        }
        Ok(((self.core_limit - fixed) / self.act_per_example) as usize)
    }

    /// Calibrate (overhead, act_per_example) from two published cells
    /// `(opt, batch_per_core, observed_bytes)` — a 2×2 linear solve.
    /// Calibration is always against f32-state runs (the published ones).
    pub fn calibrate(
        specs: Vec<ParamSpec>,
        core_limit: f64,
        cell_a: (&str, usize, f64),
        cell_b: (&str, usize, f64),
    ) -> Result<Self> {
        let p: usize = specs.iter().map(ParamSpec::numel).sum();
        let (oa, ba, ya) = cell_a;
        let (ob, bb, yb) = cell_b;
        let fixed_a = 8.0 * p as f64
            + 4.0 * opt_state_floats(oa, &specs)? as f64;
        let fixed_b = 8.0 * p as f64
            + 4.0 * opt_state_floats(ob, &specs)? as f64;
        let ra = ya - fixed_a;
        let rb = yb - fixed_b;
        // ra = overhead + A·ba ; rb = overhead + A·bb
        let act = (rb - ra) / (bb as f64 - ba as f64);
        let overhead = ra - act * ba as f64;
        Ok(Self { specs, overhead, act_per_example: act, core_limit })
    }
}

#[cfg(test)]
mod tests {
    use super::inventory;
    use super::*;
    use crate::optim;

    /// The static arithmetic must agree with the live optimizer bank —
    /// both the scalar counts and the per-dtype byte accounting (the
    /// latter checks the per-slot-vector q8 block partitioning).
    #[test]
    fn static_matches_dynamic_state_floats_and_bytes() {
        let specs = vec![
            ParamSpec::new("emb", &[100, 16]),
            ParamSpec::new("w", &[16, 64]),
            ParamSpec::new("b", &[64]),
            ParamSpec::new("conv", &[3, 3, 4, 8]),
        ];
        for name in optim::ALL {
            for dtype in StateDtype::ALL {
                let opt = optim::OptimSpec::named(name).unwrap()
                    .state_dtype(dtype).build(&specs).unwrap();
                assert_eq!(opt_state_floats(name, &specs).unwrap(),
                           opt.state_floats(), "{name}");
                assert_eq!(opt_state_bytes(name, &specs, dtype).unwrap(),
                           opt.state_bytes(), "{name} @ {dtype:?}");
            }
        }
    }

    /// ISSUE 4 acceptance: a live transform pipeline's bytes are exactly
    /// the accountant's static arithmetic plus the fixed two-scalar
    /// transform overhead — the accountant stays exact for pipelines.
    #[test]
    fn pipeline_bytes_are_static_plus_transform_overhead() {
        let specs = vec![
            ParamSpec::new("emb", &[100, 16]),
            ParamSpec::new("b", &[64]),
        ];
        for name in optim::ALL {
            for dtype in StateDtype::ALL {
                let pipe = optim::OptimSpec::named(name).unwrap()
                    .state_dtype(dtype)
                    .clip_by_global_norm(1.0)
                    .weight_decay(0.01)
                    .build(&specs).unwrap();
                assert_eq!(
                    pipe.state_floats(),
                    opt_state_floats(name, &specs).unwrap()
                        + TRANSFORM_STATE_FLOATS,
                    "{name}");
                assert_eq!(
                    pipe.state_bytes(),
                    opt_state_bytes(name, &specs, dtype).unwrap()
                        + 4 * TRANSFORM_STATE_FLOATS,
                    "{name} @ {dtype:?}");
            }
        }
    }

    /// ISSUE 5 tentpole: the static comm arithmetic must agree with the
    /// live engine — wire bytes and persistent buffer bytes, every
    /// dtype, several rank counts (including deliberately odd leaf
    /// lengths so partial q8 wire blocks are exercised).
    #[test]
    fn static_matches_dynamic_comm_bytes() {
        let specs = vec![
            ParamSpec::new("emb", &[33, 7]),
            ParamSpec::new("w", &[16, 64]),
            ParamSpec::new("b", &[65]),
        ];
        for dtype in StateDtype::ALL {
            for ranks in [1usize, 2, 3, 4, 8] {
                let eng = crate::comms::CommEngine::new(
                    &specs, ranks, dtype, 64, 1).unwrap();
                assert_eq!(comm_wire_bytes(&specs, ranks, dtype),
                           eng.wire_bytes_per_exchange(),
                           "{dtype:?} x{ranks} wire");
                assert_eq!(comm_buffer_bytes(&specs, ranks, dtype),
                           eng.buffer_bytes(),
                           "{dtype:?} x{ranks} buffers");
            }
        }
    }

    /// ISSUE 8: the static scratch arithmetic must agree with the live
    /// engine across chunk sizes, thread counts, overlap, and both
    /// transports — the Θ(chunk) slabs are part of the budget now that
    /// they are pinned for the run's lifetime.
    #[test]
    fn static_matches_dynamic_comm_scratch_bytes() {
        use crate::comms::{CommEngine, CommOpts, TransportKind};
        let specs = vec![
            ParamSpec::new("emb", &[33, 7]),
            ParamSpec::new("w", &[16, 64]),
            ParamSpec::new("b", &[65]),
        ];
        for ranks in [1usize, 2, 4] {
            for chunk in [64usize, 256] {
                for threads in [1usize, 3] {
                    for overlap in [false, true] {
                        for transport in TransportKind::ALL {
                            let eng = CommEngine::with_opts(
                                &specs, ranks,
                                CommOpts {
                                    dtype: StateDtype::Q8,
                                    chunk,
                                    threads,
                                    buckets: 1,
                                    overlap,
                                    transport,
                                }).unwrap();
                            assert_eq!(
                                comm_scratch_bytes(ranks, chunk, threads,
                                                   overlap, transport),
                                eng.scratch_bytes(),
                                "x{ranks} chunk {chunk} t{threads} \
                                 overlap {overlap} {}", transport.name());
                        }
                    }
                }
            }
        }
    }

    /// ISSUE 9 tentpole: the accountant stops being a hand-maintained
    /// mirror — live pool occupancy must EQUAL the static arithmetic,
    /// per tag, at every step boundary, for every optimizer × state
    /// dtype × sharding mode (serial and split). And when the owner
    /// drops, every lease must come back: occupancy returns to zero.
    #[test]
    fn accountant_equals_pool_occupancy_optimizer_grid() {
        use crate::pool::{Pool, Tag};
        use crate::tensor::Tensor;
        let specs = vec![
            ParamSpec::new("emb", &[100, 16]),
            ParamSpec::new("w", &[16, 64]),
            ParamSpec::new("b", &[65]),
        ];
        let mut rng = crate::rng::Rng::new(11);
        let params0: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        for name in optim::ALL {
            for dtype in StateDtype::ALL {
                for threads in [1usize, 4] {
                    let label =
                        format!("{name} @ {dtype:?} x{threads}");
                    let pool = Pool::new();
                    let expect =
                        opt_state_bytes(name, &specs, dtype).unwrap();
                    let mut opt = optim::OptimSpec::named(name).unwrap()
                        .state_dtype(dtype)
                        .threads(threads)
                        .pool(&pool)
                        .build(&specs)
                        .unwrap();
                    assert_eq!(opt.state_bytes(), expect, "{label}");
                    assert_eq!(pool.bytes_in_use_tag(Tag::OptState),
                               expect, "{label}: state at construction");
                    let mut params = params0.clone();
                    for step in 0..2 {
                        opt.step(&mut params, &grads, 0.1);
                        assert_eq!(pool.bytes_in_use_tag(Tag::OptState),
                                   expect,
                                   "{label}: state after step {step}");
                        assert_eq!(
                            pool.bytes_in_use_tag(Tag::KernelScratch),
                            opt.scratch_bytes(),
                            "{label}: scratch after step {step}");
                        assert_eq!(pool.bytes_in_use(),
                                   expect + opt.scratch_bytes(),
                                   "{label}: total after step {step}");
                    }
                    drop(opt);
                    assert_eq!(pool.bytes_in_use(), 0,
                               "{label}: leases must all return");
                }
            }
        }
    }

    /// ISSUE 9 tentpole, comm lane: per-tag pool occupancy equals the
    /// static comm arithmetic — flat + residual staging under
    /// `CommFlat`/`CommResidual`, wire slabs + transport slots under
    /// `CommWire`/`TransportSlot` — across comm dtype × ranks ×
    /// transport, and returns to zero when the engine drops.
    #[test]
    fn accountant_equals_pool_occupancy_comm_grid() {
        use crate::comms::{CommEngine, CommOpts, TransportKind};
        use crate::pool::{Pool, Tag};
        let specs = vec![
            ParamSpec::new("emb", &[33, 7]),
            ParamSpec::new("w", &[16, 64]),
            ParamSpec::new("b", &[65]),
        ];
        let (chunk, threads) = (64usize, 2usize);
        for dtype in StateDtype::ALL {
            for ranks in [1usize, 2, 4] {
                for transport in TransportKind::ALL {
                    let label = format!("{dtype:?} x{ranks} {}",
                                        transport.name());
                    let pool = Pool::new();
                    let eng = CommEngine::with_opts_in(
                        &specs, ranks,
                        CommOpts { dtype, chunk, threads, buckets: 2,
                                   overlap: false, transport },
                        &pool).unwrap();
                    let buffers = comm_buffer_bytes(&specs, ranks, dtype);
                    let scratch = comm_scratch_bytes(
                        ranks, chunk, threads, false, transport);
                    assert_eq!(pool.bytes_in_use_tag(Tag::CommFlat)
                               + pool.bytes_in_use_tag(Tag::CommResidual),
                               buffers, "{label}: staging buffers");
                    assert_eq!(pool.bytes_in_use_tag(Tag::CommWire)
                               + pool.bytes_in_use_tag(Tag::TransportSlot),
                               scratch, "{label}: wire scratch");
                    assert_eq!(pool.bytes_in_use(), buffers + scratch,
                               "{label}: total");
                    drop(eng);
                    assert_eq!(pool.bytes_in_use(), 0,
                               "{label}: leases must all return");
                }
            }
        }
    }

    /// Overlap mode pins one extra wire slab for the hop worker — the
    /// worker leases it on its own thread, so occupancy converges to
    /// the static figure rather than equaling it synchronously at
    /// construction return. Bounded wait, then exact.
    #[test]
    fn accountant_equals_pool_occupancy_with_overlap_worker() {
        use crate::comms::{CommEngine, CommOpts, TransportKind};
        use crate::pool::Pool;
        let specs = vec![ParamSpec::new("w", &[16, 64]),
                        ParamSpec::new("b", &[65])];
        let (ranks, chunk, threads) = (4usize, 64usize, 2usize);
        let pool = Pool::new();
        let eng = CommEngine::with_opts_in(
            &specs, ranks,
            CommOpts { dtype: StateDtype::Q8, chunk, threads, buckets: 2,
                       overlap: true, transport: TransportKind::Inproc },
            &pool).unwrap();
        let expect = comm_buffer_bytes(&specs, ranks, StateDtype::Q8)
            + comm_scratch_bytes(ranks, chunk, threads, true,
                                 TransportKind::Inproc);
        for _ in 0..2000 {
            if pool.bytes_in_use() == expect {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.bytes_in_use(), expect,
                   "overlap worker slab must land in the ledger");
        drop(eng);
        assert_eq!(pool.bytes_in_use(), 0, "leases must all return");
    }

    /// ISSUE 9 satellite: the three-way cross-check at step boundaries
    /// — static accountant == live pool occupancy, and the thread-local
    /// counting allocator brackets both (every leased byte is real heap,
    /// class round-up at most doubles it), with zero steady-state heap
    /// traffic once the leases are warm. Serial path: the counting
    /// allocator is thread-local (see `crate::alloc_count`).
    #[test]
    fn three_way_accountant_pool_allocator_cross_check() {
        use crate::pool::{Pool, Tag};
        use crate::tensor::Tensor;
        let specs = vec![
            ParamSpec::new("emb", &[100, 16]),
            ParamSpec::new("b", &[65]),
        ];
        let mut rng = crate::rng::Rng::new(23);
        for dtype in StateDtype::ALL {
            // allocate everything that is NOT under test before the
            // live-bytes baseline
            let mut params: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            let expect = opt_state_bytes("adam", &specs, dtype).unwrap();
            let live0 = crate::alloc_count::thread_live_bytes();
            let pool = Pool::new();
            let mut opt = optim::OptimSpec::named("adam").unwrap()
                .state_dtype(dtype)
                .pool(&pool)
                .build(&specs)
                .unwrap();
            opt.step(&mut params, &grads, 0.1);
            // leg 1 == leg 2: static accountant == pool ledger, per tag
            assert_eq!(pool.bytes_in_use_tag(Tag::OptState), expect,
                       "{dtype:?}: accountant == pool (state)");
            assert_eq!(pool.bytes_in_use_tag(Tag::KernelScratch),
                       opt.scratch_bytes(), "{dtype:?}: scratch ledger");
            let pooled = pool.bytes_in_use();
            assert_eq!(pooled, expect + opt.scratch_bytes(),
                       "{dtype:?}: accountant == pool (total)");
            // leg 3: the counting allocator brackets the ledger — every
            // pooled byte is live heap (lower bound), and size-class
            // round-up at most doubles each lease, plus a small
            // structural slack (Box/Vec headers, store indices)
            let delta = (crate::alloc_count::thread_live_bytes()
                         - live0) as usize;
            assert!(delta >= pooled,
                    "{dtype:?}: allocator {delta} < pool {pooled}");
            assert!(delta <= 2 * pooled + (64 << 10),
                    "{dtype:?}: allocator {delta} vs pool {pooled} — \
                     pooled leases should dominate the live heap");
            // warm steps lease from shelves, not the system
            let allocs0 = crate::alloc_count::thread_allocs();
            for _ in 0..3 {
                opt.step(&mut params, &grads, 0.1);
                assert_eq!(pool.bytes_in_use_tag(Tag::OptState), expect,
                           "{dtype:?}: state stable across steps");
            }
            assert_eq!(crate::alloc_count::thread_allocs() - allocs0, 0,
                       "{dtype:?}: steady-state steps must not touch \
                        the heap");
        }
    }

    /// The acceptance line: q8 wire payloads cut all-reduce bytes
    /// ≥ 3.5× (≈ 3.7×) below f32 on the real Transformer-Big inventory.
    #[test]
    fn q8_wire_cuts_allreduce_bytes_on_transformer_big() {
        let specs = inventory::transformer_big();
        for ranks in [4usize, 16] {
            let f32b = comm_wire_bytes(&specs, ranks, StateDtype::F32);
            let q8b = comm_wire_bytes(&specs, ranks, StateDtype::Q8);
            let red = f32b as f64 / q8b as f64;
            assert!(red >= 3.5, "x{ranks}: wire reduction {red:.2}");
            assert!(red <= 4.0, "x{ranks}: reduction {red:.2} implausible");
            // bf16 halves the wire exactly
            let bf = comm_wire_bytes(&specs, ranks, StateDtype::Bf16);
            assert_eq!(f32b, 2 * bf);
        }
        // residual overhead: compressed comm carries one extra f32 model
        // copy per rank — visible, bounded, and zero at f32
        let d: usize = specs.iter().map(ParamSpec::numel).sum();
        assert_eq!(comm_buffer_bytes(&specs, 4, StateDtype::F32), 4 * d * 4);
        assert_eq!(comm_buffer_bytes(&specs, 4, StateDtype::Q8),
                   2 * 4 * d * 4);
    }

    #[test]
    fn unknown_optimizer_is_an_error_not_a_panic() {
        let specs = vec![ParamSpec::new("w", &[4])];
        let err = opt_state_floats("adamw", &specs).unwrap_err();
        assert!(err.to_string().contains("adamw"), "{err}");
        // and it propagates through the model methods
        let m = MemoryModel {
            specs,
            overhead: 0.0,
            act_per_example: 1.0,
            core_limit: GIB,
        };
        assert!(m.bytes_per_core("adamw", 1).is_err());
        assert!(m.fits("adamw", 1).is_err());
        assert!(m.max_batch("adamw").is_err());
        assert!(MemoryModel::calibrate(
            vec![ParamSpec::new("w", &[4])], GIB,
            ("nope", 1, GIB), ("sm3", 2, GIB)).is_err());
    }

    #[test]
    fn sm3_is_the_smallest_adaptive_state() {
        let specs = inventory::transformer_big();
        let sm3 = opt_state_floats("sm3", &specs).unwrap();
        let ada = opt_state_floats("adagrad", &specs).unwrap();
        let adam = opt_state_floats("adam", &specs).unwrap();
        let af = opt_state_floats("adafactor", &specs).unwrap();
        // SM3 ≤ Adafactor: for matrices both keep rows+cols (+ momentum);
        // the paper's 0.07 GiB gap between them is framework overhead noise
        assert!(sm3 <= af, "sm3 {sm3} <= adafactor {af}");
        assert!(af < ada);
        assert_eq!(ada, adam);
        // SM3's second-moment state is negligible vs d (paper: "virtually
        // eliminates the memory overhead")
        let d: usize = specs.iter().map(ParamSpec::numel).sum();
        assert!((sm3 - d) * 100 < d, "covers are <1% of d");
    }

    #[test]
    fn table1_shape_reproduced() {
        // Transformer-Big on 4x4 TPUv2 (16 cores, 8 GiB each), Table 1.
        let m = MemoryModel::calibrate(
            inventory::transformer_big(),
            8.0 * GIB,
            ("adam", 12, 6.88 * GIB),
            ("sm3", 24, 7.02 * GIB),
        ).unwrap();
        // predicted cells, paper values in comments
        let adagrad12 = m.gib_per_core("adagrad", 12).unwrap();   // 6.85
        let adafactor12 = m.gib_per_core("adafactor", 12).unwrap(); // 5.43
        let sm3_12 = m.gib_per_core("sm3", 12).unwrap();          // 5.36
        let adafactor24 = m.gib_per_core("adafactor", 24).unwrap(); // 7.04
        assert!((adagrad12 - 6.85).abs() < 0.15, "adagrad@12 {adagrad12}");
        assert!((adafactor12 - 5.43).abs() < 0.25, "adafactor@12 {adafactor12}");
        assert!((sm3_12 - 5.36).abs() < 0.25, "sm3@12 {sm3_12}");
        assert!((adafactor24 - 7.04).abs() < 0.25, "adafactor@24 {adafactor24}");
        // the qualitative claim: Adam/Adagrad OOM at 24/core, SM3/Adafactor fit
        assert!(m.fits("sm3", 24).unwrap());
        assert!(m.fits("adafactor", 24).unwrap());
        assert!(!m.fits("adam", 24).unwrap());
        assert!(!m.fits("adagrad", 24).unwrap());
    }

    #[test]
    fn max_batch_doubles_for_sm3() {
        let m = MemoryModel::calibrate(
            inventory::transformer_big(),
            8.0 * GIB,
            ("adam", 12, 6.88 * GIB),
            ("sm3", 24, 7.02 * GIB),
        ).unwrap();
        let adam_max = m.max_batch("adam").unwrap();
        let sm3_max = m.max_batch("sm3").unwrap();
        // the paper doubles 12 → 24; our calibrated activation model puts
        // Adam's ceiling at ~20 and SM3's at ~31 — SM3 fits 24, Adam not
        assert!(sm3_max >= 24, "sm3 {sm3_max}");
        assert!(adam_max < 24, "adam {adam_max}");
        assert!(sm3_max as f64 >= 1.5 * adam_max as f64,
                "sm3 {sm3_max} vs adam {adam_max}");
    }

    /// The qstate acceptance lines: f32 cells are unchanged by the dtype
    /// plumbing, and q8 cuts second-moment bytes ≥ 3.5× on the real
    /// Transformer-Big inventory while raising the max-batch frontier.
    #[test]
    fn quantized_columns_extend_the_frontier() {
        // amortized per-scalar accounting agrees with the headline claim…
        assert_eq!(bytes_per_slot(StateDtype::F32), 4.0);
        assert!(bytes_per_slot(StateDtype::F32)
                / bytes_per_slot(StateDtype::Q8) >= 3.5);
        // …and the exact per-slot-vector arithmetic below refines it
        let specs = inventory::transformer_big();
        // f32 via the dtype path == the legacy 4·floats arithmetic
        for opt in ["adam", "adagrad", "adafactor", "sm3", "sgdm"] {
            let floats = opt_state_floats(opt, &specs).unwrap();
            let f32_bytes =
                opt_state_bytes(opt, &specs, StateDtype::F32).unwrap();
            assert_eq!(f32_bytes, 4 * floats, "{opt}");
        }
        // q8 second-moment reduction on Transformer-Big
        for opt in ["adam", "adagrad", "sm3", "adafactor"] {
            let layout = SlotLayout::for_optimizer(opt, &specs).unwrap();
            let f32_sm = layout.second_moment_bytes(StateDtype::F32);
            let q8_sm = layout.second_moment_bytes(StateDtype::Q8);
            let red = f32_sm as f64 / q8_sm as f64;
            assert!(red >= 3.5, "{opt}: second-moment reduction {red}");
        }
        // the frontier moves: quantized Adam state buys strictly larger
        // max batch than f32 Adam state under the calibrated Table 1 model
        let m = MemoryModel::calibrate(
            specs,
            8.0 * GIB,
            ("adam", 12, 6.88 * GIB),
            ("sm3", 24, 7.02 * GIB),
        ).unwrap();
        let f32_max = m.max_batch_dtype("adam", StateDtype::F32).unwrap();
        let q8_max = m.max_batch_dtype("adam", StateDtype::Q8).unwrap();
        let bf16_max = m.max_batch_dtype("adam", StateDtype::Bf16).unwrap();
        assert!(q8_max > bf16_max && bf16_max > f32_max,
                "frontier must move: f32 {f32_max}, bf16 {bf16_max}, \
                 q8 {q8_max}");
        // q8 Adam state (~2.8 GiB saved on 375M params) clears the
        // paper's doubled batch
        assert!(q8_max >= 24, "q8 adam max batch {q8_max}");
    }
}
