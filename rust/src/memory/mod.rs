//! The memory accountant — reproduces the paper's Tables 1 and 2.
//!
//! Per-core training memory is modeled as
//!
//! ```text
//! bytes/core = overhead                      (runtime + program constants)
//!            + 4·P/cores_model               (fp32 parameters, replicated*)
//!            + 4·P/cores_model               (fp32 gradients)
//!            + 4·S_opt/cores_model           (optimizer slots — the paper's term)
//!            + A·batch_per_core              (activations, per example)
//! ```
//!
//! The optimizer-slot arithmetic `S_opt` is *exact* (same code as the
//! optimizer bank, cross-checked in tests); `overhead` and the per-example
//! activation cost `A` are calibrated once against two published cells of
//! Table 1 (Adam@384 and SM3@768) and then *predict* the remaining cells
//! and all of Table 2. What the tables demonstrate — who fits, who OOMs,
//! and the gap between Adam/Adagrad and Adafactor/SM3 — is driven entirely
//! by the exact slot arithmetic.
//!
//! (*) the paper's runs are data-parallel: parameters are replicated per
//! core, so `cores_model = 1`.

pub mod inventory;

use crate::optim::ParamSpec;

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Exact optimizer-state scalar count for a parameter inventory —
/// the static mirror of `Optimizer::state_floats`.
pub fn opt_state_floats(opt: &str, specs: &[ParamSpec]) -> usize {
    let d: usize = specs.iter().map(ParamSpec::numel).sum();
    match opt {
        // m + v
        "adam" => 2 * d,
        // γ + momentum
        "adagrad" => 2 * d,
        // momentum only
        "sgdm" => d,
        // co-dim-1 slice accumulators + momentum
        "sm3" | "sm3i" => {
            let covers: usize = specs
                .iter()
                .map(|s| {
                    if s.shape.len() <= 1 {
                        s.numel() // singleton cover == full vector
                    } else {
                        s.shape.iter().sum()
                    }
                })
                .sum();
            covers + d
        }
        // factored row/col stats (full for vectors) + momentum
        "adafactor" => {
            let stats: usize = specs
                .iter()
                .map(|s| {
                    if s.shape.len() >= 2 {
                        let cols = *s.shape.last().unwrap();
                        s.numel() / cols + cols
                    } else {
                        s.numel()
                    }
                })
                .sum();
            stats + d
        }
        other => panic!("unknown optimizer {other}"),
    }
}

/// Calibrated activation/overhead model for one hardware+model setting.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// parameter inventory of the model
    pub specs: Vec<ParamSpec>,
    /// fixed per-core overhead, bytes
    pub overhead: f64,
    /// activation bytes per example
    pub act_per_example: f64,
    /// device memory per core, bytes (TPUv2: 8 GiB; TPUv3: 16 GiB)
    pub core_limit: f64,
}

impl MemoryModel {
    /// Per-core usage in bytes for `opt` at `batch_per_core`.
    pub fn bytes_per_core(&self, opt: &str, batch_per_core: usize) -> f64 {
        let p: usize = self.specs.iter().map(ParamSpec::numel).sum();
        let slots = opt_state_floats(opt, &self.specs);
        self.overhead
            + 4.0 * p as f64          // params
            + 4.0 * p as f64          // grads
            + 4.0 * slots as f64      // optimizer state
            + self.act_per_example * batch_per_core as f64
    }

    pub fn gib_per_core(&self, opt: &str, batch_per_core: usize) -> f64 {
        self.bytes_per_core(opt, batch_per_core) / GIB
    }

    /// Does (optimizer, batch/core) fit on the device?
    pub fn fits(&self, opt: &str, batch_per_core: usize) -> bool {
        self.bytes_per_core(opt, batch_per_core) <= self.core_limit
    }

    /// Largest batch/core that fits (0 if even batch 1 does not).
    pub fn max_batch(&self, opt: &str) -> usize {
        let fixed = self.bytes_per_core(opt, 0);
        if fixed > self.core_limit {
            return 0;
        }
        ((self.core_limit - fixed) / self.act_per_example) as usize
    }

    /// Calibrate (overhead, act_per_example) from two published cells
    /// `(opt, batch_per_core, observed_bytes)` — a 2×2 linear solve.
    pub fn calibrate(
        specs: Vec<ParamSpec>,
        core_limit: f64,
        cell_a: (&str, usize, f64),
        cell_b: (&str, usize, f64),
    ) -> Self {
        let p: usize = specs.iter().map(ParamSpec::numel).sum();
        let fixed = |opt: &str| {
            4.0 * p as f64 * 2.0
                + 4.0 * opt_state_floats(opt, &specs) as f64
        };
        let (oa, ba, ya) = cell_a;
        let (ob, bb, yb) = cell_b;
        let ra = ya - fixed(oa);
        let rb = yb - fixed(ob);
        // ra = overhead + A·ba ; rb = overhead + A·bb
        let act = (rb - ra) / (bb as f64 - ba as f64);
        let overhead = ra - act * ba as f64;
        Self { specs, overhead, act_per_example: act, core_limit }
    }
}

#[cfg(test)]
mod tests {
    use super::inventory;
    use super::*;
    use crate::optim;

    /// The static arithmetic must agree with the live optimizer bank.
    #[test]
    fn static_matches_dynamic_state_floats() {
        let specs = vec![
            ParamSpec::new("emb", &[100, 16]),
            ParamSpec::new("w", &[16, 64]),
            ParamSpec::new("b", &[64]),
            ParamSpec::new("conv", &[3, 3, 4, 8]),
        ];
        for name in optim::ALL {
            let opt = optim::build(name, &specs, 0.9, 0.98).unwrap();
            assert_eq!(opt_state_floats(name, &specs), opt.state_floats(),
                       "{name}");
        }
    }

    #[test]
    fn sm3_is_the_smallest_adaptive_state() {
        let specs = inventory::transformer_big();
        let sm3 = opt_state_floats("sm3", &specs);
        let ada = opt_state_floats("adagrad", &specs);
        let adam = opt_state_floats("adam", &specs);
        let af = opt_state_floats("adafactor", &specs);
        // SM3 ≤ Adafactor: for matrices both keep rows+cols (+ momentum);
        // the paper's 0.07 GiB gap between them is framework overhead noise
        assert!(sm3 <= af, "sm3 {sm3} <= adafactor {af}");
        assert!(af < ada);
        assert_eq!(ada, adam);
        // SM3's second-moment state is negligible vs d (paper: "virtually
        // eliminates the memory overhead")
        let d: usize = specs.iter().map(ParamSpec::numel).sum();
        assert!((sm3 - d) * 100 < d, "covers are <1% of d");
    }

    #[test]
    fn table1_shape_reproduced() {
        // Transformer-Big on 4x4 TPUv2 (16 cores, 8 GiB each), Table 1.
        let m = MemoryModel::calibrate(
            inventory::transformer_big(),
            8.0 * GIB,
            ("adam", 12, 6.88 * GIB),
            ("sm3", 24, 7.02 * GIB),
        );
        // predicted cells, paper values in comments
        let adagrad12 = m.gib_per_core("adagrad", 12);   // 6.85
        let adafactor12 = m.gib_per_core("adafactor", 12); // 5.43
        let sm3_12 = m.gib_per_core("sm3", 12);          // 5.36
        let adafactor24 = m.gib_per_core("adafactor", 24); // 7.04
        assert!((adagrad12 - 6.85).abs() < 0.15, "adagrad@12 {adagrad12}");
        assert!((adafactor12 - 5.43).abs() < 0.25, "adafactor@12 {adafactor12}");
        assert!((sm3_12 - 5.36).abs() < 0.25, "sm3@12 {sm3_12}");
        assert!((adafactor24 - 7.04).abs() < 0.25, "adafactor@24 {adafactor24}");
        // the qualitative claim: Adam/Adagrad OOM at 24/core, SM3/Adafactor fit
        assert!(m.fits("sm3", 24));
        assert!(m.fits("adafactor", 24));
        assert!(!m.fits("adam", 24));
        assert!(!m.fits("adagrad", 24));
    }

    #[test]
    fn max_batch_doubles_for_sm3() {
        let m = MemoryModel::calibrate(
            inventory::transformer_big(),
            8.0 * GIB,
            ("adam", 12, 6.88 * GIB),
            ("sm3", 24, 7.02 * GIB),
        );
        let adam_max = m.max_batch("adam");
        let sm3_max = m.max_batch("sm3");
        // the paper doubles 12 → 24; our calibrated activation model puts
        // Adam's ceiling at ~20 and SM3's at ~31 — SM3 fits 24, Adam not
        assert!(sm3_max >= 24, "sm3 {sm3_max}");
        assert!(adam_max < 24, "adam {adam_max}");
        assert!(sm3_max as f64 >= 1.5 * adam_max as f64,
                "sm3 {sm3_max} vs adam {adam_max}");
    }
}
