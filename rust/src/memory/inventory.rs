//! Real model parameter inventories at *published* scale.
//!
//! The memory tables (paper Tables 1–2) are pure shape arithmetic, so they
//! can — and should — use the actual architectures, not our scaled-down
//! training stand-ins. Each function returns the full `ParamSpec` list of
//! one model; tests pin the parameter totals to the paper's figures
//! (375.4M for Transformer-Big, 340M for BERT-Large).

use crate::optim::ParamSpec;

fn push(specs: &mut Vec<ParamSpec>, name: String, shape: &[usize]) {
    specs.push(ParamSpec::new(name, shape));
}

/// One pre-LN transformer block (self-attention + FFN).
fn block(specs: &mut Vec<ParamSpec>, prefix: &str, d: usize, ff: usize,
         cross_attention: bool) {
    for w in ["wq", "wk", "wv", "wo"] {
        push(specs, format!("{prefix}/{w}"), &[d, d]);
    }
    if cross_attention {
        for w in ["xwq", "xwk", "xwv", "xwo"] {
            push(specs, format!("{prefix}/{w}"), &[d, d]);
        }
        push(specs, format!("{prefix}/lnx_scale"), &[d]);
        push(specs, format!("{prefix}/lnx_bias"), &[d]);
    }
    push(specs, format!("{prefix}/ffn_w1"), &[d, ff]);
    push(specs, format!("{prefix}/ffn_b1"), &[ff]);
    push(specs, format!("{prefix}/ffn_w2"), &[ff, d]);
    push(specs, format!("{prefix}/ffn_b2"), &[d]);
    for ln in ["ln1", "ln2"] {
        push(specs, format!("{prefix}/{ln}_scale"), &[d]);
        push(specs, format!("{prefix}/{ln}_bias"), &[d]);
    }
}

/// Transformer-Big (Vaswani et al.): 6+6 layers, d=1024, ff=8192,
/// 16 heads, 32K shared word-pieces. Paper: 375.4M params, 1.432 GiB.
pub fn transformer_big() -> Vec<ParamSpec> {
    let (v, d, ff, layers) = (32_000usize, 1024usize, 8192usize, 6usize);
    let mut specs = Vec::new();
    // Lingvo-style: separate source/target embeddings + softmax projection
    push(&mut specs, "embed_src".into(), &[v, d]);
    push(&mut specs, "embed_tgt".into(), &[v, d]);
    push(&mut specs, "softmax_w".into(), &[v, d]);
    push(&mut specs, "pos_src".into(), &[1024, d]);
    push(&mut specs, "pos_tgt".into(), &[1024, d]);
    for l in 0..layers {
        block(&mut specs, &format!("enc{l}"), d, ff, false);
        block(&mut specs, &format!("dec{l}"), d, ff, true);
    }
    for ln in ["enc_lnf", "dec_lnf"] {
        push(&mut specs, format!("{ln}_scale"), &[d]);
        push(&mut specs, format!("{ln}_bias"), &[d]);
    }
    specs
}

/// Transformer (base): d=512, ff=2048, 6+6 layers. Paper: 93.3M params.
pub fn transformer_base() -> Vec<ParamSpec> {
    let (v, d, ff, layers) = (32_000usize, 512usize, 2048usize, 6usize);
    let mut specs = Vec::new();
    push(&mut specs, "embed_src".into(), &[v, d]);
    push(&mut specs, "embed_tgt".into(), &[v, d]);
    push(&mut specs, "softmax_w".into(), &[v, d]);
    push(&mut specs, "pos_src".into(), &[1024, d]);
    push(&mut specs, "pos_tgt".into(), &[1024, d]);
    for l in 0..layers {
        block(&mut specs, &format!("enc{l}"), d, ff, false);
        block(&mut specs, &format!("dec{l}"), d, ff, true);
    }
    for ln in ["enc_lnf", "dec_lnf"] {
        push(&mut specs, format!("{ln}_scale"), &[d]);
        push(&mut specs, format!("{ln}_bias"), &[d]);
    }
    specs
}

/// BERT-Large (Devlin et al.): 24 layers, d=1024, ff=4096, 16 heads,
/// 30,522 word-pieces. Paper: 340M params, 1.297 GiB.
pub fn bert_large() -> Vec<ParamSpec> {
    let (v, d, ff, layers) = (30_522usize, 1024usize, 4096usize, 24usize);
    let mut specs = Vec::new();
    push(&mut specs, "embed".into(), &[v, d]);
    push(&mut specs, "pos".into(), &[512, d]);
    push(&mut specs, "type_embed".into(), &[2, d]);
    push(&mut specs, "emb_ln_scale".into(), &[d]);
    push(&mut specs, "emb_ln_bias".into(), &[d]);
    for l in 0..layers {
        block(&mut specs, &format!("block{l}"), d, ff, false);
        // BERT's attention carries per-projection biases
        for b in ["bq", "bk", "bv", "bo"] {
            push(&mut specs, format!("block{l}/{b}"), &[d]);
        }
    }
    // pooler + MLM head (tied decoder)
    push(&mut specs, "pooler_w".into(), &[d, d]);
    push(&mut specs, "pooler_b".into(), &[d]);
    push(&mut specs, "mlm_w".into(), &[d, d]);
    push(&mut specs, "mlm_b".into(), &[d]);
    push(&mut specs, "mlm_ln_scale".into(), &[d]);
    push(&mut specs, "mlm_ln_bias".into(), &[d]);
    push(&mut specs, "mlm_out_bias".into(), &[v]);
    push(&mut specs, "nsp_w".into(), &[d, 2]);
    push(&mut specs, "nsp_b".into(), &[2]);
    specs
}

/// AmoebaNet-D-ish convolutional inventory (the paper does not publish the
/// exact parameter list; this is a representative NASNet-style stack of
/// separable/regular convs at ImageNet scale used for the Fig. 7-style
/// activation-pattern traces and conv memory accounting).
pub fn amoebanet_like() -> Vec<ParamSpec> {
    let mut specs = Vec::new();
    push(&mut specs, "stem".into(), &[3, 3, 3, 64]);
    let stages: &[(usize, usize, usize)] = &[
        // (blocks, c_in, c_out)
        (4, 64, 128),
        (4, 128, 256),
        (4, 256, 512),
        (4, 512, 1024),
    ];
    for (s, &(blocks, cin, cout)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let ci = if b == 0 { cin } else { cout };
            push(&mut specs, format!("s{s}b{b}/conv3"), &[3, 3, ci, cout]);
            push(&mut specs, format!("s{s}b{b}/conv1"), &[1, 1, cout, cout]);
            push(&mut specs, format!("s{s}b{b}/bn_scale"), &[cout]);
            push(&mut specs, format!("s{s}b{b}/bn_bias"), &[cout]);
        }
    }
    push(&mut specs, "fc_w".into(), &[1024, 1000]);
    push(&mut specs, "fc_b".into(), &[1000]);
    specs
}

pub fn param_count(specs: &[ParamSpec]) -> usize {
    specs.iter().map(ParamSpec::numel).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_big_matches_paper_param_count() {
        let p = param_count(&transformer_big());
        // paper: 375.4M
        let target = 375_400_000.0;
        let err = (p as f64 - target).abs() / target;
        assert!(err < 0.02, "got {p} ({:.1}M), want ≈375.4M", p as f64 / 1e6);
    }

    #[test]
    fn transformer_base_matches_paper_param_count() {
        let p = param_count(&transformer_base());
        // paper: 93.3M
        let err = (p as f64 - 93_300_000.0).abs() / 93_300_000.0;
        assert!(err < 0.10, "got {:.1}M, want ≈93.3M", p as f64 / 1e6);
    }

    #[test]
    fn bert_large_matches_paper_param_count() {
        let p = param_count(&bert_large());
        // paper: 340M
        let err = (p as f64 - 340_000_000.0).abs() / 340_000_000.0;
        assert!(err < 0.02, "got {:.1}M, want ≈340M", p as f64 / 1e6);
    }

    #[test]
    fn param_gib_matches_paper() {
        // paper: Transformer-Big 1.432 GiB, BERT-Large 1.297 GiB (fp32)
        let big = 4.0 * param_count(&transformer_big()) as f64
            / super::super::GIB;
        assert!((big - 1.432).abs() < 0.05, "{big}");
        let bert = 4.0 * param_count(&bert_large()) as f64
            / super::super::GIB;
        assert!((bert - 1.297).abs() < 0.05, "{bert}");
    }

    #[test]
    fn conv_inventory_has_rank4_tensors() {
        assert!(amoebanet_like().iter().any(|s| s.shape.len() == 4));
    }
}
