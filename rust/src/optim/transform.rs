//! Chainable update transforms and the [`Pipeline`] that applies them
//! around any inner [`Optimizer`] (DESIGN.md §11).
//!
//! Adafactor and CAME frame their methods as *stages* of an update
//! pipeline; this module gives the optimizer bank the same seam. A
//! [`Pipeline`] wraps an inner optimizer (a serial registry optimizer or
//! a [`crate::optim::ParallelStep`] engine) and runs three stages per
//! step, in this fixed order:
//!
//! 1. **Gradient stages**, in the order the transforms were chained:
//!    [`clip_by_value`] clamps each gradient entry to `[-c, c]`;
//!    [`clip_by_global_norm`] rescales all gradients by `c / ‖g‖₂` when
//!    the global norm exceeds `c`. Gradients are copied once into
//!    struct-held scratch (the caller's tensors are never mutated);
//!    serial (`threads == 1`) steady-state steps allocate nothing
//!    (counting-allocator-tested). With `threads > 1` each pass spawns
//!    scoped workers, which heap-allocates per step — the same tradeoff
//!    `ParallelStep`'s multi-worker path already makes.
//! 2. **Decoupled weight decay** (the AdamW convention): each leaf `i`
//!    with a non-zero rate is multiplied by `1 − (lr·s_i)·wd_i` *before*
//!    the inner update, where `s_i` is the leaf's per-group LR scale.
//!    The decay never enters the gradient, so the adaptive statistics
//!    are untouched.
//! 3. The **inner update** on the (possibly transformed) gradients.
//!
//! **`ParallelStep` correctness.** Global-norm clipping is a two-phase
//! reduce: the gradient set is partitioned into fixed [`NORM_TILE`]-sized
//! tiles (a partition that depends only on the parameter shapes, never on
//! the thread count), per-tile partial squared norms are computed —
//! in parallel when the pipeline is built with `threads > 1` — and the
//! partials are combined in tile order on one thread. The combine order
//! is therefore deterministic, so the clip factor, and with it the whole
//! trajectory, is bitwise identical between serial, sharded, and
//! intra-leaf-sharded execution at any thread count and state dtype
//! (property-tested in `crate::proptest`).
//!
//! **Checkpoint contract.** A pipeline prepends two stable transform
//! slots to the inner state — `tx_step` (its step count) and `tx_norm`
//! (the last pre-clip global gradient norm) — both 1-element tensors, so
//! the trainer's `SM3CKPT2` writer tags them f32 like every scalar slot
//! (DESIGN.md §8). `state_floats`/`state_bytes` flow through to the
//! memory accountant with the two extra scalars added.

use super::backend::Backend;
use super::{Optimizer, ParamSpec, StateDtype};
use crate::tensor::Tensor;

/// Fixed tile size (elements) of the global-norm reduction partition.
///
/// The partition depends only on the parameter shapes, so the combined
/// f64 sum — and the clip factor derived from it — is identical at any
/// thread count.
pub const NORM_TILE: usize = 4096;

/// Optimizer-state scalars a [`Pipeline`] adds on top of its inner
/// optimizer: the `tx_step` / `tx_norm` slots, stored f32 per the
/// scalar-slot rule (so `4 · TRANSFORM_STATE_FLOATS` bytes). Clipping
/// and decoupled weight decay carry no per-parameter state, which is
/// why composing them is memory-free at model scale. The memory
/// accountant re-exports this (`memory::TRANSFORM_STATE_FLOATS`).
pub const TRANSFORM_STATE_FLOATS: usize = 2;

/// One composable stage of the update pipeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateTransform {
    /// No-op stage (dropped at build; useful as a config placeholder).
    Identity,
    /// Clamp every gradient entry to `[-c, c]`.
    ClipByValue(f32),
    /// Rescale all gradients by `c / ‖g‖₂` when the global L2 norm
    /// exceeds `c` (two-phase deterministic reduce, see module docs).
    ClipByGlobalNorm(f32),
    /// Decoupled (AdamW-style) weight decay at the given base rate;
    /// per-group overrides come from `OptimSpec` param groups.
    DecoupledWeightDecay(f32),
}

impl UpdateTransform {
    /// Does this stage read or rewrite gradients? (Weight decay acts on
    /// parameters; identity acts on nothing.)
    pub fn is_grad_stage(&self) -> bool {
        matches!(self,
                 UpdateTransform::ClipByValue(_)
                 | UpdateTransform::ClipByGlobalNorm(_))
    }
}

/// Clamp every gradient entry to `[-c, c]`.
pub fn clip_by_value(c: f32) -> UpdateTransform {
    UpdateTransform::ClipByValue(c)
}

/// Rescale all gradients so the global L2 norm never exceeds `c`.
pub fn clip_by_global_norm(c: f32) -> UpdateTransform {
    UpdateTransform::ClipByGlobalNorm(c)
}

/// Decoupled (AdamW-style) weight decay at base rate `wd`.
pub fn decoupled_weight_decay(wd: f32) -> UpdateTransform {
    UpdateTransform::DecoupledWeightDecay(wd)
}

/// The no-op transform.
pub fn identity() -> UpdateTransform {
    UpdateTransform::Identity
}

/// Global squared L2 norm over a gradient set, computed with the same
/// fixed [`NORM_TILE`] partition and f64 tile-order combine the
/// [`Pipeline`] uses — so a hand-rolled transform built on this helper
/// is bitwise identical to the pipeline (the bench's fairness gate).
pub fn global_sq_norm(grads: &[Tensor]) -> f64 {
    // The per-tile partial is `KernelBackend::sq_norm_partial`, which is
    // a sequential f64 fold in *every* backend (f64 addition does not
    // reassociate — DESIGN.md §13), so this helper is bitwise identical
    // to the pipeline regardless of which backend either side uses.
    let be = Backend::default().imp();
    let mut total = 0.0f64;
    for t in grads {
        for chunk in t.data().chunks(NORM_TILE) {
            total += be.sq_norm_partial(chunk);
        }
    }
    total
}

/// The gradient scale factor implied by `clip_by_global_norm(max_norm)`
/// for a gradient set with squared norm `sq_norm`; `None` when the norm
/// is within bounds (no rescale pass runs at all).
pub fn clip_scale(sq_norm: f64, max_norm: f32) -> Option<f32> {
    let norm = sq_norm.sqrt();
    if norm > max_norm as f64 {
        Some((max_norm as f64 / norm) as f32)
    } else {
        None
    }
}

/// `ceil(a / b)` without the 1.73-stabilized `usize::div_ceil` (MSRV).
fn ceil_div(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

/// Run `f(index, &mut items[index])` over every element, splitting the
/// slice into contiguous chunks across up to `threads` scoped workers
/// (inline when `threads <= 1`). Callers only do index-independent
/// per-element work, so the result is identical at any thread count.
/// Shared by the gradient/decay passes (over leaf tensors) and the
/// norm reduce's partial phase (over per-tile f64 slots).
fn for_each_indexed_mut<T: Send>(threads: usize, items: &mut [T],
                                 f: &(impl Fn(usize, &mut T) + Sync)) {
    let n = items.len();
    if threads <= 1 || n <= 1 {
        for (i, t) in items.iter_mut().enumerate() {
            f(i, t);
        }
        return;
    }
    let per = ceil_div(n, threads.min(n));
    std::thread::scope(|scope| {
        let mut rest: &mut [T] = items;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (chunk, rem) = std::mem::take(&mut rest).split_at_mut(take);
            rest = rem;
            let start = base;
            base += take;
            scope.spawn(move || {
                for (j, t) in chunk.iter_mut().enumerate() {
                    f(start + j, t);
                }
            });
        }
    });
}

/// One tile of the global-norm partition: `(leaf, offset, len)`.
type NormTile = (usize, usize, usize);

fn tile_sq_norm(backend: Backend, src: &[Tensor],
                (leaf, off, len): NormTile) -> f64 {
    backend.imp().sq_norm_partial(&src[leaf].data()[off..off + len])
}

/// A composable update pipeline around any inner optimizer.
///
/// Built by `OptimSpec::build` whenever a spec carries gradient
/// transforms or weight decay; constructible directly for tests. See the
/// module docs for the stage order and determinism contracts.
pub struct Pipeline {
    inner: Box<dyn Optimizer>,
    stages: Vec<UpdateTransform>,
    /// per-leaf decoupled weight-decay rate (0 ⇒ no decay on that leaf)
    wd: Vec<f32>,
    /// per-leaf LR scale (group overrides; the engine applies it to the
    /// update — the copy here feeds the decay factor)
    lr_scale: Vec<f32>,
    threads: usize,
    /// kernel backend for the norm reduce's per-tile partials (bitwise
    /// identical across backends — DESIGN.md §13)
    backend: Backend,
    /// fixed global-norm partition (shapes only — never thread count)
    tiles: Vec<NormTile>,
    /// per-tile partial squared norms, combined in tile order
    partials: Vec<f64>,
    /// transformed-gradient buffers, allocated once when any grad stage
    /// exists; the caller's gradient tensors are never mutated
    scratch: Vec<Tensor>,
    /// pipeline step count (the `tx_step` checkpoint slot)
    steps: f32,
    /// last pre-clip global gradient norm (the `tx_norm` slot)
    last_norm: f32,
}

impl Pipeline {
    /// Wrap `inner` with uniform transform parameters (no per-group
    /// overrides): every leaf gets the stage-declared weight-decay rate
    /// and LR scale 1.
    pub fn new(inner: Box<dyn Optimizer>, specs: &[ParamSpec],
               stages: Vec<UpdateTransform>, threads: usize)
               -> anyhow::Result<Self> {
        let base_wd = stages
            .iter()
            .find_map(|s| match s {
                UpdateTransform::DecoupledWeightDecay(w) => Some(*w),
                _ => None,
            })
            .unwrap_or(0.0);
        let n = specs.len();
        Self::with_overrides(inner, specs, stages, vec![base_wd; n],
                             vec![1.0; n], threads)
    }

    /// Wrap `inner` with resolved per-leaf weight decay and LR scales
    /// (the `OptimSpec` param-group path). `lr_scale` must match the
    /// scales baked into the inner engine — `OptimSpec::build` guarantees
    /// this; direct constructors must too, or the decay factor and the
    /// update would disagree about the effective LR.
    pub fn with_overrides(inner: Box<dyn Optimizer>, specs: &[ParamSpec],
                          stages: Vec<UpdateTransform>, wd: Vec<f32>,
                          lr_scale: Vec<f32>, threads: usize)
                          -> anyhow::Result<Self> {
        anyhow::ensure!(wd.len() == specs.len()
                        && lr_scale.len() == specs.len(),
                        "per-leaf override lengths must match the spec \
                         list ({} leaves)", specs.len());
        anyhow::ensure!(threads >= 1, "pipeline threads must be >= 1");
        for s in &stages {
            match *s {
                UpdateTransform::ClipByValue(c)
                | UpdateTransform::ClipByGlobalNorm(c) => {
                    anyhow::ensure!(c.is_finite() && c > 0.0,
                                    "clip threshold must be finite and \
                                     > 0, got {c}");
                }
                UpdateTransform::DecoupledWeightDecay(w) => {
                    anyhow::ensure!(w.is_finite() && w >= 0.0,
                                    "weight decay must be finite and \
                                     >= 0, got {w}");
                }
                UpdateTransform::Identity => {}
            }
        }
        // the norm partition only exists when a global-norm stage will
        // read it — a decay-only pipeline holds no per-tile state
        let any_norm_stage = stages
            .iter()
            .any(|s| matches!(s, UpdateTransform::ClipByGlobalNorm(_)));
        let mut tiles = Vec::new();
        if any_norm_stage {
            for (leaf, s) in specs.iter().enumerate() {
                let n = s.numel();
                let mut off = 0;
                while off < n {
                    let len = NORM_TILE.min(n - off);
                    tiles.push((leaf, off, len));
                    off += len;
                }
            }
        }
        let partials = vec![0.0; tiles.len()];
        let any_grad_stage = stages.iter().any(UpdateTransform::is_grad_stage);
        let scratch = if any_grad_stage {
            specs.iter().map(|s| Tensor::zeros(&s.shape)).collect()
        } else {
            Vec::new()
        };
        Ok(Self { inner, stages, wd, lr_scale, threads,
                  backend: Backend::default(), tiles, partials,
                  scratch, steps: 0.0, last_norm: 0.0 })
    }

    /// Route the norm reduce's per-tile partials through `backend`
    /// (bitwise identical across backends — the partial is a sequential
    /// f64 fold in every backend). The inner optimizer's backend is set
    /// separately by `OptimSpec::build`.
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
    }

    /// The global gradient norm observed by the most recent
    /// `clip_by_global_norm` stage, *before* clipping (0 until the first
    /// step, or when no global-norm stage is configured).
    pub fn last_grad_norm(&self) -> f64 {
        self.last_norm as f64
    }

    /// Steps taken through this pipeline (the `tx_step` slot).
    pub fn step_count(&self) -> u64 {
        self.steps as u64
    }

    /// Two-phase deterministic squared-norm reduce over `src`: per-tile
    /// partials (parallel across scoped workers when `threads > 1`),
    /// combined in tile order on this thread.
    fn two_phase_sq_norm(&mut self, src: &[Tensor]) -> f64 {
        sq_norm_over(self.backend, &self.tiles, &mut self.partials, src,
                     self.threads)
    }

    /// Apply the gradient stages, filling `self.scratch` on the first
    /// rewriting stage. Returns whether scratch now holds the gradients.
    fn run_grad_stages(&mut self, grads: &[Tensor]) -> bool {
        let mut copied = false;
        for k in 0..self.stages.len() {
            match self.stages[k] {
                UpdateTransform::ClipByValue(c) => {
                    if copied {
                        for_each_indexed_mut(self.threads, &mut self.scratch,
                                          &|_, t| {
                            for v in t.data_mut() {
                                *v = v.clamp(-c, c);
                            }
                        });
                    } else {
                        for_each_indexed_mut(self.threads, &mut self.scratch,
                                          &|i, t| {
                            for (o, &g) in
                                t.data_mut().iter_mut().zip(grads[i].data())
                            {
                                *o = g.clamp(-c, c);
                            }
                        });
                        copied = true;
                    }
                }
                UpdateTransform::ClipByGlobalNorm(c) => {
                    let sq = if copied {
                        sq_norm_over(self.backend, &self.tiles,
                                     &mut self.partials, &self.scratch,
                                     self.threads)
                    } else {
                        self.two_phase_sq_norm(grads)
                    };
                    self.last_norm = sq.sqrt() as f32;
                    if let Some(s) = clip_scale(sq, c) {
                        if copied {
                            for_each_indexed_mut(self.threads,
                                              &mut self.scratch, &|_, t| {
                                for v in t.data_mut() {
                                    *v *= s;
                                }
                            });
                        } else {
                            for_each_indexed_mut(self.threads,
                                              &mut self.scratch, &|i, t| {
                                for (o, &g) in t.data_mut()
                                    .iter_mut()
                                    .zip(grads[i].data())
                                {
                                    *o = g * s;
                                }
                            });
                            copied = true;
                        }
                    }
                }
                UpdateTransform::Identity
                | UpdateTransform::DecoupledWeightDecay(_) => {}
            }
        }
        copied
    }
}

/// The two-phase reduce itself: fill `partials` (one per tile — in
/// parallel over contiguous tile ranges when `threads > 1`), then
/// combine in tile order on the calling thread. The partition and the
/// combine order never depend on `threads`, so the result is bitwise
/// identical at any thread count.
fn sq_norm_over(backend: Backend, tiles: &[NormTile], partials: &mut [f64],
                src: &[Tensor], threads: usize) -> f64 {
    debug_assert_eq!(partials.len(), tiles.len());
    for_each_indexed_mut(threads, partials,
                         &|i, p| *p = tile_sq_norm(backend, src, tiles[i]));
    partials.iter().fold(0.0f64, |a, &b| a + b)
}

impl Optimizer for Pipeline {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.wd.len(),
                   "pipeline built over {} leaves, stepped with {}",
                   self.wd.len(), params.len());
        self.steps += 1.0;
        // 1. gradient stages (into struct-held scratch; zero-copy when no
        //    stage fires)
        let copied = self.run_grad_stages(grads);
        // 2. decoupled weight decay — before the inner update, AdamW
        //    order: w ← w·(1 − (lr·s_i)·wd_i)
        if self.wd.iter().any(|&w| w != 0.0) {
            let (wd, scale) = (&self.wd, &self.lr_scale);
            for_each_indexed_mut(self.threads, params, &|i, t| {
                if wd[i] != 0.0 {
                    let eff = lr * scale[i];
                    let f = 1.0 - eff * wd[i];
                    for v in t.data_mut() {
                        *v *= f;
                    }
                }
            });
        }
        // 3. the inner update on the (possibly transformed) gradients
        let g = if copied { &self.scratch[..] } else { grads };
        self.inner.step(params, g, lr);
    }

    fn state_floats(&self) -> usize {
        self.inner.state_floats() + TRANSFORM_STATE_FLOATS
    }

    fn state_bytes(&self) -> usize {
        // the transform scalars are stored f32 (scalar-slot rule)
        self.inner.state_bytes() + 4 * TRANSFORM_STATE_FLOATS
    }

    fn state_dtype(&self) -> StateDtype {
        self.inner.state_dtype()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = vec![
            (0, "tx_step", Tensor::from_vec(&[1], vec![self.steps])),
            (0, "tx_norm", Tensor::from_vec(&[1], vec![self.last_norm])),
        ];
        out.extend(self.inner.state());
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        anyhow::ensure!(state.len() >= 2,
                        "pipeline state underrun: {} tensors, expected the \
                         tx_step/tx_norm slots plus the inner layout",
                        state.len());
        let mut it = state.into_iter();
        let step_t = it.next().expect("length checked above");
        let norm_t = it.next().expect("length checked above");
        anyhow::ensure!(step_t.len() == 1,
                        "tx_step must be a 1-element tensor, got {}",
                        step_t.len());
        anyhow::ensure!(norm_t.len() == 1,
                        "tx_norm must be a 1-element tensor, got {}",
                        norm_t.len());
        self.steps = step_t.data()[0];
        self.last_norm = norm_t.data()[0];
        self.inner.load_state(it.collect())
    }

    fn scratch_bytes(&self) -> usize {
        self.inner.scratch_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{OptimSpec, SgdmHp};
    use crate::optim::{self, Method};
    use crate::rng::Rng;

    fn specs() -> Vec<ParamSpec> {
        vec![ParamSpec::new("embed", &[20, 6]),
             ParamSpec::new("w", &[6, 6]),
             ParamSpec::new("b", &[70])]
    }

    fn rand_params(specs: &[ParamSpec], rng: &mut Rng) -> Vec<Tensor> {
        specs.iter().map(|s| Tensor::randn(&s.shape, 0.5, rng)).collect()
    }

    fn assert_bitwise(a: &[Tensor], b: &[Tensor], what: &str) {
        for (ta, tb) in a.iter().zip(b) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} != {y}");
            }
        }
    }

    /// Satellite: an identity pipeline (explicit wrapper, no effective
    /// stages) is bitwise identical to the bare optimizer across the
    /// whole registry × every state dtype.
    #[test]
    fn identity_pipeline_is_bitwise_identical_to_bare() {
        for dtype in StateDtype::ALL {
            for name in optim::ALL {
                let specs = specs();
                let mut bare = OptimSpec::named(name).unwrap()
                    .state_dtype(dtype).build(&specs).unwrap();
                let inner = OptimSpec::named(name).unwrap()
                    .state_dtype(dtype).build(&specs).unwrap();
                let mut pipe = Pipeline::new(
                    inner, &specs, vec![identity()], 1).unwrap();
                let mut rng = Rng::new(5);
                let init = rand_params(&specs, &mut rng);
                let mut pa = init.clone();
                let mut pb = init;
                for _ in 0..4 {
                    let grads = rand_params(&specs, &mut rng);
                    bare.step(&mut pa, &grads, 0.1);
                    pipe.step(&mut pb, &grads, 0.1);
                }
                assert_bitwise(&pa, &pb, &format!("{name} @ {dtype:?}"));
            }
        }
    }

    /// clip_by_value bounds every gradient entry; observed through a
    /// momentum-free SGD step at lr 1 from w = 0 (so w₁ = −g′ exactly).
    #[test]
    fn clip_by_value_bounds_entries() {
        let specs = vec![ParamSpec::new("w", &[4])];
        let inner = OptimSpec::new(
            Method::SgdMomentum(SgdmHp { beta1: 0.0 }))
            .build(&specs).unwrap();
        let mut pipe = Pipeline::new(inner, &specs,
                                     vec![clip_by_value(0.5)], 1).unwrap();
        let mut params = vec![Tensor::zeros(&[4])];
        let g = vec![Tensor::from_vec(&[4], vec![2.0, -3.0, 0.25, -0.5])];
        pipe.step(&mut params, &g, 1.0);
        assert_eq!(params[0].data(), &[-0.5, 0.5, -0.25, 0.5]);
        // the caller's gradient tensor is untouched
        assert_eq!(g[0].data(), &[2.0, -3.0, 0.25, -0.5]);
    }

    /// clip_by_global_norm actually bounds the global norm: a gradient
    /// set with ‖g‖ = 5 is scaled onto the norm-1 sphere, and a set
    /// already inside the ball is passed through bit-for-bit.
    #[test]
    fn clip_by_global_norm_bounds_the_norm() {
        let specs = vec![ParamSpec::new("a", &[1]),
                         ParamSpec::new("b", &[1])];
        let build = || {
            let inner = OptimSpec::new(
                Method::SgdMomentum(SgdmHp { beta1: 0.0 }))
                .build(&specs).unwrap();
            Pipeline::new(inner, &specs,
                          vec![clip_by_global_norm(1.0)], 1).unwrap()
        };
        // ‖(3, 4)‖ = 5 > 1 ⇒ scale 0.2
        let mut pipe = build();
        let mut params = vec![Tensor::zeros(&[1]), Tensor::zeros(&[1])];
        let g = vec![Tensor::from_vec(&[1], vec![3.0]),
                     Tensor::from_vec(&[1], vec![4.0])];
        pipe.step(&mut params, &g, 1.0);
        let clipped = ((params[0].data()[0] as f64).powi(2)
                       + (params[1].data()[0] as f64).powi(2)).sqrt();
        assert!((clipped - 1.0).abs() < 1e-6, "post-clip norm {clipped}");
        assert!((pipe.last_grad_norm() - 5.0).abs() < 1e-6);
        // inside the ball: bitwise pass-through of the gradients
        let mut pipe = build();
        let mut pa = vec![Tensor::zeros(&[1]), Tensor::zeros(&[1])];
        let g_small = vec![Tensor::from_vec(&[1], vec![0.3]),
                           Tensor::from_vec(&[1], vec![0.4])];
        pipe.step(&mut pa, &g_small, 1.0);
        assert_eq!(pa[0].data()[0].to_bits(), (-0.3f32).to_bits());
        assert_eq!(pa[1].data()[0].to_bits(), (-0.4f32).to_bits());
    }

    /// Satellite: decoupled weight decay matches a NumPy f32 oracle for
    /// Adam (the AdamW trajectory). Inputs are literal so the oracle
    /// script (same f32 op order) is exactly reproducible.
    #[test]
    fn decoupled_weight_decay_matches_numpy_oracle_adam() {
        let specs = vec![ParamSpec::new("w", &[5])];
        let mut pipe = OptimSpec::named("adam").unwrap()
            .weight_decay(0.01)
            .build(&specs).unwrap();
        let mut params =
            vec![Tensor::from_vec(&[5], vec![0.5, -0.3, 0.8, -1.2, 0.1])];
        let gs = [vec![0.4, -0.2, 0.1, 0.5, -0.3],
                  vec![-0.1, 0.3, -0.4, 0.2, 0.6],
                  vec![0.2, 0.2, -0.1, -0.3, 0.1]];
        for g in &gs {
            let g = vec![Tensor::from_vec(&[5], g.clone())];
            pipe.step(&mut params, &g, 0.1);
        }
        // python3 oracle: AdamW (decay first, lr 0.1, wd 0.01,
        // β₁ 0.9, β₂ 0.98, eps 1e-8), all-f32 arithmetic
        let expect = [0.290_720_82f32, -0.271_745_53, 0.810_559_33,
                      -1.415_960_2, 0.125_552_59];
        for (a, e) in params[0].data().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-5, "{a} vs oracle {e}");
        }
    }

    /// Satellite: the same oracle check for SM3 (vector leaf — the
    /// singleton cover, where SM3 runs the Adagrad kernel).
    #[test]
    fn decoupled_weight_decay_matches_numpy_oracle_sm3() {
        let specs = vec![ParamSpec::new("w", &[5])];
        let mut pipe = OptimSpec::named("sm3").unwrap()
            .weight_decay(0.01)
            .build(&specs).unwrap();
        let mut params =
            vec![Tensor::from_vec(&[5], vec![0.5, -0.3, 0.8, -1.2, 0.1])];
        let gs = [vec![0.4, -0.2, 0.1, 0.5, -0.3],
                  vec![-0.1, 0.3, -0.4, 0.2, 0.6],
                  vec![0.2, 0.2, -0.1, -0.3, 0.1]];
        for g in &gs {
            let g = vec![Tensor::from_vec(&[5], g.clone())];
            pipe.step(&mut params, &g, 0.1);
        }
        let expect = [0.471_671_9f32, -0.292_681_28, 0.791_311_44,
                      -1.225_660_7, 0.108_311_73];
        for (a, e) in params[0].data().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-5, "{a} vs oracle {e}");
        }
    }

    /// The pipeline's transform slots round-trip through
    /// `state`/`load_state`, and the inner layout rides behind them.
    #[test]
    fn transform_slots_roundtrip() {
        let specs = specs();
        let build = || {
            OptimSpec::named("adam").unwrap()
                .clip_by_global_norm(1.0)
                .weight_decay(0.01)
                .state_dtype(StateDtype::Q8)
                .build(&specs)
        };
        let mut pipe = build().unwrap();
        let mut rng = Rng::new(9);
        let mut params = rand_params(&specs, &mut rng);
        for _ in 0..3 {
            let grads = rand_params(&specs, &mut rng);
            pipe.step(&mut params, &grads, 0.1);
        }
        let st = pipe.state();
        assert_eq!((st[0].0, st[0].1), (0, "tx_step"));
        assert_eq!((st[1].0, st[1].1), (0, "tx_norm"));
        assert_eq!(st[0].2.data()[0], 3.0);
        assert!(st[1].2.data()[0] > 0.0);
        let tensors: Vec<Tensor> =
            st.into_iter().map(|(_, _, t)| t).collect();
        let mut fresh = build().unwrap();
        fresh.load_state(tensors.clone()).unwrap();
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(tensors, restored);
    }

    /// State accounting flows through: pipeline = inner + 2 scalars.
    #[test]
    fn state_accounting_adds_two_scalars() {
        let specs = specs();
        let bare = OptimSpec::named("adam").unwrap().build(&specs).unwrap();
        let pipe = OptimSpec::named("adam").unwrap()
            .clip_by_global_norm(1.0).weight_decay(0.01)
            .build(&specs).unwrap();
        assert_eq!(pipe.state_floats(), bare.state_floats() + 2);
        assert_eq!(pipe.state_bytes(), bare.state_bytes() + 8);
        assert_eq!(pipe.name(), "adam");
    }

    /// Steady-state pipeline steps are allocation-free at every state
    /// dtype (threads = 1 — the serial path; the counting allocator is
    /// thread-local, see `crate::alloc_count`).
    #[test]
    fn steady_state_pipeline_steps_are_allocation_free() {
        let specs = specs();
        let mut rng = Rng::new(2);
        let params0 = rand_params(&specs, &mut rng);
        let grads = rand_params(&specs, &mut rng);
        for dtype in StateDtype::ALL {
            for name in optim::ALL {
                let mut pipe = OptimSpec::named(name).unwrap()
                    .state_dtype(dtype)
                    .clip_by_value(0.8)
                    .clip_by_global_norm(1.0)
                    .weight_decay(0.01)
                    .build(&specs).unwrap();
                let mut params = params0.clone();
                for _ in 0..3 {
                    pipe.step(&mut params, &grads, 0.1);
                }
                let before = crate::alloc_count::thread_allocs();
                for _ in 0..2 {
                    pipe.step(&mut params, &grads, 0.1);
                }
                let allocs = crate::alloc_count::thread_allocs() - before;
                assert_eq!(allocs, 0,
                           "{name} @ {dtype:?}: {allocs} allocations in \
                            steady-state pipeline steps");
            }
        }
    }

    /// The two-phase reduce helpers agree with each other and with a
    /// plain f64 sum over multi-tile inputs.
    #[test]
    fn norm_helpers_agree() {
        let specs = vec![ParamSpec::new("big", &[NORM_TILE + 300]),
                         ParamSpec::new("b", &[33])];
        let mut rng = Rng::new(4);
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        let plain: f64 = grads
            .iter()
            .map(|t| t.data().iter()
                 .map(|&v| (v as f64) * (v as f64)).sum::<f64>())
            .sum();
        let tiled = global_sq_norm(&grads);
        assert!((plain - tiled).abs() <= 1e-9 * plain.max(1.0));
        // pipeline-internal reduce == free function, at 1 and 4 threads
        for threads in [1usize, 4] {
            let inner = OptimSpec::named("sgdm").unwrap()
                .build(&specs).unwrap();
            let mut pipe = Pipeline::new(
                inner, &specs, vec![clip_by_global_norm(1.0)],
                threads).unwrap();
            let got = pipe.two_phase_sq_norm(&grads);
            assert_eq!(got.to_bits(), tiled.to_bits(),
                       "x{threads}: {got} != {tiled}");
        }
        assert_eq!(clip_scale(4.0, 3.0), None);
        let s = clip_scale(25.0, 1.0).unwrap();
        assert!((s - 0.2).abs() < 1e-7);
    }
}
