//! The reference backend: today's loops, verbatim.
//!
//! Where a primitive already exists as a named, documented, tested
//! function (`kernel::adam_chunk`, `codec::q8_encode_slice`, …) this
//! backend delegates to it rather than copying the loop body — so the
//! reference semantics live in exactly one place and can never drift
//! from the seed behavior. The primitives that only ever existed inline
//! (the comms reduce/unpack lanes, the block amax scan, the norm
//! partial) are extracted here with their original op sequences intact.

use super::KernelBackend;
use crate::optim::kernel;
use crate::optim::qstate::codec;

/// The scalar (reference) implementation of [`KernelBackend`].
///
/// Stateless; obtain via `Backend::Scalar.imp()` or use the unit value
/// directly in tests.
pub struct ScalarBackend;

impl KernelBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn adagrad_update(&self, beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                      acc: &mut [f32], mom: &mut [f32]) {
        kernel::adagrad_chunk(beta1, lr, w, g, acc, mom);
    }

    #[allow(clippy::too_many_arguments)]
    fn adam_update(&self, b1: f32, b2: f32, eps: f32, bc1: f32, bc2: f32,
                   lr: f32, w: &mut [f32], g: &[f32], m: &mut [f32],
                   v: &mut [f32]) {
        kernel::adam_chunk(b1, b2, eps, bc1, bc2, lr, w, g, m, v);
    }

    fn sgdm_update(&self, beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                   mom: &mut [f32]) {
        kernel::sgdm_chunk(beta1, lr, w, g, mom);
    }

    fn add_assign(&self, dst: &mut [f32], src: &[f32]) {
        for (x, y) in dst.iter_mut().zip(src) {
            *x += y;
        }
    }

    fn scale_into(&self, dst: &mut [f32], src: &[f32], s: f32) {
        for (d, &x) in dst.iter_mut().zip(src) {
            *d = x * s;
        }
    }

    fn block_amax(&self, v: &[f32]) -> f32 {
        // the q8 encoder's scale scan, extracted: strict `>` keeps the
        // first maximum and |−0| = +0, so the result is order-invariant
        let mut amax = 0.0f32;
        for &x in v {
            let a = x.abs();
            if a > amax {
                amax = a;
            }
        }
        amax
    }

    fn q8_encode(&self, vals: &[f32], scales: &mut [f32], codes: &mut [u8]) {
        codec::q8_encode_slice(vals, scales, codes);
    }

    fn q8_decode(&self, scales: &[f32], codes: &[u8], out: &mut [f32]) {
        codec::q8_decode_slice(scales, codes, out);
    }

    fn bf16_encode(&self, vals: &[f32], out: &mut [u16]) {
        for (b, &x) in out.iter_mut().zip(vals) {
            *b = codec::f32_to_bf16(x);
        }
    }

    fn bf16_decode(&self, vals: &[u16], out: &mut [f32]) {
        for (o, &b) in out.iter_mut().zip(vals) {
            *o = codec::bf16_to_f32(b);
        }
    }

    fn sq_norm_partial(&self, v: &[f32]) -> f64 {
        // transform.rs's tile partial, verbatim: one sequential f64
        // accumulator in index order (the combine-order contract)
        let mut acc = 0.0f64;
        for &x in v {
            acc += (x as f64) * (x as f64);
        }
        acc
    }
}
