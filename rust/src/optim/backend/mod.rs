//! Kernel backend layering (DESIGN.md §13): one [`KernelBackend`] trait
//! under every tile-granular inner loop in the crate — step-kernel update
//! lanes, q8/bf16 codecs, block amax, global-norm partials, and the comms
//! reduce/unpack lanes — with two implementations gated bitwise against
//! each other:
//!
//! * [`ScalarBackend`] — the reference semantics. Delegates to the same
//!   named loops the crate has always run (`kernel::adam_chunk`,
//!   `codec::q8_encode_slice`, …), so "scalar" *is* the seed behavior.
//! * [`SimdBackend`] — explicit 8-lane unrolling of the same loops in
//!   stable Rust (fixed-size inner blocks the autovectorizer maps onto
//!   vector units), written so every primitive is bitwise identical to
//!   scalar: elementwise lanes keep the exact per-element op sequence
//!   (no FMA contraction, no reassociation), block amax exploits that
//!   max over NaN-free absolute values is order-invariant, and the f64
//!   sum-of-squares partial deliberately stays a single sequential
//!   accumulator because float addition does *not* reassociate.
//!
//! Dispatch is by the [`Backend`] enum — a `Copy` token threaded through
//! [`StateOpts`](crate::optim::StateOpts), the optimizer structs, the
//! comm engine, `TrainConfig::kernel_backend`, and the `--kernel-backend`
//! CLI flag. [`Backend::imp`] resolves it to a `&'static dyn
//! KernelBackend`; one virtual call per *tile* (4096 elements on the step
//! path, 64-element blocks only inside a slice call) is noise next to the
//! sqrt/div arithmetic it amortizes.
//!
//! The `simd` cargo feature does not gate compilation — both backends
//! always build and are proptest-gated against each other — it only
//! flips [`Backend::default`] from `Scalar` to `Simd`, so a
//! `--features simd` build exercises the vectorized lanes everywhere
//! without touching any call site.

pub mod scalar;
pub mod simd;

pub use scalar::ScalarBackend;
pub use simd::SimdBackend;

use anyhow::{bail, Result};

/// Which [`KernelBackend`] implementation the hot loops dispatch to.
///
/// Parsed from `TrainConfig::kernel_backend` / `--kernel-backend`;
/// defaults to [`Backend::Scalar`] (reference semantics) unless the crate
/// is built with `--features simd`, which flips the default to
/// [`Backend::Simd`]. Every implementation is bitwise identical on every
/// primitive (property-tested in `crate::proptest`), so the choice is a
/// pure performance knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Reference scalar loops — the seed semantics, verbatim.
    Scalar,
    /// Explicit 8-lane unrolled loops, bitwise identical to scalar.
    Simd,
}

impl Default for Backend {
    fn default() -> Self {
        if cfg!(feature = "simd") {
            Backend::Simd
        } else {
            Backend::Scalar
        }
    }
}

impl Backend {
    /// Every backend, scalar (reference) first.
    pub const ALL: [Backend; 2] = [Backend::Scalar, Backend::Simd];

    /// Parse a config/CLI name ("scalar" | "simd").
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "scalar" => Backend::Scalar,
            "simd" => Backend::Simd,
            other => bail!("unknown kernel backend {other:?} (scalar|simd)"),
        })
    }

    /// Canonical name (inverse of [`Backend::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Simd => "simd",
        }
    }

    /// Resolve the token to its implementation. `&'static` because both
    /// backends are stateless unit structs; callers hoist this out of
    /// per-element loops and pay one virtual dispatch per tile.
    #[inline]
    pub fn imp(self) -> &'static dyn KernelBackend {
        match self {
            Backend::Scalar => &ScalarBackend,
            Backend::Simd => &SimdBackend,
        }
    }
}

/// The primitive tile operations every hot loop in the crate is built
/// from. One implementation = one uniform answer to "how does this crate
/// run an inner loop" — swapping it accelerates the step kernels, the
/// qstate codecs, the global-norm reduction, and the comms wire path at
/// once.
///
/// # Contract
///
/// Every method is a pure function of its arguments (no hidden state —
/// implementations are stateless unit structs) and every implementation
/// must be **bitwise identical** to [`ScalarBackend`] on every input the
/// crate can produce (finite values; the q8 encoder additionally
/// debug-asserts finiteness like the reference codec). Concretely that
/// means: identical per-element operation sequence for elementwise lanes
/// (no FMA, no strength reduction that changes rounding), identical
/// combine order for order-sensitive reductions ([`KernelBackend::sq_norm_partial`]
/// must keep one sequential f64 accumulator), and order-*invariant*
/// reductions (max of absolute values) may reassociate freely. Slice
/// length handling must match the reference exactly — lanes are unrolled,
/// never padded, and remainders run the identical scalar op sequence.
pub trait KernelBackend: Sync {
    /// Canonical backend name (matches [`Backend::name`]).
    fn name(&self) -> &'static str;

    /// Adagrad-with-momentum update lanes over one tile:
    /// `nu = acc + g²; mom = β₁·mom + (1−β₁)·g·rsqrt(nu); w −= lr·mom;
    /// acc = nu` per element (see `kernel::adagrad_chunk`).
    fn adagrad_update(&self, beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                      acc: &mut [f32], mom: &mut [f32]);

    /// Adam update lanes over one tile: EWMA moments, bias correction by
    /// the precomputed `bc1`/`bc2`, then `w −= lr·m̂/(√v̂+ε)` per element
    /// (see `kernel::adam_chunk`).
    #[allow(clippy::too_many_arguments)]
    fn adam_update(&self, b1: f32, b2: f32, eps: f32, bc1: f32, bc2: f32,
                   lr: f32, w: &mut [f32], g: &[f32], m: &mut [f32],
                   v: &mut [f32]);

    /// Heavy-ball momentum SGD lanes over one tile:
    /// `mom = β₁·mom + g; w −= lr·mom` per element
    /// (see `kernel::sgdm_chunk`).
    fn sgdm_update(&self, beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                   mom: &mut [f32]);

    /// Elementwise `dst[k] += src[k]` over `min(dst.len(), src.len())`
    /// elements — the f32 ring-reduce hop.
    fn add_assign(&self, dst: &mut [f32], src: &[f32]);

    /// Elementwise `dst[k] = src[k] * s` over `min(dst.len(), src.len())`
    /// elements — the comms unpack (mean-finalize) lane.
    fn scale_into(&self, dst: &mut [f32], src: &[f32], s: f32);

    /// Maximum absolute value of `v` (0.0 for an empty slice). The q8
    /// block-scale scan. NaN-free contract: callers feed optimizer state,
    /// which the codec debug-asserts finite; ±0 and infinities are fine
    /// (|−0| = +0, and max over non-negative values is order-invariant,
    /// which is what lets the 8-lane split reduce bitwise-identically).
    fn block_amax(&self, v: &[f32]) -> f32;

    /// q8-encode `vals` per 64-element block (see
    /// `codec::q8_encode_slice` for the wire format and the canonical
    /// zero / saturated-block semantics, which implementations must
    /// reproduce exactly). `scales` holds one f32 per block, `codes` one
    /// byte per element; both must already be sized.
    fn q8_encode(&self, vals: &[f32], scales: &mut [f32], codes: &mut [u8]);

    /// Decode q8 blocks back to f32 (see `codec::q8_decode_slice`;
    /// ±127 codes decode to ±amax exactly — the idempotence contract).
    fn q8_decode(&self, scales: &[f32], codes: &[u8], out: &mut [f32]);

    /// Round-to-nearest-even truncate each f32 to bf16
    /// (see `codec::f32_to_bf16`).
    fn bf16_encode(&self, vals: &[f32], out: &mut [u16]);

    /// Widen each bf16 back to f32 (exact; see `codec::bf16_to_f32`).
    fn bf16_decode(&self, vals: &[u16], out: &mut [f32]);

    /// Partial sum of squares of one tile, accumulated in f64 —
    /// **sequentially, in index order, in every implementation**: f64
    /// addition does not reassociate, and the global-norm determinism
    /// argument (DESIGN.md §13) leans on every backend producing the
    /// same per-tile partial. A reassociation-tolerant mode is
    /// documented design space, not implemented.
    fn sq_norm_partial(&self, v: &[f32]) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_roundtrip() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
            assert_eq!(b.imp().name(), b.name());
        }
        assert!(Backend::parse("avx2").is_err());
        assert!(Backend::parse("").is_err());
    }

    #[test]
    fn default_tracks_the_simd_feature() {
        let want = if cfg!(feature = "simd") {
            Backend::Simd
        } else {
            Backend::Scalar
        };
        assert_eq!(Backend::default(), want);
    }

    #[test]
    fn dispatch_is_stateless_and_static() {
        // same token → same implementation object, usable from anywhere
        let a = Backend::Simd.imp();
        let b = Backend::Simd.imp();
        assert_eq!(a.name(), b.name());
        assert_eq!(a.block_amax(&[-3.0, 2.0]), 3.0);
        assert_eq!(b.block_amax(&[]), 0.0);
    }
}
