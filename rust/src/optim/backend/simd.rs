//! The vectorized backend: explicit 8-lane unrolling in stable Rust.
//!
//! Each primitive processes `chunks_exact(8)` bodies with a fixed-bound
//! inner loop — the shape LLVM's autovectorizer reliably maps onto
//! 8-wide vector units (AVX/NEON; the same 8-lane granularity the
//! VPU-style accelerators use for elementwise work) — and runs the
//! *identical scalar op sequence* over the remainder. Nothing here may
//! change numerics:
//!
//! * elementwise lanes keep the reference per-element expression exactly
//!   (no `mul_add` — FMA skips the intermediate rounding and would break
//!   the bitwise gate);
//! * the amax scan may lane-split because max over NaN-free absolute
//!   values is order-invariant (every non-negative f32 has one bit
//!   pattern, so "same value" is "same bits");
//! * the f64 sum-of-squares partial stays sequential — float addition
//!   does not reassociate, and the global-norm determinism argument
//!   needs every backend to produce the same per-tile partial.
//!
//! Equivalence with [`ScalarBackend`] is enforced bitwise per primitive
//! and end-to-end in `crate::proptest` (lengths off the 8- and 64-grids,
//! denormals, ±0).

use super::{KernelBackend, ScalarBackend};
use crate::optim::qstate::codec;
use crate::optim::safe_rsqrt;

/// Unroll width (f32 lanes per inner block).
const LANES: usize = 8;

/// The 8-lane unrolled implementation of [`KernelBackend`], bitwise
/// identical to [`ScalarBackend`] on every primitive.
///
/// Stateless; obtain via `Backend::Simd.imp()` or use the unit value
/// directly in tests.
pub struct SimdBackend;

impl KernelBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn adagrad_update(&self, beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                      acc: &mut [f32], mom: &mut [f32]) {
        let mut wi = w.chunks_exact_mut(LANES);
        let mut gi = g.chunks_exact(LANES);
        let mut ai = acc.chunks_exact_mut(LANES);
        let mut mi = mom.chunks_exact_mut(LANES);
        for (((wc, gc), ac), mc) in
            (&mut wi).zip(&mut gi).zip(&mut ai).zip(&mut mi)
        {
            for k in 0..LANES {
                let nu = ac[k] + gc[k] * gc[k];
                let upd = gc[k] * safe_rsqrt(nu);
                mc[k] = beta1 * mc[k] + (1.0 - beta1) * upd;
                wc[k] -= lr * mc[k];
                ac[k] = nu;
            }
        }
        let (wr, gr) = (wi.into_remainder(), gi.remainder());
        let (ar, mr) = (ai.into_remainder(), mi.into_remainder());
        for k in 0..wr.len() {
            let nu = ar[k] + gr[k] * gr[k];
            let upd = gr[k] * safe_rsqrt(nu);
            mr[k] = beta1 * mr[k] + (1.0 - beta1) * upd;
            wr[k] -= lr * mr[k];
            ar[k] = nu;
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn adam_update(&self, b1: f32, b2: f32, eps: f32, bc1: f32, bc2: f32,
                   lr: f32, w: &mut [f32], g: &[f32], m: &mut [f32],
                   v: &mut [f32]) {
        let mut wi = w.chunks_exact_mut(LANES);
        let mut gi = g.chunks_exact(LANES);
        let mut mi = m.chunks_exact_mut(LANES);
        let mut vi = v.chunks_exact_mut(LANES);
        for (((wc, gc), mc), vc) in
            (&mut wi).zip(&mut gi).zip(&mut mi).zip(&mut vi)
        {
            for k in 0..LANES {
                mc[k] = b1 * mc[k] + (1.0 - b1) * gc[k];
                vc[k] = b2 * vc[k] + (1.0 - b2) * gc[k] * gc[k];
                let mhat = mc[k] / bc1;
                let vhat = vc[k] / bc2;
                wc[k] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        let (wr, gr) = (wi.into_remainder(), gi.remainder());
        let (mr, vr) = (mi.into_remainder(), vi.into_remainder());
        for k in 0..wr.len() {
            mr[k] = b1 * mr[k] + (1.0 - b1) * gr[k];
            vr[k] = b2 * vr[k] + (1.0 - b2) * gr[k] * gr[k];
            let mhat = mr[k] / bc1;
            let vhat = vr[k] / bc2;
            wr[k] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }

    fn sgdm_update(&self, beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                   mom: &mut [f32]) {
        let mut wi = w.chunks_exact_mut(LANES);
        let mut gi = g.chunks_exact(LANES);
        let mut mi = mom.chunks_exact_mut(LANES);
        for ((wc, gc), mc) in (&mut wi).zip(&mut gi).zip(&mut mi) {
            for k in 0..LANES {
                mc[k] = beta1 * mc[k] + gc[k];
                wc[k] -= lr * mc[k];
            }
        }
        let (wr, gr, mr) =
            (wi.into_remainder(), gi.remainder(), mi.into_remainder());
        for k in 0..wr.len() {
            mr[k] = beta1 * mr[k] + gr[k];
            wr[k] -= lr * mr[k];
        }
    }

    fn add_assign(&self, dst: &mut [f32], src: &[f32]) {
        let mut di = dst.chunks_exact_mut(LANES);
        let mut si = src.chunks_exact(LANES);
        for (dc, sc) in (&mut di).zip(&mut si) {
            for k in 0..LANES {
                dc[k] += sc[k];
            }
        }
        for (x, y) in di.into_remainder().iter_mut().zip(si.remainder()) {
            *x += y;
        }
    }

    fn scale_into(&self, dst: &mut [f32], src: &[f32], s: f32) {
        let mut di = dst.chunks_exact_mut(LANES);
        let mut si = src.chunks_exact(LANES);
        for (dc, sc) in (&mut di).zip(&mut si) {
            for k in 0..LANES {
                dc[k] = sc[k] * s;
            }
        }
        for (d, &x) in di.into_remainder().iter_mut().zip(si.remainder()) {
            *d = x * s;
        }
    }

    fn block_amax(&self, v: &[f32]) -> f32 {
        // max over |v| is order-invariant (NaN-free contract, |−0| = +0,
        // one bit pattern per non-negative value), so lane maxima plus a
        // horizontal reduce are bitwise identical to the sequential scan
        let mut it = v.chunks_exact(LANES);
        let mut lanes = [0.0f32; LANES];
        for c in &mut it {
            for k in 0..LANES {
                let a = c[k].abs();
                if a > lanes[k] {
                    lanes[k] = a;
                }
            }
        }
        let mut amax = 0.0f32;
        for &l in &lanes {
            if l > amax {
                amax = l;
            }
        }
        for &x in it.remainder() {
            let a = x.abs();
            if a > amax {
                amax = a;
            }
        }
        amax
    }

    fn q8_encode(&self, vals: &[f32], scales: &mut [f32], codes: &mut [u8]) {
        debug_assert_eq!(scales.len(), codec::q8_blocks(vals.len()));
        debug_assert_eq!(codes.len(), vals.len());
        for (bi, block) in vals.chunks(codec::Q8_BLOCK).enumerate() {
            let lo = bi * codec::Q8_BLOCK;
            let cb = &mut codes[lo..lo + block.len()];
            debug_assert!(block.iter().all(|x| x.is_finite()),
                          "non-finite optimizer-state value reached the q8 \
                           encoder (diverged accumulator?)");
            let amax = self.block_amax(block);
            if amax.is_infinite() {
                // reference saturation semantics, see codec::q8_encode_slice
                scales[bi] = f32::MAX;
                for (c, &x) in cb.iter_mut().zip(block) {
                    *c = if x == f32::INFINITY {
                        254
                    } else if x == f32::NEG_INFINITY {
                        0
                    } else {
                        codec::Q8_ZERO_CODE
                    };
                }
                continue;
            }
            let scale = amax / 127.0;
            if scale == 0.0 {
                scales[bi] = 0.0;
                for c in cb.iter_mut() {
                    *c = codec::Q8_ZERO_CODE;
                }
                continue;
            }
            scales[bi] = amax;
            let mut vi = block.chunks_exact(LANES);
            let mut ci = cb.chunks_exact_mut(LANES);
            for (vc, cc) in (&mut vi).zip(&mut ci) {
                for k in 0..LANES {
                    let q = (codec::round_ties_even(vc[k] / scale) as i32)
                        .clamp(-127, 127);
                    cc[k] = (q + 127) as u8;
                }
            }
            for (c, &x) in ci.into_remainder().iter_mut().zip(vi.remainder())
            {
                let q = (codec::round_ties_even(x / scale) as i32)
                    .clamp(-127, 127);
                *c = (q + 127) as u8;
            }
        }
    }

    fn q8_decode(&self, scales: &[f32], codes: &[u8], out: &mut [f32]) {
        debug_assert_eq!(scales.len(), codec::q8_blocks(codes.len()));
        debug_assert_eq!(out.len(), codes.len());
        for (b, block) in codes.chunks(codec::Q8_BLOCK).enumerate() {
            let lo = b * codec::Q8_BLOCK;
            let ob = &mut out[lo..lo + block.len()];
            let amax = scales[b];
            let scale = amax / 127.0;
            let mut ci = block.chunks_exact(LANES);
            let mut oi = ob.chunks_exact_mut(LANES);
            for (cc, oc) in (&mut ci).zip(&mut oi) {
                for k in 0..LANES {
                    let q = cc[k] as i32 - 127;
                    oc[k] = match q {
                        127 => amax,
                        -127 => -amax,
                        _ => scale * q as f32,
                    };
                }
            }
            for (o, &c) in oi.into_remainder().iter_mut().zip(ci.remainder())
            {
                let q = c as i32 - 127;
                *o = match q {
                    127 => amax,
                    -127 => -amax,
                    _ => scale * q as f32,
                };
            }
        }
    }

    fn bf16_encode(&self, vals: &[f32], out: &mut [u16]) {
        let mut vi = vals.chunks_exact(LANES);
        let mut oi = out.chunks_exact_mut(LANES);
        for (vc, oc) in (&mut vi).zip(&mut oi) {
            for k in 0..LANES {
                oc[k] = codec::f32_to_bf16(vc[k]);
            }
        }
        for (b, &x) in oi.into_remainder().iter_mut().zip(vi.remainder()) {
            *b = codec::f32_to_bf16(x);
        }
    }

    fn bf16_decode(&self, vals: &[u16], out: &mut [f32]) {
        let mut vi = vals.chunks_exact(LANES);
        let mut oi = out.chunks_exact_mut(LANES);
        for (vc, oc) in (&mut vi).zip(&mut oi) {
            for k in 0..LANES {
                oc[k] = codec::bf16_to_f32(vc[k]);
            }
        }
        for (o, &b) in oi.into_remainder().iter_mut().zip(vi.remainder()) {
            *o = codec::bf16_to_f32(b);
        }
    }

    fn sq_norm_partial(&self, v: &[f32]) -> f64 {
        // deliberately NOT unrolled: f64 addition is order-sensitive and
        // the determinism contract fixes the combine order (DESIGN.md §13)
        ScalarBackend.sq_norm_partial(v)
    }
}
