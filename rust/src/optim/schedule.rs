//! Learning-rate schedules — paper Appendix C, Table 4.
//!
//! | optimizer (experiment)      | post-warmup schedule        |
//! |-----------------------------|-----------------------------|
//! | Adam/Adafactor (Transformer)| η·√(d/t)                    |
//! | Adam/Adafactor (BERT)       | η·(1 − t/T)                 |
//! | SGD+momentum (AmoebaNet)    | max{η₀, η·α^⌊t/τ⌋}          |
//! | Adagrad, SM3 (all)          | η (constant — the paper's   |
//! |                             | "single hyper-parameter")   |
//!
//! All schedules are wrapped in linear warmup over the first `T₀` steps:
//! the paper gradually ramps η from zero for every optimizer.

/// Post-warmup decay shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Decay {
    /// η (SM3/Adagrad: no decay schedule to tune).
    Constant,
    /// η·√(d/t) — the Transformer schedule; `d` is the model dimension.
    Rsqrt {
        /// model dimension d
        d: f64,
    },
    /// η·(1 − t/T) — the BERT schedule; `t_total` is T.
    Linear {
        /// total step count T
        t_total: u64,
    },
    /// max{η₀, η·α^⌊t/τ⌋} — staircase exponential (AmoebaNet SGD).
    Staircase {
        /// LR floor η₀
        eta0: f64,
        /// per-stair decay factor α
        alpha: f64,
        /// stair width τ in steps
        tau: u64,
    },
}

/// A complete schedule: base rate, warmup, decay.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// base learning rate η
    pub base: f64,
    /// linear-warmup steps T₀
    pub warmup: u64,
    /// post-warmup decay shape
    pub decay: Decay,
}

impl Schedule {
    /// Constant η after warmup.
    pub fn constant(base: f64, warmup: u64) -> Self {
        Self { base, warmup, decay: Decay::Constant }
    }

    /// Inverse-sqrt decay (the Transformer schedule).
    pub fn rsqrt(base: f64, warmup: u64, d: usize) -> Self {
        Self { base, warmup, decay: Decay::Rsqrt { d: d as f64 } }
    }

    /// Linear decay to zero at `t_total` (the BERT schedule).
    pub fn linear(base: f64, warmup: u64, t_total: u64) -> Self {
        Self { base, warmup, decay: Decay::Linear { t_total } }
    }

    /// Staircase exponential decay with floor η₀ (AmoebaNet SGD).
    pub fn staircase(base: f64, warmup: u64, eta0: f64, alpha: f64, tau: u64)
                     -> Self {
        Self { base, warmup, decay: Decay::Staircase { eta0, alpha, tau } }
    }

    /// Parse from config: "constant" | "rsqrt" | "linear" | "staircase",
    /// with the default staircase parameters.
    pub fn from_name(name: &str, base: f64, warmup: u64, d_model: usize,
                     t_total: u64) -> anyhow::Result<Self> {
        Self::from_name_with(name, base, warmup, d_model, t_total,
                             &StaircaseParams::default())
    }

    /// [`Schedule::from_name`] with explicit staircase parameters
    /// (config keys `lr_eta0` / `lr_alpha` / `lr_tau`; validated here).
    pub fn from_name_with(name: &str, base: f64, warmup: u64, d_model: usize,
                          t_total: u64, stair: &StaircaseParams)
                          -> anyhow::Result<Self> {
        Ok(match name {
            "constant" => Self::constant(base, warmup),
            "rsqrt" => Self::rsqrt(base, warmup, d_model),
            "linear" => Self::linear(base, warmup, t_total),
            "staircase" => {
                let (eta0, alpha, tau) = stair.resolve(base, t_total)?;
                Self::staircase(base, warmup, eta0, alpha, tau)
            }
            other => anyhow::bail!("unknown schedule {other:?}"),
        })
    }

    /// Learning rate at (1-based) step `t`.
    pub fn lr(&self, t: u64) -> f64 {
        let t = t.max(1);
        let warm = if self.warmup > 0 && t <= self.warmup {
            t as f64 / self.warmup as f64
        } else {
            1.0
        };
        let decayed = match &self.decay {
            Decay::Constant => self.base,
            Decay::Rsqrt { d } => {
                // η·√(d/t), counting t from the end of warmup (Vaswani et al.)
                let tt = (t.max(self.warmup + 1) - self.warmup) as f64;
                self.base * (d / tt).sqrt()
            }
            Decay::Linear { t_total } => {
                self.base * (1.0 - t as f64 / *t_total as f64).max(0.0)
            }
            Decay::Staircase { eta0, alpha, tau } => {
                (self.base * alpha.powf((t / tau) as f64)).max(*eta0)
            }
        };
        warm * decayed
    }
}

/// Staircase-decay parameters (AmoebaNet SGD, Table 4). The defaults are
/// the values `Schedule::from_name` used to hard-code; a config can now
/// override each (`lr_eta0` / `lr_alpha` / `lr_tau` under `[optim]`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StaircaseParams {
    /// floor η₀; `None` derives the old default, `base · 0.01`
    pub eta0: Option<f64>,
    /// per-stair decay factor α, must satisfy 0 < α < 1
    pub alpha: f64,
    /// stair width τ in steps; `None` derives `max(t_total / 10, 1)`
    pub tau: Option<u64>,
}

impl Default for StaircaseParams {
    fn default() -> Self {
        Self { eta0: None, alpha: 0.88, tau: None }
    }
}

impl StaircaseParams {
    /// Resolve against the run's base LR and total steps, validating
    /// ranges (a decay factor outside (0, 1) would grow the LR or stall
    /// it — reject loudly instead of training with it).
    pub fn resolve(&self, base: f64, t_total: u64)
                   -> anyhow::Result<(f64, f64, u64)> {
        anyhow::ensure!(self.alpha > 0.0 && self.alpha < 1.0,
                        "lr_alpha must be in (0, 1), got {}", self.alpha);
        let eta0 = self.eta0.unwrap_or(base * 0.01);
        anyhow::ensure!(eta0.is_finite() && eta0 >= 0.0,
                        "lr_eta0 must be a finite non-negative floor, \
                         got {eta0}");
        let tau = self.tau.unwrap_or((t_total / 10).max(1));
        anyhow::ensure!(tau >= 1, "lr_tau must be >= 1 step");
        Ok((eta0, self.alpha, tau))
    }
}

/// The paper's default schedule per optimizer name (Table 4).
pub fn paper_default(opt: &str, base: f64, warmup: u64, d_model: usize,
                     t_total: u64) -> Schedule {
    paper_default_with(opt, base, warmup, d_model, t_total,
                       &StaircaseParams::default())
        .expect("default staircase parameters are valid")
}

/// [`paper_default`] with explicit staircase parameters (only the sgdm
/// row uses them).
pub fn paper_default_with(opt: &str, base: f64, warmup: u64, d_model: usize,
                          t_total: u64, stair: &StaircaseParams)
                          -> anyhow::Result<Schedule> {
    Ok(match opt {
        "adam" | "adafactor" => Schedule::rsqrt(base, warmup, d_model),
        "sgdm" => {
            let (eta0, alpha, tau) = stair.resolve(base, t_total)?;
            Schedule::staircase(base, warmup, eta0, alpha, tau)
        }
        // Adagrad and both SM3 variants: constant past warmup
        _ => Schedule::constant(base, warmup),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::constant(1.0, 10);
        assert!((s.lr(1) - 0.1).abs() < 1e-12);
        assert!((s.lr(5) - 0.5).abs() < 1e-12);
        assert!((s.lr(10) - 1.0).abs() < 1e-12);
        assert!((s.lr(100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_never_decays() {
        let s = Schedule::constant(0.25, 0);
        assert_eq!(s.lr(1), 0.25);
        assert_eq!(s.lr(1_000_000), 0.25);
    }

    #[test]
    fn rsqrt_decays_after_warmup() {
        let s = Schedule::rsqrt(0.001, 100, 512);
        let a = s.lr(200);
        let b = s.lr(800);
        assert!(b < a);
        // ratio follows sqrt: lr(t) ∝ 1/sqrt(t - warmup)
        let expect = ((200.0f64 - 100.0) / (800.0 - 100.0)).sqrt();
        assert!((b / a - expect).abs() < 1e-9);
    }

    #[test]
    fn linear_hits_zero_at_t_total() {
        let s = Schedule::linear(0.1, 0, 1000);
        assert!(s.lr(1000) < 1e-12);
        assert!((s.lr(500) - 0.05).abs() < 1e-9);
    }

    #[test]
    fn staircase_steps_down_and_floors() {
        let s = Schedule::staircase(1.0, 0, 0.05, 0.5, 100);
        assert_eq!(s.lr(50), 1.0);
        assert_eq!(s.lr(150), 0.5);
        assert_eq!(s.lr(250), 0.25);
        // floor
        assert_eq!(s.lr(10_000), 0.05);
    }

    /// ISSUE 3 satellite: the staircase parameters are configurable, the
    /// old hard-coded values remain the defaults, and α is validated.
    #[test]
    fn staircase_params_resolve_and_validate() {
        // defaults reproduce the historical hard-coding
        let d = StaircaseParams::default();
        let (eta0, alpha, tau) = d.resolve(0.5, 1000).unwrap();
        assert_eq!(eta0, 0.5 * 0.01);
        assert_eq!(alpha, 0.88);
        assert_eq!(tau, 100);
        // t_total < 10 floors tau at 1
        assert_eq!(d.resolve(0.5, 3).unwrap().2, 1);
        // explicit overrides pass through
        let p = StaircaseParams { eta0: Some(0.02), alpha: 0.5,
                                  tau: Some(250) };
        assert_eq!(p.resolve(1.0, 1000).unwrap(), (0.02, 0.5, 250));
        let s = Schedule::from_name_with("staircase", 1.0, 0, 512, 1000, &p)
            .unwrap();
        assert_eq!(s.lr(100), 1.0);
        assert_eq!(s.lr(300), 0.5);
        assert_eq!(s.lr(100_000), 0.02); // the configured floor
        // 0 < alpha < 1 is enforced
        for bad in [0.0, 1.0, 1.5, -0.1] {
            let p = StaircaseParams { alpha: bad, ..Default::default() };
            assert!(p.resolve(1.0, 1000).is_err(), "alpha {bad} accepted");
            assert!(Schedule::from_name_with(
                "staircase", 1.0, 0, 512, 1000, &p).is_err());
        }
        // non-staircase schedules ignore the params entirely
        assert!(Schedule::from_name_with(
            "constant", 1.0, 0, 512, 1000,
            &StaircaseParams { alpha: 0.88, eta0: Some(-1.0), tau: Some(0) })
            .is_ok());
        // negative floor rejected on the staircase path
        let p = StaircaseParams { eta0: Some(-1.0), ..Default::default() };
        assert!(p.resolve(1.0, 1000).is_err());
    }

    #[test]
    fn paper_defaults_match_table4() {
        assert_eq!(paper_default("sm3", 0.1, 10, 512, 1000).decay,
                   Decay::Constant);
        assert_eq!(paper_default("adagrad", 0.1, 10, 512, 1000).decay,
                   Decay::Constant);
        assert!(matches!(paper_default("adam", 0.1, 10, 512, 1000).decay,
                         Decay::Rsqrt { .. }));
        assert!(matches!(paper_default("sgdm", 0.1, 10, 512, 1000).decay,
                         Decay::Staircase { .. }));
    }
}
