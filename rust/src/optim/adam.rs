//! Adam (Kingma & Ba) — the 2×d-state baseline whose memory footprint
//! motivates the paper (Tables 1–2).

use super::backend::Backend;
use super::kernel::{self, ChunkScratch};
use super::qstate::{QuantizedSlots, StateDtype};
use super::{Optimizer, ParamSpec};
use crate::pool::Pool;
use crate::tensor::Tensor;
use anyhow::ensure;

/// Adam optimizer state over a parameter list (see [`AdamHp`] for the
/// hyperparameters; `eps` is configurable — `[optim] eps` / `--eps`).
///
/// [`AdamHp`]: super::AdamHp
pub struct Adam {
    beta1: f32,
    beta2: f32,
    eps: f32,
    /// global step count for bias correction — an integer-valued scalar,
    /// deliberately NOT stored through the quantized slots (q8 would
    /// perturb `beta^t`)
    t: f32,
    /// streaming tile (elements; multiple of the q8 block)
    chunk: usize,
    /// kernel backend for the update lanes (bitwise identical across
    /// backends — DESIGN.md §13)
    backend: Backend,
    scratch: ChunkScratch,
    /// leaf `i`: slot `2i` is the first moment m, slot `2i + 1` the
    /// second moment v
    slots: QuantizedSlots,
    specs: Vec<ParamSpec>,
}

impl Adam {
    /// f32-state instance (see [`Adam::with_opts`]).
    pub fn new(specs: &[ParamSpec], beta1: f32, beta2: f32, eps: f32) -> Self {
        Self::with_dtype(specs, beta1, beta2, eps, StateDtype::F32)
    }

    /// Instance with explicit state-storage precision.
    pub fn with_dtype(specs: &[ParamSpec], beta1: f32, beta2: f32, eps: f32,
                      dtype: StateDtype) -> Self {
        Self::with_opts(specs, beta1, beta2, eps, dtype,
                        kernel::DEFAULT_CHUNK)
    }

    /// Fully explicit instance: hyperparameters, storage precision, and
    /// streaming tile (panics on an invalid tile — `OptimSpec` validates
    /// upstream).
    pub fn with_opts(specs: &[ParamSpec], beta1: f32, beta2: f32, eps: f32,
                     dtype: StateDtype, chunk: usize) -> Self {
        Self::build(specs, beta1, beta2, eps, dtype, chunk, None)
    }

    /// [`Adam::with_opts`] with state slots and decode scratch leased
    /// from `pool` (bitwise identical to the unpooled constructor).
    pub fn with_opts_in(specs: &[ParamSpec], beta1: f32, beta2: f32,
                        eps: f32, dtype: StateDtype, chunk: usize,
                        pool: &Pool) -> Self {
        Self::build(specs, beta1, beta2, eps, dtype, chunk, Some(pool))
    }

    fn build(specs: &[ParamSpec], beta1: f32, beta2: f32, eps: f32,
             dtype: StateDtype, chunk: usize, pool: Option<&Pool>) -> Self {
        kernel::check_chunk(chunk).unwrap();
        let mut slots = match pool {
            Some(p) => QuantizedSlots::new_in(dtype, p.clone()),
            None => QuantizedSlots::new(dtype),
        };
        for s in specs {
            slots.add_zeros(s.numel()); // m
            slots.add_zeros(s.numel()); // v
        }
        let scratch = match pool {
            Some(p) => ChunkScratch::new_in(p),
            None => ChunkScratch::default(),
        };
        Self { beta1, beta2, eps, t: 0.0, chunk,
               backend: Backend::default(),
               scratch, slots,
               specs: specs.to_vec() }
    }

    /// Route the update lanes and the state store's codec lanes through
    /// `backend` (bitwise identical across backends).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
        self.slots.set_backend(backend);
    }

    /// Advance the step count and return this step's `(bc1, bc2)` bias
    /// corrections — f32 powers, matching the kernel exactly.
    fn advance(&mut self) -> (f32, f32) {
        self.t += 1.0;
        (1.0 - self.beta1.powf(self.t), 1.0 - self.beta2.powf(self.t))
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let (bc1, bc2) = self.advance();
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let be = self.backend.imp();
        for idx in 0..params.len() {
            kernel::step_chunked2(
                &mut self.slots, 2 * idx, 2 * idx + 1, self.chunk,
                &mut self.scratch, params[idx].data_mut(), grads[idx].data(),
                |w, g, m, v| {
                    be.adam_update(b1, b2, eps, bc1, bc2, lr, w, g, m, v)
                });
        }
    }

    fn step_flat(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(self.specs.len(), 1,
                   "step_flat needs a single-leaf instance");
        let (bc1, bc2) = self.advance();
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let be = self.backend.imp();
        kernel::step_chunked2(&mut self.slots, 0, 1, self.chunk,
                              &mut self.scratch, w, g, |w, g, m, v| {
            be.adam_update(b1, b2, eps, bc1, bc2, lr, w, g, m, v)
        });
    }

    fn state_floats(&self) -> usize {
        self.slots.state_floats()
    }

    fn state_bytes(&self) -> usize {
        self.slots.state_bytes()
    }

    fn state_dtype(&self) -> StateDtype {
        self.slots.dtype()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        // step count rides along as a 1-element tensor on slot "t" of leaf 0
        out.push((0, "t", Tensor::from_vec(&[1], vec![self.t])));
        for (i, s) in self.specs.iter().enumerate() {
            out.push((i, "m",
                      Tensor::from_vec(&s.shape, self.slots.to_vec(2 * i))));
            out.push((i, "v",
                      Tensor::from_vec(&s.shape,
                                       self.slots.to_vec(2 * i + 1))));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        let want = 1 + 2 * self.specs.len();
        ensure!(state.len() == want,
                "adam state layout mismatch: got {} tensors, expected {} \
                 (t + m/v per leaf over {} leaves)",
                state.len(), want, self.specs.len());
        let mut it = state.into_iter();
        let t0 = it.next().expect("length checked above");
        ensure!(t0.data().len() == 1,
                "adam step counter must be a 1-element tensor, got {} \
                 elements", t0.data().len());
        self.t = t0.data()[0];
        for (i, s) in self.specs.iter().enumerate() {
            for (slot, kind) in [(2 * i, "m"), (2 * i + 1, "v")] {
                let t = it.next().expect("length checked above");
                ensure!(t.shape() == s.shape.as_slice(),
                        "adam leaf {:?} slot {kind}: state shape {:?}, \
                         expected {:?}", s.name, t.shape(), s.shape);
                self.slots.write(slot, t.data());
            }
        }
        Ok(())
    }

    fn scratch_bytes(&self) -> usize {
        self.scratch.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        // bias correction ⇒ |Δw| ≈ lr on step 1 regardless of g scale
        let specs = vec![ParamSpec::new("w", &[1])];
        let mut opt = Adam::new(&specs, 0.9, 0.999, 1e-8);
        for scale in [0.01f32, 1.0, 100.0] {
            let mut opt2 = Adam::new(&specs, 0.9, 0.999, 1e-8);
            let mut params = vec![Tensor::zeros(&[1])];
            let g = Tensor::from_vec(&[1], vec![scale]);
            opt2.step(&mut params, &[g], 0.01);
            assert!((params[0].data()[0].abs() - 0.01).abs() < 1e-4,
                    "scale {scale}: {}", params[0].data()[0]);
        }
        let _ = opt.state_floats();
    }

    #[test]
    fn step_counter_in_state_roundtrip() {
        let specs = vec![ParamSpec::new("w", &[2])];
        let mut opt = Adam::new(&specs, 0.9, 0.999, 1e-8);
        let mut params = vec![Tensor::zeros(&[2])];
        let g = Tensor::from_vec(&[2], vec![1.0, -1.0]);
        for _ in 0..5 {
            opt.step(&mut params, std::slice::from_ref(&g), 0.01);
        }
        let st: Vec<Tensor> = opt.state().into_iter().map(|(_, _, t)| t).collect();
        let mut fresh = Adam::new(&specs, 0.9, 0.999, 1e-8);
        fresh.load_state(st).unwrap();
        assert_eq!(fresh.t, 5.0);
    }

    /// The step counter must survive quantized-state round-trips exactly
    /// (it is kept outside the quantized store).
    #[test]
    fn step_counter_is_exact_under_q8() {
        let specs = vec![ParamSpec::new("w", &[70])];
        let mut opt = Adam::with_dtype(&specs, 0.9, 0.999, 1e-8,
                                       StateDtype::Q8);
        let mut params = vec![Tensor::zeros(&[70])];
        let g = Tensor::full(&[70], 1.0);
        for _ in 0..7 {
            opt.step(&mut params, std::slice::from_ref(&g), 0.01);
        }
        let st: Vec<Tensor> =
            opt.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(st[0].data()[0], 7.0);
        let mut fresh = Adam::with_dtype(&specs, 0.9, 0.999, 1e-8,
                                         StateDtype::Q8);
        fresh.load_state(st).unwrap();
        assert_eq!(fresh.t, 7.0);
    }

    #[test]
    fn q8_state_is_at_least_3_5x_smaller() {
        let specs = vec![ParamSpec::new("emb", &[512, 64])];
        let f = Adam::new(&specs, 0.9, 0.999, 1e-8);
        let q = Adam::with_dtype(&specs, 0.9, 0.999, 1e-8, StateDtype::Q8);
        assert_eq!(f.state_floats(), q.state_floats());
        let red = f.state_bytes() as f64 / q.state_bytes() as f64;
        assert!(red >= 3.5, "reduction {red}");
    }
}
