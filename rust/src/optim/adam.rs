//! Adam (Kingma & Ba) — the 2×d-state baseline whose memory footprint
//! motivates the paper (Tables 1–2).

use super::{Optimizer, ParamSpec};
use crate::tensor::Tensor;

pub struct Adam {
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: f32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
}

impl Adam {
    pub fn new(specs: &[ParamSpec], beta1: f32, beta2: f32, eps: f32) -> Self {
        Self {
            beta1,
            beta2,
            eps,
            t: 0.0,
            m: specs.iter().map(|s| Tensor::zeros(&s.shape)).collect(),
            v: specs.iter().map(|s| Tensor::zeros(&s.shape)).collect(),
        }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.t += 1.0;
        let (b1, b2) = (self.beta1, self.beta2);
        // f32 powers, matching the kernel exactly
        let bc1 = 1.0 - b1.powf(self.t);
        let bc2 = 1.0 - b2.powf(self.t);
        for idx in 0..params.len() {
            let wd = params[idx].data_mut();
            let gd = grads[idx].data();
            let m = self.m[idx].data_mut();
            let v = self.v[idx].data_mut();
            for k in 0..wd.len() {
                m[k] = b1 * m[k] + (1.0 - b1) * gd[k];
                v[k] = b2 * v[k] + (1.0 - b2) * gd[k] * gd[k];
                let mhat = m[k] / bc1;
                let vhat = v[k] / bc2;
                wd[k] -= lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.m.iter().map(Tensor::len).sum::<usize>()
            + self.v.iter().map(Tensor::len).sum::<usize>()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        // step count rides along as a 1-element tensor on slot "t" of leaf 0
        out.push((0, "t", Tensor::from_vec(&[1], vec![self.t])));
        for i in 0..self.m.len() {
            out.push((i, "m", self.m[i].clone()));
            out.push((i, "v", self.v[i].clone()));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) {
        let mut it = state.into_iter();
        self.t = it.next().expect("state underrun").data()[0];
        for i in 0..self.m.len() {
            self.m[i] = it.next().expect("state underrun");
            self.v[i] = it.next().expect("state underrun");
        }
        assert!(it.next().is_none());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        // bias correction ⇒ |Δw| ≈ lr on step 1 regardless of g scale
        let specs = vec![ParamSpec::new("w", &[1])];
        let mut opt = Adam::new(&specs, 0.9, 0.999, 1e-8);
        for scale in [0.01f32, 1.0, 100.0] {
            let mut opt2 = Adam::new(&specs, 0.9, 0.999, 1e-8);
            let mut params = vec![Tensor::zeros(&[1])];
            let g = Tensor::from_vec(&[1], vec![scale]);
            opt2.step(&mut params, &[g], 0.01);
            assert!((params[0].data()[0].abs() - 0.01).abs() < 1e-4,
                    "scale {scale}: {}", params[0].data()[0]);
        }
        let _ = opt.state_floats();
    }

    #[test]
    fn step_counter_in_state_roundtrip() {
        let specs = vec![ParamSpec::new("w", &[2])];
        let mut opt = Adam::new(&specs, 0.9, 0.999, 1e-8);
        let mut params = vec![Tensor::zeros(&[2])];
        let g = Tensor::from_vec(&[2], vec![1.0, -1.0]);
        for _ in 0..5 {
            opt.step(&mut params, std::slice::from_ref(&g), 0.01);
        }
        let st: Vec<Tensor> = opt.state().into_iter().map(|(_, _, t)| t).collect();
        let mut fresh = Adam::new(&specs, 0.9, 0.999, 1e-8);
        fresh.load_state(st);
        assert_eq!(fresh.t, 5.0);
    }
}
