//! SM3-I and SM3-II with the co-dimension-1 cover (paper §3 / §4).
//!
//! Matrix parameters keep one accumulator per row and per column
//! (Θ(m+n) state); rank-p tensors keep p slice accumulators; vectors use
//! the singleton cover (== Adagrad). The update math matches the Pallas
//! kernels in `python/compile/kernels/sm3.py` f32-op-for-f32-op.
//!
//! The matrix hot path is single-pass: `nu` is computed per element,
//! consumed immediately for the weight update, and folded into the *new*
//! row/col accumulators without materializing the m×n `nu` matrix — this
//! is the memory story of the paper executed literally.

use super::{safe_rsqrt, Optimizer, ParamSpec};
use crate::tensor::{axis_index, Tensor};

/// Which algorithm from the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sm3Variant {
    /// Algorithm SM3-I: `mu += max g²` then `nu = min mu`.
    I,
    /// Algorithm SM3-II: `nu = min mu_prev + g²`, `mu = max nu` (tighter).
    II,
}

struct LeafState {
    /// One accumulator vector per tensor axis (rank-p ⇒ p vectors);
    /// vectors (rank 1) store the full elementwise accumulator.
    accs: Vec<Vec<f32>>,
    mom: Tensor,
}

/// SM3 optimizer state over a parameter list.
pub struct Sm3 {
    variant: Sm3Variant,
    beta1: f32,
    leaves: Vec<LeafState>,
    specs: Vec<ParamSpec>,
}

impl Sm3 {
    pub fn new(specs: &[ParamSpec], variant: Sm3Variant, beta1: f32) -> Self {
        let leaves = specs
            .iter()
            .map(|s| {
                let accs = if s.shape.len() <= 1 {
                    vec![vec![0.0; s.numel()]]
                } else {
                    s.shape.iter().map(|&n| vec![0.0; n]).collect()
                };
                LeafState { accs, mom: Tensor::zeros(&s.shape) }
            })
            .collect();
        Self { variant, beta1, leaves, specs: specs.to_vec() }
    }

    /// Read accumulator `axis` of parameter `idx` (trace / tests).
    pub fn acc(&self, idx: usize, axis: usize) -> &[f32] {
        &self.leaves[idx].accs[axis]
    }

    /// The implied per-entry `nu` (min over covering accumulators) for a
    /// matrix parameter — the quantity Fig. 5 compares against Adagrad.
    pub fn implied_nu_matrix(&self, idx: usize) -> Tensor {
        let shape = &self.specs[idx].shape;
        assert_eq!(shape.len(), 2);
        let (m, n) = (shape[0], shape[1]);
        let row = &self.leaves[idx].accs[0];
        let col = &self.leaves[idx].accs[1];
        let mut out = Tensor::zeros(&[m, n]);
        let data = out.data_mut();
        for i in 0..m {
            for j in 0..n {
                data[i * n + j] = row[i].min(col[j]);
            }
        }
        out
    }

    fn step_vector(&mut self, idx: usize, w: &mut Tensor, g: &Tensor, lr: f32) {
        let beta1 = self.beta1;
        let leaf = &mut self.leaves[idx];
        let acc = &mut leaf.accs[0];
        let mom = leaf.mom.data_mut();
        let wd = w.data_mut();
        let gd = g.data();
        for i in 0..wd.len() {
            let nu = acc[i] + gd[i] * gd[i];
            let upd = gd[i] * safe_rsqrt(nu);
            mom[i] = beta1 * mom[i] + (1.0 - beta1) * upd;
            wd[i] -= lr * mom[i];
            acc[i] = nu;
        }
    }

    fn step_matrix_ii(&mut self, idx: usize, w: &mut Tensor, g: &Tensor, lr: f32) {
        let beta1 = self.beta1;
        let (m, n) = (w.shape()[0], w.shape()[1]);
        let leaf = &mut self.leaves[idx];
        let mom = leaf.mom.data_mut();
        let wd = w.data_mut();
        let gd = g.data();
        let (rows, cols) = leaf.accs.split_at_mut(1);
        let row = &mut rows[0];
        let col = &mut cols[0];
        let mut new_col = vec![f32::NEG_INFINITY; n];
        // Single fused pass: nu is computed per element, consumed for the
        // update, and folded into the new row/col maxima — the m×n nu
        // matrix is never materialized (memory stays Θ(m+n)).
        // Perf-pass note (EXPERIMENTS.md §Perf): a 5-way-zip variant and a
        // 2-pass scratch-row variant both measured SLOWER on this
        // toolchain; this indexed loop is the keeper.
        for i in 0..m {
            let ri = row[i];
            let base = i * n;
            let mut rmax = f32::NEG_INFINITY;
            for j in 0..n {
                let k = base + j;
                let gv = gd[k];
                let nu = ri.min(col[j]) + gv * gv;
                let upd = gv * safe_rsqrt(nu);
                mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
                wd[k] -= lr * mom[k];
                if nu > rmax {
                    rmax = nu;
                }
                if nu > new_col[j] {
                    new_col[j] = nu;
                }
            }
            row[i] = rmax;
        }
        *col = new_col;
    }

    fn step_matrix_i(&mut self, idx: usize, w: &mut Tensor, g: &Tensor, lr: f32) {
        let beta1 = self.beta1;
        let (m, n) = (w.shape()[0], w.shape()[1]);
        let leaf = &mut self.leaves[idx];
        let gd = g.data();
        // pass 1: mu += max over slice of g²
        {
            let (rows, cols) = leaf.accs.split_at_mut(1);
            let row = &mut rows[0];
            let col = &mut cols[0];
            let mut rowmax = vec![0.0f32; m];
            let mut colmax = vec![0.0f32; n];
            for i in 0..m {
                let base = i * n;
                for j in 0..n {
                    let g2 = gd[base + j] * gd[base + j];
                    if g2 > rowmax[i] {
                        rowmax[i] = g2;
                    }
                    if g2 > colmax[j] {
                        colmax[j] = g2;
                    }
                }
            }
            for i in 0..m {
                row[i] += rowmax[i];
            }
            for j in 0..n {
                col[j] += colmax[j];
            }
        }
        // pass 2: nu = min(mu_row, mu_col); update
        let mom = leaf.mom.data_mut();
        let wd = w.data_mut();
        let row = &leaf.accs[0];
        let col = &leaf.accs[1];
        for i in 0..m {
            let base = i * n;
            for j in 0..n {
                let k = base + j;
                let nu = row[i].min(col[j]);
                let upd = gd[k] * safe_rsqrt(nu);
                mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
                wd[k] -= lr * mom[k];
            }
        }
    }

    /// Generic rank-p path (conv kernels etc.). SM3-II semantics.
    fn step_tensor_ii(&mut self, idx: usize, w: &mut Tensor, g: &Tensor, lr: f32) {
        let beta1 = self.beta1;
        let shape = w.shape().to_vec();
        let p = shape.len();
        let leaf = &mut self.leaves[idx];
        let mom = leaf.mom.data_mut();
        let wd = w.data_mut();
        let gd = g.data();
        let mut new_accs: Vec<Vec<f32>> =
            shape.iter().map(|&nn| vec![f32::NEG_INFINITY; nn]).collect();
        for k in 0..wd.len() {
            let mut nu = f32::INFINITY;
            for a in 0..p {
                let v = leaf.accs[a][axis_index(&shape, k, a)];
                if v < nu {
                    nu = v;
                }
            }
            nu += gd[k] * gd[k];
            let upd = gd[k] * safe_rsqrt(nu);
            mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
            wd[k] -= lr * mom[k];
            for a in 0..p {
                let ai = axis_index(&shape, k, a);
                if nu > new_accs[a][ai] {
                    new_accs[a][ai] = nu;
                }
            }
        }
        leaf.accs = new_accs;
    }

    fn step_tensor_i(&mut self, idx: usize, w: &mut Tensor, g: &Tensor, lr: f32) {
        let beta1 = self.beta1;
        let shape = w.shape().to_vec();
        let p = shape.len();
        let leaf = &mut self.leaves[idx];
        let gd = g.data();
        // pass 1: accumulate slice maxima of g²
        for a in 0..p {
            let mut mx = vec![0.0f32; shape[a]];
            for k in 0..gd.len() {
                let g2 = gd[k] * gd[k];
                let ai = axis_index(&shape, k, a);
                if g2 > mx[ai] {
                    mx[ai] = g2;
                }
            }
            for (acc, m) in leaf.accs[a].iter_mut().zip(mx) {
                *acc += m;
            }
        }
        // pass 2: update
        let mom = leaf.mom.data_mut();
        let wd = w.data_mut();
        for k in 0..wd.len() {
            let mut nu = f32::INFINITY;
            for a in 0..p {
                let v = leaf.accs[a][axis_index(&shape, k, a)];
                if v < nu {
                    nu = v;
                }
            }
            let upd = gd[k] * safe_rsqrt(nu);
            mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
            wd[k] -= lr * mom[k];
        }
    }
}

impl Optimizer for Sm3 {
    fn name(&self) -> &'static str {
        match self.variant {
            Sm3Variant::I => "sm3i",
            Sm3Variant::II => "sm3",
        }
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.leaves.len());
        for idx in 0..params.len() {
            let rank = params[idx].rank();
            // Split borrows: temporarily move the tensor out.
            let mut w = std::mem::replace(&mut params[idx], Tensor::zeros(&[0]));
            let g = &grads[idx];
            match (rank, self.variant) {
                (0 | 1, _) => self.step_vector(idx, &mut w, g, lr),
                (2, Sm3Variant::II) => self.step_matrix_ii(idx, &mut w, g, lr),
                (2, Sm3Variant::I) => self.step_matrix_i(idx, &mut w, g, lr),
                (_, Sm3Variant::II) => self.step_tensor_ii(idx, &mut w, g, lr),
                (_, Sm3Variant::I) => self.step_tensor_i(idx, &mut w, g, lr),
            }
            params[idx] = w;
        }
    }

    fn state_floats(&self) -> usize {
        self.leaves
            .iter()
            .map(|l| l.accs.iter().map(Vec::len).sum::<usize>() + l.mom.len())
            .sum()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        // One distinct name per axis — clamping (the old `a.min(3)`) made
        // rank ≥ 5 tensors emit duplicate "acc3" slots, silently aliasing
        // state across axes on checkpoint round-trips. The checkpoint
        // format caps tensor rank at 8 (see `checkpoint.rs`), so eight
        // static names cover every representable parameter.
        const AXIS_NAMES: [&str; 8] =
            ["acc0", "acc1", "acc2", "acc3", "acc4", "acc5", "acc6", "acc7"];
        let mut out = Vec::new();
        for (i, leaf) in self.leaves.iter().enumerate() {
            assert!(leaf.accs.len() <= AXIS_NAMES.len(),
                    "rank {} exceeds the {}-axis slot-name cap",
                    leaf.accs.len(), AXIS_NAMES.len());
            for (a, acc) in leaf.accs.iter().enumerate() {
                out.push((i, AXIS_NAMES[a],
                          Tensor::from_vec(&[acc.len()], acc.clone())));
            }
            out.push((i, "mom", leaf.mom.clone()));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) {
        let mut it = state.into_iter();
        for leaf in self.leaves.iter_mut() {
            for acc in leaf.accs.iter_mut() {
                let t = it.next().expect("state underrun");
                assert_eq!(t.len(), acc.len());
                acc.copy_from_slice(t.data());
            }
            let t = it.next().expect("state underrun");
            assert_eq!(t.shape(), leaf.mom.shape());
            leaf.mom = t;
        }
        assert!(it.next().is_none(), "state overrun");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn run_steps(variant: Sm3Variant, shape: &[usize], steps: usize,
                 seed: u64) -> (Tensor, Sm3) {
        let specs = vec![ParamSpec::new("w", shape)];
        let mut opt = Sm3::new(&specs, variant, 0.9);
        let mut rng = Rng::new(seed);
        let mut params = vec![Tensor::randn(shape, 0.5, &mut rng)];
        for _ in 0..steps {
            let g = vec![Tensor::randn(shape, 1.0, &mut rng)];
            opt.step(&mut params, &g, 0.1);
        }
        (params.pop().unwrap(), opt)
    }

    /// Claim 2: nu_t(i) >= sum_s g_s²(i), accumulators monotone.
    #[test]
    fn claim2_lower_bound_matrix() {
        let shape = [6, 9];
        let specs = vec![ParamSpec::new("w", &shape)];
        for variant in [Sm3Variant::I, Sm3Variant::II] {
            let mut opt = Sm3::new(&specs, variant, 0.9);
            let mut rng = Rng::new(1);
            let mut params = vec![Tensor::zeros(&shape)];
            let mut gsq = vec![0.0f64; 54];
            let mut prev_rows = vec![0.0f32; 6];
            for _ in 0..15 {
                let g = Tensor::randn(&shape, 1.0, &mut rng);
                for (acc, &gv) in gsq.iter_mut().zip(g.data()) {
                    *acc += (gv as f64) * (gv as f64);
                }
                opt.step(&mut params, &[g], 0.1);
                let nu = opt.implied_nu_matrix(0);
                for (k, &nuv) in nu.data().iter().enumerate() {
                    assert!(nuv as f64 + 1e-3 >= gsq[k],
                            "{variant:?} nu {nuv} < gsq {}", gsq[k]);
                }
                for (i, (&r, &p)) in
                    opt.acc(0, 0).iter().zip(&prev_rows).enumerate()
                {
                    assert!(r + 1e-6 >= p, "row {i} not monotone");
                }
                prev_rows = opt.acc(0, 0).to_vec();
            }
        }
    }

    /// Prop. 3: SM3-II accumulators are tighter than SM3-I's.
    #[test]
    fn prop3_sm3ii_tighter() {
        let shape = [8, 5];
        let specs = vec![ParamSpec::new("w", &shape)];
        let mut o1 = Sm3::new(&specs, Sm3Variant::I, 0.9);
        let mut o2 = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut rng = Rng::new(2);
        let mut p1 = vec![Tensor::zeros(&shape)];
        let mut p2 = vec![Tensor::zeros(&shape)];
        for _ in 0..20 {
            let g = Tensor::randn(&shape, 1.0, &mut rng);
            o1.step(&mut p1, std::slice::from_ref(&g), 0.1);
            o2.step(&mut p2, std::slice::from_ref(&g), 0.1);
            let nu1 = o1.implied_nu_matrix(0);
            let nu2 = o2.implied_nu_matrix(0);
            for (a, b) in nu2.data().iter().zip(nu1.data()) {
                assert!(a <= &(b + 1e-5), "nu2 {a} > nu1 {b}");
            }
        }
    }

    /// §3: with singleton cover (vectors) SM3 == Adagrad exactly.
    #[test]
    fn vector_equals_adagrad() {
        let specs = vec![ParamSpec::new("b", &[33])];
        let mut sm3 = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut ada = super::super::Adagrad::new(&specs, 0.9);
        let mut rng = Rng::new(3);
        let w0 = Tensor::randn(&[33], 1.0, &mut rng);
        let mut p1 = vec![w0.clone()];
        let mut p2 = vec![w0];
        for _ in 0..10 {
            let g = Tensor::randn(&[33], 1.0, &mut rng);
            sm3.step(&mut p1, std::slice::from_ref(&g), 0.2);
            ada.step(&mut p2, std::slice::from_ref(&g), 0.2);
        }
        assert_eq!(p1[0], p2[0]);
    }

    /// 1×n and m×1 matrices: cover degenerates to whole-tensor max + diag.
    #[test]
    fn degenerate_matrix_shapes() {
        for shape in [[1usize, 7], [7, 1]] {
            let (w, _) = run_steps(Sm3Variant::II, &shape, 5, 4);
            assert!(w.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn zero_gradients_are_noop() {
        let specs = vec![ParamSpec::new("w", &[4, 4])];
        let mut opt = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut params = vec![Tensor::full(&[4, 4], 1.5)];
        let g = vec![Tensor::zeros(&[4, 4])];
        opt.step(&mut params, &g, 0.5);
        assert_eq!(params[0], Tensor::full(&[4, 4], 1.5));
    }

    #[test]
    fn rank3_matches_matrix_when_trailing_dim_1() {
        // (m, n, 1) tensor path must agree with the (m, n) matrix fast path.
        let mut rng = Rng::new(5);
        let w0 = Tensor::randn(&[5, 6], 0.5, &mut rng);
        let g0 = Tensor::randn(&[5, 6], 1.0, &mut rng);

        let specs2 = vec![ParamSpec::new("w", &[5, 6])];
        let mut o2 = Sm3::new(&specs2, Sm3Variant::II, 0.9);
        let mut p2 = vec![w0.clone()];
        o2.step(&mut p2, &[g0.clone()], 0.1);

        let specs3 = vec![ParamSpec::new("w", &[5, 6, 1])];
        let mut o3 = Sm3::new(&specs3, Sm3Variant::II, 0.9);
        let mut p3 = vec![w0.clone().reshape(&[5, 6, 1])];
        o3.step(&mut p3, &[g0.reshape(&[5, 6, 1])], 0.1);

        for (a, b) in p2[0].data().iter().zip(p3[0].data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn state_roundtrip() {
        let (_, opt) = run_steps(Sm3Variant::II, &[4, 3], 3, 7);
        let saved: Vec<Tensor> =
            opt.state().into_iter().map(|(_, _, t)| t).collect();
        let specs = vec![ParamSpec::new("w", &[4, 3])];
        let mut fresh = Sm3::new(&specs, Sm3Variant::II, 0.9);
        fresh.load_state(saved.clone());
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t.clone()).collect();
        assert_eq!(saved, restored);
    }

    /// Regression: rank ≥ 5 tensors used to clamp axis slot names to
    /// "acc3", so axes 3, 4, … aliased one checkpoint slot. Every axis
    /// must get a distinct name and round-trip without aliasing.
    #[test]
    fn rank5_state_slots_are_distinct_and_roundtrip() {
        let shape = [2usize, 3, 4, 5, 6];
        let (_, opt) = run_steps(Sm3Variant::II, &shape, 2, 11);
        let state = opt.state();
        // 5 axis accumulators + momentum
        assert_eq!(state.len(), 6);
        let names: Vec<&str> = state.iter().map(|(_, n, _)| *n).collect();
        assert_eq!(names, ["acc0", "acc1", "acc2", "acc3", "acc4", "mom"]);
        // each axis slot has that axis's length, not an alias of another
        for (a, &dim) in shape.iter().enumerate() {
            assert_eq!(state[a].2.len(), dim, "axis {a}");
        }
        // round-trip restores bit-identical state
        let saved: Vec<Tensor> =
            state.into_iter().map(|(_, _, t)| t).collect();
        let specs = vec![ParamSpec::new("w", &shape)];
        let mut fresh = Sm3::new(&specs, Sm3Variant::II, 0.9);
        fresh.load_state(saved.clone());
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(saved, restored);
    }

    #[test]
    fn memory_is_sublinear_for_matrices() {
        let specs = vec![ParamSpec::new("emb", &[512, 128])];
        let opt = Sm3::new(&specs, Sm3Variant::II, 0.0);
        // acc floats only: 512 + 128 (mom is counted in state_floats)
        let acc_floats: usize = (0..2).map(|a| opt.acc(0, a).len()).sum();
        assert_eq!(acc_floats, 512 + 128);
    }
}
