//! SM3-I and SM3-II with the co-dimension-1 cover (paper §3 / §4).
//!
//! Matrix parameters keep one accumulator per row and per column
//! (Θ(m+n) state); rank-p tensors keep p slice accumulators; vectors use
//! the singleton cover (== Adagrad). The update math matches the Pallas
//! kernels in `python/compile/kernels/sm3.py` f32-op-for-f32-op.
//!
//! The matrix hot path is single-pass: `nu` is computed per element,
//! consumed immediately for the weight update, and folded into the *new*
//! row/col accumulators without materializing the m×n `nu` matrix — this
//! is the memory story of the paper executed literally.
//!
//! State lives in a [`QuantizedSlots`] store (DESIGN.md §10). Vector
//! leaves (rank ≤ 1, the singleton cover — where SM3 coincides with
//! Adagrad) stream through the tiled kernel layer (`optim::kernel`):
//! zero-copy at f32, O(tile) scratch at bf16/q8. Matrix/tensor leaves
//! are reduction-coupled (each `nu` folds into row/col maxima), so they
//! keep the leaf-granular two-pass shape: dequantize the leaf's
//! accumulators and momentum into struct-held buffers (no per-step
//! allocation), run the exact update arithmetic, quantize back. Either
//! way the trajectory is bit-identical to the pre-qstate `Vec<f32>`
//! fields at `StateDtype::F32`.

use super::backend::Backend;
use super::kernel::{self, ChunkScratch};
use super::qstate::{QuantizedSlots, StateDtype};
use super::{safe_rsqrt, Optimizer, ParamSpec};
use crate::pool::{Pool, PoolBuf, Tag};
use crate::tensor::{axis_index, Tensor};
use anyhow::ensure;

/// Ensure `bufs` holds at least `k` buffer shells (capacity inside each
/// shell grows to the lengths seen and is then reused — steady-state
/// steps allocate nothing). Shells lease from `pool` when present
/// ([`Tag::KernelScratch`]), else run unpooled.
fn ensure_bufs(bufs: &mut Vec<PoolBuf<f32>>, k: usize, pool: Option<&Pool>) {
    while bufs.len() < k {
        bufs.push(match pool {
            Some(p) => p.take_f32(Tag::KernelScratch, 0),
            None => PoolBuf::unpooled(Tag::KernelScratch),
        });
    }
}

/// Live f32 bytes across a shell set (the pool's view of these leases).
fn bufs_bytes(bufs: &[PoolBuf<f32>]) -> usize {
    bufs.iter().map(|b| b.len() * 4).sum()
}

/// Which algorithm from the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sm3Variant {
    /// Algorithm SM3-I: `mu += max g²` then `nu = min mu`.
    I,
    /// Algorithm SM3-II: `nu = min mu_prev + g²`, `mu = max nu` (tighter).
    II,
}

/// Slot ids of one parameter leaf in the store.
struct LeafIds {
    /// one accumulator vector per tensor axis (rank-p ⇒ p ids);
    /// vectors (rank ≤ 1) store the full elementwise accumulator
    accs: Vec<usize>,
    mom: usize,
}

/// SM3 optimizer state over a parameter list.
pub struct Sm3 {
    variant: Sm3Variant,
    beta1: f32,
    /// streaming tile for vector (singleton-cover) leaves
    chunk: usize,
    /// kernel backend for the singleton-cover update lanes and the state
    /// store's codec lanes (bitwise identical across backends —
    /// DESIGN.md §13); the reduction-coupled matrix/tensor loops stay
    /// leaf-granular indexed code (a lane-unrolled variant measured
    /// slower — see the perf note in `step_matrix_ii`)
    backend: Backend,
    scratch: ChunkScratch,
    /// reduction-coupled leaves: dequantized accumulator buffers (one per
    /// axis), momentum buffer, and per-axis reduction scratch — all
    /// struct-held so steady-state steps are allocation-free; pooled
    /// instances lease them under [`Tag::KernelScratch`]
    acc_bufs: Vec<PoolBuf<f32>>,
    mom_buf: PoolBuf<f32>,
    axis_scratch: Vec<PoolBuf<f32>>,
    /// lease source for lazily-grown shells; `None` = legacy unpooled
    pool: Option<Pool>,
    store: QuantizedSlots,
    leaves: Vec<LeafIds>,
    specs: Vec<ParamSpec>,
}

impl Sm3 {
    /// f32-state instance (see [`Sm3::with_opts`]).
    pub fn new(specs: &[ParamSpec], variant: Sm3Variant, beta1: f32) -> Self {
        Self::with_dtype(specs, variant, beta1, StateDtype::F32)
    }

    /// Instance with explicit state-storage precision.
    pub fn with_dtype(specs: &[ParamSpec], variant: Sm3Variant, beta1: f32,
                      dtype: StateDtype) -> Self {
        Self::with_opts(specs, variant, beta1, dtype, kernel::DEFAULT_CHUNK)
    }

    /// Fully explicit instance: variant, momentum, storage precision,
    /// and streaming tile (vector leaves only — matrix/tensor covers are
    /// reduction-coupled and leaf-granular).
    pub fn with_opts(specs: &[ParamSpec], variant: Sm3Variant, beta1: f32,
                     dtype: StateDtype, chunk: usize) -> Self {
        Self::build(specs, variant, beta1, dtype, chunk, None)
    }

    /// [`Sm3::with_opts`] with state slots and all working scratch
    /// leased from `pool` (bitwise identical to the unpooled
    /// constructor).
    pub fn with_opts_in(specs: &[ParamSpec], variant: Sm3Variant,
                        beta1: f32, dtype: StateDtype, chunk: usize,
                        pool: &Pool) -> Self {
        Self::build(specs, variant, beta1, dtype, chunk, Some(pool))
    }

    fn build(specs: &[ParamSpec], variant: Sm3Variant, beta1: f32,
             dtype: StateDtype, chunk: usize, pool: Option<&Pool>) -> Self {
        kernel::check_chunk(chunk).unwrap();
        let mut store = match pool {
            Some(p) => QuantizedSlots::new_in(dtype, p.clone()),
            None => QuantizedSlots::new(dtype),
        };
        let leaves: Vec<LeafIds> = specs
            .iter()
            .map(|s| {
                let accs = if s.shape.len() <= 1 {
                    vec![store.add_zeros(s.numel())]
                } else {
                    s.shape.iter().map(|&n| store.add_zeros(n)).collect()
                };
                LeafIds { accs, mom: store.add_zeros(s.numel()) }
            })
            .collect();
        let (scratch, mom_buf) = match pool {
            Some(p) => (ChunkScratch::new_in(p),
                        p.take_f32(Tag::KernelScratch, 0)),
            None => (ChunkScratch::default(),
                     PoolBuf::unpooled(Tag::KernelScratch)),
        };
        Self { variant, beta1, chunk, backend: Backend::default(),
               scratch,
               acc_bufs: Vec::new(), mom_buf,
               axis_scratch: Vec::new(),
               pool: pool.cloned(), store, leaves,
               specs: specs.to_vec() }
    }

    /// Route the singleton-cover update lanes and the state store's codec
    /// lanes through `backend` (bitwise identical across backends).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
        self.store.set_backend(backend);
    }

    /// Read accumulator `axis` of parameter `idx`, dequantized
    /// (trace / tests).
    pub fn acc(&self, idx: usize, axis: usize) -> Vec<f32> {
        self.store.to_vec(self.leaves[idx].accs[axis])
    }

    /// The implied per-entry `nu` (min over covering accumulators) for a
    /// matrix parameter — the quantity Fig. 5 compares against Adagrad.
    pub fn implied_nu_matrix(&self, idx: usize) -> Tensor {
        let shape = &self.specs[idx].shape;
        assert_eq!(shape.len(), 2);
        let (m, n) = (shape[0], shape[1]);
        let row = self.store.to_vec(self.leaves[idx].accs[0]);
        let col = self.store.to_vec(self.leaves[idx].accs[1]);
        let mut out = Tensor::zeros(&[m, n]);
        let data = out.data_mut();
        for i in 0..m {
            for j in 0..n {
                data[i * n + j] = row[i].min(col[j]);
            }
        }
        out
    }
}

fn step_matrix_ii(accs: &mut [PoolBuf<f32>], mom: &mut [f32],
                  w: &mut Tensor, g: &Tensor, lr: f32, beta1: f32,
                  scratch: &mut [PoolBuf<f32>]) {
    let (m, n) = (w.shape()[0], w.shape()[1]);
    let wd = w.data_mut();
    let gd = g.data();
    let (rows, cols) = accs.split_at_mut(1);
    let row = &mut rows[0];
    let col = &mut cols[0];
    let new_col = &mut scratch[0];
    new_col.clear();
    new_col.resize_fill(n, f32::NEG_INFINITY);
    // Single fused pass: nu is computed per element, consumed for the
    // update, and folded into the new row/col maxima — the m×n nu
    // matrix is never materialized (memory stays Θ(m+n)).
    // Perf-pass note (EXPERIMENTS.md §Perf): a 5-way-zip variant and a
    // 2-pass scratch-row variant both measured SLOWER on this
    // toolchain; this indexed loop is the keeper.
    for i in 0..m {
        let ri = row[i];
        let base = i * n;
        let mut rmax = f32::NEG_INFINITY;
        for j in 0..n {
            let k = base + j;
            let gv = gd[k];
            let nu = ri.min(col[j]) + gv * gv;
            let upd = gv * safe_rsqrt(nu);
            mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
            wd[k] -= lr * mom[k];
            if nu > rmax {
                rmax = nu;
            }
            if nu > new_col[j] {
                new_col[j] = nu;
            }
        }
        row[i] = rmax;
    }
    col.copy_from_slice(&new_col[..]);
}

fn step_matrix_i(accs: &mut [PoolBuf<f32>], mom: &mut [f32], w: &mut Tensor,
                 g: &Tensor, lr: f32, beta1: f32,
                 scratch: &mut [PoolBuf<f32>]) {
    let (m, n) = (w.shape()[0], w.shape()[1]);
    let gd = g.data();
    // pass 1: mu += max over slice of g²
    {
        let (rows, cols) = accs.split_at_mut(1);
        let row = &mut rows[0];
        let col = &mut cols[0];
        let (rm, cm) = scratch.split_at_mut(1);
        let rowmax = &mut rm[0];
        let colmax = &mut cm[0];
        rowmax.clear();
        rowmax.resize(m);
        colmax.clear();
        colmax.resize(n);
        for i in 0..m {
            let base = i * n;
            for j in 0..n {
                let g2 = gd[base + j] * gd[base + j];
                if g2 > rowmax[i] {
                    rowmax[i] = g2;
                }
                if g2 > colmax[j] {
                    colmax[j] = g2;
                }
            }
        }
        for i in 0..m {
            row[i] += rowmax[i];
        }
        for j in 0..n {
            col[j] += colmax[j];
        }
    }
    // pass 2: nu = min(mu_row, mu_col); update
    let wd = w.data_mut();
    let row = &accs[0];
    let col = &accs[1];
    for i in 0..m {
        let base = i * n;
        for j in 0..n {
            let k = base + j;
            let nu = row[i].min(col[j]);
            let upd = gd[k] * safe_rsqrt(nu);
            mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
            wd[k] -= lr * mom[k];
        }
    }
}

/// Generic rank-p path (conv kernels etc.). SM3-II semantics.
fn step_tensor_ii(accs: &mut [PoolBuf<f32>], mom: &mut [f32],
                  w: &mut Tensor, g: &Tensor, lr: f32, beta1: f32,
                  scratch: &mut [PoolBuf<f32>]) {
    let shape = g.shape();
    let wd = w.data_mut();
    let gd = g.data();
    let new_accs = &mut scratch[..shape.len()];
    for (na, &nn) in new_accs.iter_mut().zip(shape) {
        na.clear();
        na.resize_fill(nn, f32::NEG_INFINITY);
    }
    for k in 0..wd.len() {
        let mut nu = f32::INFINITY;
        for (a, acc) in accs.iter().enumerate() {
            let v = acc[axis_index(shape, k, a)];
            if v < nu {
                nu = v;
            }
        }
        nu += gd[k] * gd[k];
        let upd = gd[k] * safe_rsqrt(nu);
        mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
        wd[k] -= lr * mom[k];
        for (a, na) in new_accs.iter_mut().enumerate() {
            let ai = axis_index(shape, k, a);
            if nu > na[ai] {
                na[ai] = nu;
            }
        }
    }
    for (dst, src) in accs.iter_mut().zip(new_accs.iter()) {
        dst.copy_from_slice(&src[..]);
    }
}

fn step_tensor_i(accs: &mut [PoolBuf<f32>], mom: &mut [f32], w: &mut Tensor,
                 g: &Tensor, lr: f32, beta1: f32,
                 scratch: &mut [PoolBuf<f32>]) {
    let shape = g.shape();
    let gd = g.data();
    // pass 1: accumulate slice maxima of g²
    let mx = &mut scratch[0];
    for (a, acc) in accs.iter_mut().enumerate() {
        mx.clear();
        mx.resize(shape[a]);
        for k in 0..gd.len() {
            let g2 = gd[k] * gd[k];
            let ai = axis_index(shape, k, a);
            if g2 > mx[ai] {
                mx[ai] = g2;
            }
        }
        for (av, &m) in acc.iter_mut().zip(mx.iter()) {
            *av += m;
        }
    }
    // pass 2: update
    let wd = w.data_mut();
    for k in 0..wd.len() {
        let mut nu = f32::INFINITY;
        for (a, acc) in accs.iter().enumerate() {
            let v = acc[axis_index(shape, k, a)];
            if v < nu {
                nu = v;
            }
        }
        let upd = gd[k] * safe_rsqrt(nu);
        mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
        wd[k] -= lr * mom[k];
    }
}

impl Optimizer for Sm3 {
    fn name(&self) -> &'static str {
        match self.variant {
            Sm3Variant::I => "sm3i",
            Sm3Variant::II => "sm3",
        }
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.leaves.len());
        let (beta1, variant, chunk) = (self.beta1, self.variant, self.chunk);
        let be = self.backend.imp();
        for idx in 0..params.len() {
            let rank = params[idx].rank();
            if rank <= 1 {
                // Singleton cover == Adagrad (paper §3): element-wise,
                // streamed through the tiled kernel layer — zero-copy at
                // f32, O(tile) scratch at bf16/q8.
                let (acc_id, mom_id) =
                    (self.leaves[idx].accs[0], self.leaves[idx].mom);
                kernel::step_chunked2(
                    &mut self.store, acc_id, mom_id, chunk,
                    &mut self.scratch, params[idx].data_mut(),
                    grads[idx].data(), |w, g, acc, mom| {
                        be.adagrad_update(beta1, lr, w, g, acc, mom)
                    });
                continue;
            }
            // Reduction-coupled covers: dequantize this leaf's state into
            // the struct-held buffers, run the two-pass update, quantize
            // back. `read_into`/`resize` reuse capacity, so steady-state
            // steps stay allocation-free.
            let w = &mut params[idx];
            let g = &grads[idx];
            let ids = &self.leaves[idx];
            ensure_bufs(&mut self.acc_bufs, ids.accs.len(),
                        self.pool.as_ref());
            // per-variant axis-scratch shells the step fn will index
            let shells = match (rank, variant) {
                (2, Sm3Variant::II) => 1,
                (2, Sm3Variant::I) => 2,
                (_, Sm3Variant::II) => rank,
                (_, Sm3Variant::I) => 1,
            };
            ensure_bufs(&mut self.axis_scratch, shells, self.pool.as_ref());
            let accs = &mut self.acc_bufs[..ids.accs.len()];
            {
                let store = &self.store;
                for (buf, &id) in accs.iter_mut().zip(&ids.accs) {
                    buf.with_vec(|v| store.read_into(id, v));
                }
            }
            {
                let (store, mom_buf) = (&self.store, &mut self.mom_buf);
                mom_buf.with_vec(|v| store.read_into(ids.mom, v));
            }
            let mom = &mut self.mom_buf[..];
            let scratch = &mut self.axis_scratch[..];
            match (rank, variant) {
                (2, Sm3Variant::II) => {
                    step_matrix_ii(accs, mom, w, g, lr, beta1, scratch)
                }
                (2, Sm3Variant::I) => {
                    step_matrix_i(accs, mom, w, g, lr, beta1, scratch)
                }
                (_, Sm3Variant::II) => {
                    step_tensor_ii(accs, mom, w, g, lr, beta1, scratch)
                }
                (_, Sm3Variant::I) => {
                    step_tensor_i(accs, mom, w, g, lr, beta1, scratch)
                }
            }
            for (buf, &id) in accs.iter().zip(&ids.accs) {
                self.store.write(id, buf);
            }
            self.store.write(ids.mom, &self.mom_buf);
        }
    }

    fn step_flat(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(self.specs.len(), 1,
                   "step_flat needs a single-leaf instance");
        assert!(self.specs[0].shape.len() <= 1,
                "step_flat: SM3 is element-wise only under the singleton \
                 cover (rank <= 1)");
        let beta1 = self.beta1;
        let be = self.backend.imp();
        let (acc_id, mom_id) = (self.leaves[0].accs[0], self.leaves[0].mom);
        kernel::step_chunked2(&mut self.store, acc_id, mom_id, self.chunk,
                              &mut self.scratch, w, g, |w, g, acc, mom| {
            be.adagrad_update(beta1, lr, w, g, acc, mom)
        });
    }

    fn state_floats(&self) -> usize {
        self.store.state_floats()
    }

    fn state_bytes(&self) -> usize {
        self.store.state_bytes()
    }

    fn state_dtype(&self) -> StateDtype {
        self.store.dtype()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        // One distinct name per axis — clamping (the old `a.min(3)`) made
        // rank ≥ 5 tensors emit duplicate "acc3" slots, silently aliasing
        // state across axes on checkpoint round-trips. The checkpoint
        // format caps tensor rank at 8 (see `checkpoint.rs`), so eight
        // static names cover every representable parameter.
        const AXIS_NAMES: [&str; 8] =
            ["acc0", "acc1", "acc2", "acc3", "acc4", "acc5", "acc6", "acc7"];
        let mut out = Vec::new();
        for (i, ids) in self.leaves.iter().enumerate() {
            assert!(ids.accs.len() <= AXIS_NAMES.len(),
                    "rank {} exceeds the {}-axis slot-name cap",
                    ids.accs.len(), AXIS_NAMES.len());
            for (a, &id) in ids.accs.iter().enumerate() {
                out.push((i, AXIS_NAMES[a],
                          Tensor::from_vec(&[self.store.slot_len(id)],
                                           self.store.to_vec(id))));
            }
            out.push((i, "mom",
                      Tensor::from_vec(&self.specs[i].shape,
                                       self.store.to_vec(ids.mom))));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        let want: usize =
            self.leaves.iter().map(|l| l.accs.len() + 1).sum();
        ensure!(state.len() == want,
                "sm3 state layout mismatch: got {} tensors, expected {} \
                 (per-axis accumulators + momentum over {} leaves)",
                state.len(), want, self.leaves.len());
        let mut it = state.into_iter();
        for i in 0..self.leaves.len() {
            let ids = &self.leaves[i];
            for (a, &id) in ids.accs.iter().enumerate() {
                let t = it.next().expect("length checked above");
                ensure!(t.len() == self.store.slot_len(id),
                        "sm3 leaf {:?} axis {a}: accumulator has {} \
                         elements, expected {}", self.specs[i].name,
                        t.len(), self.store.slot_len(id));
                self.store.write(id, t.data());
            }
            let t = it.next().expect("length checked above");
            ensure!(t.shape() == self.specs[i].shape.as_slice(),
                    "sm3 leaf {:?} slot mom: state shape {:?}, expected \
                     {:?}", self.specs[i].name, t.shape(),
                    self.specs[i].shape);
            self.store.write(ids.mom, t.data());
        }
        Ok(())
    }

    fn scratch_bytes(&self) -> usize {
        self.scratch.bytes() + bufs_bytes(&self.acc_bufs)
            + self.mom_buf.len() * 4 + bufs_bytes(&self.axis_scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn run_steps(variant: Sm3Variant, shape: &[usize], steps: usize,
                 seed: u64) -> (Tensor, Sm3) {
        let specs = vec![ParamSpec::new("w", shape)];
        let mut opt = Sm3::new(&specs, variant, 0.9);
        let mut rng = Rng::new(seed);
        let mut params = vec![Tensor::randn(shape, 0.5, &mut rng)];
        for _ in 0..steps {
            let g = vec![Tensor::randn(shape, 1.0, &mut rng)];
            opt.step(&mut params, &g, 0.1);
        }
        (params.pop().unwrap(), opt)
    }

    /// Claim 2: nu_t(i) >= sum_s g_s²(i), accumulators monotone.
    #[test]
    fn claim2_lower_bound_matrix() {
        let shape = [6, 9];
        let specs = vec![ParamSpec::new("w", &shape)];
        for variant in [Sm3Variant::I, Sm3Variant::II] {
            let mut opt = Sm3::new(&specs, variant, 0.9);
            let mut rng = Rng::new(1);
            let mut params = vec![Tensor::zeros(&shape)];
            let mut gsq = vec![0.0f64; 54];
            let mut prev_rows = vec![0.0f32; 6];
            for _ in 0..15 {
                let g = Tensor::randn(&shape, 1.0, &mut rng);
                for (acc, &gv) in gsq.iter_mut().zip(g.data()) {
                    *acc += (gv as f64) * (gv as f64);
                }
                opt.step(&mut params, &[g], 0.1);
                let nu = opt.implied_nu_matrix(0);
                for (k, &nuv) in nu.data().iter().enumerate() {
                    assert!(nuv as f64 + 1e-3 >= gsq[k],
                            "{variant:?} nu {nuv} < gsq {}", gsq[k]);
                }
                for (i, (&r, &p)) in
                    opt.acc(0, 0).iter().zip(&prev_rows).enumerate()
                {
                    assert!(r + 1e-6 >= p, "row {i} not monotone");
                }
                prev_rows = opt.acc(0, 0);
            }
        }
    }

    /// Prop. 3: SM3-II accumulators are tighter than SM3-I's.
    #[test]
    fn prop3_sm3ii_tighter() {
        let shape = [8, 5];
        let specs = vec![ParamSpec::new("w", &shape)];
        let mut o1 = Sm3::new(&specs, Sm3Variant::I, 0.9);
        let mut o2 = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut rng = Rng::new(2);
        let mut p1 = vec![Tensor::zeros(&shape)];
        let mut p2 = vec![Tensor::zeros(&shape)];
        for _ in 0..20 {
            let g = Tensor::randn(&shape, 1.0, &mut rng);
            o1.step(&mut p1, std::slice::from_ref(&g), 0.1);
            o2.step(&mut p2, std::slice::from_ref(&g), 0.1);
            let nu1 = o1.implied_nu_matrix(0);
            let nu2 = o2.implied_nu_matrix(0);
            for (a, b) in nu2.data().iter().zip(nu1.data()) {
                assert!(a <= &(b + 1e-5), "nu2 {a} > nu1 {b}");
            }
        }
    }

    /// §3: with singleton cover (vectors) SM3 == Adagrad exactly.
    #[test]
    fn vector_equals_adagrad() {
        let specs = vec![ParamSpec::new("b", &[33])];
        let mut sm3 = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut ada = super::super::Adagrad::new(&specs, 0.9);
        let mut rng = Rng::new(3);
        let w0 = Tensor::randn(&[33], 1.0, &mut rng);
        let mut p1 = vec![w0.clone()];
        let mut p2 = vec![w0];
        for _ in 0..10 {
            let g = Tensor::randn(&[33], 1.0, &mut rng);
            sm3.step(&mut p1, std::slice::from_ref(&g), 0.2);
            ada.step(&mut p2, std::slice::from_ref(&g), 0.2);
        }
        assert_eq!(p1[0], p2[0]);
    }

    /// The singleton-cover equivalence must also hold quantized: both
    /// optimizers see the same dequantized state and quantize the same
    /// values, so the trajectories stay bitwise equal even at q8.
    #[test]
    fn vector_equals_adagrad_under_q8() {
        let specs = vec![ParamSpec::new("b", &[70])];
        let mut sm3 =
            Sm3::with_dtype(&specs, Sm3Variant::II, 0.9, StateDtype::Q8);
        let mut ada =
            super::super::Adagrad::with_dtype(&specs, 0.9, StateDtype::Q8);
        let mut rng = Rng::new(5);
        let w0 = Tensor::randn(&[70], 1.0, &mut rng);
        let mut p1 = vec![w0.clone()];
        let mut p2 = vec![w0];
        for _ in 0..10 {
            let g = Tensor::randn(&[70], 1.0, &mut rng);
            sm3.step(&mut p1, std::slice::from_ref(&g), 0.2);
            ada.step(&mut p2, std::slice::from_ref(&g), 0.2);
        }
        assert_eq!(p1[0], p2[0]);
    }

    /// 1×n and m×1 matrices: cover degenerates to whole-tensor max + diag.
    #[test]
    fn degenerate_matrix_shapes() {
        for shape in [[1usize, 7], [7, 1]] {
            let (w, _) = run_steps(Sm3Variant::II, &shape, 5, 4);
            assert!(w.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn zero_gradients_are_noop() {
        let specs = vec![ParamSpec::new("w", &[4, 4])];
        let mut opt = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut params = vec![Tensor::full(&[4, 4], 1.5)];
        let g = vec![Tensor::zeros(&[4, 4])];
        opt.step(&mut params, &g, 0.5);
        assert_eq!(params[0], Tensor::full(&[4, 4], 1.5));
    }

    #[test]
    fn rank3_matches_matrix_when_trailing_dim_1() {
        // (m, n, 1) tensor path must agree with the (m, n) matrix fast path.
        let mut rng = Rng::new(5);
        let w0 = Tensor::randn(&[5, 6], 0.5, &mut rng);
        let g0 = Tensor::randn(&[5, 6], 1.0, &mut rng);

        let specs2 = vec![ParamSpec::new("w", &[5, 6])];
        let mut o2 = Sm3::new(&specs2, Sm3Variant::II, 0.9);
        let mut p2 = vec![w0.clone()];
        o2.step(&mut p2, &[g0.clone()], 0.1);

        let specs3 = vec![ParamSpec::new("w", &[5, 6, 1])];
        let mut o3 = Sm3::new(&specs3, Sm3Variant::II, 0.9);
        let mut p3 = vec![w0.clone().reshape(&[5, 6, 1])];
        o3.step(&mut p3, &[g0.reshape(&[5, 6, 1])], 0.1);

        for (a, b) in p2[0].data().iter().zip(p3[0].data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn state_roundtrip() {
        let (_, opt) = run_steps(Sm3Variant::II, &[4, 3], 3, 7);
        let saved: Vec<Tensor> =
            opt.state().into_iter().map(|(_, _, t)| t).collect();
        let specs = vec![ParamSpec::new("w", &[4, 3])];
        let mut fresh = Sm3::new(&specs, Sm3Variant::II, 0.9);
        fresh.load_state(saved.clone()).unwrap();
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t.clone()).collect();
        assert_eq!(saved, restored);
    }

    /// Quantized state round-trips bitwise through the state API: the
    /// dequantized tensors re-quantize to identical codes (codec
    /// idempotence contract).
    #[test]
    fn state_roundtrip_quantized_dtypes() {
        for dtype in [StateDtype::Bf16, StateDtype::Q8] {
            let shape = [9usize, 13];
            let specs = vec![ParamSpec::new("w", &shape)];
            let mut opt =
                Sm3::with_dtype(&specs, Sm3Variant::II, 0.9, dtype);
            let mut rng = Rng::new(9);
            let mut params = vec![Tensor::randn(&shape, 0.5, &mut rng)];
            for _ in 0..4 {
                let g = vec![Tensor::randn(&shape, 1.0, &mut rng)];
                opt.step(&mut params, &g, 0.1);
            }
            let saved: Vec<Tensor> =
                opt.state().into_iter().map(|(_, _, t)| t).collect();
            let mut fresh =
                Sm3::with_dtype(&specs, Sm3Variant::II, 0.9, dtype);
            fresh.load_state(saved.clone()).unwrap();
            let restored: Vec<Tensor> =
                fresh.state().into_iter().map(|(_, _, t)| t).collect();
            assert_eq!(saved, restored, "{dtype:?}");
        }
    }

    /// Regression: rank ≥ 5 tensors used to clamp axis slot names to
    /// "acc3", so axes 3, 4, … aliased one checkpoint slot. Every axis
    /// must get a distinct name and round-trip without aliasing.
    #[test]
    fn rank5_state_slots_are_distinct_and_roundtrip() {
        let shape = [2usize, 3, 4, 5, 6];
        let (_, opt) = run_steps(Sm3Variant::II, &shape, 2, 11);
        let state = opt.state();
        // 5 axis accumulators + momentum
        assert_eq!(state.len(), 6);
        let names: Vec<&str> = state.iter().map(|(_, n, _)| *n).collect();
        assert_eq!(names, ["acc0", "acc1", "acc2", "acc3", "acc4", "mom"]);
        // each axis slot has that axis's length, not an alias of another
        for (a, &dim) in shape.iter().enumerate() {
            assert_eq!(state[a].2.len(), dim, "axis {a}");
        }
        // round-trip restores bit-identical state
        let saved: Vec<Tensor> =
            state.into_iter().map(|(_, _, t)| t).collect();
        let specs = vec![ParamSpec::new("w", &shape)];
        let mut fresh = Sm3::new(&specs, Sm3Variant::II, 0.9);
        fresh.load_state(saved.clone()).unwrap();
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(saved, restored);
    }

    #[test]
    fn memory_is_sublinear_for_matrices() {
        let specs = vec![ParamSpec::new("emb", &[512, 128])];
        let opt = Sm3::new(&specs, Sm3Variant::II, 0.0);
        // acc floats only: 512 + 128 (mom is counted in state_floats)
        let acc_floats: usize = (0..2).map(|a| opt.acc(0, a).len()).sum();
        assert_eq!(acc_floats, 512 + 128);
    }

    /// The q8 second-moment state of a big matrix is ≥ 3.5× smaller than
    /// f32 while the update still descends.
    #[test]
    fn q8_matrix_state_shrinks_and_descends() {
        let specs = vec![ParamSpec::new("emb", &[256, 128])];
        let f = Sm3::new(&specs, Sm3Variant::II, 0.9);
        let mut q =
            Sm3::with_dtype(&specs, Sm3Variant::II, 0.9, StateDtype::Q8);
        assert_eq!(f.state_floats(), q.state_floats());
        let red = f.state_bytes() as f64 / q.state_bytes() as f64;
        assert!(red >= 3.5, "q8 reduction {red}");
        let mut rng = Rng::new(13);
        let target = Tensor::randn(&[256, 128], 1.0, &mut rng);
        let mut params = vec![Tensor::zeros(&[256, 128])];
        let loss = |p: &Tensor| p.zip(&target, |a, b| (a - b) * (a - b))
            .sq_norm();
        let l0 = loss(&params[0]);
        for _ in 0..50 {
            let g = params[0].zip(&target, |a, b| 2.0 * (a - b));
            q.step(&mut params, &[g], 0.3);
        }
        let l1 = loss(&params[0]);
        assert!(l1 < 0.5 * l0, "q8 SM3 failed to descend: {l0} -> {l1}");
    }
}
