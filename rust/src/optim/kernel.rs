//! The shared step-kernel layer: per-element update kernels and the
//! tile-streaming drivers that run them over quantized state.
//!
//! Before this layer every optimizer in the bank carried its own copy of
//! the `read_into` / loop / `write` scaffolding and paid a full-slot
//! dequantize→buffer→requantize round trip per slot per step. The
//! drivers here ([`step_chunked1`] / [`step_chunked2`]) stream a leaf's
//! state through the `qstate` [`ChunkCursor`] in fixed tiles instead:
//! f32 tiles lend the backing storage (zero copies), bf16/q8 tiles
//! decode into an O(tile) scratch and commit on drop. The kernels
//! themselves ([`adagrad_chunk`], [`adam_chunk`], [`sgdm_chunk`]) are the
//! exact per-element f32 op sequences the optimizers inlined before, so
//! the streamed trajectory is bitwise identical to the whole-slot path
//! (property-tested in `crate::proptest`).
//!
//! The named kernels here are the *reference* lanes: the optimizers
//! dispatch per tile through [`super::backend::KernelBackend`]
//! (DESIGN.md §13), whose scalar implementation delegates straight back
//! to these functions and whose `simd` implementation is gated bitwise
//! against them — so this file stays the single source of truth for the
//! update arithmetic.
//!
//! Only *element-wise* updates fit this shape — [`elementwise`] says
//! which (optimizer, leaf-rank) pairs qualify. SM3's matrix/tensor
//! covers and Adafactor couple elements through row/col reductions and
//! keep leaf-granular two-pass updates (with scratch hoisted into their
//! structs so steady-state steps stay allocation-free). The same
//! predicate gates `ParallelStep`'s intra-leaf sharding: element-wise
//! leaves may be split at q8-block-aligned boundaries with no change to
//! any element's arithmetic or quantization.

use super::qstate::QuantizedSlots;
use super::safe_rsqrt;
use crate::pool::{Pool, PoolBuf, Tag};
use crate::telemetry::{self, Counter};
use anyhow::ensure;

/// Elements per q8 block — the alignment unit for tiles and shard splits.
pub use super::qstate::codec::Q8_BLOCK;

/// Default streaming tile: 4096 scalars = 64 q8 blocks = 16 KiB of f32
/// scratch per slot — small enough to live in L1/L2 alongside the param
/// and grad tiles, large enough to amortize the per-tile dispatch.
pub const DEFAULT_CHUNK: usize = 4096;

/// Validate a tile size (config key `step_chunk`): positive multiple of
/// the q8 block, so tiles always start on block boundaries.
pub fn check_chunk(chunk: usize) -> anyhow::Result<()> {
    ensure!(chunk > 0 && chunk % Q8_BLOCK == 0,
            "step_chunk must be a positive multiple of {Q8_BLOCK} \
             (got {chunk})");
    Ok(())
}

/// Can `name`'s update of a rank-`rank` leaf be expressed as a
/// per-element kernel (and therefore sharded *inside* the leaf)?
///
/// A thin name-based bridge over the typed capability declaration
/// [`super::api::Method::elementwise_at_rank`] — the registry's single
/// source of truth (its match is exhaustive, so a new method must
/// declare itself). Unknown names are never element-wise. Kept for the
/// name-indexed callers (benches, proptests, docs); typed code should
/// ask the [`super::api::Method`] directly.
pub fn elementwise(name: &str, rank: usize) -> bool {
    super::api::Method::from_name(name)
        .map(|m| m.elementwise_at_rank(rank))
        .unwrap_or(false)
}

/// Reusable decode scratch for up to two streamed slots. Lives in the
/// optimizer struct so steady-state steps allocate nothing; f32 stores
/// never touch it. Storage is a pool lease tagged
/// [`Tag::KernelScratch`] (the `Default` impl stays unpooled so legacy
/// constructors keep their exact behavior).
pub struct ChunkScratch {
    /// decode scratch for the first streamed slot
    pub a: PoolBuf<f32>,
    /// decode scratch for the second streamed slot
    pub b: PoolBuf<f32>,
}

impl Default for ChunkScratch {
    fn default() -> Self {
        ChunkScratch {
            a: PoolBuf::unpooled(Tag::KernelScratch),
            b: PoolBuf::unpooled(Tag::KernelScratch),
        }
    }
}

impl ChunkScratch {
    /// Scratch whose buffers lease from `pool` under
    /// [`Tag::KernelScratch`]; sized lazily by the cursor exactly as the
    /// unpooled default is.
    pub fn new_in(pool: &Pool) -> Self {
        ChunkScratch {
            a: pool.take_f32(Tag::KernelScratch, 0),
            b: pool.take_f32(Tag::KernelScratch, 0),
        }
    }

    /// Live bytes currently held by this scratch pair (the quantity the
    /// pool attributes to [`Tag::KernelScratch`] for these leases).
    pub fn bytes(&self) -> usize {
        (self.a.len() + self.b.len()) * 4
    }
}

/// Count non-finite values in one tile. Read-only — the watchdog scans
/// below observe the same f32 stream the kernels consume; they never
/// alter it, so telemetry on == off stays bitwise (the crate-wide
/// contract, proptested).
#[inline]
fn nonfinite_in(xs: &[f32]) -> u64 {
    xs.iter().filter(|x| !x.is_finite()).count() as u64
}

/// Scan one tile pair for the health counters: incoming gradient values
/// feed `grad/nonfinite`, post-update parameter values feed
/// `opt/update_nonfinite`. Callers gate on [`telemetry::enabled`] once
/// per driver call so the disabled path pays a single branch.
#[inline]
fn scan_tile(w: &[f32], g_bad: u64) {
    if g_bad > 0 {
        telemetry::count(Counter::GradNonFinite, g_bad);
    }
    let w_bad = nonfinite_in(w);
    if w_bad > 0 {
        telemetry::count(Counter::UpdateNonFinite, w_bad);
    }
}

/// Stream one state slot alongside the leaf's param/grad data in `tile`-
/// sized pieces, calling `f(w, g, s)` per tile. Slot, param and grad
/// must have equal length.
pub fn step_chunked1(
    slots: &mut QuantizedSlots, id: usize, tile: usize,
    scratch: &mut ChunkScratch, w: &mut [f32], g: &[f32],
    mut f: impl FnMut(&mut [f32], &[f32], &mut [f32]),
) {
    debug_assert_eq!(slots.slot_len(id), w.len());
    debug_assert_eq!(g.len(), w.len());
    // lend the lease's backing Vec to the cursor (whose scratch
    // contract predates the pool); the lease reconciles its accounting
    // when the closure returns
    let tele = telemetry::enabled();
    scratch.a.with_vec(|sa| {
        let mut cur = slots.slot_mut(id).chunks_mut(tile, sa);
        while let Some(mut t) = cur.next_tile() {
            let (off, n) = (t.offset(), t.len());
            let g_bad =
                if tele { nonfinite_in(&g[off..off + n]) } else { 0 };
            f(&mut w[off..off + n], &g[off..off + n], &mut t);
            if tele {
                scan_tile(&w[off..off + n], g_bad);
            }
        }
    });
}

/// Stream two state slots (e.g. accumulator + momentum) in lockstep with
/// the leaf's param/grad data, calling `f(w, g, a, b)` per tile.
#[allow(clippy::too_many_arguments)]
pub fn step_chunked2(
    slots: &mut QuantizedSlots, id_a: usize, id_b: usize, tile: usize,
    scratch: &mut ChunkScratch, w: &mut [f32], g: &[f32],
    mut f: impl FnMut(&mut [f32], &[f32], &mut [f32], &mut [f32]),
) {
    debug_assert_eq!(slots.slot_len(id_a), w.len());
    debug_assert_eq!(slots.slot_len(id_b), w.len());
    debug_assert_eq!(g.len(), w.len());
    let (sa, sb) = slots.slot_pair_mut(id_a, id_b);
    let (buf_a, buf_b) = (&mut scratch.a, &mut scratch.b);
    buf_a.with_vec(|va| {
        buf_b.with_vec(|vb| {
            let tele = telemetry::enabled();
            let mut ca = sa.chunks_mut(tile, va);
            let mut cb = sb.chunks_mut(tile, vb);
            while let Some(mut ta) = ca.next_tile() {
                let mut tb = cb.next_tile().expect("slot lengths diverge");
                let (off, n) = (ta.offset(), ta.len());
                debug_assert_eq!(tb.len(), n);
                let g_bad =
                    if tele { nonfinite_in(&g[off..off + n]) } else { 0 };
                f(&mut w[off..off + n], &g[off..off + n], &mut ta, &mut tb);
                if tele {
                    scan_tile(&w[off..off + n], g_bad);
                }
            }
        });
    });
}

/// Adagrad with heavy-ball momentum, one tile (paper Eq. 1–2). Also
/// SM3's singleton-cover (rank ≤ 1) update — under that cover the two
/// methods coincide exactly (paper §3).
#[inline]
pub fn adagrad_chunk(beta1: f32, lr: f32, w: &mut [f32], g: &[f32],
                     acc: &mut [f32], mom: &mut [f32]) {
    for k in 0..w.len() {
        let nu = acc[k] + g[k] * g[k];
        let upd = g[k] * safe_rsqrt(nu);
        mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
        w[k] -= lr * mom[k];
        acc[k] = nu;
    }
}

/// Adam, one tile. `bc1`/`bc2` are the step's bias corrections
/// `1 - β^t`, computed once per step by the caller (the step count is a
/// per-optimizer scalar, not tile state).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn adam_chunk(b1: f32, b2: f32, eps: f32, bc1: f32, bc2: f32, lr: f32,
                  w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
    for k in 0..w.len() {
        m[k] = b1 * m[k] + (1.0 - b1) * g[k];
        v[k] = b2 * v[k] + (1.0 - b2) * g[k] * g[k];
        let mhat = m[k] / bc1;
        let vhat = v[k] / bc2;
        w[k] -= lr * mhat / (vhat.sqrt() + eps);
    }
}

/// SGD with heavy-ball momentum, one tile.
#[inline]
pub fn sgdm_chunk(b1: f32, lr: f32, w: &mut [f32], g: &[f32],
                  mom: &mut [f32]) {
    for k in 0..w.len() {
        mom[k] = b1 * mom[k] + g[k];
        w[k] -= lr * mom[k];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::StateDtype;

    #[test]
    fn chunk_validation() {
        assert!(check_chunk(64).is_ok());
        assert!(check_chunk(DEFAULT_CHUNK).is_ok());
        assert!(check_chunk(0).is_err());
        assert!(check_chunk(100).is_err());
        assert!(check_chunk(65).is_err());
    }

    #[test]
    fn elementwise_classification() {
        for rank in 0..5 {
            assert!(elementwise("adagrad", rank));
            assert!(elementwise("adam", rank));
            assert!(elementwise("sgdm", rank));
            assert!(!elementwise("adafactor", rank));
        }
        for name in ["sm3", "sm3i"] {
            assert!(elementwise(name, 0));
            assert!(elementwise(name, 1));
            assert!(!elementwise(name, 2));
            assert!(!elementwise(name, 4));
        }
        assert!(!elementwise("nope", 1));
    }

    /// The drivers visit every element exactly once, in order, across
    /// uneven final tiles, and commit quantized tiles.
    #[test]
    fn drivers_cover_the_slot_exactly_once() {
        for dtype in StateDtype::ALL {
            let n = 130;
            let mut slots = QuantizedSlots::new(dtype);
            let a = slots.add_zeros(n);
            let b = slots.add_zeros(n);
            let mut scratch = ChunkScratch::default();
            let mut w = vec![0.0f32; n];
            let g = vec![1.0f32; n];
            let mut visited = 0usize;
            step_chunked2(&mut slots, a, b, 64, &mut scratch, &mut w, &g,
                          |w, g, a, b| {
                for k in 0..w.len() {
                    w[k] += g[k];
                    a[k] = 2.0; // block max → decodes exactly at any dtype
                    b[k] = 2.0;
                }
                visited += w.len();
            });
            assert_eq!(visited, n, "{dtype:?}");
            assert!(w.iter().all(|&x| x == 1.0));
            assert!(slots.to_vec(a).iter().all(|&x| x == 2.0), "{dtype:?}");
            assert!(slots.to_vec(b).iter().all(|&x| x == 2.0), "{dtype:?}");
            let mut seen = 0usize;
            step_chunked1(&mut slots, a, 64, &mut scratch, &mut w, &g,
                          |w, _, s| {
                seen += w.len();
                assert!(s.iter().all(|&x| x == 2.0));
            });
            assert_eq!(seen, n);
        }
    }

    /// The tile scans feed the health counters: non-finite gradient
    /// values count into `grad/nonfinite`, non-finite post-update
    /// parameters into `opt/update_nonfinite` — and a clean pass counts
    /// nothing.
    #[test]
    fn nonfinite_scans_feed_the_health_counters() {
        use crate::telemetry::{self, Counter};
        let _g = telemetry::enable();
        let n = 130;
        let mut slots = QuantizedSlots::new(StateDtype::F32);
        let a = slots.add_zeros(n);
        let b = slots.add_zeros(n);
        let mut scratch = ChunkScratch::default();
        let mut w = vec![0.0f32; n];
        let mut g = vec![1.0f32; n];

        let before = telemetry::thread_totals();
        step_chunked1(&mut slots, a, 64, &mut scratch, &mut w, &g,
                      |_, _, _| {});
        let clean = telemetry::thread_totals();
        assert_eq!(clean.counter(Counter::GradNonFinite)
                       - before.counter(Counter::GradNonFinite), 0);
        assert_eq!(clean.counter(Counter::UpdateNonFinite)
                       - before.counter(Counter::UpdateNonFinite), 0);

        g[3] = f32::NAN;
        g[70] = f32::INFINITY;
        g[129] = f32::NEG_INFINITY;
        step_chunked2(&mut slots, a, b, 64, &mut scratch, &mut w, &g,
                      |w, _, _, _| {
            w[0] = f32::NAN; // first element of each of the 3 tiles
        });
        let after = telemetry::thread_totals();
        assert_eq!(after.counter(Counter::GradNonFinite)
                       - clean.counter(Counter::GradNonFinite), 3);
        assert_eq!(after.counter(Counter::UpdateNonFinite)
                       - clean.counter(Counter::UpdateNonFinite), 3);
    }
}
