//! `ParallelStep` — deterministic sharded execution of `Optimizer::step`.
//!
//! SM3/Adafactor buy memory headroom so larger models and batches can be
//! stepped; that makes the host-side update loop the next wall-clock
//! bottleneck on the split execution path (grad artifact → Rust optimizer).
//! Every optimizer in the bank updates each parameter leaf independently,
//! so the leaf loop parallelizes with *no* change to the arithmetic. On
//! top of that, **element-wise** updates (`kernel::elementwise`: Adagrad,
//! Adam, SGD+momentum at any rank; SM3 under the singleton cover) update
//! each *element* independently — so a dominant leaf (a 32k×1024
//! embedding under Adam) can be split into q8-block-aligned ranges and
//! sharded **inside the leaf** instead of serializing one worker.
//! Reduction-coupled optimizers (SM3 matrix/tensor covers, Adafactor)
//! keep the whole-leaf plan.
//!
//! Results are bitwise identical to serial execution regardless of
//! thread count, scheduling, split plan, or state dtype: element-wise
//! updates touch disjoint elements, split boundaries sit on q8 block
//! boundaries (a block never straddles two ranges, so every per-block
//! quantization sees the identical inputs serial stepping would), and
//! per-step scalars (Adam's bias-correction count) advance identically
//! in every range. Property-tested in `crate::proptest`; measured by
//! `benches/bench_optim.rs`.
//!
//! Design: one inner optimizer instance per *task* — a whole leaf, or
//! one block-aligned range of a split leaf viewed as a flat sub-spec —
//! built from the same registry entry. A static plan assigns tasks to at
//! most `threads` workers by greedy bin-packing on element count; `step`
//! hands each worker its disjoint `(param view, grad view, task state)`
//! triples under `std::thread::scope`. Range tasks run through
//! [`Optimizer::step_flat`], whole leaves through `Optimizer::step`.
//!
//! Checkpoint note: [`Optimizer::state`] stitches split leaves back
//! together (per-element slots are concatenated in range order; per-step
//! scalars like Adam's `t`, identical in every range, are emitted once),
//! so the layout equals the whole-leaf per-leaf layout at any thread
//! count and any split plan. As in PR 1, the per-leaf layout still
//! differs from *serial* for optimizers with global slots (Adam's `t`
//! appears once per leaf instead of once); `load_state` pre-counts and
//! fails fast on such a mismatch.

use super::api::{Method, StateOpts};
use super::kernel;
use super::qstate::codec::Q8_BLOCK;
use super::qstate::StateDtype;
use super::{Optimizer, ParamSpec};
use crate::pool::{Pool, PoolBuf, Tag};
use crate::telemetry::{self, trace_event, Gauge, Probe};
use crate::tensor::Tensor;
use anyhow::ensure;
use std::sync::atomic::{AtomicU64, Ordering};

/// `lr · s` skipping the multiply when `s == 1` (the uniform case keeps
/// the exact historical arithmetic; `x · 1.0` is exact anyway, but the
/// skip makes the invariance obvious).
#[inline(always)]
fn eff_lr(lr: f32, s: f32) -> f32 {
    if s == 1.0 {
        lr
    } else {
        lr * s
    }
}

/// How `ParallelStep` may divide the update across workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitPolicy {
    /// One task per leaf (the PR 1 engine) — a dominant leaf serializes
    /// its worker.
    WholeLeaf,
    /// Split dominant element-wise leaves into q8-block-aligned ranges
    /// (the default; bitwise identical to `WholeLeaf` and to serial).
    IntraLeaf,
}

/// Greedy bin-packing of task indices over at most `threads` bins:
/// descending by weight, each task to the currently lightest bin (ties
/// to the lowest bin id — fully deterministic). Bins keep their tasks in
/// ascending index order; empty bins are dropped.
fn pack(weights: &[usize], threads: usize) -> Vec<Vec<usize>> {
    let bins = threads.min(weights.len()).max(1);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
    let mut shards = vec![Vec::new(); bins];
    let mut load = vec![0usize; bins];
    for i in order {
        let lightest = (0..bins).min_by_key(|&b| (load[b], b)).unwrap();
        shards[lightest].push(i);
        // max(1): zero-sized tasks still cost a dispatch
        load[lightest] += weights[i].max(1);
    }
    for s in shards.iter_mut() {
        s.sort_unstable();
    }
    shards.retain(|s| !s.is_empty());
    shards
}

/// Leaf-level bin-packing by [`ParamSpec::numel`] (the whole-leaf plan).
pub fn shard_by_numel(specs: &[ParamSpec], threads: usize) -> Vec<Vec<usize>> {
    let weights: Vec<usize> = specs.iter().map(ParamSpec::numel).collect();
    pack(&weights, threads)
}

/// Block-aligned range bounds splitting a leaf of `numel` elements into
/// at most `threads` near-equal parts of at least one part each ~`target`
/// elements. Every interior bound is a multiple of the q8 block, so a
/// block never straddles two ranges. Returns `[0, ..., numel]`; a result
/// of length 2 means "don't split".
fn split_bounds(numel: usize, target: usize, threads: usize) -> Vec<usize> {
    // manual ceil-div, like codec::q8_blocks (keeps the crate's MSRV)
    let ceil_div = |a: usize, b: usize| a / b + usize::from(a % b != 0);
    let k = ceil_div(numel, target.max(1)).clamp(1, threads);
    let per = ceil_div(ceil_div(numel, k), Q8_BLOCK) * Q8_BLOCK;
    let mut bounds = vec![0];
    let mut lo = 0;
    while lo + per < numel {
        lo += per;
        bounds.push(lo);
    }
    bounds.push(numel);
    bounds
}

/// One block-aligned range of a split leaf, with its own sub-optimizer
/// over the flat sub-spec `[hi - lo]`.
struct Part {
    lo: usize,
    hi: usize,
    opt: Box<dyn Optimizer>,
}

enum Leaf {
    /// the whole leaf is one task (reduction-coupled, or small)
    Whole(Box<dyn Optimizer>),
    /// element-wise leaf split into block-aligned ranges
    Split { spec: ParamSpec, parts: Vec<Part> },
}

/// A sharded optimizer-step engine over any registry optimizer.
pub struct ParallelStep {
    /// one entry per parameter leaf, index-aligned with the spec list
    leaves: Vec<Leaf>,
    /// worker id per task (task order: leaves in order, parts in order)
    task_worker: Vec<usize>,
    /// number of non-empty worker bins
    workers: usize,
    threads: usize,
    /// per-leaf LR multipliers (`OptimSpec` param groups); empty =
    /// uniform 1.0 — the historical arithmetic, skip the multiply
    lr_scales: Vec<f32>,
    /// pool the checkpoint stitch path stages split-leaf slots in
    /// ([`Tag::CkptStitch`]); `None` = plain Vec staging
    pool: Option<Pool>,
    /// telemetry: one preallocated slot per worker. Scoped workers die
    /// inside the step, so each measures its own elapsed time here and
    /// the owning thread folds the slots — in worker-index order — into
    /// its thread-local cells after the scope joins (DESIGN.md §14).
    worker_ns: Vec<AtomicU64>,
    /// start timestamps paired with `worker_ns`, so the owner can
    /// replay each worker's span onto its synthetic trace lane
    /// (`trace_event::worker_lane`) after the scope joins — scoped
    /// workers die inside the step, so their own thread-local rings
    /// would be unreachable to the drainer.
    worker_t0: Vec<AtomicU64>,
}

impl ParallelStep {
    /// Build with a custom per-leaf optimizer factory. The factory must
    /// be deterministic (same spec → same initial state) for the bitwise
    /// guarantee to hold. Custom factories always get the whole-leaf
    /// plan — the engine cannot prove their updates element-wise.
    pub fn new<F>(specs: &[ParamSpec], threads: usize, build_leaf: F)
                  -> anyhow::Result<Self>
    where
        F: FnMut(&ParamSpec) -> anyhow::Result<Box<dyn Optimizer>>,
    {
        Self::build_impl(specs, threads, SplitPolicy::WholeLeaf, |_| false,
                         build_leaf)
    }

    /// Build from the optimizer registry (the `optim::ALL` names) with
    /// f32 state storage.
    pub fn from_registry(name: &str, specs: &[ParamSpec], beta1: f32,
                         beta2: f32, threads: usize) -> anyhow::Result<Self> {
        Self::from_registry_dtype(name, specs, beta1, beta2, threads,
                                  StateDtype::F32)
    }

    /// Build from the registry with quantized state storage (DESIGN.md
    /// §10), the default streaming tile, and intra-leaf splitting.
    pub fn from_registry_dtype(name: &str, specs: &[ParamSpec], beta1: f32,
                               beta2: f32, threads: usize,
                               dtype: StateDtype) -> anyhow::Result<Self> {
        Self::from_registry_opts(name, specs, beta1, beta2, threads, dtype,
                                 kernel::DEFAULT_CHUNK, SplitPolicy::IntraLeaf)
    }

    /// Fully explicit registry constructor: state dtype, streaming tile
    /// (`step_chunk`), and split policy.
    #[allow(clippy::too_many_arguments)]
    pub fn from_registry_opts(name: &str, specs: &[ParamSpec], beta1: f32,
                              beta2: f32, threads: usize, dtype: StateDtype,
                              chunk: usize, policy: SplitPolicy)
                              -> anyhow::Result<Self> {
        kernel::check_chunk(chunk)?;
        let mut method = Method::from_name(name)?;
        method.set_beta1(beta1);
        method.set_beta2(beta2);
        let opts = StateOpts { dtype, chunk, ..StateOpts::default() };
        Self::with_leaf_factory(
            specs, threads, policy,
            |s| kernel::elementwise(name, s.shape.len()),
            |s| Ok(method.build_serial(std::slice::from_ref(s), &opts,
                                       None)))
    }

    /// Fully generic constructor: a deterministic per-leaf factory plus
    /// a predicate saying which leaves may be split at q8-block-aligned
    /// bounds (they must be element-wise — see [`kernel::elementwise`]).
    /// This is the entry point `OptimSpec::build` drives.
    pub fn with_leaf_factory<F>(specs: &[ParamSpec], threads: usize,
                                policy: SplitPolicy,
                                splittable: impl Fn(&ParamSpec) -> bool,
                                build_leaf: F) -> anyhow::Result<Self>
    where
        F: FnMut(&ParamSpec) -> anyhow::Result<Box<dyn Optimizer>>,
    {
        Self::build_impl(specs, threads, policy, splittable, build_leaf)
    }

    /// Attach per-leaf LR multipliers (`OptimSpec` param groups): leaf
    /// `i` steps at `lr · scales[i]`. Splitting and sharding are
    /// unaffected — every range of a split leaf inherits its leaf's
    /// scale, so results stay bitwise identical at any thread count.
    pub fn set_lr_scales(&mut self, scales: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(scales.len() == self.leaves.len(),
                        "lr_scales has {} entries, engine has {} leaves",
                        scales.len(), self.leaves.len());
        anyhow::ensure!(scales.iter().all(|s| s.is_finite() && *s > 0.0),
                        "lr_scales must be finite and > 0");
        self.lr_scales = scales.to_vec();
        Ok(())
    }

    fn build_impl<F>(specs: &[ParamSpec], threads: usize, policy: SplitPolicy,
                     splittable: impl Fn(&ParamSpec) -> bool,
                     mut build_leaf: F) -> anyhow::Result<Self>
    where
        F: FnMut(&ParamSpec) -> anyhow::Result<Box<dyn Optimizer>>,
    {
        anyhow::ensure!(threads >= 1, "step_threads must be >= 1");
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        // ideal per-worker load: leaves above it hog a worker, so (policy
        // permitting) they get split
        let target = (total / threads.max(1)).max(1);
        let mut leaves = Vec::with_capacity(specs.len());
        let mut weights = Vec::new(); // one weight per task
        for s in specs {
            let n = s.numel();
            let bounds = if policy == SplitPolicy::IntraLeaf && threads > 1
                && n > target && splittable(s)
            {
                split_bounds(n, target, threads)
            } else {
                vec![0, n]
            };
            if bounds.len() <= 2 {
                leaves.push(Leaf::Whole(build_leaf(s)?));
                weights.push(n);
                continue;
            }
            let mut parts = Vec::with_capacity(bounds.len() - 1);
            for w in bounds.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                let sub = ParamSpec::new(format!("{}[{lo}..{hi}]", s.name),
                                         &[hi - lo]);
                parts.push(Part { lo, hi, opt: build_leaf(&sub)? });
                weights.push(hi - lo);
            }
            leaves.push(Leaf::Split { spec: s.clone(), parts });
        }
        let bins = pack(&weights, threads);
        let mut task_worker = vec![0usize; weights.len()];
        for (wid, bin) in bins.iter().enumerate() {
            for &t in bin {
                task_worker[t] = wid;
            }
        }
        let worker_ns = (0..bins.len()).map(|_| AtomicU64::new(0)).collect();
        let worker_t0 = (0..bins.len()).map(|_| AtomicU64::new(0)).collect();
        Ok(Self { leaves, task_worker, workers: bins.len(), threads,
                  lr_scales: Vec::new(), pool: None, worker_ns, worker_t0 })
    }

    /// Stage split-leaf checkpoint stitching through `pool`
    /// ([`Tag::CkptStitch`]). The per-leaf sub-optimizers are pooled
    /// through the leaf factory, not here — see `OptimSpec::pool`.
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = Some(pool);
    }

    /// Configured worker count (the live worker count may be lower when
    /// there are fewer tasks than threads).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of block-aligned ranges each leaf was split into (1 ⇒ the
    /// leaf is one whole task). Introspection for tests and benches.
    pub fn parts_per_leaf(&self) -> Vec<usize> {
        self.leaves
            .iter()
            .map(|l| match l {
                Leaf::Whole(_) => 1,
                Leaf::Split { parts, .. } => parts.len(),
            })
            .collect()
    }
}

/// One unit of sharded work: a whole leaf, or a flat range of one.
/// `lr_mul` is the owning leaf's LR multiplier (1.0 = uniform).
enum Item<'a> {
    Whole {
        w: &'a mut Tensor,
        g: &'a Tensor,
        opt: &'a mut Box<dyn Optimizer>,
        lr_mul: f32,
    },
    Range {
        w: &'a mut [f32],
        g: &'a [f32],
        opt: &'a mut Box<dyn Optimizer>,
        lr_mul: f32,
    },
}

impl Item<'_> {
    fn run(self, lr: f32) {
        match self {
            Item::Whole { w, g, opt, lr_mul } => {
                opt.step(std::slice::from_mut(w), std::slice::from_ref(g),
                         eff_lr(lr, lr_mul))
            }
            Item::Range { w, g, opt, lr_mul } => {
                opt.step_flat(w, g, eff_lr(lr, lr_mul))
            }
        }
    }
}

impl Optimizer for ParallelStep {
    fn name(&self) -> &'static str {
        match self.leaves.first() {
            Some(Leaf::Whole(o)) => o.name(),
            Some(Leaf::Split { parts, .. }) => parts[0].opt.name(),
            None => "parallel",
        }
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.leaves.len());
        if self.workers <= 1 {
            // single worker: run every task inline in leaf/part order —
            // no thread spawns and no per-step bucket allocations
            let scales = &self.lr_scales;
            for (i, leaf) in self.leaves.iter_mut().enumerate() {
                let lr_i =
                    eff_lr(lr, scales.get(i).copied().unwrap_or(1.0));
                match leaf {
                    Leaf::Whole(opt) => {
                        opt.step(&mut params[i..i + 1],
                                 std::slice::from_ref(&grads[i]), lr_i);
                    }
                    Leaf::Split { parts, .. } => {
                        let wd = params[i].data_mut();
                        let gd = grads[i].data();
                        for p in parts.iter_mut() {
                            p.opt.step_flat(&mut wd[p.lo..p.hi],
                                            &gd[p.lo..p.hi], lr_i);
                        }
                    }
                }
            }
            return;
        }
        // Hand each worker its tasks' disjoint (param view, grad view,
        // state) triples: split leaves are carved with split_at_mut in
        // part order (parts tile the leaf exactly, by construction).
        let mut buckets: Vec<Vec<Item>> =
            (0..self.workers).map(|_| Vec::new()).collect();
        let mut tid = 0usize;
        let mut param_it = params.iter_mut();
        let scales = &self.lr_scales;
        for (i, leaf) in self.leaves.iter_mut().enumerate() {
            let w = param_it.next().expect("params shorter than leaves");
            let g = &grads[i];
            let lr_mul = scales.get(i).copied().unwrap_or(1.0);
            match leaf {
                Leaf::Whole(opt) => {
                    buckets[self.task_worker[tid]]
                        .push(Item::Whole { w, g, opt, lr_mul });
                    tid += 1;
                }
                Leaf::Split { spec, parts } => {
                    assert_eq!(w.len(), spec.numel(),
                               "leaf {} shape drifted from its spec", i);
                    let mut wrest: &mut [f32] = w.data_mut();
                    let mut grest: &[f32] = g.data();
                    for p in parts.iter_mut() {
                        let n = p.hi - p.lo;
                        // mem::take moves the full-lifetime slice out so
                        // the split halves outlive this loop iteration
                        let (wa, wb) =
                            std::mem::take(&mut wrest).split_at_mut(n);
                        let (ga, gb) = grest.split_at(n);
                        wrest = wb;
                        grest = gb;
                        buckets[self.task_worker[tid]].push(Item::Range {
                            w: wa,
                            g: ga,
                            opt: &mut p.opt,
                            lr_mul,
                        });
                        tid += 1;
                    }
                }
            }
        }
        // Sample the flag once so every worker this step agrees; the
        // slots are preallocated, so measuring adds no allocations.
        let tele = telemetry::enabled();
        let worker_ns = &self.worker_ns;
        let worker_t0 = &self.worker_t0;
        std::thread::scope(|scope| {
            for (wid, bucket) in buckets.into_iter().enumerate() {
                let slot = &worker_ns[wid];
                let t0_slot = &worker_t0[wid];
                scope.spawn(move || {
                    let t0 = if tele { telemetry::now_ns() } else { 0 };
                    for item in bucket {
                        item.run(lr);
                    }
                    if tele {
                        t0_slot.store(t0, Ordering::Relaxed);
                        slot.store(
                            telemetry::now_ns().saturating_sub(t0),
                            Ordering::Relaxed);
                    }
                });
            }
        });
        if tele {
            // fold in worker-index order: deterministic aggregate
            // regardless of which worker finished first
            let mut sum = 0u64;
            let mut max = 0u64;
            for (wid, slot) in worker_ns.iter().enumerate() {
                let ns = slot.load(Ordering::Relaxed);
                telemetry::record_ns(Probe::OptWorker, ns);
                // replay the span onto a per-worker synthetic lane so
                // the trace shows imbalance as parallel bars
                trace_event::complete_on_lane(
                    Probe::OptWorker, trace_event::worker_lane(wid),
                    worker_t0[wid].load(Ordering::Relaxed), ns);
                sum += ns;
                max = max.max(ns);
            }
            if sum > 0 {
                // slowest worker over the mean, permille (1000 = balanced)
                let permille =
                    max * self.workers as u64 * 1000 / sum;
                telemetry::gauge(Gauge::OptImbalancePermille, permille);
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.leaves
            .iter()
            .map(|l| match l {
                Leaf::Whole(o) => o.state_floats(),
                Leaf::Split { parts, .. } => {
                    parts.iter().map(|p| p.opt.state_floats()).sum()
                }
            })
            .sum()
    }

    fn state_bytes(&self) -> usize {
        // block-aligned splits preserve the q8 block partitioning, so
        // this equals the unsplit engine's bytes exactly
        self.leaves
            .iter()
            .map(|l| match l {
                Leaf::Whole(o) => o.state_bytes(),
                Leaf::Split { parts, .. } => {
                    parts.iter().map(|p| p.opt.state_bytes()).sum()
                }
            })
            .sum()
    }

    fn state_dtype(&self) -> StateDtype {
        match self.leaves.first() {
            Some(Leaf::Whole(o)) => o.state_dtype(),
            Some(Leaf::Split { parts, .. }) => parts[0].opt.state_dtype(),
            None => StateDtype::F32,
        }
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        for (i, leaf) in self.leaves.iter().enumerate() {
            match leaf {
                Leaf::Whole(opt) => {
                    for (_, slot, t) in opt.state() {
                        out.push((i, slot, t));
                    }
                }
                Leaf::Split { spec, parts } => {
                    // Stitch the ranges back into whole-leaf slots.
                    // Part 0 spans >= one q8 block, so a 1-element tensor
                    // there is unambiguously a per-step scalar (Adam's
                    // `t`) — identical in every range, emitted once.
                    let per: Vec<Vec<(usize, &'static str, Tensor)>> =
                        parts.iter().map(|p| p.opt.state()).collect();
                    for (j, (_, slot, t0)) in per[0].iter().enumerate() {
                        if t0.len() <= 1 {
                            out.push((i, *slot, t0.clone()));
                            continue;
                        }
                        // stage the concatenation in a pooled lease so
                        // repeated checkpointing reuses one slab
                        let mut data = match &self.pool {
                            Some(p) => p.take_f32(Tag::CkptStitch, 0),
                            None => PoolBuf::unpooled(Tag::CkptStitch),
                        };
                        for p in &per {
                            data.extend_from_slice(p[j].2.data());
                        }
                        out.push((i, *slot,
                                  Tensor::from_vec(&spec.shape,
                                                   data.to_vec())));
                    }
                }
            }
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        // Pre-count so a layout mismatch (e.g. serial-Adam state, whose
        // global `t` slot appears once instead of per leaf) fails fast
        // BEFORE any leaf is mutated. Split leaves expect the *stitched*
        // layout, which has exactly one part's slot count per leaf.
        let lens: Vec<usize> = self
            .leaves
            .iter()
            .map(|l| match l {
                Leaf::Whole(o) => o.state().len(),
                Leaf::Split { parts, .. } => parts[0].opt.state().len(),
            })
            .collect();
        let expect: usize = lens.iter().sum();
        ensure!(state.len() == expect,
                "state layout mismatch: got {} tensors, this {}-leaf \
                 ParallelStep expects {} (per-leaf slot layout differs \
                 from serial for optimizers with global slots — see \
                 module docs)",
                state.len(), self.leaves.len(), expect);
        let mut it = state.into_iter();
        for (leaf, n) in self.leaves.iter_mut().zip(lens) {
            match leaf {
                Leaf::Whole(opt) => {
                    let chunk: Vec<Tensor> = it.by_ref().take(n).collect();
                    opt.load_state(chunk)?;
                }
                Leaf::Split { spec, parts } => {
                    // slice each stitched slot back into range tensors
                    let probe: Vec<usize> = parts[0]
                        .opt
                        .state()
                        .iter()
                        .map(|(_, _, t)| t.len())
                        .collect();
                    let mut per_part: Vec<Vec<Tensor>> =
                        parts.iter().map(|_| Vec::with_capacity(n)).collect();
                    for &len0 in &probe {
                        let t = it.next().expect("pre-counted above");
                        if len0 <= 1 {
                            // per-step scalar: every range restores it
                            for v in per_part.iter_mut() {
                                v.push(t.clone());
                            }
                            continue;
                        }
                        ensure!(t.len() == spec.numel(),
                                "split leaf {:?}: stitched slot has {} \
                                 elements, expected {}",
                                spec.name, t.len(), spec.numel());
                        let data = t.data();
                        for (p, v) in parts.iter().zip(per_part.iter_mut()) {
                            v.push(Tensor::from_vec(
                                &[p.hi - p.lo], data[p.lo..p.hi].to_vec()));
                        }
                    }
                    for (p, st) in parts.iter_mut().zip(per_part) {
                        p.opt.load_state(st)?;
                    }
                }
            }
        }
        Ok(())
    }

    fn scratch_bytes(&self) -> usize {
        self.leaves
            .iter()
            .map(|l| match l {
                Leaf::Whole(o) => o.scratch_bytes(),
                Leaf::Split { parts, .. } => {
                    parts.iter().map(|p| p.opt.scratch_bytes()).sum()
                }
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim;
    use crate::rng::Rng;

    fn mixed_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("embed", &[40, 8]),
            ParamSpec::new("w1", &[8, 16]),
            ParamSpec::new("w2", &[16, 8]),
            ParamSpec::new("conv", &[3, 3, 2, 4]),
            ParamSpec::new("b", &[16]),
        ]
    }

    /// A skewed set where one embedding dominates: the intra-leaf planner
    /// must split it (for element-wise optimizers).
    fn skewed_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("embed", &[256, 16]), // 4096 of ~4400 elements
            ParamSpec::new("w", &[8, 16]),
            ParamSpec::new("b1", &[100]),
            ParamSpec::new("b2", &[70]),
        ]
    }

    #[test]
    fn shard_plan_is_a_disjoint_cover_and_balanced() {
        let specs = mixed_specs();
        let shards = shard_by_numel(&specs, 2);
        assert_eq!(shards.len(), 2);
        let mut seen = vec![false; specs.len()];
        for s in &shards {
            for &i in s {
                assert!(!seen[i], "leaf {i} sharded twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "not a cover");
        // the 320-elem embedding dominates: greedy packing must not put
        // every other leaf in the same bin with it
        let loads: Vec<usize> = shards
            .iter()
            .map(|s| s.iter().map(|&i| specs[i].numel()).sum())
            .collect();
        let (max, min) = (*loads.iter().max().unwrap(),
                          *loads.iter().min().unwrap());
        assert!(max < 2 * min + specs[0].numel(),
                "unbalanced shards: {loads:?}");
    }

    #[test]
    fn split_bounds_are_block_aligned_and_cover() {
        for (n, target, threads) in
            [(4096usize, 1100usize, 4usize), (390, 200, 2), (33_554_432, 8_388_608, 4),
             (65, 10, 8), (128, 1, 16)]
        {
            let b = split_bounds(n, target, threads);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), n);
            for w in b.windows(2) {
                assert!(w[0] < w[1], "empty range in {b:?}");
            }
            for &x in &b[1..b.len() - 1] {
                assert_eq!(x % Q8_BLOCK, 0, "interior bound {x} misaligned");
            }
            assert!(b.len() - 1 <= threads.max(1));
        }
        // tiny leaves never split
        assert_eq!(split_bounds(64, 1, 8), vec![0, 64]);
    }

    #[test]
    fn more_threads_than_leaves_is_fine() {
        let specs = vec![ParamSpec::new("w", &[4, 4])];
        let shards = shard_by_numel(&specs, 8);
        assert_eq!(shards, vec![vec![0]]);
        let mut opt =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 8).unwrap();
        let mut params = vec![Tensor::zeros(&[4, 4])];
        let g = vec![Tensor::full(&[4, 4], 1.0)];
        opt.step(&mut params, &g, 0.1);
        assert!(params[0].data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bitwise_identical_to_serial_sm3() {
        let specs = mixed_specs();
        let mut serial =
            optim::OptimSpec::named("sm3").unwrap().build(&specs).unwrap();
        let mut par =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 3).unwrap();
        let mut rng = Rng::new(7);
        let init: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let mut pa = init.clone();
        let mut pb = init;
        for _ in 0..5 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            serial.step(&mut pa, &grads, 0.1);
            par.step(&mut pb, &grads, 0.1);
        }
        for (a, b) in pa.iter().zip(&pb) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
            }
        }
    }

    /// The intra-leaf planner splits the dominant leaf for element-wise
    /// optimizers, keeps it whole for reduction-coupled ones, and the
    /// results stay bitwise identical to serial either way.
    #[test]
    fn intra_leaf_split_is_bitwise_identical_to_serial() {
        let specs = skewed_specs();
        for (name, expect_split) in
            [("adam", true), ("adagrad", true), ("sgdm", true),
             ("sm3", false), ("adafactor", false)]
        {
            let mut par = ParallelStep::from_registry(
                name, &specs, 0.9, 0.98, 4).unwrap();
            let parts = par.parts_per_leaf();
            assert_eq!(parts[0] > 1, expect_split,
                       "{name}: embedding parts = {}", parts[0]);
            assert!(parts[1..].iter().all(|&p| p == 1),
                    "{name}: small leaves must stay whole");
            let mut serial = optim::OptimSpec::named(name).unwrap()
                .build(&specs).unwrap();
            let mut rng = Rng::new(11);
            let init: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let mut pa = init.clone();
            let mut pb = init;
            for _ in 0..4 {
                let grads: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect();
                serial.step(&mut pa, &grads, 0.1);
                par.step(&mut pb, &grads, 0.1);
            }
            for (a, b) in pa.iter().zip(&pb) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name}: {x} != {y}");
                }
            }
        }
    }

    /// Split-leaf state stitches back to the whole-leaf layout: same slot
    /// count and shapes as an unsplit engine, bitwise round-trip, and
    /// cross-loading between split and unsplit engines works.
    #[test]
    fn split_leaf_state_is_layout_compatible_and_roundtrips() {
        let specs = skewed_specs();
        let mut split = ParallelStep::from_registry(
            "adam", &specs, 0.9, 0.98, 4).unwrap();
        assert!(split.parts_per_leaf()[0] > 1);
        let mut whole = ParallelStep::from_registry_opts(
            "adam", &specs, 0.9, 0.98, 4, StateDtype::F32,
            kernel::DEFAULT_CHUNK, SplitPolicy::WholeLeaf).unwrap();
        assert_eq!(whole.parts_per_leaf(), vec![1; specs.len()]);
        let mut rng = Rng::new(3);
        let init: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        let mut pa = init.clone();
        let mut pb = init;
        split.step(&mut pa, &grads, 0.1);
        whole.step(&mut pb, &grads, 0.1);
        let sa = split.state();
        let sb = whole.state();
        assert_eq!(sa.len(), sb.len());
        for ((la, na, ta), (lb, nb, tb)) in sa.iter().zip(&sb) {
            assert_eq!((la, na), (lb, nb));
            assert_eq!(ta, tb, "slot {na} differs between split and whole");
        }
        // cross-load: whole-leaf state into the split engine and back
        let tensors: Vec<Tensor> =
            sb.into_iter().map(|(_, _, t)| t).collect();
        let mut fresh = ParallelStep::from_registry(
            "adam", &specs, 0.9, 0.98, 4).unwrap();
        fresh.load_state(tensors.clone()).unwrap();
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(tensors, restored);
    }

    #[test]
    fn state_floats_and_name_delegate() {
        let specs = mixed_specs();
        let serial =
            optim::OptimSpec::named("adam").unwrap().build(&specs).unwrap();
        let par =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 4).unwrap();
        assert_eq!(par.state_floats(), serial.state_floats());
        assert_eq!(par.name(), "adam");
        assert_eq!(par.threads(), 4);
    }

    #[test]
    fn state_roundtrip() {
        let specs = mixed_specs();
        let mut par =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 2).unwrap();
        let mut rng = Rng::new(3);
        let mut params: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        par.step(&mut params, &grads, 0.1);
        let saved: Vec<Tensor> =
            par.state().into_iter().map(|(_, _, t)| t).collect();
        let mut fresh =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 2).unwrap();
        fresh.load_state(saved.clone()).unwrap();
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(saved, restored);
    }

    /// A state vector with the wrong tensor count (e.g. serial Adam's
    /// layout, whose global `t` appears once instead of per leaf) must
    /// fail fast before any leaf is mutated.
    #[test]
    fn load_state_rejects_wrong_layout_before_mutating() {
        let specs = mixed_specs();
        let serial =
            optim::OptimSpec::named("adam").unwrap().build(&specs).unwrap();
        // serial Adam: 1 global `t` + (m, v) per leaf = 11 tensors;
        // per-leaf Adam expects (t, m, v) per leaf = 15.
        let saved: Vec<Tensor> =
            serial.state().into_iter().map(|(_, _, t)| t).collect();
        let mut par =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 2).unwrap();
        let err = par.load_state(saved).unwrap_err().to_string();
        assert!(err.contains("state layout mismatch"), "{err}");
    }

    /// The determinism contract at q8: sharded stepping with quantized
    /// state is bitwise identical to serial quantized stepping (blocks
    /// never straddle shard OR split boundaries), and splitting preserves
    /// the exact q8 byte accounting. The broader sweep lives in
    /// `crate::proptest`.
    #[test]
    fn bitwise_identical_to_serial_with_q8_state() {
        let specs = mixed_specs();
        for name in ["sm3", "adam", "adafactor"] {
            let mut serial = optim::OptimSpec::named(name).unwrap()
                .state_dtype(StateDtype::Q8).build(&specs).unwrap();
            let mut par = ParallelStep::from_registry_dtype(
                name, &specs, 0.9, 0.98, 3, StateDtype::Q8).unwrap();
            assert_eq!(par.state_dtype(), StateDtype::Q8);
            assert_eq!(par.state_bytes(), serial.state_bytes(), "{name}");
            let mut rng = Rng::new(17);
            let init: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let mut pa = init.clone();
            let mut pb = init;
            for _ in 0..4 {
                let grads: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect();
                serial.step(&mut pa, &grads, 0.1);
                par.step(&mut pb, &grads, 0.1);
            }
            for (a, b) in pa.iter().zip(&pb) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name}: {x} != {y}");
                }
            }
        }
    }

    /// Per-leaf LR scales: the multi-worker path (including split-leaf
    /// ranges, which inherit their leaf's scale) is bitwise identical to
    /// the single-worker inline path, and bad scale vectors are
    /// rejected.
    #[test]
    fn lr_scales_are_split_and_shard_invariant() {
        let specs = skewed_specs();
        let scales = [0.5f32, 1.0, 2.0, 1.0];
        let mut one =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 1)
                .unwrap();
        one.set_lr_scales(&scales).unwrap();
        let mut four =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 4)
                .unwrap();
        assert!(four.parts_per_leaf()[0] > 1, "embedding must split");
        four.set_lr_scales(&scales).unwrap();
        let mut rng = Rng::new(23);
        let init: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let mut pa = init.clone();
        let mut pb = init;
        for _ in 0..4 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            one.step(&mut pa, &grads, 0.1);
            four.step(&mut pb, &grads, 0.1);
        }
        for (a, b) in pa.iter().zip(&pb) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
            }
        }
        // wrong length / non-positive scales are rejected
        assert!(one.set_lr_scales(&[1.0]).is_err());
        assert!(one.set_lr_scales(&[0.5, 1.0, 0.0, 1.0]).is_err());
    }

    /// ISSUE 7: sharded steps record one `opt_worker` span per live
    /// worker (folded in index order on the owning thread) plus a load
    /// -imbalance gauge — and the measurement changes no parameter bit.
    #[test]
    fn sharded_step_records_per_worker_spans_and_imbalance() {
        let specs = skewed_specs();
        let mut rng = Rng::new(29);
        let init: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        // reference trajectory, telemetry off (modulo parallel tests'
        // overlapping guards — measurement never touches f32 math)
        let mut quiet =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 3)
                .unwrap();
        let mut pa = init.clone();
        quiet.step(&mut pa, &grads, 0.1);

        let _g = telemetry::enable();
        let mut loud =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 3)
                .unwrap();
        let before = telemetry::thread_totals();
        let mut pb = init;
        loud.step(&mut pb, &grads, 0.1);
        let after = telemetry::thread_totals();
        assert_eq!(after.spans(Probe::OptWorker)
                       - before.spans(Probe::OptWorker),
                   loud.workers as u64,
                   "one folded span per live worker");
        let imb = telemetry::thread_gauge(Gauge::OptImbalancePermille);
        assert!(imb.last >= 1000,
                "slowest/mean is >= 1 by construction, got {}", imb.last);
        for (a, b) in pa.iter().zip(&pb) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(),
                           "telemetry changed the trajectory: {x} != {y}");
            }
        }
    }

    #[test]
    fn empty_param_list_is_a_noop() {
        let mut par =
            ParallelStep::from_registry("sm3", &[], 0.9, 0.98, 4).unwrap();
        par.step(&mut [], &[], 0.1);
        assert_eq!(par.state_floats(), 0);
        assert!(par.state().is_empty());
    }
}
