//! `ParallelStep` — deterministic sharded execution of `Optimizer::step`.
//!
//! SM3/Adafactor buy memory headroom so larger models and batches can be
//! stepped; that makes the host-side update loop the next wall-clock
//! bottleneck on the split execution path (grad artifact → Rust optimizer).
//! Every optimizer in the bank updates each parameter leaf independently —
//! leaf `i`'s update reads only `params[i]`, `grads[i]`, and leaf `i`'s
//! state — so the leaf loop parallelizes with *no* change to the arithmetic:
//! results are bitwise identical to serial execution regardless of thread
//! count or scheduling (asserted by the property test in `crate::proptest`
//! and measured by `benches/bench_optim.rs`).
//!
//! Design: one inner optimizer instance per leaf, built from the same
//! registry entry (so per-step *global* scalars like Adam's bias-correction
//! step count advance identically in every shard), and a static shard plan
//! computed once by greedy bin-packing of leaves over `threads` bins by
//! [`ParamSpec::numel`]. `step` hands each bin's disjoint
//! `(param, grad, leaf state)` triples to a `std::thread::scope` worker.
//!
//! Checkpoint note: [`Optimizer::state`] emits slots leaf-by-leaf. For
//! every optimizer except Adam this is byte-compatible with the serial
//! layout; Adam's single global `t` slot becomes one `t` slot per leaf.
//! Round-trips within one `step_threads` setting are exact; restoring
//! across the knob is NOT supported for such optimizers — this engine's
//! `load_state` pre-counts and fails fast on a layout mismatch, and a
//! future PR can add layout translation if cross-knob restore is needed.

use super::qstate::StateDtype;
use super::{Optimizer, ParamSpec};
use crate::tensor::Tensor;

/// Greedy bin-packing of leaf indices over at most `threads` bins:
/// descending by `numel`, each leaf to the currently lightest bin (ties to
/// the lowest bin id — fully deterministic). Bins keep their leaves in
/// ascending index order; empty bins are dropped.
pub fn shard_by_numel(specs: &[ParamSpec], threads: usize) -> Vec<Vec<usize>> {
    let bins = threads.min(specs.len()).max(1);
    let mut order: Vec<usize> = (0..specs.len()).collect();
    order.sort_by(|&a, &b| {
        specs[b].numel().cmp(&specs[a].numel()).then(a.cmp(&b))
    });
    let mut shards = vec![Vec::new(); bins];
    let mut load = vec![0usize; bins];
    for i in order {
        let lightest = (0..bins).min_by_key(|&b| (load[b], b)).unwrap();
        shards[lightest].push(i);
        // max(1): zero-sized leaves still cost a dispatch
        load[lightest] += specs[i].numel().max(1);
    }
    for s in shards.iter_mut() {
        s.sort_unstable();
    }
    shards.retain(|s| !s.is_empty());
    shards
}

/// A sharded optimizer-step engine over any registry optimizer.
pub struct ParallelStep {
    /// one inner optimizer per parameter leaf, index-aligned with `specs`
    leaf_opts: Vec<Box<dyn Optimizer>>,
    /// static shard plan: disjoint leaf-index sets, one per worker
    shards: Vec<Vec<usize>>,
    threads: usize,
}

impl ParallelStep {
    /// Build with a custom per-leaf optimizer factory. The factory must be
    /// deterministic (same spec → same initial state) for the bitwise
    /// guarantee to hold.
    pub fn new<F>(specs: &[ParamSpec], threads: usize, mut build_leaf: F)
                  -> anyhow::Result<Self>
    where
        F: FnMut(&ParamSpec) -> anyhow::Result<Box<dyn Optimizer>>,
    {
        anyhow::ensure!(threads >= 1, "step_threads must be >= 1");
        let leaf_opts = specs
            .iter()
            .map(|s| build_leaf(s))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self { leaf_opts, shards: shard_by_numel(specs, threads), threads })
    }

    /// Build from the optimizer registry (the `optim::build` names) with
    /// f32 state storage.
    pub fn from_registry(name: &str, specs: &[ParamSpec], beta1: f32,
                         beta2: f32, threads: usize) -> anyhow::Result<Self> {
        Self::from_registry_dtype(name, specs, beta1, beta2, threads,
                                  StateDtype::F32)
    }

    /// Build from the registry with quantized state storage (DESIGN.md
    /// §10). Sharding preserves the bitwise guarantee at any dtype: q8
    /// blocks live inside one leaf's slot vectors and shards are whole
    /// leaves, so a block never straddles a shard boundary and every
    /// quantization sees the identical inputs serial stepping would.
    pub fn from_registry_dtype(name: &str, specs: &[ParamSpec], beta1: f32,
                               beta2: f32, threads: usize,
                               dtype: StateDtype) -> anyhow::Result<Self> {
        Self::new(specs, threads, |s| {
            super::build_with_dtype(name, std::slice::from_ref(s), beta1,
                                    beta2, dtype)
        })
    }

    /// Configured worker count (the shard count may be lower when there
    /// are fewer leaves than threads).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The static shard plan (leaf indices per worker).
    pub fn shards(&self) -> &[Vec<usize>] {
        &self.shards
    }
}

impl Optimizer for ParallelStep {
    fn name(&self) -> &'static str {
        self.leaf_opts.first().map(|o| o.name()).unwrap_or("parallel")
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.leaf_opts.len());
        if self.shards.len() <= 1 {
            // single shard: run inline, no thread-spawn overhead
            for (i, opt) in self.leaf_opts.iter_mut().enumerate() {
                opt.step(&mut params[i..i + 1],
                         std::slice::from_ref(&grads[i]), lr);
            }
            return;
        }
        // Hand each worker its shard's disjoint (param, grad, state)
        // triples. take() proves disjointness to the borrow checker; the
        // shard plan guarantees it by construction.
        let mut param_slots: Vec<Option<&mut Tensor>> =
            params.iter_mut().map(Some).collect();
        let mut opt_slots: Vec<Option<&mut Box<dyn Optimizer>>> =
            self.leaf_opts.iter_mut().map(Some).collect();
        let mut work: Vec<Vec<(usize, &mut Tensor, &mut Box<dyn Optimizer>)>> =
            Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            work.push(
                shard
                    .iter()
                    .map(|&i| {
                        (i,
                         param_slots[i].take().expect("leaf sharded twice"),
                         opt_slots[i].take().expect("leaf sharded twice"))
                    })
                    .collect(),
            );
        }
        std::thread::scope(|scope| {
            for chunk in work {
                scope.spawn(move || {
                    for (i, w, opt) in chunk {
                        opt.step(std::slice::from_mut(w),
                                 std::slice::from_ref(&grads[i]), lr);
                    }
                });
            }
        });
    }

    fn state_floats(&self) -> usize {
        self.leaf_opts.iter().map(|o| o.state_floats()).sum()
    }

    fn state_bytes(&self) -> usize {
        self.leaf_opts.iter().map(|o| o.state_bytes()).sum()
    }

    fn state_dtype(&self) -> StateDtype {
        self.leaf_opts
            .first()
            .map(|o| o.state_dtype())
            .unwrap_or(StateDtype::F32)
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        for (i, opt) in self.leaf_opts.iter().enumerate() {
            for (_, slot, t) in opt.state() {
                out.push((i, slot, t));
            }
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) {
        // Slot counts via state() clone one leaf's tensors at a time —
        // acceptable on this checkpoint path (see the Optimizer::state
        // contract), and it lets the total be checked BEFORE any leaf is
        // mutated: a layout mismatch (e.g. serial-Adam state, whose global
        // `t` slot appears once instead of per leaf) must fail fast, not
        // corrupt some leaves and then abort.
        let lens: Vec<usize> =
            self.leaf_opts.iter().map(|o| o.state().len()).collect();
        let expect: usize = lens.iter().sum();
        assert_eq!(state.len(), expect,
                   "state layout mismatch: got {} tensors, this {}-leaf \
                    ParallelStep expects {} (per-leaf slot layout differs \
                    from serial for optimizers with global slots — see \
                    module docs)",
                   state.len(), self.leaf_opts.len(), expect);
        let mut it = state.into_iter();
        for (opt, n) in self.leaf_opts.iter_mut().zip(lens) {
            let chunk: Vec<Tensor> = it.by_ref().take(n).collect();
            opt.load_state(chunk);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim;
    use crate::rng::Rng;

    fn mixed_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("embed", &[40, 8]),
            ParamSpec::new("w1", &[8, 16]),
            ParamSpec::new("w2", &[16, 8]),
            ParamSpec::new("conv", &[3, 3, 2, 4]),
            ParamSpec::new("b", &[16]),
        ]
    }

    #[test]
    fn shard_plan_is_a_disjoint_cover_and_balanced() {
        let specs = mixed_specs();
        let shards = shard_by_numel(&specs, 2);
        assert_eq!(shards.len(), 2);
        let mut seen = vec![false; specs.len()];
        for s in &shards {
            for &i in s {
                assert!(!seen[i], "leaf {i} sharded twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "not a cover");
        // the 320-elem embedding dominates: greedy packing must not put
        // every other leaf in the same bin with it
        let loads: Vec<usize> = shards
            .iter()
            .map(|s| s.iter().map(|&i| specs[i].numel()).sum())
            .collect();
        let (max, min) = (*loads.iter().max().unwrap(),
                          *loads.iter().min().unwrap());
        assert!(max < 2 * min + specs[0].numel(),
                "unbalanced shards: {loads:?}");
    }

    #[test]
    fn more_threads_than_leaves_is_fine() {
        let specs = vec![ParamSpec::new("w", &[4, 4])];
        let shards = shard_by_numel(&specs, 8);
        assert_eq!(shards, vec![vec![0]]);
        let mut opt =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 8).unwrap();
        let mut params = vec![Tensor::zeros(&[4, 4])];
        let g = vec![Tensor::full(&[4, 4], 1.0)];
        opt.step(&mut params, &g, 0.1);
        assert!(params[0].data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bitwise_identical_to_serial_sm3() {
        let specs = mixed_specs();
        let mut serial = optim::build("sm3", &specs, 0.9, 0.98).unwrap();
        let mut par =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 3).unwrap();
        let mut rng = Rng::new(7);
        let init: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let mut pa = init.clone();
        let mut pb = init;
        for _ in 0..5 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            serial.step(&mut pa, &grads, 0.1);
            par.step(&mut pb, &grads, 0.1);
        }
        for (a, b) in pa.iter().zip(&pb) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
            }
        }
    }

    #[test]
    fn state_floats_and_name_delegate() {
        let specs = mixed_specs();
        let serial = optim::build("adam", &specs, 0.9, 0.98).unwrap();
        let par =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 4).unwrap();
        assert_eq!(par.state_floats(), serial.state_floats());
        assert_eq!(par.name(), "adam");
        assert_eq!(par.threads(), 4);
    }

    #[test]
    fn state_roundtrip() {
        let specs = mixed_specs();
        let mut par =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 2).unwrap();
        let mut rng = Rng::new(3);
        let mut params: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        par.step(&mut params, &grads, 0.1);
        let saved: Vec<Tensor> =
            par.state().into_iter().map(|(_, _, t)| t).collect();
        let mut fresh =
            ParallelStep::from_registry("sm3", &specs, 0.9, 0.98, 2).unwrap();
        fresh.load_state(saved.clone());
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        assert_eq!(saved, restored);
    }

    /// A state vector with the wrong tensor count (e.g. serial Adam's
    /// layout, whose global `t` appears once instead of per leaf) must
    /// fail fast before any leaf is mutated.
    #[test]
    #[should_panic(expected = "state layout mismatch")]
    fn load_state_rejects_wrong_layout_before_mutating() {
        let specs = mixed_specs();
        let serial = optim::build("adam", &specs, 0.9, 0.98).unwrap();
        // serial Adam: 1 global `t` + (m, v) per leaf = 11 tensors;
        // per-leaf Adam expects (t, m, v) per leaf = 15.
        let saved: Vec<Tensor> =
            serial.state().into_iter().map(|(_, _, t)| t).collect();
        let mut par =
            ParallelStep::from_registry("adam", &specs, 0.9, 0.98, 2).unwrap();
        par.load_state(saved);
    }

    /// The determinism contract at q8: sharded stepping with quantized
    /// state is bitwise identical to serial quantized stepping (blocks
    /// never straddle shard boundaries). The broader sweep lives in
    /// `crate::proptest`.
    #[test]
    fn bitwise_identical_to_serial_with_q8_state() {
        let specs = mixed_specs();
        for name in ["sm3", "adam", "adafactor"] {
            let mut serial = optim::build_with_dtype(
                name, &specs, 0.9, 0.98, StateDtype::Q8).unwrap();
            let mut par = ParallelStep::from_registry_dtype(
                name, &specs, 0.9, 0.98, 3, StateDtype::Q8).unwrap();
            assert_eq!(par.state_dtype(), StateDtype::Q8);
            assert_eq!(par.state_bytes(), serial.state_bytes(), "{name}");
            let mut rng = Rng::new(17);
            let init: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let mut pa = init.clone();
            let mut pb = init;
            for _ in 0..4 {
                let grads: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect();
                serial.step(&mut pa, &grads, 0.1);
                par.step(&mut pb, &grads, 0.1);
            }
            for (a, b) in pa.iter().zip(&pb) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name}: {x} != {y}");
                }
            }
        }
    }

    #[test]
    fn empty_param_list_is_a_noop() {
        let mut par =
            ParallelStep::from_registry("sm3", &[], 0.9, 0.98, 4).unwrap();
        par.step(&mut [], &[], 0.1);
        assert_eq!(par.state_floats(), 0);
        assert!(par.state().is_empty());
    }
}
